"""End-to-end behaviour of the Krites system (live policies + simulator).

The headline paper property is asserted here on a reduced calibrated
workload: Krites raises the static-origin served fraction substantially at
unchanged total hit rate and non-increased error, with zero serving-path
changes for the triggering requests.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.judge import NoisyOracleJudge, OracleJudge
from repro.core.policy import BaselinePolicy, KritesPolicy
from repro.core.simulate import simulate, summarize
from repro.core.tiers import CacheConfig, make_static_tier
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark


def _bench(n=8000, classes=1200):
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=n,
                               n_classes=classes)
    return build_benchmark(spec)


def test_krites_increases_static_origin_at_fixed_totals():
    b = _bench()
    cfg = CacheConfig(0.88, 0.88, capacity=2048, judge_latency=32)
    args = dict(static_emb=jnp.asarray(b.static_emb),
                static_cls=jnp.asarray(b.static_cls),
                q_emb=jnp.asarray(b.eval_emb),
                q_cls=jnp.asarray(b.eval_cls), cfg=cfg)
    rb = summarize(simulate(krites=False, **args))
    rk = summarize(simulate(krites=True, **args))
    assert rk["static_origin_rate"] > 1.5 * rb["static_origin_rate"]
    assert abs(rk["total_hit_rate"] - rb["total_hit_rate"]) < 0.01
    assert rk["error_rate"] <= rb["error_rate"] + 0.002
    assert rk["static_hit_rate"] == rb["static_hit_rate"]


def test_noisy_judge_error_bounded_by_eps_p_prom():
    """§5: incremental error from promotions <= eps * promoted traffic."""
    b = _bench()
    cfg = CacheConfig(0.88, 0.88, capacity=2048, judge_latency=32)
    args = dict(static_emb=jnp.asarray(b.static_emb),
                static_cls=jnp.asarray(b.static_cls),
                q_emb=jnp.asarray(b.eval_emb),
                q_cls=jnp.asarray(b.eval_cls), cfg=cfg)
    rb = summarize(simulate(krites=False, **args))
    rk = summarize(simulate(krites=True, **args))
    # oracle-judge run: promotions add no error at all
    assert rk["error_rate"] <= rb["error_rate"] + 1e-9


def _live_setup(judge, tau=0.92):
    rng = np.random.default_rng(0)
    # toy intent space with string prompts
    canon = [f"intent number {c} canonical" for c in range(12)]
    from repro.embedding.embedder import Embedder
    embed = Embedder(d_out=32)
    tier = make_static_tier(np.asarray(embed.batch(canon)),
                            np.arange(12))
    answers = [f"curated-{c}" for c in range(12)]
    cfg = CacheConfig(tau, tau, sigma_min=0.2, capacity=128)
    backend_calls = []

    def backend(prompt):
        backend_calls.append(prompt)
        return f"generated({prompt})"

    return embed, tier, answers, cfg, backend, backend_calls


def test_live_policies_same_serving_decisions():
    """Krites' serving decisions equal the baseline's for the same
    stream (given both start cold and judging is withheld)."""
    embed, tier, answers, cfg, backend, _ = _live_setup(None)
    base = BaselinePolicy(cfg, tier, answers, embed, backend, d=32)
    kr = KritesPolicy(cfg, tier, answers, embed, backend,
                      OracleJudge(), d=32,
                      judge_rate_per_s=1e-9)  # judging disabled
    prompts = [f"intent number {i % 12} canonical" for i in range(40)] + \
              [f"hey intent number {i % 12} canonical" for i in range(40)]
    for p in prompts:
        r1 = base.serve(p, meta={"cls": hash(p) % 12})
        r2 = kr.serve(p, meta={"cls": hash(p) % 12})
        assert r1.served_by == r2.served_by
    kr.pool.stop()


def test_live_krites_promotes_and_serves_curated():
    # tau above the paraphrase similarity (~0.944) so the first serve is a
    # grey-zone backend miss rather than a static hit
    embed, tier, answers, cfg, backend, calls = _live_setup(None, tau=0.96)
    kr = KritesPolicy(cfg, tier, answers, embed, backend,
                      OracleJudge(), d=32)
    para = "umm, intent number 3 canonical"
    r1 = kr.serve(para, meta={"cls": 3})
    assert r1.served_by == "backend"
    kr.pool.drain()
    r2 = kr.serve(para, meta={"cls": 3})
    assert r2.served_by == "dynamic" and r2.static_origin
    assert r2.answer == "curated-3"
    kr.pool.stop()
