"""Snapshot/restore round-trip conformance (``serving/persist.py``,
DESIGN.md §14).

A restored policy must be indistinguishable from the one that was
snapshotted: every dynamic-tier field, host mirror, answer and the
logical clock restore exactly, and the *serving decisions* after
restore are identical to the uninterrupted policy's — through each
static-index config (exact flat, IVF warm-restored from the packed
snapshot layout, IVF rebuilt when the snapshot is stale) and through
the segmented dynamic index (restored via ``bulk_load`` from a live
set that includes sealed segments and tombstones). Corruption and
version/topology mismatches must be detected, not misread.

Determinism: judge workers are disabled (``n_workers=0``) so no async
promotion races the comparisons; promotions are applied as explicit
``_promote`` bursts. Each test gets its own ``tmp_path``.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import tiers as T
from repro.core.policy import KritesPolicy
from repro.core.promo_wal import PromotionWAL, replay_into
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark
from repro.serving import persist

CAP = 48
N_SERVE = 96
N_PROBE = 64


@pytest.fixture(scope="module")
def bench():
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=600,
                               n_classes=80, n_topics=8)
    b = build_benchmark(spec)
    emb = {f"q{i}": np.asarray(b.eval_emb[i])
           for i in range(len(b.eval_emb))}
    return b, emb


def _mk(bench, emb, index=None, dyn_index=None, wal=None,
        static_emb=None) -> KritesPolicy:
    s_emb = bench.static_emb if static_emb is None else static_emb
    tier = T.make_static_tier(jnp.asarray(s_emb),
                              jnp.asarray(bench.static_cls))
    answers = [f"curated-{int(c)}" for c in bench.static_cls]
    cfg = T.CacheConfig(0.92, 0.88, sigma_min=0.0, capacity=CAP)
    return KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                        lambda p: f"gen({p})",
                        judge_fn=lambda **kw: True,
                        d=s_emb.shape[1], n_workers=0,
                        index=index, dyn_index=dyn_index, wal=wal)


def _drive(pol, bench, lo, hi):
    for i in range(lo, hi):
        pol.serve(f"q{i}", meta={"cls": int(bench.eval_cls[i])})


def _burst(pol, bench, m, t0, seed=3):
    """Deterministic promotion burst: m approved verdicts, including
    re-promotions of the same key at later timestamps (LWW churn)."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 200, size=m)
    for k, i in enumerate(idx):
        pol._promote({"v": np.asarray(bench.eval_emb[int(i)]),
                      "h_idx": int(np.argmax(
                          bench.static_emb @ bench.eval_emb[int(i)])),
                      "enq_t": t0 + k})
    pol.t = max(pol.t, t0 + m)


def _decisions(pol, bench, lo, hi):
    out = []
    for i in range(lo, hi):
        r = pol.serve(f"q{i}", meta={"cls": int(bench.eval_cls[i])})
        out.append((r.served_by, str(r.answer), bool(r.static_origin),
                    round(float(r.similarity), 5)))
    return out


def _assert_same_state(a: KritesPolicy, b: KritesPolicy):
    for f in T.DynamicTier._fields:
        assert np.array_equal(np.asarray(getattr(a.dyn, f)),
                              np.asarray(getattr(b.dyn, f))), f
    assert np.array_equal(a._valid_np, b._valid_np)
    assert np.array_equal(a._last_used_np, b._last_used_np)
    assert np.array_equal(a._static_origin_np, b._static_origin_np)
    assert np.array_equal(a._written_at_np, b._written_at_np)
    assert a.dyn_answers == b.dyn_answers
    assert a.t == b.t


# ---------------------------------------------------------------------------
# flat path
# ---------------------------------------------------------------------------

def test_snapshot_restores_every_field(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    _drive(live, b, 0, N_SERVE)
    _burst(live, b, 20, live.t + 1)
    persist.save_snapshot(tmp_path, live)

    restored = _mk(b, emb)
    rep = persist.restore_policy(restored, tmp_path)
    assert rep["dyn_live"] == int(live._valid_np.sum()) > 0
    _assert_same_state(live, restored)


def test_restored_decisions_identical_flat(bench, tmp_path):
    """The serving contract: after restore, every subsequent decision
    (tier, answer, provenance, similarity) matches the policy that
    never went down."""
    b, emb = bench
    live = _mk(b, emb)
    _drive(live, b, 0, N_SERVE)
    _burst(live, b, 16, live.t + 1)
    persist.save_snapshot(tmp_path, live)

    restored = _mk(b, emb)
    persist.restore_policy(restored, tmp_path)
    want = _decisions(live, b, N_SERVE, N_SERVE + N_PROBE)
    got = _decisions(restored, b, N_SERVE, N_SERVE + N_PROBE)
    assert got == want
    _assert_same_state(live, restored)   # probes mutate identically too


def test_snapshot_plus_wal_tail_recovers_live_state(bench, tmp_path):
    """The full recovery recipe in-process: snapshot mid-stream, keep
    journaling promotions, then restore + replay(skip=wal_seq). The
    seq cursor prevents pre-snapshot records from clobbering the LRU
    clocks the snapshot captured."""
    b, emb = bench
    wal = PromotionWAL(tmp_path / "promo.wal", fsync_every=1)
    live = _mk(b, emb, wal=wal)
    _drive(live, b, 0, 40)
    _burst(live, b, 12, live.t + 1)           # journaled pre-snapshot
    _drive(live, b, 40, 64)                   # LRU touches after burst
    persist.save_snapshot(tmp_path, live)
    _burst(live, b, 9, live.t + 1, seed=11)   # journaled post-snapshot
    wal.close()

    snap = persist.load_snapshot(tmp_path)
    assert snap.extra["wal_seq"] == 12
    recovered = _mk(b, emb)
    persist.restore_policy(recovered, snap)
    rep = replay_into(recovered, tmp_path / "promo.wal",
                      skip=snap.extra["wal_seq"])
    assert rep == {"records": 21, "skipped": 12, "replayed": 9,
                   "clean": True}
    recovered.t = live.t
    _assert_same_state(live, recovered)
    assert _decisions(recovered, b, 64, 64 + 32) \
        == _decisions(live, b, 64, 64 + 32)


# ---------------------------------------------------------------------------
# IVF static index: warm restore + stale rebuild
# ---------------------------------------------------------------------------

def _with_ivf(pol):
    """Build the serving IVF from the policy's own (normalized) tier
    matrix — the corpus identity the snapshot's ``corpus_hash`` ties
    warm restore to."""
    from repro.index.ivf import IVFIndex, build_ivf
    pol.index = IVFIndex(build_ivf(pol.static.emb, n_clusters=8,
                                   iters=4, corpus_normalized=True),
                         nprobe=64, n_candidates=64)
    return pol


def test_ivf_warm_restore_decision_identical(bench, tmp_path):
    b, emb = bench
    live = _with_ivf(_mk(b, emb))
    _drive(live, b, 0, N_SERVE)
    _burst(live, b, 16, live.t + 1)
    persist.save_snapshot(tmp_path, live)

    restored = _mk(b, emb)                    # no index: cold process
    rep = persist.restore_policy(restored, tmp_path)
    assert rep["index"] == "warm"
    # the warm index is the snapshotted packed layout re-wired to the
    # live corpus — serving through it must match the live policy
    assert _decisions(restored, b, N_SERVE, N_SERVE + N_PROBE) \
        == _decisions(live, b, N_SERVE, N_SERVE + N_PROBE)


def test_ivf_stale_snapshot_rebuilds(bench, tmp_path):
    """Same dynamic state, but the static corpus changed after the
    snapshot: the saved index must NOT be installed (its row geometry
    is wrong); an inline rebuild over the new corpus must serve
    decisions identical to a never-persisted policy on that corpus."""
    b, emb = bench
    live = _with_ivf(_mk(b, emb))
    _drive(live, b, 0, N_SERVE)
    persist.save_snapshot(tmp_path, live)

    new_emb = np.asarray(b.static_emb).copy()
    new_emb[:8] = -new_emb[:8]                # corpus drifted
    stale = _mk(b, emb, static_emb=new_emb)
    rep = persist.restore_policy(stale, tmp_path, rebuild="inline")
    assert rep["index"] == "rebuild-inline"

    fresh = _with_ivf(_mk(b, emb, static_emb=new_emb))
    persist.restore_policy(fresh, tmp_path, rebuild="never")
    assert _decisions(stale, b, N_SERVE, N_SERVE + N_PROBE) \
        == _decisions(fresh, b, N_SERVE, N_SERVE + N_PROBE)


def test_ivf_background_rebuild_swaps_atomically(bench, tmp_path):
    b, emb = bench
    live = _with_ivf(_mk(b, emb))
    _drive(live, b, 0, 32)
    persist.save_snapshot(tmp_path, live)

    new_emb = np.asarray(b.static_emb).copy()
    new_emb[:8] = -new_emb[:8]
    pol = _mk(b, emb, static_emb=new_emb)
    rep = persist.restore_policy(pol, tmp_path, rebuild="background")
    assert rep["index"] == "rebuild-background"
    rep["rebuild_thread"].join(120)
    assert not rep["rebuild_thread"].is_alive()
    assert pol.index is not None
    assert pol.index.describe().startswith("ivf(")


# ---------------------------------------------------------------------------
# segmented dynamic index: bulk_load restore with seals + tombstones
# ---------------------------------------------------------------------------

def _seg_index():
    from repro.index.segmented import SegmentedIndex
    # tiny tail + aggressive compaction: the drive below seals several
    # segments and tombstones slots via LRU overwrite + re-promotion;
    # full probe + candidate budgets covering the live set = the
    # test-enforced flat-equivalence config (DESIGN.md §12)
    return SegmentedIndex(CAP, 64, tail_rows=8, compact_every=2,
                          nprobe=None, n_candidates=CAP,
                          tail_candidates=CAP)


def test_segmented_restore_decision_identical(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb, dyn_index=_seg_index())
    _drive(live, b, 0, N_SERVE)               # > CAP writes: overwrites
    _burst(live, b, 24, live.t + 1)           # + promotion churn
    st = live.dyn_index.stats()
    assert st["seals"] > 0 and st["tombstones"] > 0, \
        f"drive did not exercise seals/tombstones: {st}"
    persist.save_snapshot(tmp_path, live)

    restored = _mk(b, emb, dyn_index=_seg_index())
    persist.restore_policy(restored, tmp_path)
    # bulk_load seeds exactly the live set (tombstoned slots excluded)
    assert restored.dyn_index.stats()["live"] == \
        int(live._valid_np.sum())

    flat = _mk(b, emb)
    persist.restore_policy(flat, tmp_path)
    want = _decisions(live, b, N_SERVE, N_SERVE + N_PROBE)
    assert _decisions(restored, b, N_SERVE, N_SERVE + N_PROBE) == want
    # exact-rerank contract: the restored segmented path serves the
    # same decisions as the flat masked scan over the same tier
    assert _decisions(flat, b, N_SERVE, N_SERVE + N_PROBE) == want


def test_restore_rejects_used_dyn_index(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    _drive(live, b, 0, 16)
    persist.save_snapshot(tmp_path, live)
    dirty = _mk(b, emb, dyn_index=_seg_index())
    _drive(dirty, b, 16, 24)                  # index now has state
    with pytest.raises(ValueError, match="fresh dyn_index"):
        persist.restore_policy(dirty, tmp_path)


# ---------------------------------------------------------------------------
# integrity + versioning
# ---------------------------------------------------------------------------

def test_corrupt_leaf_detected(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    _drive(live, b, 0, 16)
    path = persist.save_snapshot(tmp_path, live)
    victim = sorted(path.glob("*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        persist.load_snapshot(tmp_path)


def test_unknown_manifest_format_rejected(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    path = persist.save_snapshot(tmp_path, live)
    mf = json.loads((path / "manifest.json").read_text())
    mf["extra"]["format"] = 99
    (path / "manifest.json").write_text(json.dumps(mf))
    with pytest.raises(ValueError, match="format"):
        persist.load_snapshot(tmp_path)


def test_capacity_mismatch_rejected(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    persist.save_snapshot(tmp_path, live)
    other = KritesPolicy(
        T.CacheConfig(0.92, 0.88, sigma_min=0.0, capacity=CAP * 2),
        live.static, live.static_answers, lambda p: emb[p],
        lambda p: "g", judge_fn=lambda **kw: True, d=64, n_workers=0)
    with pytest.raises(ValueError, match="capacity"):
        persist.restore_policy(other, tmp_path)


def test_latest_snapshot_ignores_torn_tmp(bench, tmp_path):
    b, emb = bench
    live = _mk(b, emb)
    persist.save_snapshot(tmp_path, live, step=3)
    persist.save_snapshot(tmp_path, live, step=7)
    # a crash mid-save leaves only an unpublished tmp dir
    (tmp_path / ".tmp_step_00000009").mkdir()
    assert persist.latest_snapshot(tmp_path) == 7
    assert persist.load_snapshot(tmp_path).step == 7


def test_missing_snapshot_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        persist.load_snapshot(tmp_path / "nowhere")
    assert persist.latest_snapshot(tmp_path / "nowhere") is None
