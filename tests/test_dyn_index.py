"""Segmented dynamic-tier index (DESIGN.md §12).

Coverage layers, mirroring `test_ivf_index.py` for the static tier:

1. **Lookup equivalence** — `dynamic_lookup{,_batch}` with an injected
   full-recall ``SegmentedIndex`` must equal the flat masked scan
   (same slot, same score) through interleaved writes, seals, merges
   and tombstones.
2. **Policy differential** — serve/serve_batch decisions with
   ``dyn_index=`` match the flat decisions request for request,
   including Krites promotions feeding the tail through the async
   VerifyAndPromote path (the acceptance-criterion bit-identical
   guarantee, scalar and batched).
3. **Telemetry** — router stats surface segment/tail occupancy and
   compaction counts; describe strings name the path in use.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import tiers as T
from repro.core.policy import BaselinePolicy, KritesPolicy
from repro.index.segmented import SegmentedIndex
from repro.serving.router import CacheRouter

from test_serve_batch import _assert_rows_equal, _trace_setup


def _full_recall_index(capacity, d, tail_rows=32, compact_every=3,
                       background=False):
    """Budgets that force recall 1: full probe, candidate budgets
    covering every live row — the exact-equivalence contract config."""
    return SegmentedIndex(capacity, d, tail_rows=tail_rows,
                          nprobe=None, n_candidates=4 * capacity,
                          tail_candidates=tail_rows,
                          compact_every=compact_every,
                          background=background)


# ---------------------------------------------------------------------------
# 1. lookup equivalence vs the flat masked scan
# ---------------------------------------------------------------------------

def test_lookup_matches_flat_through_churn():
    rng = np.random.default_rng(0)
    cap, d = 128, 16
    tier = T.make_dynamic_tier(cap, d)
    idx = _full_recall_index(cap, d, tail_rows=16)
    for t in range(1, 260):
        v = rng.standard_normal(d).astype(np.float32)
        v /= np.linalg.norm(v)
        slot = int(rng.integers(0, cap))
        tier = T._write(tier, slot, jnp.asarray(v), jnp.int32(t % 5),
                        jnp.int32(-1), jnp.asarray(False), t)
        idx.record_write(slot, v)
        if t % 25 == 0:
            q = rng.standard_normal((8, d)).astype(np.float32)
            q /= np.linalg.norm(q, axis=1, keepdims=True)
            q = jnp.asarray(q)
            sf, jf = T.dynamic_lookup_batch(tier, q)
            ss, js = T.dynamic_lookup_batch(tier, q, index=idx)
            assert np.array_equal(np.asarray(jf), np.asarray(js))
            np.testing.assert_allclose(np.asarray(sf), np.asarray(ss),
                                       rtol=0, atol=2e-6)
    st = idx.stats()
    assert st["seals"] > 5 and st["merges"] > 0 and st["tombstones"] > 0


def test_scalar_lookup_and_empty_index_contract():
    cap, d = 16, 8
    tier = T.make_dynamic_tier(cap, d)
    idx = _full_recall_index(cap, d, tail_rows=4)
    q = jnp.asarray(np.eye(d, dtype=np.float32)[0])
    # empty: (-inf, 0), exactly like the flat masked scan
    sf, jf = T.dynamic_lookup(tier, q)
    ss, js = T.dynamic_lookup(tier, q, index=idx)
    assert float(sf) == float(ss) == -np.inf
    assert int(jf) == int(js) == 0
    v = np.eye(d, dtype=np.float32)[0]
    tier = T._write(tier, 3, jnp.asarray(v), jnp.int32(1), jnp.int32(-1),
                    jnp.asarray(False), 1)
    idx.record_write(3, v)
    ss, js = T.dynamic_lookup(tier, q, index=idx)
    assert int(js) == 3 and float(ss) == pytest.approx(1.0)


def test_tombstone_never_resurrects_across_seal_and_compact():
    """An overwritten slot's old key must be unfindable even after the
    stale copy was sealed into a segment and survived a merge."""
    rng = np.random.default_rng(1)
    cap, d = 64, 8
    tier = T.make_dynamic_tier(cap, d)
    idx = _full_recall_index(cap, d, tail_rows=8, compact_every=2)
    old = rng.standard_normal(d).astype(np.float32)
    old /= np.linalg.norm(old)
    tier = T._write(tier, 7, jnp.asarray(old), jnp.int32(0),
                    jnp.int32(-1), jnp.asarray(False), 1)
    idx.record_write(7, old)
    # bury slot 7's entry in a sealed segment, then overwrite slot 7
    for t in range(2, 40):
        v = rng.standard_normal(d).astype(np.float32)
        v /= np.linalg.norm(v)
        slot = int(rng.integers(8, cap))
        tier = T._write(tier, slot, jnp.asarray(v), jnp.int32(0),
                        jnp.int32(-1), jnp.asarray(False), t)
        idx.record_write(slot, v)
    new = rng.standard_normal(d).astype(np.float32)
    new /= np.linalg.norm(new)
    tier = T._write(tier, 7, jnp.asarray(new), jnp.int32(0),
                    jnp.int32(-1), jnp.asarray(False), 99)
    idx.record_write(7, new)
    s, j = T.dynamic_lookup(tier, jnp.asarray(old), index=idx)
    s_f, j_f = T.dynamic_lookup(tier, jnp.asarray(old))
    assert int(j) == int(j_f)
    assert float(s) == pytest.approx(float(s_f), abs=2e-6)
    assert float(s) < 0.999     # the old key is gone, not resurrected
    idx.compact()
    s2, j2 = T.dynamic_lookup(tier, jnp.asarray(old), index=idx)
    assert int(j2) == int(j) and float(s2) == pytest.approx(float(s),
                                                            abs=2e-6)


def test_ttl_eviction_propagates_to_index():
    """evict_expired(index=) must tombstone expired slots in the
    segmented index — otherwise an indexed lookup would serve an
    expired entry the flat masked scan rejects."""
    rng = np.random.default_rng(3)
    cap, d = 32, 8
    tier = T.make_dynamic_tier(cap, d)
    idx = _full_recall_index(cap, d, tail_rows=8)
    vecs = {}
    for t in range(1, 21):
        v = rng.standard_normal(d).astype(np.float32)
        v /= np.linalg.norm(v)
        vecs[t] = v
        tier = T._write(tier, t % cap, jnp.asarray(v), jnp.int32(0),
                        jnp.int32(-1), jnp.asarray(False), t)
        idx.record_write(t % cap, v)
    tier = T.evict_expired(tier, now=30, ttl=15, index=idx)
    assert idx.stats()["live"] == int(tier.valid.sum())
    for t, v in vecs.items():
        q = jnp.asarray(v[None])
        sf, jf = T.dynamic_lookup_batch(tier, q)
        ss, js = T.dynamic_lookup_batch(tier, q, index=idx)
        assert np.array_equal(np.asarray(jf), np.asarray(js))
        both_inf = np.isneginf(np.asarray(sf)) \
            & np.isneginf(np.asarray(ss))
        if not both_inf.all():
            np.testing.assert_allclose(np.asarray(sf), np.asarray(ss),
                                       rtol=0, atol=2e-6)


# ---------------------------------------------------------------------------
# 2. policy differential: segmented vs flat decisions
# ---------------------------------------------------------------------------

def _mk_policy(s, dyn_index=None):
    return BaselinePolicy(
        s["cfg"], s["tier"], s["answers"], s["embed_fn"], s["backend_fn"],
        d=s["d"], embed_batch_fn=s["embed_batch_fn"],
        backend_batch_fn=s["backend_batch_fn"], dyn_index=dyn_index)


@pytest.mark.parametrize("mode", ["scalar", "batch"])
def test_policy_with_segmented_matches_flat_decisions(mode):
    s = _trace_setup()
    flat_pol = _mk_policy(s)
    seg_pol = _mk_policy(s, _full_recall_index(s["cfg"].capacity, s["d"]))
    n, bs = 320, 32
    if mode == "scalar":
        flat = [flat_pol.serve(p, m)
                for p, m in zip(s["prompts"][:n], s["metas"][:n])]
        seg = [seg_pol.serve(p, m)
               for p, m in zip(s["prompts"][:n], s["metas"][:n])]
    else:
        flat, seg = [], []
        for i in range(0, n, bs):
            flat += flat_pol.serve_batch(s["prompts"][i:i + bs],
                                         s["metas"][i:i + bs])
            seg += seg_pol.serve_batch(s["prompts"][i:i + bs],
                                       s["metas"][i:i + bs])
    assert {r.served_by for r in flat} == {"static", "dynamic", "backend"}
    _assert_rows_equal(flat, seg)
    assert flat_pol.events == seg_pol.events
    assert flat_pol.stats() == seg_pol.stats()
    st = seg_pol.dyn_index_stats()
    assert st["seals"] > 0 and st["live"] > 0


def _run_krites(s, dyn_index, judge):
    pol = KritesPolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                       s["backend_fn"], judge, d=s["d"], n_workers=1,
                       embed_batch_fn=s["embed_batch_fn"],
                       backend_batch_fn=s["backend_batch_fn"],
                       dyn_index=dyn_index)
    out = []
    for i in range(0, 320, 32):
        out += pol.serve_batch(s["prompts"][i:i + 32],
                               s["metas"][i:i + 32])
        judge.gate.set()
        pol.pool.drain()
        judge.gate.clear()
    judge.gate.set()
    pol.pool.drain()
    pol.pool.stop()
    return pol, out


def test_krites_promotions_feed_tail_and_match_flat():
    """Full Alg. 2 differential: async promotions land in the segmented
    tail and every decision — including dynamic hits on promoted
    entries — matches the flat path request for request."""
    from test_serve_batch import _GatedOracle
    s = _trace_setup()
    pol_f, flat = _run_krites(s, None, _GatedOracle())
    pol_s, seg = _run_krites(
        s, _full_recall_index(s["cfg"].capacity, s["d"]), _GatedOracle())
    _assert_rows_equal(flat, seg)
    assert pol_f.events == pol_s.events
    sf, ss = pol_f.stats(), pol_s.stats()
    for k in ("judge_submitted", "judged", "approved", "static_hit_rate",
              "dynamic_hit_rate", "backend_rate", "static_origin_rate"):
        assert sf[k] == ss[k], k
    assert ss["approved"] > 0
    assert any(r.served_by == "dynamic" and r.static_origin for r in seg)
    assert pol_s.dyn_index_stats()["writes"] > 0


def test_background_compactor_preserves_full_recall_decisions():
    """With background compaction the merge races serving; under the
    full-recall config decisions must still equal flat exactly."""
    s = _trace_setup()
    idx = _full_recall_index(s["cfg"].capacity, s["d"], tail_rows=16,
                             compact_every=2, background=True)
    flat_pol, seg_pol = _mk_policy(s), _mk_policy(s, idx)
    flat, seg = [], []
    for i in range(0, 256, 32):
        flat += flat_pol.serve_batch(s["prompts"][i:i + 32],
                                     s["metas"][i:i + 32])
        seg += seg_pol.serve_batch(s["prompts"][i:i + 32],
                                   s["metas"][i:i + 32])
    idx.wait_compaction()
    _assert_rows_equal(flat, seg)
    assert flat_pol.events == seg_pol.events
    assert idx.stats()["merges"] > 0


# ---------------------------------------------------------------------------
# 3. telemetry
# ---------------------------------------------------------------------------

def test_router_surfaces_segment_occupancy_and_compactions():
    s = _trace_setup(n=160)
    pol = _mk_policy(s, _full_recall_index(s["cfg"].capacity, s["d"]))
    router = CacheRouter(pol, max_batch=16, max_wait_ms=5.0)
    results = router.submit_many(s["prompts"][:160], s["metas"][:160])
    assert all(r is not None for r in results)
    st = router.stats()
    assert st["dynamic_index"].startswith("segmented(")
    assert st["dyn_tail_live"] + st["dyn_segment_live"] > 0
    assert st["dyn_seals"] >= 1
    for k in ("dyn_segments", "dyn_merges", "dyn_tombstones"):
        assert k in st
    router.stop()


def test_describe_strings_name_the_lookup_path():
    s = _trace_setup(n=10)
    flat_pol = _mk_policy(s)
    seg_pol = _mk_policy(s, _full_recall_index(s["cfg"].capacity,
                                               s["d"]))
    assert flat_pol.describe_dyn_index().startswith("flat-masked(")
    assert seg_pol.describe_dyn_index().startswith("segmented(")
    assert flat_pol.dyn_index_stats() is None
