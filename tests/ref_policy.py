"""Pure-numpy reference simulator: Algorithm 1 (baseline) and Algorithm 2
(Krites) as a plain Python loop over the request stream.

This is the *independent oracle* for the JAX simulator
(``repro.core.simulate``): no jit, no scan, no vmap — every rule of the
paper's Algorithms 1/2 written out imperatively, one request at a time.
``tests/test_ref_differential.py`` enforces that ``simulate`` and
``simulate_sweep`` match it decision-for-decision.

Semantics mirrored (see DESIGN.md §3-4, §10, §16):
- serving: static threshold, then dynamic threshold over valid rows,
  else miss + LRU write-back; LRU touch on dynamic hit;
- grey-zone trigger (Krites only): sigma_min <= s_static < tau_static,
  optional dedup skip when a promoted pointer already serves the query,
  token-bucket rate limiting;
- async VerifyAndPromote: a task enqueued at request t completes at
  request t + max(1, judge_latency), at most one completion per step
  (earliest due first), processed before the step's serving decision;
- promotion upsert: near-duplicate overwrite (sim >= 0.9999), else LRU
  slot; last-writer-wins guard comparing the duplicate's ``written_at``
  against the task's *enqueue* time, and the clock split of the live
  policy: the promoted row's ``written_at`` records the enqueue time
  (LWW) while ``last_used`` records the apply time (LRU-warm);
- freshness (§16): per-entry ``expires_at`` masks expired rows out of
  every lookup lazily (the eviction count lands once, at the first
  expired step); a promotion's expiry anchors at its *enqueue* time and
  a verdict that outlived its own TTL is dropped; the L1 exact-match
  front (one cell per exact-duplicate key) is probed after the volatile
  bypass and before any tier traffic, and every semantic serve writes
  back under its key with the content clock the staleness rule judges
  against (epoch(now) vs epoch(content); static content is epoch 0,
  backend answers are current by definition);
- rewrite verdicts (§18): when ``cfg.rewrite`` is on, a would-reject
  completion whose request was flagged ``rewritable`` spends one token
  from a per-step-refilled bucket (``cfg.rewrite_rate``) and promotes a
  tailored variant keyed to the *query's* class with the
  ``answer_ref = -2`` provenance sentinel; serving such a row reports
  the ``REWRITTEN_HIT`` event code.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

MISS, STATIC_HIT, DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED, L1_HIT, \
    REWRITTEN_HIT = 0, 1, 2, 3, 4, 5
DEDUP_SIM = 0.9999
L1_NEVER = 1 << 30      # sim's unbounded-L1 sentinel (0 = empty cell)


class _RefSegIndex:
    """Pure-numpy twin of ``index/segmented.SegmentedIndex`` for the
    reference loop: a slot-id tail that seals into frozen segments, a
    compactor merging every ``compact_every`` of them, and tombstoning
    on overwrite/evict. Scores come from the *tier's* embedding matrix
    (the exact-rerank contract), so with the index's live set equal to
    the tier's valid set — the invariant this structure maintains —
    lookups are bit-identical to the flat masked scan. The reference
    simulator therefore stays a decision-for-decision oracle for both
    the flat and the segmented dynamic-lookup configs."""

    def __init__(self, tail_rows: int = 16, compact_every: int = 3):
        self.tail: dict = {}          # slot -> None (insertion order)
        self.segments: list = []      # frozen slot-id sets
        self.tail_rows = tail_rows
        self.compact_every = compact_every
        self.seals = self.merges = self.tombstones = 0

    def record_write(self, slot: int) -> None:
        self.invalidate(slot)
        if len(self.tail) == self.tail_rows:
            self.segments.append(set(self.tail))
            self.tail = {}
            self.seals += 1
            if len(self.segments) >= self.compact_every:
                merged = set().union(*self.segments)
                self.segments = [merged] if merged else []
                self.merges += 1
        self.tail[slot] = None

    def invalidate(self, slot: int) -> None:
        if self.tail.pop(slot, 0) is None:
            self.tombstones += 1
        for seg in self.segments:
            if slot in seg:
                seg.discard(slot)
                self.tombstones += 1

    def lookup(self, dyn: "_Dyn", q: np.ndarray, now=None):
        """Exact rerank of the live set against the tier matrix: the
        same sims vector the flat scan computes, masked to the index's
        live slots (tail + segments, tombstones excluded) and — when a
        clock is given — to unexpired rows."""
        sims = (dyn.emb @ q).astype(np.float32)
        live = np.zeros(len(sims), bool)
        for store in [self.tail, *self.segments]:
            for slot in store:
                live[slot] = True
        if now is not None:
            live &= (dyn.expires == 0) | (now <= dyn.expires)
        sims[~live] = -np.inf
        j = int(np.argmax(sims))
        return float(sims[j]), j


@dataclass
class _Dyn:
    """Mutable dynamic tier (struct-of-arrays, numpy)."""
    emb: np.ndarray
    cls: np.ndarray
    answer_ref: np.ndarray
    static_origin: np.ndarray
    valid: np.ndarray
    last_used: np.ndarray
    written_at: np.ndarray
    expires: np.ndarray = None
    index: object = None          # optional _RefSegIndex twin

    @classmethod
    def make(cls_, capacity: int, d: int, index=None) -> "_Dyn":
        return cls_(
            emb=np.zeros((capacity, d), np.float32),
            cls=np.zeros(capacity, np.int32),
            answer_ref=np.full(capacity, -1, np.int32),
            static_origin=np.zeros(capacity, bool),
            valid=np.zeros(capacity, bool),
            last_used=np.zeros(capacity, np.int32),
            written_at=np.zeros(capacity, np.int32),
            expires=np.zeros(capacity, np.int32),
            index=index,
        )

    def live(self, now=None) -> np.ndarray:
        """Valid AND unexpired (expiry is lazy: ``valid`` stays set, the
        mask does the killing — exactly the simulator's rule). With no
        clock, plain validity (the pre-§16 semantics; identical anyway
        whenever no entry carries an expiry)."""
        if now is None:
            return self.valid
        return self.valid & ((self.expires == 0) | (now <= self.expires))

    def lookup(self, q: np.ndarray, now=None):
        """Best (similarity, index) over live rows; (-inf, 0) if none."""
        if self.index is not None:
            return self.index.lookup(self, q, now)
        sims = (self.emb @ q).astype(np.float32)
        sims[~self.live(now)] = -np.inf
        j = int(np.argmax(sims))
        return float(sims[j]), j

    def lru_slot(self, now=None) -> int:
        """First dead (invalid or expired) row, else least-recently-used."""
        key = np.where(self.live(now), self.last_used.astype(np.int64),
                       -2**40)
        return int(np.argmin(key))

    def write(self, slot, q, cls, ref, so, now, written_at=None, exp=0):
        """``now`` stamps the LRU clock; ``written_at`` (default
        ``now``) stamps the LWW clock — promotions pass their enqueue
        time, mirroring ``tiers._write``. ``exp`` is the entry's
        ``expires_at`` (0 = never)."""
        self.emb[slot] = q
        self.cls[slot] = cls
        self.answer_ref[slot] = ref
        self.static_origin[slot] = so
        self.valid[slot] = True
        self.last_used[slot] = now
        self.written_at[slot] = now if written_at is None else written_at
        self.expires[slot] = exp
        if self.index is not None:
            self.index.record_write(slot)

    def upsert(self, q, cls, ref, now, enq=None, so=True, exp=0,
               dup_sim=DEDUP_SIM):
        """Idempotent, LWW-guarded promotion write (Alg. 2 line 21).

        ``enq`` is the promotion's enqueue time (default ``now``): the
        LWW guard compares against it and it becomes the row's
        ``written_at``, while ``now`` — the apply time — becomes the
        LRU clock, so a delayed promotion lands LRU-warm (the live
        ``KritesPolicy._promote`` clock split). ``dup_sim`` is the
        near-duplicate overwrite gate (``CacheConfig.dup_threshold``)."""
        enq = now if enq is None else enq
        s, j = self.lookup(q, now)
        dup = s >= dup_sim
        if dup and self.written_at[j] > enq:
            return                     # stale judgment: newer entry wins
        self.write(j if dup else self.lru_slot(now), q, cls, ref, so,
                   now, written_at=enq, exp=exp)


@dataclass
class _Task:
    due: int
    emb: np.ndarray
    qcls: int
    hcls: int
    href: int
    flip: bool
    vol: bool = False
    rw: bool = False


def ref_simulate(static_emb, static_cls, q_emb, q_cls, cfg, krites,
                 capacity=None, judge_flip=None, dyn_index=None,
                 drain=False, crash_after=None,
                 extra_replays=0, volatile=None, key_id=None,
                 drift_every=0, rewritable=None) -> dict:
    """Reference run; returns plain-numpy analogues of ``SimResult``.

    ``cfg`` is any object with the :class:`repro.core.tiers.CacheConfig`
    fields (tau_static, tau_dynamic, sigma_min, capacity, judge_latency,
    dedup, judge_rate). ``dyn_index='segmented'`` routes dynamic
    lookups through the :class:`_RefSegIndex` twin (tail + sealed
    segments + tombstones, exact rerank) — decisions must be identical
    to the flat config, keeping this loop the oracle for both.

    **Recovery semantics** (the numpy oracle for DESIGN.md §14).
    ``drain=True`` runs the end-of-trace promotion burst: every still-
    pending task is judged in due order and each approved promotion is
    first appended to a journal (the WAL analogue — journal order is
    apply order) and then upserted. ``crash_after=k`` models a crash
    mid-burst: only the first ``k`` journaled upserts land before the
    process dies; recovery then replays the *whole* journal, in order,
    with each record's original ``now`` — and ``extra_replays`` runs
    replay again that many times. The contract under test: any
    ``crash_after`` point followed by >=1 replay, plus any number of
    extra replays, yields a ``final`` tier state identical to the
    uninterrupted run — replay idempotence and the LWW ``written_at``
    guard are exactly what make this hold. ``final`` (the full dynamic
    tier arrays) and ``journal_len`` are added to the result only when
    ``drain=True``, so the existing simulator differentials — which
    have no drain phase — are untouched.

    **Freshness semantics** (the numpy oracle for DESIGN.md §16),
    driven by the ``cfg`` fields ``l1`` / ``volatile_bypass`` /
    ``ttl_volatile`` / ``ttl_stable`` (read with safe defaults so
    pre-§16 config objects keep working) plus the per-request
    ``volatile`` (bool) and ``key_id`` (exact-duplicate id) arrays and
    the ``drift_every`` ground-truth rotation period. All freshness
    logic is inert when those fields are off, so legacy calls stay
    bit-identical.
    """
    static_emb = np.asarray(static_emb, np.float32)
    static_cls = np.asarray(static_cls, np.int32)
    q_emb = np.asarray(q_emb, np.float32)
    q_cls = np.asarray(q_cls, np.int32)
    N, d = q_emb.shape
    if judge_flip is None:
        judge_flip = np.zeros(N, bool)
    if volatile is None:
        volatile = np.zeros(N, bool)
    if key_id is None:
        key_id = np.zeros(N, np.int64)
    if rewritable is None:
        rewritable = np.zeros(N, bool)

    C = capacity or cfg.capacity
    lat = max(1, cfg.judge_latency)
    dup_sim = float(getattr(cfg, "dup_threshold", DEDUP_SIM))
    l1f = bool(getattr(cfg, "l1", False))
    vbp = bool(getattr(cfg, "volatile_bypass", False))
    ttl_v = int(getattr(cfg, "ttl_volatile", 0))
    ttl_s = int(getattr(cfg, "ttl_stable", 0))
    rw_on = bool(getattr(cfg, "rewrite", False))
    rrate = float(getattr(cfg, "rewrite_rate", 1.0))
    rbud = np.float32(0.0)
    D = int(drift_every)
    dyn = _Dyn.make(C, d, index=_RefSegIndex()
                    if dyn_index == "segmented" else None)
    pending: list[_Task] = []
    budget = np.float32(1.0)
    l1: dict = {}          # key_id -> (expires, content_t, ok, so)

    # hoisted static lookup, like the simulator
    sims = q_emb @ static_emb.T
    h_idx = np.argmax(sims, axis=1)
    s_static = sims[np.arange(N), h_idx].astype(np.float32)
    h_cls = static_cls[h_idx]

    served_by = np.zeros(N, np.int8)
    correct = np.zeros(N, bool)
    static_origin = np.zeros(N, bool)
    stale = np.zeros(N, bool)
    judge_calls = judge_approved = promotions = enq_dropped = 0
    ttl_evicted = bypassed = rewrites = rewrite_dropped = 0

    def epoch(x):
        return x // D

    for t in range(N):
        q, qc = q_emb[t], int(q_cls[t])
        ss, hc, hr = float(s_static[t]), int(h_cls[t]), int(h_idx[t])
        vol, kid = bool(volatile[t]), int(key_id[t])

        # ---- 0. per-entry expiry: lazy death, counted exactly once at
        # the first expired step — before any write can reuse the slot
        ttl_evicted += int(np.sum(dyn.valid & (dyn.expires > 0)
                                  & (t == dyn.expires + 1)))

        # ---- 1. async completion due now (earliest first, one per step)
        # the rewrite token bucket refills once per step at the
        # completion point (the sim cores refill inside their step fn)
        if rw_on:
            rbud = np.float32(min(rbud + np.float32(rrate), 1e9))
        due_i = min((i for i, p in enumerate(pending) if p.due <= t),
                    key=lambda i: pending[i].due, default=None)
        if due_i is not None:
            task = pending.pop(due_i)
            judge_calls += 1
            approve = task.qcls == task.hcls or task.flip
            # REWRITE verdict (§18): a would-reject whose request was
            # rewritable spends a rewrite token and promotes a tailored
            # variant keyed to the *query's* class, answer_ref = -2
            rw_can = False
            if rw_on and not approve and task.rw:
                if rbud >= 1.0:
                    rw_can = True
                    rbud = np.float32(rbud - np.float32(1.0))
                    rewrites += 1
                else:
                    rewrite_dropped += 1
            if approve:
                judge_approved += 1
            if approve or rw_can:
                promotions += 1       # counts the verdict, like the sim
                # TTL verdict: expiry anchors at the *enqueue* time (what
                # the promotion WAL records); a verdict that outlived its
                # own TTL is dropped, like the live _promote
                tau_p = ttl_v if task.vol else ttl_s
                enq = task.due - lat
                exp_p = enq + tau_p if tau_p > 0 else 0
                if not (exp_p > 0 and exp_p < t):
                    cls_p = task.qcls if rw_can else task.hcls
                    ref_p = -2 if rw_can else task.href
                    dyn.upsert(task.emb, cls_p, ref_p, now=t,
                               enq=enq, exp=exp_p, dup_sim=dup_sim)

        # ---- 1b. freshness front: volatile bypass, then the L1 exact-
        # match probe — both before any tier traffic
        byp = vbp and vol
        le, l1_w, l1_ok, l1_so = l1.get(kid, (0, 0, False, False))
        l1hit = l1f and not byp and le > 0 and t <= le
        front = byp or l1hit
        if byp:
            bypassed += 1

        # ---- 2. serving path ----
        static_hit_sem = ss >= cfg.tau_static
        s_dyn, j_dyn = dyn.lookup(q, t)
        dyn_hit_sem = (not static_hit_sem) and s_dyn >= cfg.tau_dynamic
        static_hit = static_hit_sem and not front
        dyn_hit = dyn_hit_sem and not front
        miss = not front and not (static_hit_sem or dyn_hit_sem)
        wa_j = int(dyn.written_at[j_dyn])

        is_promoted = dyn_hit and bool(dyn.static_origin[j_dyn])
        is_rewritten = rw_on and dyn_hit \
            and int(dyn.answer_ref[j_dyn]) == -2
        if l1hit:
            served_by[t], served_cls = L1_HIT, qc
        elif static_hit:
            served_by[t], served_cls = STATIC_HIT, hc
        elif is_rewritten:
            served_by[t], served_cls = REWRITTEN_HIT, int(dyn.cls[j_dyn])
        elif is_promoted:
            served_by[t], served_cls = DYN_HIT_PROMOTED, int(dyn.cls[j_dyn])
        elif dyn_hit:
            served_by[t], served_cls = DYN_HIT_DYNAMIC, int(dyn.cls[j_dyn])
        else:
            served_by[t], served_cls = MISS, qc
        correct[t] = l1_ok if l1hit else served_cls == qc
        static_origin[t] = l1_so if l1hit else (static_hit or is_promoted)

        # drift staleness: a volatile query served content produced in
        # an earlier drift epoch (static is epoch 0; backend is current)
        if D > 0 and vol:
            if l1hit:
                stale[t] = epoch(t) != epoch(l1_w)
            elif static_hit:
                stale[t] = epoch(t) != 0
            elif dyn_hit:
                stale[t] = epoch(t) != epoch(wa_j)

        if dyn_hit:
            dyn.last_used[j_dyn] = t          # LRU touch
        tau_q = ttl_v if vol else ttl_s
        exp_q = t + tau_q if tau_q > 0 else 0
        if miss:
            dyn.write(dyn.lru_slot(t), q, qc, -1, False, t, exp=exp_q)

        # ---- 2b. L1 write-back: every semantic serve lands under the
        # query's exact key (never refreshed by later hits — the stored
        # content clock is what staleness is judged against)
        if l1f and not front:
            content_t = 0 if static_hit else (wa_j if dyn_hit else t)
            l1[kid] = (exp_q if tau_q > 0 else L1_NEVER, content_t,
                       bool(correct[t]), bool(static_origin[t]))

        # ---- 3. grey-zone trigger (off-path); front-resolved requests
        # never embed, so they can never trigger
        grey = cfg.sigma_min <= ss < cfg.tau_static
        want = grey and bool(krites) and not front
        if cfg.dedup and is_promoted and s_dyn >= cfg.tau_dynamic:
            want = False
        budget = np.float32(min(budget + np.float32(cfg.judge_rate), 1e9))
        if want and budget >= 1.0:
            budget = np.float32(budget - np.float32(1.0))
            pending.append(_Task(t + lat, q.copy(), qc, hc, hr,
                                 bool(judge_flip[t]), vol,
                                 bool(rewritable[t])))
        elif want:
            enq_dropped += 1

    out = {
        "served_by": served_by, "correct": correct,
        "static_origin": static_origin, "stale": stale,
        "judge_calls": judge_calls,
        "judge_approved": judge_approved, "promotions": promotions,
        "enq_dropped": enq_dropped,
        "ttl_evicted": ttl_evicted, "bypassed": bypassed,
        "rewrites": rewrites, "rewrite_dropped": rewrite_dropped,
    }
    if not drain:
        return out

    # ---- 4. end-of-trace drain: judge the backlog, journal-then-apply
    journal = []              # (emb, cls, ref, now, enq) in append order
    for task in sorted(pending, key=lambda p: p.due):
        judge_calls += 1
        if task.qcls == task.hcls or task.flip:
            judge_approved += 1
            promotions += 1
            journal.append((task.emb, task.hcls, task.href,
                            int(task.due), int(task.due) - lat))
    applied = len(journal) if crash_after is None \
        else min(crash_after, len(journal))
    for rec in journal[:applied]:       # upserts that landed pre-crash
        dyn.upsert(*rec, dup_sim=dup_sim)
    if crash_after is not None or extra_replays:
        for _ in range(max(1 if crash_after is not None else 0,
                           extra_replays)):
            for rec in journal:         # full-journal replay, in order
                dyn.upsert(*rec, dup_sim=dup_sim)

    out.update({
        "judge_calls": judge_calls, "judge_approved": judge_approved,
        "promotions": promotions,
        "journal_len": len(journal),
        "final": {
            "emb": dyn.emb.copy(), "cls": dyn.cls.copy(),
            "answer_ref": dyn.answer_ref.copy(),
            "static_origin": dyn.static_origin.copy(),
            "valid": dyn.valid.copy(),
            "last_used": dyn.last_used.copy(),
            "written_at": dyn.written_at.copy(),
            "expires": dyn.expires.copy(),
        },
    })
    return out


def ref_adaptive(static_emb, static_cls, q_emb, q_label, q_seg, cfg,
                 params=None, feedback=None) -> dict:
    """Numpy twin of ``BaselinePolicy`` + ``AdaptiveController`` on the
    scalar serving path (the oracle for DESIGN.md §17).

    One imperative loop per request: serve under the *live per-segment*
    thresholds, record (embedding, label, segment) into the bounded
    window, and at the controller's cadence run the shadow sweep — here
    evaluated candidate-by-candidate through :func:`ref_simulate`
    (krites=False), the independent numpy evaluator, instead of the
    live controller's one batched ``simulate_sweep`` dispatch. The
    *selection* arithmetic (grid construction, feasibility, hysteresis,
    bounded step, LCG exploration) is deliberately the shared pure code
    from ``core/adaptive.py``: the oracle's independence lives in the
    decision streams, and the existing simulator differentials already
    pin ``ref_simulate`` against ``simulate_sweep``. Every adaptive
    decision — tau trajectory, move/explore/regret counters, and the
    serving stream they produce — must match the live policy
    field-identically.

    ``q_label`` is the caller-declared class per request (−1 = none:
    the static neighbor's class is recorded instead, like the live
    ``_adapt_record``). ``q_seg`` is the per-request traffic segment.
    ``feedback``, when given, marks requests whose served answer gets
    an immediate wrong-answer report: the window row's label is
    poisoned with the live path's unique ``−2−seq`` sentinel right
    after serving, before the next request.
    """
    from repro.core.adaptive import (N_SEGMENTS, AdaptiveParams,
                                     candidate_grid, choose_candidate,
                                     lcg_next)
    from repro.core.tiers import CacheConfig

    p = params or AdaptiveParams()
    static_emb = np.asarray(static_emb, np.float32)
    static_cls = np.asarray(static_cls, np.int32)
    q_emb = np.asarray(q_emb, np.float32)
    q_label = np.asarray(q_label, np.int64)
    q_seg = np.asarray(q_seg, np.int64)
    N, d = q_emb.shape
    if feedback is None:
        feedback = np.zeros(N, bool)

    tau_s = [float(cfg.tau_static)] * N_SEGMENTS
    tau_d = [float(cfg.tau_dynamic)] * N_SEGMENTS
    w_emb = np.zeros((p.window, d), np.float32)
    w_label = np.zeros(p.window, np.int32)
    w_seg = np.zeros(p.window, np.int8)
    count = since = 0
    rng = lcg_next(p.seed & ((1 << 64) - 1))
    dyn = _Dyn.make(cfg.capacity, d)
    adaptations = moves = explores = 0
    regret = [0] * N_SEGMENTS

    sims = q_emb @ static_emb.T
    h_idx = np.argmax(sims, axis=1)
    s_static = sims[np.arange(N), h_idx].astype(np.float32)
    h_cls = static_cls[h_idx]

    served_by = np.zeros(N, np.int8)
    tau_trail = []          # (request idx, tau_s copy, tau_d copy)

    def shadow_cfg(ts, td):
        # must construct the SAME candidate config the live
        # AdaptiveController._shadow_cfg builds
        return CacheConfig(tau_static=ts, tau_dynamic=td, sigma_min=0.0,
                           capacity=p.shadow_capacity, judge_latency=1,
                           dup_threshold=1.0)

    for t in range(N):
        q, seg = q_emb[t], int(q_seg[t])
        ss = float(s_static[t])
        if ss >= tau_s[seg]:
            served_by[t] = STATIC_HIT
        else:
            s_dyn, j = dyn.lookup(q, t)
            if s_dyn >= tau_d[seg]:
                served_by[t] = DYN_HIT_PROMOTED \
                    if dyn.static_origin[j] else DYN_HIT_DYNAMIC
                dyn.last_used[j] = t
            else:
                served_by[t] = MISS
                dyn.write(dyn.lru_slot(t), q, int(q_label[t]), -1,
                          False, t)
        # window record (every semantic serve) + optional feedback
        label = int(q_label[t]) if q_label[t] >= 0 else int(h_cls[t])
        i = count % p.window
        w_emb[i], w_seg[i] = q, seg
        count += 1
        since += 1
        w_label[i] = (-2 - count) if feedback[t] else label

        # serve-call-boundary adaptation check (scalar cadence)
        if since < p.adapt_every or count < p.window:
            continue
        since = 0
        pos = count % p.window
        order = np.concatenate([np.arange(pos, p.window),
                                np.arange(0, pos)])
        emb, lab, sg = w_emb[order], w_label[order], w_seg[order]
        rng = lcg_next(rng)
        adaptations += 1

        active = [s for s in range(N_SEGMENTS)
                  if int((sg == s).sum()) >= p.min_segment]
        if not active:
            continue
        spans, cfgs = {}, []
        for s in active:
            cands, ci = candidate_grid(tau_s[s], tau_d[s], p)
            spans[s] = (len(cfgs), cands, ci)
            cfgs.extend(shadow_cfg(ts, td) for ts, td in cands)
        sb = np.stack([ref_simulate(static_emb, static_cls, emb, lab,
                                    c, krites=False)["served_by"]
                       for c in cfgs])
        cr = np.stack([ref_simulate(static_emb, static_cls, emb, lab,
                                    c, krites=False)["correct"]
                       for c in cfgs])
        hit = sb != MISS
        bad = hit & ~cr
        explore = (rng >> 17) % 1_000_000 < int(p.epsilon * 1_000_000)
        for s in active:
            start, cands, ci = spans[s]
            mask = sg == s
            n_seg = int(mask.sum())
            hits = [int((hit[start + k] & mask).sum())
                    for k in range(len(cands))]
            errs = [int((bad[start + k] & mask).sum())
                    for k in range(len(cands))]
            pick = (lcg_next(rng + s) >> 11) if explore else None
            k, reason = choose_candidate(hits, errs, n_seg, ci, p, pick)
            g, _ = choose_candidate(hits, errs, n_seg, ci, p, None)
            regret[s] += max(0, hits[g] - hits[ci])
            if reason == "explore":
                explores += 1
            cs, cd = tau_s[s], tau_d[s]
            ts = cs + min(max(cands[k][0] - cs, -p.max_step), p.max_step)
            td = cd + min(max(cands[k][1] - cd, -p.max_step), p.max_step)
            ts = min(max(ts, p.tau_lo), p.tau_hi)
            td = min(max(td, p.tau_lo), p.tau_hi)
            if (ts, td) != (tau_s[s], tau_d[s]):
                moves += 1
                tau_s[s], tau_d[s] = ts, td
        tau_trail.append((t, list(tau_s), list(tau_d)))

    return {
        "served_by": served_by, "tau_static": tau_s, "tau_dynamic": tau_d,
        "tau_trail": tau_trail, "adaptations": adaptations,
        "moves": moves, "explores": explores, "regret": regret,
        "count": count,
    }
