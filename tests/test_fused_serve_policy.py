"""Fused serve path vs the dispatched lookups: decision-identical.

The fused single-pass pipeline (``kernels/fused_serve``, DESIGN.md §15)
replaces the policy's two lookups (static top-1 + masked dynamic top-1)
with ONE dispatch. These tests pin the safety contract of the flag: a
fused policy must serve *field-identical* results — served_by, answer,
static_origin, similarity — to the flat-dispatched and IVF-dispatched
policies, scalar and batched, and leave identical tier state behind.

The fused configs here probe every cluster with a candidate budget
covering the whole corpus / tier (recall 1.0 by construction), so the
exact fp32 rerank makes equality mathematical, not statistical: any
mismatch is a real serving-path bug, hence the hard agreement == 1.0.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.judge import OracleJudge
from repro.core.policy import KritesPolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.index.ivf import IVFIndex, build_ivf
from repro.kernels.fused_serve import FusedServe

D, S, CAP = 32, 24, 16


def _world(seed=0):
    """Static tier + a trace with static hits, grey-zone paraphrases,
    repeats (dynamic hits) and novel prompts, all via an embed map."""
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((S, D)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    tier = make_static_tier(jnp.asarray(emb),
                            jnp.arange(S, dtype=jnp.int32))
    answers = [f"curated-{i}" for i in range(S)]
    texts = [f"canonical prompt {i}" for i in range(S)]

    emb_map, trace = {}, []

    def para(i, w, name, cls):
        v = emb[i] + w * rng.standard_normal(D).astype(np.float32)
        emb_map[name] = (v / np.linalg.norm(v)).astype(np.float32)
        trace.append((name, {"cls": cls}))

    for i in range(8):
        para(i, 0.05, f"hit-{i}", i)        # sim ~0.999 -> static hit
    for i in range(8):
        para(i, 0.45, f"grey-{i}", i)       # grey zone -> judge+promote
    for i in range(6):
        v = rng.standard_normal(D).astype(np.float32)
        emb_map[f"novel-{i}"] = v / np.linalg.norm(v)
        trace.append((f"novel-{i}", None))  # backend miss -> insert
    # repeats: dynamic hits on promoted/inserted keys
    for name in [f"grey-{i}" for i in range(4)] + ["novel-0", "novel-3"]:
        trace.append((name, {"cls": -1} if name.startswith("n") else
                      {"cls": int(name.split("-")[1])}))
    return tier, answers, texts, emb_map, trace


def _policy(tier, answers, texts, emb_map, **kw):
    return KritesPolicy(
        CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=CAP),
        tier, answers, lambda p: emb_map[p], lambda p: f"gen({p})",
        OracleJudge(), d=D, n_workers=1, static_texts=texts, **kw)


def _variants(tier, answers, texts, emb_map):
    ivf = build_ivf(np.asarray(tier.emb), n_clusters=4, iters=4,
                    corpus_normalized=True)
    return {
        "flat": _policy(tier, answers, texts, emb_map),
        "ivf": _policy(tier, answers, texts, emb_map,
                       index=IVFIndex(ivf, nprobe=4, n_candidates=S)),
        # full probe + corpus-wide candidate budgets: recall 1.0, so
        # the fused decisions must be exactly the dispatched ones
        "fused": _policy(tier, answers, texts, emb_map,
                         fused=FusedServe(ivf, nprobe=4,
                                          n_candidates=S,
                                          n_dyn_candidates=CAP)),
    }


def _row(r):
    return (r.served_by, str(r.answer), bool(r.static_origin),
            float(r.similarity))


def _same(a, b):
    # decisions must match exactly; the similarity only to float32
    # accumulation order (matmul vs gathered-einsum differ in the ulp)
    return a[:3] == b[:3] \
        and (a[3] == b[3] or abs(a[3] - b[3]) < 5e-5)


def _assert_same_state(pols):
    base = pols["flat"]
    for name, p in pols.items():
        assert (p._valid_np == base._valid_np).all(), name
        assert (p._static_origin_np == base._static_origin_np).all(), name
        assert (p._written_at_np == base._written_at_np).all(), name
        assert (p._last_used_np == base._last_used_np).all(), name
        assert p.dyn_answers == base.dyn_answers, name
        np.testing.assert_allclose(np.asarray(p.dyn.emb),
                                   np.asarray(base.dyn.emb), atol=1e-6)


def test_scalar_fused_matches_dispatched_agreement_one():
    tier, answers, texts, emb_map, trace = _world()
    pols = _variants(tier, answers, texts, emb_map)
    total = agree = 0
    for prompt, meta in trace:
        rows = {}
        for name, p in pols.items():
            rows[name] = _row(p.serve(prompt, meta=meta))
            p.pool.drain(5)    # promotions land before the next serve
        total += 1
        agree += int(_same(rows["fused"], rows["flat"])
                     and _same(rows["ivf"], rows["flat"]))
        assert _same(rows["fused"], rows["flat"]), (prompt, rows)
        assert _same(rows["ivf"], rows["flat"]), (prompt, rows)
    assert total and agree / total == 1.0
    _assert_same_state(pols)
    for p in pols.values():
        p.pool.stop()


def test_batch_fused_matches_dispatched_agreement_one():
    tier, answers, texts, emb_map, trace = _world(seed=1)
    pols = _variants(tier, answers, texts, emb_map)
    total = agree = 0
    for lo in range(0, len(trace), 8):
        chunk = trace[lo:lo + 8]
        prompts = [p for p, _ in chunk]
        metas = [m for _, m in chunk]
        rows = {name: [_row(r) for r in
                       p.serve_batch(prompts, metas)]
                for name, p in pols.items()}
        for p in pols.values():
            p.pool.drain(5)
        for i in range(len(chunk)):
            total += 1
            same = _same(rows["fused"][i], rows["flat"][i]) \
                and _same(rows["ivf"][i], rows["flat"][i])
            agree += int(same)
            assert same, (prompts[i], {k: v[i] for k, v in rows.items()})
    assert total and agree / total == 1.0
    _assert_same_state(pols)
    for p in pols.values():
        p.pool.stop()


def test_fused_excludes_other_lookup_configs():
    """fused= replaces both lookups; combining it with index=,
    dyn_index= or mesh= must be rejected, not silently shadowed."""
    tier, answers, texts, emb_map, _ = _world()
    ivf = build_ivf(np.asarray(tier.emb), n_clusters=4,
                    corpus_normalized=True)
    fused = FusedServe(ivf)
    with pytest.raises(ValueError):
        _policy(tier, answers, texts, emb_map, fused=fused,
                index=IVFIndex(ivf))
    with pytest.raises(ValueError):
        _policy(tier, answers, texts, emb_map, fused=fused,
                dyn_index="segmented")


def test_fused_interpret_kernel_end_to_end_tiny():
    """One tiny config through the real Pallas kernel (interpret mode)
    inside the policy — the fused flag's device path, not just the jnp
    twin — must still match the flat policy decision for decision."""
    tier, answers, texts, emb_map, trace = _world(seed=2)
    pols = {
        "flat": _policy(tier, answers, texts, emb_map),
        "fused": _policy(
            tier, answers, texts, emb_map,
            fused=FusedServe(
                build_ivf(np.asarray(tier.emb), n_clusters=4, iters=4,
                          corpus_normalized=True),
                nprobe=4, n_candidates=S, n_dyn_candidates=CAP,
                force="interpret")),
    }
    for prompt, meta in trace[:8]:     # interpret mode is slow; a
        rows = {}                      # prefix covers hit/grey/backend
        for name, p in pols.items():
            rows[name] = _row(p.serve(prompt, meta=meta))
            p.pool.drain(5)
        assert _same(rows["fused"], rows["flat"]), (prompt, rows)
    for p in pols.values():
        p.pool.stop()
