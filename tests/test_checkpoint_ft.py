"""Checkpointing, restart-on-failure, elastic remesh, compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st

from repro.distributed import checkpoint as ck
from repro.distributed import compression as comp
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerPolicy,
                                               run_with_restarts)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32),
                       "c": [jnp.ones((3,)), jnp.zeros((2, 2))]}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 7, t, extra={"note": "hi"})
    out = ck.restore(tmp_path, 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    path = ck.save(tmp_path, 1, t)
    victim = next(p for p in path.iterdir() if p.suffix == ".npy")
    arr = np.load(victim)
    arr = arr.copy()
    arr.flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError):
        ck.restore(tmp_path, 1, t)


def test_latest_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ck.save(tmp_path, s, t)
    assert ck.latest_step(tmp_path) == 4
    ck.prune(tmp_path, keep=2)
    assert ck.latest_step(tmp_path) == 4
    assert not (tmp_path / "step_00000001").exists()


def test_run_with_restarts_recovers(tmp_path):
    calls = {"n": 0}

    def step(i, state):
        calls["n"] += 1
        if i == 7 and calls["n"] < 9:    # fail once at step 7
            raise RuntimeError("simulated node failure")
        return {"x": state["x"] + 1}

    final, report = run_with_restarts(
        step, {"x": jnp.zeros(())}, n_steps=10,
        ckpt_dir=str(tmp_path), ckpt_every=2)
    assert float(final["x"]) == 10
    assert report.failures == 1 and report.restarts == 1


def test_heartbeat_detects_dead():
    dead = []
    mon = HeartbeatMonitor(deadline_s=0.05, on_dead=dead.append)
    mon.beat("w0")
    mon.beat("w1")
    import time
    time.sleep(0.08)
    mon.beat("w1")
    newly = mon.check()
    assert newly == ["w0"] and dead == ["w0"]
    assert "w0" in mon.dead and "w1" not in mon.dead


def test_straggler_redispatch():
    sp = StragglerPolicy(deadline_s=0.02)
    sp.started("t1")
    sp.started("t2")
    sp.finished("t2")
    import time
    time.sleep(0.04)
    assert sp.stragglers() == ["t1"]
    assert sp.redispatched == 1


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
def test_prop_compression_error_bound(seed, n):
    """int8 block quantization: |x - roundtrip| <= scale/2 per block."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32)
                    * rng.uniform(0.01, 100))
    y = comp.roundtrip(x)
    q, scale, _ = comp.quantize(x)
    pad = (-n) % comp.BLOCK
    bound = np.repeat(np.asarray(scale), comp.BLOCK)[:n] * 0.5 + 1e-6
    assert (np.abs(np.asarray(x - y)) <= bound).all()


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((512,)).astype(np.float32))
    grads = {"w": g}
    res = comp.init_residual(grads)
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    for _ in range(50):
        acc_plain = acc_plain + comp.roundtrip(g)
        qt, res = comp.compress_grads_with_feedback(grads, res)
        q, s, n = qt["w"]
        acc_ef = acc_ef + comp.dequantize(q, s, n, g.shape)
    true = g * 50
    assert float(jnp.linalg.norm(acc_ef - true)) \
        <= float(jnp.linalg.norm(acc_plain - true)) + 1e-3
