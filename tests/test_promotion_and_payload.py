"""Async-promotion staleness (LWW) + judge-payload regression tests.

Pins the two Krites write-path bugs fixed in this PR:

- ``KritesPolicy._promote`` used to write unconditionally, so a slow
  judge's stale promotion clobbered a dynamic entry written *after* the
  task was enqueued — violating the LWW contract ``tiers.upsert``
  documents. The tests here fail on that behavior.
- ``_grey_submission`` used to submit empty ``h_text``/``answer``, so
  the judge verified on class ids alone; payloads must now carry the
  full (q_text, h_text, answer) triple.

Plus the batch-long-lock concurrency invariant: async ``_promote``
racing ``serve_batch`` must keep the host mirrors field-identical to
the JAX tier, on flat and segmented dynamic-index configs.
"""
import dataclasses
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiers as T
from repro.core.judge import OracleJudge
from repro.core.policy import KritesPolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark

D = 8


def _static(n=4):
    emb = np.eye(D, dtype=np.float32)[:n]
    tier = make_static_tier(jnp.asarray(emb),
                            jnp.arange(n, dtype=jnp.int32))
    answers = [f"curated-{i}" for i in range(n)]
    texts = [f"canonical prompt {i}" for i in range(n)]
    return tier, answers, texts


def _para(i=0, j=1, w=0.3):
    """A paraphrase-like direction near static row ``i``."""
    v = np.eye(D, dtype=np.float32)[i] + w * np.eye(D, dtype=np.float32)[j]
    return (v / np.linalg.norm(v)).astype(np.float32)


class _GatedOracle:
    def __init__(self):
        self.gate = threading.Event()

    def __call__(self, q_cls, h_cls, **kw):
        self.gate.wait()
        return int(q_cls) == int(h_cls)


# ---------------------------------------------------------------------------
# LWW promotion staleness
# ---------------------------------------------------------------------------

def test_stale_promote_skips_newer_write_unit():
    """Direct twin of tiers.upsert's LWW guard: once a promotion with
    enq_t=10 owns the key, a straggler with enq_t=5 must be dropped."""
    tier, answers, texts = _static()
    pol = KritesPolicy(CacheConfig(0.99, 0.99, capacity=4), tier,
                       answers, lambda p: _para(), lambda p: f"gen({p})",
                       OracleJudge(), d=D, static_texts=texts)
    v = _para()
    pol._promote({"v": v, "h_idx": 1, "enq_t": 10})
    slot = int(np.argmax(pol._valid_np))
    assert pol.dyn_answers[slot] == "curated-1"
    pol._promote({"v": v, "h_idx": 0, "enq_t": 5})   # stale straggler
    assert pol.dyn_answers[slot] == "curated-1", \
        "stale promotion clobbered a newer entry"
    assert int(np.asarray(pol.dyn.written_at)[slot]) == 10
    assert int(np.asarray(pol.dyn.answer_ref)[slot]) == 1
    # equal timestamps (the promotion racing its own miss-insert) and
    # genuinely newer promotions still win, per upsert's `>` guard
    pol._promote({"v": v, "h_idx": 0, "enq_t": 10})
    assert pol.dyn_answers[slot] == "curated-0"
    pol.pool.stop()


def test_delayed_judge_promotion_respects_lww():
    """End-to-end regression: a grey task enqueued at t=1 whose judge
    completes only after the same key was rewritten at t=2 must NOT
    promote over the newer entry. Fails on the old unconditional
    ``T._write`` promote."""
    tier, answers, texts = _static()
    judge = _GatedOracle()
    # capacity 1 + unreachable tau_dynamic: every serve is a backend
    # miss that overwrites slot 0, giving the key a newer written_at
    # while the judge is stuck
    cfg = CacheConfig(tau_static=0.99, tau_dynamic=1.01, sigma_min=0.0,
                      capacity=1)
    pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                       lambda p: f"gen({p})", judge, d=D, n_workers=1,
                       static_texts=texts)
    pol.serve("p1", {"cls": 0})     # t=1: insert + grey task (enq_t=1)
    pol.serve("p1", {"cls": 0})     # t=2: rewrite of the same key
    assert int(pol._written_at_np[0]) == 2
    judge.gate.set()                # the slow judge finally answers
    pol.pool.drain()
    pol.pool.stop()
    assert pol.pool.stats.approved >= 1     # judge did approve ...
    assert not bool(pol._static_origin_np[0]), \
        "stale promotion (enq_t=1) clobbered the t=2 write"
    assert pol.dyn_answers[0] == "gen(p1)"
    assert int(np.asarray(pol.dyn.written_at)[0]) == 2
    assert not bool(np.asarray(pol.dyn.static_origin)[0])


def test_delayed_promotion_survives_subsequent_insert():
    """LRU regression: a slow judge's promotion must land LRU-warm.

    The old ``_promote`` stamped ``last_used`` with the task's enqueue
    time, so a promotion applied at t=3 for a task enqueued at t=1
    entered the tier as the LRU-coldest entry and was evicted by the
    very next insert. With the clock split (written_at = enq_t for LWW,
    last_used = live clock) it must survive. Fails on the old code.
    """
    tier, answers, texts = _static()
    judge = _GatedOracle()
    cfg = CacheConfig(tau_static=0.95, tau_dynamic=0.9, sigma_min=0.3,
                      capacity=3)
    # p1 is a paraphrase of static row 0 (grey); p2/p3/p4 are orthogonal
    # directions (plain misses that only churn the LRU clock)
    eye = np.eye(D, dtype=np.float32)
    emb = {"p1": _para(0, 1, 0.5), "p2": eye[4], "p3": eye[5],
           "p4": eye[6]}
    pol = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                       lambda p: f"gen({p})", judge, d=D, n_workers=1,
                       static_texts=texts)
    pol.serve("p1", {"cls": 0})   # t=1: miss insert slot0 + grey task
    pol.serve("p2", {"cls": 4})   # t=2: miss insert slot1
    pol.serve("p3", {"cls": 5})   # t=3: miss insert slot2 (tier full)
    judge.gate.set()              # the slow judge answers at t=3
    pol.pool.drain()
    assert pol.pool.stats.approved == 1
    # the promotion overwrote its own miss insert in slot0: LWW clock
    # keeps the enqueue time, LRU clock gets the live time
    assert bool(pol._static_origin_np[0])
    assert int(np.asarray(pol.dyn.written_at)[0]) == 1
    assert int(np.asarray(pol.dyn.last_used)[0]) == 3
    assert int(pol._last_used_np[0]) == 3

    pol.serve("p4", {"cls": 6})   # t=4: insert must evict p2, not p1
    assert bool(pol._static_origin_np[0]), \
        "delayed promotion was evicted by the next insert (LRU-cold)"
    assert pol.dyn_answers[0] == "curated-0"

    # and the promoted pointer still serves its query
    r = pol.serve("p1", {"cls": 0})
    pol.pool.stop()
    assert r.served_by == "dynamic" and r.static_origin
    assert r.answer == "curated-0"


def test_fresh_promotion_still_overwrites_its_own_insert():
    """The guard must not break the normal flow: a promotion whose
    enq_t equals the miss-insert's timestamp overwrites it in place."""
    tier, answers, texts = _static()
    judge = _GatedOracle()
    cfg = CacheConfig(tau_static=0.99, tau_dynamic=1.01, sigma_min=0.0,
                      capacity=4)
    pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                       lambda p: f"gen({p})", judge, d=D, n_workers=1,
                       static_texts=texts)
    pol.serve("p1", {"cls": 0})
    judge.gate.set()
    pol.pool.drain()
    pol.pool.stop()
    assert bool(pol._static_origin_np[0])
    assert pol.dyn_answers[0] == "curated-0"


# ---------------------------------------------------------------------------
# WAL append ordering: skipped promotions must not be journaled
# ---------------------------------------------------------------------------

def test_stale_promotion_not_journaled(tmp_path):
    """Regression: ``_promote`` used to append the WAL record BEFORE
    the dup/LWW decision, so a promotion skipped as stale still landed
    in the journal — and was re-replayed (and survived compaction)
    forever. The journal must hold exactly the promotions that applied.
    Fails on the old code (2 records instead of 1)."""
    from repro.core.promo_wal import PromotionWAL, read_wal

    tier, answers, texts = _static()
    path = str(tmp_path / "promo.wal")
    pol = KritesPolicy(CacheConfig(0.99, 0.99, capacity=4), tier,
                       answers, lambda p: _para(), lambda p: f"gen({p})",
                       OracleJudge(), d=D, static_texts=texts,
                       wal=PromotionWAL(path, fsync_every=1))
    v = _para()
    pol._promote({"v": v, "h_idx": 1, "enq_t": 10})     # applies
    pol._promote({"v": v, "h_idx": 0, "enq_t": 5})      # stale: skipped
    slot = int(np.argmax(pol._valid_np))
    assert pol.dyn_answers[slot] == "curated-1"          # LWW held
    recs, clean = read_wal(path)
    assert clean
    assert len(recs) == 1, \
        "a skipped-as-stale promotion landed in the WAL"
    assert int(recs[0]["h_idx"]) == 1
    # a genuinely newer promotion still journals (append-before-apply)
    pol._promote({"v": v, "h_idx": 0, "enq_t": 11})
    recs, clean = read_wal(path)
    assert clean and len(recs) == 2
    pol.wal.close()
    pol.pool.stop()


# ---------------------------------------------------------------------------
# configurable near-duplicate gate (CacheConfig.dup_threshold)
# ---------------------------------------------------------------------------

def test_dup_threshold_validation():
    with pytest.raises(ValueError):
        CacheConfig(0.9, 0.95, dup_threshold=0.93)   # < tau_dynamic
    with pytest.raises(ValueError):
        CacheConfig(0.9, 0.85, dup_threshold=1.5)    # outside (0, 1]
    CacheConfig(0.9, 0.95, dup_threshold=0.95)       # boundary is fine


def test_dup_threshold_non_default_matches_oracle():
    """Pin the lifted gate at a NON-default value: two promotion keys
    with similarity ~0.993 (above 0.98, below the old hardcoded 0.9999)
    must overwrite in place under ``dup_threshold=0.98`` and take two
    slots under the default — and the numpy oracle's ``_Dyn.upsert``
    must land field-identical state at the same gate."""
    import sys
    sys.path.insert(0, "tests")
    from ref_policy import _Dyn

    tier, answers, texts = _static()
    v1 = _para(0, 1, 0.3)
    v2 = v1 + 0.12 * np.eye(D, dtype=np.float32)[3]
    v2 = (v2 / np.linalg.norm(v2)).astype(np.float32)
    sim = float(v1 @ v2)
    assert 0.98 < sim < 0.9999

    def promote_pair(cfg):
        pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                           lambda p: f"gen({p})", OracleJudge(), d=D,
                           static_texts=texts)
        pol._promote({"v": v1, "h_idx": 0, "enq_t": 1})
        pol._promote({"v": v2, "h_idx": 1, "enq_t": 2})
        pol.pool.stop()
        return pol

    pol = promote_pair(CacheConfig(0.99, 0.95, capacity=4,
                                   dup_threshold=0.98))
    assert int(pol._valid_np.sum()) == 1, \
        "sim above dup_threshold must overwrite in place"
    pol_def = promote_pair(CacheConfig(0.99, 0.95, capacity=4))
    assert int(pol_def._valid_np.sum()) == 2, \
        "sim below the default 0.9999 gate must take a fresh slot"

    # numpy-oracle field identity at the non-default gate
    ref = _Dyn.make(4, D)
    ref.upsert(v1, 0, 0, now=0, enq=1, dup_sim=0.98)
    ref.upsert(v2, 1, 1, now=0, enq=2, dup_sim=0.98)
    assert np.array_equal(ref.valid, pol._valid_np)
    assert np.array_equal(ref.emb, np.asarray(pol.dyn.emb))
    assert np.array_equal(ref.cls, np.asarray(pol.dyn.cls))
    assert np.array_equal(ref.answer_ref, np.asarray(pol.dyn.answer_ref))
    assert np.array_equal(ref.static_origin,
                          np.asarray(pol.dyn.static_origin))
    assert np.array_equal(ref.written_at, np.asarray(pol.dyn.written_at))
    assert np.array_equal(ref.last_used, np.asarray(pol.dyn.last_used))


# ---------------------------------------------------------------------------
# judge payload fidelity
# ---------------------------------------------------------------------------

def _recording_judge(seen):
    def judge(q_cls, h_cls, q_text="", h_text="", answer=""):
        seen.append(dict(q_cls=q_cls, h_cls=h_cls, q_text=q_text,
                         h_text=h_text, answer=answer))
        return int(q_cls) == int(h_cls)
    return judge


def test_grey_payload_carries_real_texts_scalar_and_batch():
    tier, answers, texts = _static()
    seen: list = []
    cfg = CacheConfig(tau_static=0.99, tau_dynamic=0.99, sigma_min=0.0,
                      capacity=8)
    # two distinct paraphrases of static row 0, far enough apart that
    # the second misses the first's promoted entry and is judged too
    emb = {"scalar prompt": _para(0, 1), "batched prompt": _para(0, 2)}
    pol = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                       lambda p: f"gen({p})", _recording_judge(seen),
                       d=D, n_workers=1, static_texts=texts,
                       backend_batch_fn=lambda ps: [f"gen({p})"
                                                    for p in ps])
    pol.serve("scalar prompt", {"cls": 0})
    pol.pool.drain()
    pol.serve_batch(["batched prompt"], [{"cls": 0}])
    pol.pool.drain()
    pol.pool.stop()
    assert len(seen) == 2
    for rec, q in zip(seen, ("scalar prompt", "batched prompt")):
        assert rec["q_text"] == q
        assert rec["h_text"] == texts[0]        # the static neighbor's
        assert rec["answer"] == answers[0]      # curated answer
        assert rec["q_text"] and rec["h_text"] and rec["answer"]


def test_grey_payload_nonempty_without_static_texts():
    """Legacy callers that pass no static_texts still get a non-empty
    h_text (the curated answer is the fallback proxy) and the real
    answer — never the old empty strings."""
    tier, answers, _ = _static()
    seen: list = []
    cfg = CacheConfig(0.99, 0.99, sigma_min=0.0, capacity=8)
    pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                       lambda p: f"gen({p})", _recording_judge(seen),
                       d=D, n_workers=1)
    pol.serve("q", {"cls": 0})
    pol.pool.drain()
    pol.pool.stop()
    assert len(seen) == 1
    assert seen[0]["answer"] == "curated-0"
    assert seen[0]["h_text"]        # non-empty fallback
    # and the strict oracle accepts the payload end to end
    OracleJudge(require_texts=True)(0, 0, **{
        k: seen[0][k] for k in ("q_text", "h_text", "answer")})


def test_oracle_judge_require_texts_rejects_empty_payload():
    with pytest.raises(ValueError):
        OracleJudge(require_texts=True)(0, 0, q_text="q", h_text="",
                                        answer="a")
    assert OracleJudge(require_texts=True)(1, 1, q_text="q", h_text="h",
                                           answer="a")


# ---------------------------------------------------------------------------
# judge-rate knob threading (cfg.judge_rate -> live pool)
# ---------------------------------------------------------------------------

def test_cfg_judge_rate_throttles_live_pool():
    tier, answers, texts = _static()
    cfg = CacheConfig(0.99, 0.99, sigma_min=0.0, capacity=8,
                      judge_rate=0.0)     # judging disabled by config
    pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                       lambda p: f"gen({p})", OracleJudge(), d=D,
                       static_texts=texts)
    for i in range(4):
        pol.serve(f"p{i}", {"cls": 0})
    pol.pool.drain()
    pol.pool.stop()
    s = pol.stats()
    assert s["judged"] == 0
    assert s["judge_rate_limited"] >= 1

    # an explicit wall-clock override still wins over cfg.judge_rate
    pol2 = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                        lambda p: f"gen({p})", OracleJudge(), d=D,
                        judge_rate_per_s=float("inf"),
                        static_texts=texts)
    pol2.serve("p0", {"cls": 0})
    pol2.pool.drain()
    pol2.pool.stop()
    assert pol2.stats()["judged"] == 1


def test_default_judge_rate_never_throttles():
    """cfg.judge_rate's default (1 per request) must keep the historic
    always-judge behavior: one grey submission per request can never be
    rate-limited."""
    tier, answers, texts = _static()
    cfg = CacheConfig(0.99, 0.99, sigma_min=0.0, capacity=64)
    pol = KritesPolicy(cfg, tier, answers, lambda p: _para(),
                       lambda p: f"gen({p})", OracleJudge(), d=D,
                       static_texts=texts)
    for i in range(20):
        pol.serve(f"p{i}", {"cls": 0})
    pol.pool.drain()
    pol.pool.stop()
    assert pol.stats()["judge_rate_limited"] == 0


# ---------------------------------------------------------------------------
# async _promote racing serve_batch: host mirrors == device tier
# ---------------------------------------------------------------------------

def _trace_setup(n=256, capacity=64):
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=4000,
                               n_classes=120)
    bench = build_benchmark(spec)
    emb = {f"q{i}": bench.eval_emb[i] for i in range(n)}
    return dict(
        prompts=[f"q{i}" for i in range(n)],
        metas=[{"cls": int(bench.eval_cls[i])} for i in range(n)],
        tier=make_static_tier(jnp.asarray(bench.static_emb),
                              jnp.asarray(bench.static_cls)),
        answers=[f"curated-{int(c)}" for c in bench.static_cls],
        texts=[f"canon-{i}" for i in range(len(bench.static_cls))],
        d=bench.static_emb.shape[1],
        embed_fn=lambda p: emb[p],
        embed_batch_fn=lambda ps: np.stack([emb[p] for p in ps]),
        backend_batch_fn=lambda ps: [f"gen({p})" for p in ps],
        n=n, capacity=capacity)


@pytest.mark.parametrize("dyn_index", [None, "segmented"])
def test_promote_racing_serve_batch_keeps_mirrors_identical(dyn_index):
    """Interleave real async promotions (slow judge, 2 workers) with
    batched serving under the batch-long dyn_lock hold; afterwards the
    host mirrors must be field-identical to the JAX tier."""
    s = _trace_setup()

    def slow_judge(q_cls, h_cls, **kw):
        time.sleep(0.002)       # let promotions straddle batches
        return int(q_cls) == int(h_cls)

    cfg = CacheConfig(tau_static=0.92, tau_dynamic=0.88, sigma_min=0.0,
                      capacity=s["capacity"])
    pol = KritesPolicy(cfg, s["tier"], s["answers"], s["embed_fn"],
                       lambda p: f"gen({p})", slow_judge, d=s["d"],
                       n_workers=2, static_texts=s["texts"],
                       dyn_index=dyn_index,
                       embed_batch_fn=s["embed_batch_fn"],
                       backend_batch_fn=s["backend_batch_fn"])
    for i in range(0, s["n"], 16):
        pol.serve_batch(s["prompts"][i:i + 16], s["metas"][i:i + 16])
    pol.pool.drain()
    pol.pool.stop()
    assert pol.pool.stats.approved > 0, "race never exercised promotes"
    assert np.array_equal(pol._valid_np, np.asarray(pol.dyn.valid))
    assert np.array_equal(pol._last_used_np,
                          np.asarray(pol.dyn.last_used))
    assert np.array_equal(pol._static_origin_np,
                          np.asarray(pol.dyn.static_origin))
    assert np.array_equal(pol._written_at_np,
                          np.asarray(pol.dyn.written_at))
    # and the policy still serves coherently afterwards, with mirrors
    # staying in lockstep through the extra batch
    r = pol.serve_batch([s["prompts"][0]], [s["metas"][0]])[0]
    assert r.answer is not None
    assert np.array_equal(pol._valid_np, np.asarray(pol.dyn.valid))
    assert np.array_equal(pol._last_used_np,
                          np.asarray(pol.dyn.last_used))
