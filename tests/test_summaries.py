"""Event-code accounting of `summarize` / `coverage_curve` on hand-built
SimResults, and the tune_threshold determinism contract: the sweep-based
tuner must return the identical t* a sequential per-config loop picks,
on both workload presets.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulate import (DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED, MISS,
                                 STATIC_HIT, SimResult, coverage_curve,
                                 simulate, summarize)
from repro.core.tiers import CacheConfig
from repro.data.synth_traces import (LMARENA_LIKE, SEARCH_LIKE,
                                     build_benchmark, tune_threshold)


def _mk_result(served_by, correct, static_origin, stale=None, **counters):
    c = dict(judge_calls=0, judge_approved=0, promotions=0,
             enq_dropped=0, ttl_evicted=0, bypassed=0)
    c.update(counters)
    if stale is None:
        stale = [False] * len(served_by)
    return SimResult(
        served_by=jnp.asarray(served_by, jnp.int8),
        correct=jnp.asarray(correct, bool),
        static_origin=jnp.asarray(static_origin, bool),
        stale=jnp.asarray(stale, bool),
        judge_calls=jnp.int32(c["judge_calls"]),
        judge_approved=jnp.int32(c["judge_approved"]),
        promotions=jnp.int32(c["promotions"]),
        enq_dropped=jnp.int32(c["enq_dropped"]),
        ttl_evicted=jnp.int32(c["ttl_evicted"]),
        bypassed=jnp.int32(c["bypassed"]),
    )


def test_summarize_event_code_accounting():
    # 8 requests: 2 static, 1 dynamic, 2 promoted, 3 misses; one wrong
    # dynamic answer and one wrong promoted answer
    sb = [STATIC_HIT, MISS, DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED, MISS,
          STATIC_HIT, DYN_HIT_PROMOTED, MISS]
    correct = [True, True, False, True, True, True, False, True]
    so = [True, False, False, True, False, True, True, False]
    res = _mk_result(sb, correct, so, judge_calls=5, judge_approved=3,
                     promotions=2, enq_dropped=1)
    s = summarize(res)
    assert s["requests"] == 8
    assert s["static_hit_rate"] == pytest.approx(2 / 8)
    assert s["dyn_hit_rate"] == pytest.approx(3 / 8)
    assert s["promoted_hit_rate"] == pytest.approx(2 / 8)
    assert s["total_hit_rate"] == pytest.approx(5 / 8)
    assert s["static_origin_rate"] == pytest.approx(4 / 8)
    # errors only count served-from-cache wrong answers, never misses
    assert s["error_rate"] == pytest.approx(2 / 8)
    assert s["judge_calls"] == 5
    assert s["judge_approved"] == 3
    assert s["promotions"] == 2
    assert s["enq_dropped"] == 1


def test_summarize_all_miss_zero_rates():
    res = _mk_result([MISS] * 4, [True] * 4, [False] * 4)
    s = summarize(res)
    assert s["total_hit_rate"] == 0.0
    assert s["error_rate"] == 0.0
    assert s["static_origin_rate"] == 0.0


def test_summarize_miss_never_counts_as_error():
    # wrong "correct" flags on misses must not contribute to error_rate
    res = _mk_result([MISS, MISS], [False, False], [False, False])
    assert summarize(res)["error_rate"] == 0.0


def test_coverage_curve_cumulative_fraction():
    n = 10
    so = [True, False, True, True, False, False, False, True, False,
          False]
    res = _mk_result([STATIC_HIT if x else MISS for x in so],
                     [True] * n, so)
    pts, cum = coverage_curve(res, n_points=n)
    assert pts.shape == (n,) and cum.shape == (n,)
    expect = np.cumsum(so) / (np.arange(n) + 1)
    np.testing.assert_allclose(np.asarray(cum), expect, rtol=1e-6)
    assert int(pts[0]) == 0 and int(pts[-1]) == n - 1


def test_coverage_curve_endpoint_equals_static_origin_rate():
    rng = np.random.default_rng(0)
    so = rng.random(333) < 0.3
    res = _mk_result([STATIC_HIT if x else MISS for x in so],
                     [True] * 333, so)
    _, cum = coverage_curve(res, n_points=50)
    assert float(cum[-1]) == pytest.approx(so.mean(), rel=1e-5)


# ---------------------------------------------------------------------------
# tune_threshold: sweep rewrite must pick the identical t*
# ---------------------------------------------------------------------------

def _sequential_tune(bench, error_budget, grid, sample, capacity):
    """The pre-sweep reference tuner: one simulate per grid point with
    the identical selection rule (lowest t within budget maximizing
    total hit rate)."""
    emb = jnp.asarray(bench.eval_emb[:sample])
    cls = jnp.asarray(bench.eval_cls[:sample])
    s_emb = jnp.asarray(bench.static_emb)
    s_cls = jnp.asarray(bench.static_cls)
    best_t, best_hit = float(grid[-1]), -1.0
    for t in grid:
        cfg = CacheConfig(tau_static=float(t), tau_dynamic=float(t),
                          capacity=capacity)
        row = summarize(simulate(s_emb, s_cls, emb, cls, cfg,
                                 krites=False))
        if row["error_rate"] <= error_budget \
                and row["total_hit_rate"] > best_hit:
            best_hit = row["total_hit_rate"]
            best_t = float(t)
    return best_t


@pytest.mark.parametrize("preset", [LMARENA_LIKE, SEARCH_LIKE])
def test_tune_threshold_deterministic_vs_sequential(preset):
    spec = dataclasses.replace(preset, n_requests=6000,
                               n_classes=min(preset.n_classes, 900))
    bench = build_benchmark(spec)
    grid = np.arange(0.80, 0.95, 0.03)
    kw = dict(error_budget=0.02, grid=grid, sample=2500, capacity=256)
    t_sweep = tune_threshold(bench, **kw)
    t_seq = _sequential_tune(bench, **kw)
    assert t_sweep == t_seq
    assert t_sweep in [float(t) for t in grid]


def test_tune_threshold_repeatable():
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=4000,
                               n_classes=500)
    bench = build_benchmark(spec)
    grid = np.arange(0.82, 0.94, 0.04)
    a = tune_threshold(bench, grid=grid, sample=1500, capacity=128)
    b = tune_threshold(bench, grid=grid, sample=1500, capacity=128)
    assert a == b
