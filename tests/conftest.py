"""Suite-wide pytest config: a per-test timeout that works with or
without the ``pytest-timeout`` plugin.

CI runs the suite with ``--timeout=<seconds>`` (scripts/ci.sh) so a
single wedged test cannot hang the pipeline silently. When
``pytest-timeout`` is installed it owns that flag (and its
process-level enforcement). When it is not — this container image has
no network access to install it — a SIGALRM-based fallback defined here
enforces the same flag: the alarm fires in the main thread and fails
the test with a traceback. The fallback cannot interrupt a test stuck
in non-Python code (e.g. a wedged C extension holding the GIL), which
the real plugin's thread/process methods can — install pytest-timeout
where possible (it is in the ``test`` extra).
"""
from __future__ import annotations

import signal

import pytest

try:
    import pytest_timeout  # noqa: F401
    HAVE_PYTEST_TIMEOUT = True
except ModuleNotFoundError:
    HAVE_PYTEST_TIMEOUT = False


def pytest_addoption(parser):
    parser.addoption(
        "--run-slow", action="store_true", default=False,
        help="run tests marked @pytest.mark.slow (e.g. the full "
             "crash-injection matrix in test_crash_recovery.py); "
             "they are deselected by default to keep tier-1 fast")
    if not HAVE_PYTEST_TIMEOUT:
        parser.addoption(
            "--timeout", type=float, default=0,
            help="per-test timeout in seconds, 0 = disabled "
                 "(SIGALRM fallback; install pytest-timeout for "
                 "process-level enforcement)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow: pass --run-slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


if not HAVE_PYTEST_TIMEOUT:

    @pytest.hookimpl(wrapper=True)
    def pytest_runtest_call(item):
        timeout = item.config.getoption("--timeout")
        if not timeout or not hasattr(signal, "SIGALRM"):
            return (yield)

        def _on_alarm(signum, frame):
            pytest.fail(f"test exceeded --timeout={timeout:g}s "
                        "(SIGALRM fallback)", pytrace=True)

        old = signal.signal(signal.SIGALRM, _on_alarm)
        signal.setitimer(signal.ITIMER_REAL, timeout)
        try:
            return (yield)
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)
