"""Property-based invariants of the segmented dynamic index
(`index/segmented.py`, DESIGN.md §12), via the `_hypothesis_compat`
shim so they execute (deterministic examples) even without hypothesis:

- **lookup equivalence** — after ANY interleaving of upsert (write),
  evict (invalidate) and compact, a full-recall segmented lookup equals
  the flat masked scan slot-for-slot;
- **tombstones never resurrect** — an evicted or overwritten key stays
  unfindable through every later seal/merge;
- **conservation** — the index's live count always equals the model's,
  and every live slot is found at similarity ~1 by its own key.
"""
import numpy as np

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.core import tiers as T
from repro.index.segmented import SegmentedIndex

CAP, D = 32, 8

# an op is (kind, slot, seed): kind 0/1 = write, 2 = evict, 3 = compact
_OPS = st.lists(st.tuples(st.integers(0, 3), st.integers(0, CAP - 1),
                          st.integers(0, 2**31 - 1)),
                min_size=1, max_size=45)


def _vec(rng):
    v = rng.standard_normal(D).astype(np.float32)
    return v / np.linalg.norm(v)


def _apply(ops, tail_rows=4, compact_every=3):
    """Replay an op sequence through (tier, index, model dict)."""
    tier = T.make_dynamic_tier(CAP, D)
    idx = SegmentedIndex(CAP, D, tail_rows=tail_rows, nprobe=None,
                         n_candidates=4 * CAP, tail_candidates=tail_rows,
                         compact_every=compact_every)
    model = {}                       # slot -> vec (the live set)
    for t, (kind, slot, seed) in enumerate(ops, start=1):
        if kind <= 1:
            v = _vec(np.random.default_rng(seed))
            tier = T._write(tier, slot, jnp.asarray(v), jnp.int32(0),
                            jnp.int32(-1), jnp.asarray(False), t)
            idx.record_write(slot, v)
            model[slot] = v
        elif kind == 2:
            tier = tier._replace(valid=tier.valid.at[slot].set(False))
            idx.invalidate(slot)
            model.pop(slot, None)
        else:
            idx.compact()
    return tier, idx, model


def _assert_lookup_equal(tier, idx, q):
    sf, jf = T.dynamic_lookup_batch(tier, q)
    ss, js = T.dynamic_lookup_batch(tier, q, index=idx)
    assert np.array_equal(np.asarray(jf), np.asarray(js)), (jf, js)
    sf, ss = np.asarray(sf), np.asarray(ss)
    both_inf = np.isneginf(sf) & np.isneginf(ss)
    np.testing.assert_allclose(sf[~both_inf], ss[~both_inf],
                               rtol=0, atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(_OPS, st.integers(0, 2**31 - 1))
def test_prop_segmented_equals_flat_after_any_interleaving(ops, qseed):
    tier, idx, model = _apply(ops)
    rng = np.random.default_rng(qseed)
    q = rng.standard_normal((6, D)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    _assert_lookup_equal(tier, idx, jnp.asarray(q))
    assert idx.stats()["live"] == len(model) == int(tier.valid.sum())


@settings(max_examples=10, deadline=None)
@given(_OPS)
def test_prop_every_live_slot_findable_every_dead_slot_gone(ops):
    tier, idx, model = _apply(ops)
    # live keys: their own vector must come back as (their slot, ~1.0)
    for slot, v in model.items():
        s, j = T.dynamic_lookup(tier, jnp.asarray(v), index=idx)
        assert int(j) == slot
        assert float(s) > 0.999
    # probing with a dead key must agree with the flat masked scan
    # (the dead copy is tombstoned, not resurrected)
    dead = [(kind, slot, seed) for kind, slot, seed in ops if kind <= 1]
    for kind, slot, seed in dead[:10]:
        v = _vec(np.random.default_rng(seed))
        _assert_lookup_equal(tier, idx, jnp.asarray(v[None]))


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 30),
       st.integers(2, 6), st.integers(2, 4))
def test_prop_compaction_schedule_never_changes_results(seed, n_writes,
                                                       tail_rows,
                                                       compact_every):
    """The same write sequence through different tail/compaction
    schedules must serve identical (slot, score) answers — compaction
    timing is a performance knob, never a semantics knob."""
    rng = np.random.default_rng(seed)
    ops = [(0, int(rng.integers(0, CAP)), int(rng.integers(0, 2**31)))
           for _ in range(n_writes)]
    tier_a, idx_a, _ = _apply(ops, tail_rows=2, compact_every=2)
    tier_b, idx_b, _ = _apply(ops, tail_rows=tail_rows,
                              compact_every=compact_every)
    q = rng.standard_normal((5, D)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    q = jnp.asarray(q)
    sa, ja = T.dynamic_lookup_batch(tier_a, q, index=idx_a)
    sb, jb = T.dynamic_lookup_batch(tier_b, q, index=idx_b)
    assert np.array_equal(np.asarray(ja), np.asarray(jb))
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=0, atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 30))
def test_prop_rewrite_after_evict_resurrects_only_new_value(seed, churn):
    """evict(slot) then write(slot, new): lookups must see exactly the
    new value — never the pre-eviction one, whatever was sealed."""
    rng = np.random.default_rng(seed)
    ops = [(0, 5, seed)]                                   # old value
    ops += [(0, int(rng.integers(6, CAP)), int(rng.integers(0, 2**31)))
            for _ in range(churn)]                         # bury it
    ops += [(2, 5, 0), (0, 5, seed + 1)]                   # evict, new
    tier, idx, _model = _apply(ops)
    old, new = _vec(np.random.default_rng(seed)), \
        _vec(np.random.default_rng(seed + 1))
    s_new, j_new = T.dynamic_lookup(tier, jnp.asarray(new), index=idx)
    assert int(j_new) == 5 and float(s_new) > 0.999
    _assert_lookup_equal(tier, idx, jnp.asarray(old[None]))
