"""Back-compat contract of the verdict refactor (DESIGN.md §18).

The judge pipeline moved from promote-or-reject booleans to structured
``Verdict`` outcomes dispatched through an action registry. Every
pre-verdict program must keep working unchanged:

- a legacy ``bool``-returning judge callable injected into
  ``KritesPolicy`` produces serving decisions BIT-IDENTICAL to the
  Verdict-returning oracle over the same workload (agreement 1.0 on
  served_by / answer / static_origin / similarity), with its approvals
  and rejections mapped onto the new per-outcome counters;
- ``as_verdict`` wraps plain bools, passes Verdicts through, and
  ``bool(verdict)`` means "approved as-is" (REWRITE is falsy — the
  judge ruled the cached answer NOT servable verbatim);
- the per-outcome ``PoolStats`` fields exist and count — a regression
  guard that fails on the old binary API, where rejections vanished
  into ``judged - approved`` arithmetic.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiers as T
from repro.core.async_queue import PoolStats, VerifyAndPromotePool
from repro.core.judge import (APPROVE, REJECT, REWRITE, OracleJudge,
                              Verdict, as_verdict)
from repro.core.policy import KritesPolicy

D, S = 32, 8


def _pool(n, d, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(d, n)))
    return np.ascontiguousarray(q.T, np.float32)


P = _pool(32, D)
# grey query i: sim 0.8 to static row i%S (inside [sigma_min, tau_s)),
# orthogonal to every other grey query's fresh component
N_GREY = 12
GREY = {f"g{i}": (0.8 * P[i % S] + 0.6 * P[8 + i]).astype(np.float32)
        for i in range(N_GREY)}


def mk_policy(judge_fn):
    tier = T.StaticTier(emb=jnp.asarray(P[:S]),
                        cls=jnp.arange(S, dtype=jnp.int32),
                        answer_ref=jnp.arange(S, dtype=jnp.int32))
    cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=32)
    return KritesPolicy(cfg, tier, [f"a{i}" for i in range(S)],
                        embed_fn=lambda p: GREY[p],
                        backend_fn=lambda p: "gen(" + p + ")",
                        judge_fn=judge_fn, d=D, n_workers=2)


def _drive(pol):
    """Two phases over the grey workload: first-seen (all misses, every
    row a grey trigger; even rows carry the neighbor's class -> approve,
    odd rows a foreign class -> reject), then drain the pool and repeat
    every prompt (promoted keys now serve from the dynamic tier).
    Returns the full decision stream."""
    dec = []
    for i in range(N_GREY):
        cls = (i % S) if i % 2 == 0 else 99
        r = pol.serve(f"g{i}", meta={"cls": cls})
        dec.append((r.served_by, str(r.answer), bool(r.static_origin),
                    round(float(r.similarity), 6)))
    pol.pool.drain()
    for i in range(N_GREY):
        r = pol.serve(f"g{i}")
        dec.append((r.served_by, str(r.answer), bool(r.static_origin),
                    round(float(r.similarity), 6)))
    pol.pool.drain()
    return dec


def test_legacy_bool_judge_is_bit_identical():
    legacy = mk_policy(lambda q_cls, h_cls, **kw: q_cls == h_cls)
    verdict = mk_policy(OracleJudge())
    dec_l, dec_v = _drive(legacy), _drive(verdict)

    agreement = np.mean([a == b for a, b in zip(dec_l, dec_v)])
    assert agreement == 1.0, (
        f"legacy bool judge diverged from verdict judge "
        f"(agreement {agreement}): "
        f"{[(a, b) for a, b in zip(dec_l, dec_v) if a != b]}")
    # the workload exercised both outcomes end to end: approved keys
    # serve static-origin promoted entries on repeat, rejected keys
    # serve their plain write-back
    assert ("dynamic", "a0", True, 1.0) in dec_l
    assert ("dynamic", "gen(g1)", False, 1.0) in dec_l

    # counters mapped: the wrapped bools land on the same per-outcome
    # fields the structured judge fills
    sl, sv = legacy.stats(), verdict.stats()
    for key in ("judged", "approved", "rejected", "rewritten",
                "rewrite_failed", "rewrite_rate_limited"):
        assert sl[key] == sv[key], (key, sl[key], sv[key])
    assert sl["approved"] == N_GREY // 2
    # rejected keys leave no promoted pointer, so their repeat trigger
    # re-judges (the dedup gate only skips static-origin hits): each
    # odd row rejects twice — first-seen and repeat
    assert sl["rejected"] == N_GREY
    assert sl["rewritten"] == 0
    legacy.pool.stop()
    verdict.pool.stop()


def test_as_verdict_wraps_bools():
    assert as_verdict(True).outcome == APPROVE
    assert as_verdict(False).outcome == REJECT
    v = Verdict(REWRITE, text="t")
    assert as_verdict(v) is v
    # truthiness == "approved as-is": REWRITE must NOT read as approval
    assert bool(Verdict(APPROVE))
    assert not bool(Verdict(REJECT))
    assert not bool(Verdict(REWRITE, text="t"))
    with pytest.raises(ValueError):
        Verdict("maybe")


def test_pool_counts_rejections_fails_on_old_api():
    """Regression guard on the old binary API: PoolStats must carry the
    per-outcome fields, and a rejecting judge must increment
    ``rejected`` (the old pipeline only ever counted approvals)."""
    fields = {f.name for f in dataclasses.fields(PoolStats)}
    assert {"rejected", "rewritten", "rewrite_failed",
            "rewrite_rate_limited"} <= fields

    promoted = []
    pool = VerifyAndPromotePool(judge_fn=lambda p: p["ok"],
                                promote_fn=promoted.append,
                                n_workers=1)
    pool.submit(("k1",), {"ok": False})
    pool.submit(("k2",), {"ok": False})
    pool.submit(("k3",), {"ok": True})
    pool.drain()
    assert pool.stats.judged == 3
    assert pool.stats.approved == 1
    assert pool.stats.rejected == 2
    assert promoted == [{"ok": True}]
    pool.stop()


def test_rewrite_verdict_dispatches_promote_action():
    """A REWRITE verdict routes through the promote action (the payload
    carries the outcome) and counts on the ``rewritten`` counter — the
    action registry's default wiring."""
    landed = []
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: Verdict(REWRITE, text="tailored"),
        promote_fn=landed.append, n_workers=1)
    pool.submit(("k",), {"x": 1})
    pool.drain()
    assert pool.stats.rewritten == 1
    assert pool.stats.approved == 0
    assert landed == [{"x": 1}]
    pool.stop()
