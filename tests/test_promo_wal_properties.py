"""Property tests for the write-ahead promotion journal
(``core/promo_wal.py``, DESIGN.md §14), via the ``_hypothesis_compat``
shim (full hypothesis when installed, the deterministic fallback runner
otherwise).

The properties pinned here are the ones crash recovery rests on:

1. **frame round-trip** — encode/append/scan reproduces every record,
   in order, with bit-exact fp32 vectors (a decimal round-trip could
   move a key across the 0.9999 dedup threshold);
2. **prefix-crash safety** — a journal cut at ANY byte offset (torn
   append) or with any single byte corrupted still scans to a valid
   prefix of the original records, never raises, and reopening the WAL
   truncates the damage so subsequent appends produce a clean journal;
3. **replay idempotence** — replaying a journal N times into a policy
   leaves exactly the state of one replay;
4. **LWW interleaving** — randomized promotion sequences (shared keys,
   shuffled ``enq_t``) replay to the same final tier state as live
   application, and both agree with the independent numpy oracle
   (``ref_policy._Dyn.upsert``);
5. **compaction** — dropping the seq-prefix a snapshot covers keeps
   every snapshot ``wal_seq`` cursor valid and appends continuing the
   original seq numbering. The journal is the ADMITTED subsequence of
   the promotion stream (LWW-skipped promotions never journal), so the
   cursor arithmetic runs through the same admission rule.

Property tests manage their own per-example temp dirs (the shim's
fallback runner hides the wrapped signature, so pytest fixtures cannot
be injected into ``@given`` tests).
"""
from __future__ import annotations

import tempfile
from contextlib import contextmanager
from pathlib import Path

import numpy as np
from _hypothesis_compat import given, settings, st
from ref_policy import _Dyn

import jax.numpy as jnp

from repro.core import tiers as T
from repro.core.policy import KritesPolicy
from repro.core.promo_wal import (PromotionWAL, compact, decode_vector,
                                  encode_record, read_wal, replay_into,
                                  scan_wal)

D, S, CAP = 16, 8, 8


def _unit_pool(n: int, d: int = D, seed: int = 0) -> np.ndarray:
    """n well-separated unit vectors (pairwise sim far below the 0.9999
    dedup threshold), deterministic."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(d, n)))
    return np.ascontiguousarray(q.T, np.float32)


POOL = _unit_pool(S)
STATIC = T.StaticTier(emb=jnp.asarray(_unit_pool(S, seed=9)),
                      cls=jnp.arange(S, dtype=jnp.int32),
                      answer_ref=jnp.arange(S, dtype=jnp.int32))


@contextmanager
def _wal_path():
    with tempfile.TemporaryDirectory(prefix="pwal-test-") as tmp:
        yield Path(tmp) / "w.wal"


def _policy(wal=None) -> KritesPolicy:
    cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=CAP)
    return KritesPolicy(cfg, STATIC, [f"a{i}" for i in range(S)],
                        embed_fn=lambda p: np.zeros(D, np.float32),
                        backend_fn=lambda p: "b",
                        judge_fn=lambda **kw: True, d=D,
                        n_workers=0, wal=wal)


def _payloads(ops):
    """(key_id, h_idx, enq_t) triples -> _promote payloads over POOL."""
    return [{"v": POOL[k], "h_idx": h, "enq_t": t} for k, h, t in ops]


def _admitted(ops):
    """Indices of the ops the WAL admits. ``_promote`` journals only
    promotions that actually apply: a record whose key already holds a
    strictly newer ``enq_t`` is LWW-skipped — no tier write, no WAL
    record — so the journal is a subsequence of the op stream. POOL
    keys are orthonormal (dedup is exact-match) and CAP covers every
    distinct key, so per-key max-enq_t bookkeeping models admission
    exactly."""
    latest: dict = {}
    out = []
    for i, (k, h, t) in enumerate(ops):
        if k in latest and latest[k] > t:
            continue
        latest[k] = t
        out.append(i)
    return out


def _state(pol: KritesPolicy) -> tuple:
    return (np.asarray(pol.dyn.emb).tobytes(),
            pol._valid_np.tolist(), pol._written_at_np.tolist(),
            pol._last_used_np.tolist(), pol._static_origin_np.tolist(),
            np.asarray(pol.dyn.cls).tolist(),
            np.asarray(pol.dyn.answer_ref).tolist(),
            list(pol.dyn_answers))


# an op stream: which pool vector (keys repeat -> dedup/LWW paths),
# which static neighbor, and a shuffled logical enqueue time
OPS = st.lists(st.tuples(st.integers(0, S - 1), st.integers(0, S - 1),
                         st.integers(1, 30)), min_size=1, max_size=24)


# ---------------------------------------------------------------------------
# 1. frame round-trip
# ---------------------------------------------------------------------------

def test_vector_roundtrip_bit_exact():
    rng = np.random.default_rng(1)
    for _ in range(50):
        v = rng.normal(size=D).astype(np.float32) * \
            np.float32(rng.choice([1e-20, 1.0, 1e20]))
        rec = encode_record(v, 0, 1)
        assert decode_vector(rec).tobytes() == v.tobytes()


@given(OPS)
@settings(max_examples=25)
def test_append_scan_roundtrip(ops):
    with _wal_path() as path:
        with PromotionWAL(path, fsync_every=4) as wal:
            for k, h, t in ops:
                wal.append(encode_record(POOL[k], h, t))
        records, clean = read_wal(path)
        assert clean and len(records) == len(ops)
        for i, (rec, (k, h, t)) in enumerate(zip(records, ops)):
            assert rec["seq"] == i + 1
            assert (rec["h_idx"], rec["enq_t"]) == (h, t)
            assert decode_vector(rec).tobytes() == POOL[k].tobytes()


# ---------------------------------------------------------------------------
# 2. prefix-crash safety
# ---------------------------------------------------------------------------

@given(OPS, st.floats(0.0, 1.0))
@settings(max_examples=25)
def test_any_truncation_scans_to_valid_prefix(ops, cut_frac):
    with _wal_path() as path:
        with PromotionWAL(path, fsync_every=1) as wal:
            for k, h, t in ops:
                wal.append(encode_record(POOL[k], h, t))
        data = path.read_bytes()
        cut = int(len(data) * cut_frac)
        path.write_bytes(data[:cut])              # torn tail
        records, clean, valid_bytes = scan_wal(path)
        assert len(records) <= len(ops)
        for i, rec in enumerate(records):         # a prefix, in order
            assert rec["seq"] == i + 1
        assert valid_bytes <= cut
        # reopening truncates the damage; appends continue the seq
        with PromotionWAL(path, fsync_every=1) as wal:
            assert wal.seq == len(records)
            wal.append(encode_record(POOL[0], 0, 99))
        records2, clean2 = read_wal(path)
        assert clean2 and len(records2) == len(records) + 1
        assert records2[-1]["seq"] == len(records) + 1


@given(OPS, st.floats(0.0, 1.0))
@settings(max_examples=25)
def test_single_byte_corruption_never_raises(ops, pos_frac):
    with _wal_path() as path:
        with PromotionWAL(path, fsync_every=1) as wal:
            for k, h, t in ops:
                wal.append(encode_record(POOL[k], h, t))
        data = bytearray(path.read_bytes())
        pos = min(int(len(data) * pos_frac), len(data) - 1)
        data[pos] ^= 0xFF
        path.write_bytes(bytes(data))
        records, clean, _ = scan_wal(path)        # must not raise
        if pos >= 8:                              # header intact
            for i, rec in enumerate(records):
                assert rec["seq"] == i + 1
        else:
            assert records == [] and not clean


# ---------------------------------------------------------------------------
# 3. + 4. replay idempotence and LWW, vs live state and the numpy oracle
# ---------------------------------------------------------------------------

@given(OPS, st.integers(1, 3))
@settings(max_examples=15)
def test_replay_idempotent_and_matches_live(ops, n_replays):
    with _wal_path() as path:
        live = _policy(wal=PromotionWAL(path, fsync_every=1))
        for p in _payloads(ops):
            live._promote(p)
        live.wal.close()
        want = _state(live)

        fresh = _policy()
        for _ in range(n_replays):
            rep = replay_into(fresh, path)
            assert rep["clean"]
        assert _state(fresh) == want, \
            f"{n_replays} replays != live application"


@given(OPS)
@settings(max_examples=15)
def test_lww_interleaving_matches_numpy_oracle(ops):
    """Same op stream through three implementations — live policy,
    journal replay, and the independent ``ref_policy._Dyn`` upsert loop
    — must agree on every tier field (valid/written_at/emb/slots)."""
    with _wal_path() as path:
        live = _policy(wal=PromotionWAL(path, fsync_every=1))
        oracle = _Dyn.make(CAP, D)
        ref_np = np.asarray(STATIC.answer_ref)
        cls_np = np.asarray(STATIC.cls)
        for k, h, t in ops:
            live._promote({"v": POOL[k], "h_idx": h, "enq_t": t})
            # the policy never serves here, so its live clock stays 0:
            # apply time (LRU clock) 0, enqueue time (LWW clock) t
            oracle.upsert(POOL[k], int(cls_np[h]), int(ref_np[h]), 0,
                          enq=t)
        live.wal.close()

        replayed = _policy()
        replay_into(replayed, path)

        for pol in (live, replayed):
            assert pol._valid_np.tolist() == oracle.valid.tolist()
            assert pol._written_at_np.tolist() == \
                oracle.written_at.tolist()
            assert pol._last_used_np.tolist() == \
                oracle.last_used.tolist()
            assert np.array_equal(
                np.asarray(pol.dyn.emb)[pol._valid_np],
                oracle.emb[oracle.valid])
            assert np.asarray(pol.dyn.cls).tolist() == \
                oracle.cls.tolist()
            assert np.asarray(pol.dyn.answer_ref).tolist() == \
                oracle.answer_ref.tolist()


def test_stale_replay_cannot_clobber_newer_write(tmp_path):
    """Direct LWW pin: a journaled promotion older than the entry now
    holding its key must be a no-op on replay (the crash-recovery twin
    of test_promotion_and_payload.test_stale_promote_skips...)."""
    path = tmp_path / "w.wal"
    wal = PromotionWAL(path, fsync_every=1)
    wal.append(encode_record(POOL[0], 0, 5))     # journaled at t=5
    wal.close()

    pol = _policy()
    pol._promote({"v": POOL[0], "h_idx": 1, "enq_t": 10},
                 journal=False)                  # newer write, same key
    before = _state(pol)
    rep = replay_into(pol, path)
    assert rep["replayed"] == 1
    assert _state(pol) == before, \
        "stale journal record clobbered a newer write"
    slot = int(np.argmax(pol._valid_np))
    assert pol._written_at_np[slot] == 10


def test_equal_timestamp_replay_beats_miss_insert(tmp_path):
    """A promotion and a miss-insert of the same key at the same
    logical time: the promotion wins live (strict-> LWW guard), so it
    must also win on replay — recovery keeps the promoted provenance."""
    path = tmp_path / "w.wal"
    wal = PromotionWAL(path, fsync_every=1)
    wal.append(encode_record(POOL[2], 3, 7))
    wal.close()

    pol = _policy()
    with pol.dyn_lock:   # the miss-insert twin: same key, same t
        slot = pol._host_lru_slot()
        pol.dyn = pol._write_fn(pol.dyn, slot, jnp.asarray(POOL[2]),
                                jnp.int32(-1), jnp.int32(-1),
                                jnp.asarray(False), 7)
        pol._mirror_write(slot, 7, static_origin=False)
        pol.dyn_answers[slot] = "miss"
    replay_into(pol, path)
    assert bool(pol._static_origin_np[slot])
    assert pol.dyn_answers[slot] == "a3"


# ---------------------------------------------------------------------------
# 5. compaction
# ---------------------------------------------------------------------------

@given(OPS, st.floats(0.0, 1.0))
@settings(max_examples=15)
def test_compact_preserves_cursor_and_seq(ops, keep_frac):
    with _wal_path() as path:
        live = _policy(wal=PromotionWAL(path, fsync_every=1))
        for p in _payloads(ops):
            live._promote(p)
        live.wal.close()
        want = _state(live)
        adm = _admitted(ops)         # journal = admitted subsequence
        cursor = int(len(adm) * keep_frac)     # a snapshot's wal_seq

        # state-at-cursor + replay-of-tail must still reach `want`
        # whether or not the prefix has been compacted away
        kept = compact(path, keep_from_seq=cursor)
        assert kept == len(adm) - cursor
        recovered = _policy()
        # the snapshot at wal_seq=cursor held the state after the op
        # that produced journal record `cursor`; LWW-skipped ops in
        # between are state no-ops, so replaying the op prefix through
        # that point reconstructs it exactly
        n_at_cursor = adm[cursor - 1] + 1 if cursor else 0
        for p in _payloads(ops[:n_at_cursor]):
            recovered._promote(p, journal=False)
        rep = replay_into(recovered, path, skip=cursor)
        assert rep["skipped"] == 0 and rep["replayed"] == kept
        assert _state(recovered) == want

        # appends after compaction continue the original numbering
        with PromotionWAL(path, fsync_every=1) as wal:
            assert wal.seq == len(adm)
            assert wal.append(encode_record(POOL[0], 0, 50)) \
                == len(adm) + 1
