"""Differential test: the JAX simulator (single-config and sweep) must
match the pure-numpy reference loop (`ref_policy.py`) decision-for-
decision on a 2k-request benchmark trace — served_by, correct,
static_origin per request plus every counter — for baseline and Krites
across multiple configs (the DESIGN.md §10 equivalence contract).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from ref_policy import ref_simulate

from repro.core.simulate import (simulate, simulate_sweep, slice_config,
                                 sweep_from_configs)
from repro.core.tiers import CacheConfig
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark

N_REQ = 2000

# >= 3 configs, exercising thresholds, sigma_min, capacity, latency,
# rate limiting, and both policies
CONFIGS = [
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8), True),
    (CacheConfig(0.86, 0.90, sigma_min=0.5, capacity=64,
                 judge_latency=32, judge_rate=0.25), True),
    (CacheConfig(0.94, 0.88, sigma_min=0.7, capacity=256,
                 judge_latency=1), True),
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8), False),
]


@pytest.fixture(scope="module")
def trace():
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=N_REQ + 500,
                               n_classes=400, n_topics=16)
    b = build_benchmark(spec)
    return (b.static_emb, b.static_cls,
            b.eval_emb[:N_REQ], b.eval_cls[:N_REQ])


def _assert_matches(res, ref, label):
    for name, want in ref.items():
        got = np.asarray(getattr(res, name))
        assert np.array_equal(got, np.asarray(want)), (
            f"{label}: field {name} diverges from the numpy reference "
            f"({np.sum(got != np.asarray(want))} mismatches)"
            if got.shape else f"{label}: {name} {got} != {want}")


@pytest.mark.parametrize("idx", range(len(CONFIGS)))
def test_simulate_matches_reference(trace, idx):
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[idx]
    res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites)
    ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites)
    _assert_matches(res, ref, f"simulate cfg{idx}")


def test_sweep_matches_reference_per_config(trace):
    """One mixed-latency sweep dispatch (stepwise core) — every config's
    slice must equal the reference run."""
    s_emb, s_cls, q_emb, q_cls = trace
    sweep = sweep_from_configs([c for c, _ in CONFIGS],
                               [k for _, k in CONFIGS])
    res = simulate_sweep(jnp.asarray(s_emb), jnp.asarray(s_cls),
                         jnp.asarray(q_emb), jnp.asarray(q_cls), sweep)
    for i, (cfg, krites) in enumerate(CONFIGS):
        ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites)
        _assert_matches(slice_config(res, i), ref, f"sweep cfg{i}")


def test_uniform_latency_sweep_matches_reference(trace):
    """Uniform-latency sweep (blocked core) against the reference."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfgs = [dataclasses.replace(c, judge_latency=16) for c, _ in CONFIGS]
    krs = [k for _, k in CONFIGS]
    res = simulate_sweep(jnp.asarray(s_emb), jnp.asarray(s_cls),
                         jnp.asarray(q_emb), jnp.asarray(q_cls),
                         sweep_from_configs(cfgs, krs))
    for i, (cfg, krites) in enumerate(zip(cfgs, krs)):
        ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites)
        _assert_matches(slice_config(res, i), ref, f"ublocked cfg{i}")


@pytest.mark.parametrize("idx", range(len(CONFIGS)))
def test_segmented_reference_is_decision_identical_to_flat(trace, idx):
    """The oracle's own segmented dynamic-index path (tail + sealed
    segments + tombstones, `ref_policy._RefSegIndex`) must reproduce
    the flat reference field-for-field — so the numpy loop stays a
    decision-for-decision oracle for both dyn-index configs, and the
    JAX simulator keeps matching it transitively."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[idx]
    flat = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites)
    seg = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                       dyn_index="segmented")
    for name, want in flat.items():
        assert np.array_equal(np.asarray(seg[name]), np.asarray(want)), \
            f"segmented ref cfg{idx}: field {name} diverges from flat"


def test_simulate_matches_segmented_reference(trace):
    """Direct differential: the JAX simulator against the reference
    running in segmented mode (the structure churns — seals, merges,
    tombstones — while decisions must not move)."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[0]
    res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites)
    ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                       dyn_index="segmented")
    _assert_matches(res, ref, "simulate-vs-segmented-ref")


def _final_equal(a, b, label):
    for name, want in b["final"].items():
        got = a["final"][name]
        assert np.array_equal(got, want), (
            f"{label}: recovered tier field {name} diverges "
            f"({np.sum(got != want)} rows)")


@pytest.mark.parametrize("idx", range(3))
def test_crash_replay_recovers_reference_state(trace, idx):
    """Recovery oracle (DESIGN.md §14): crash the end-of-trace promotion
    burst at every point — after 0, 1, ..., all journaled upserts — and
    replay the full journal; the recovered tier must be field-identical
    to the uninterrupted run at every crash point. This is the numpy
    statement of the theorem the live fault-injection tests
    (test_crash_recovery.py) check on the real WAL + policy."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[idx]
    base = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                        drain=True)
    assert base["journal_len"] > 0, "trace produced no drained backlog"
    for k in range(base["journal_len"] + 1):
        crashed = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                               drain=True, crash_after=k)
        _final_equal(crashed, base, f"cfg{idx} crash_after={k}")


def test_replay_is_idempotent_reference(trace):
    """N replays of the full journal == 1 application (no crash): the
    oracle-level statement of WAL replay idempotence."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[0]
    base = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                        drain=True)
    for n in (1, 3):
        again = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                             drain=True, extra_replays=n)
        _final_equal(again, base, f"extra_replays={n}")


def test_drain_does_not_change_trace_decisions(trace):
    """The drain phase runs after the last request: per-request fields
    must be untouched relative to the non-drain run (guards the
    existing simulator differentials against the new path)."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[0]
    plain = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites)
    drained = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                           drain=True)
    for name, want in plain.items():
        if name in ("judge_calls", "judge_approved", "promotions"):
            continue   # drain legitimately grows the judge counters
        assert np.array_equal(np.asarray(drained[name]),
                              np.asarray(want)), name
    assert drained["judge_calls"] >= plain["judge_calls"]


# ---------------------------------------------------------------------------
# freshness subsystem (DESIGN.md §16): L1 front, volatile bypass, TTLs,
# drift staleness — the simulator must track the reference through all
# of it, field-identically (including the new stale / ttl_evicted /
# bypassed outputs)
# ---------------------------------------------------------------------------

DRIFT = 128

FRESH_CONFIGS = [
    # L1 alone: pure exact-match front, no expiry anywhere
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8, l1=True), True),
    # the full subsystem: L1 + volatile bypass + split TTLs (the stable
    # TTL short enough that entries expire before LRU churn reclaims
    # them — with the bypass on the tier sees only stable writes)
    (CacheConfig(0.90, 0.90, sigma_min=0.5, capacity=256,
                 judge_latency=8, l1=True, volatile_bypass=True,
                 ttl_volatile=40, ttl_stable=90), True),
    # TTLs without the L1 (expiry + promotion-verdict TTL only)
    (CacheConfig(0.86, 0.90, sigma_min=0.5, capacity=64,
                 judge_latency=32, judge_rate=0.25,
                 ttl_volatile=64), True),
    # baseline policy with L1 + TTLs (no promotions at all)
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8, l1=True, ttl_volatile=48,
                 ttl_stable=200), False),
]


@pytest.fixture(scope="module")
def fresh_trace():
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=N_REQ + 500,
                               n_classes=400, n_topics=16,
                               volatile_frac=0.3)
    b = build_benchmark(spec)
    return (b.static_emb, b.static_cls, b.eval_emb[:N_REQ],
            b.eval_cls[:N_REQ], b.eval_key[:N_REQ],
            b.eval_volatile[:N_REQ])


@pytest.mark.parametrize("idx", range(len(FRESH_CONFIGS)))
def test_freshness_simulate_matches_reference(fresh_trace, idx):
    """Blocked core (uniform latency) with every freshness feature the
    config turns on, against the reference — per-request fields plus
    the stale/ttl_evicted/bypassed accounting."""
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    cfg, krites = FRESH_CONFIGS[idx]
    res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites, volatile=vol, key_id=key,
                   drift_every=DRIFT)
    ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                       volatile=vol, key_id=key, drift_every=DRIFT)
    _assert_matches(res, ref, f"fresh cfg{idx}")
    if cfg.l1:      # the config must actually exercise the front
        assert (ref["served_by"] == 4).sum() > 0, "no L1 hits produced"
    if cfg.ttl_volatile or cfg.ttl_stable:
        assert ref["ttl_evicted"] > 0, "no TTL evictions produced"
    if cfg.volatile_bypass:
        assert ref["bypassed"] > 0
        # with the bypass on, volatile queries never touch a cache, so
        # no serve can be stale — the subsystem's headline guarantee
        assert ref["stale"].sum() == 0
    else:
        assert ref["stale"].sum() > 0, "trace produced no stale serves"


def test_freshness_sweep_stepwise_matches_reference(fresh_trace):
    """Mixed-latency sweep (stepwise core) over the freshness configs:
    every config's slice must equal the reference run."""
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    sweep = sweep_from_configs([c for c, _ in FRESH_CONFIGS],
                               [k for _, k in FRESH_CONFIGS])
    res = simulate_sweep(jnp.asarray(s_emb), jnp.asarray(s_cls),
                         jnp.asarray(q_emb), jnp.asarray(q_cls), sweep,
                         volatile=vol, key_id=key, drift_every=DRIFT)
    for i, (cfg, krites) in enumerate(FRESH_CONFIGS):
        ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                           volatile=vol, key_id=key, drift_every=DRIFT)
        _assert_matches(slice_config(res, i), ref, f"fresh sweep cfg{i}")


def test_freshness_off_is_bit_identical_to_plain(fresh_trace):
    """Passing the volatile/key arrays with every freshness feature off
    must reproduce the plain run bit-for-bit (the feature-off gate)."""
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    cfg, krites = CONFIGS[0]
    plain = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                     jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                     krites=krites)
    off = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites, volatile=vol, key_id=key)
    for name in ("served_by", "correct", "static_origin", "stale"):
        assert np.array_equal(np.asarray(getattr(off, name)),
                              np.asarray(getattr(plain, name))), name
    assert int(off.ttl_evicted) == 0 and int(off.bypassed) == 0


# ---------------------------------------------------------------------------
# rewrite verdicts (DESIGN.md §18): the three-outcome pipeline — the
# simulator must track the reference through REWRITE promotions, the
# rewrite token bucket, and REWRITTEN_HIT serving, field-identically
# ---------------------------------------------------------------------------

RW_CONFIGS = [
    # rewrite at full rate
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8, rewrite=True), True),
    # rate-limited rewrites (the bucket must drop some)
    (CacheConfig(0.86, 0.90, sigma_min=0.5, capacity=64,
                 judge_latency=32, judge_rate=0.5, rewrite=True,
                 rewrite_rate=0.02), True),
    # rewrite atop the freshness subsystem (L1 + TTL interplay)
    (CacheConfig(0.90, 0.90, sigma_min=0.5, capacity=256,
                 judge_latency=8, l1=True, ttl_stable=90,
                 rewrite=True), True),
    # rewrite off in the same sweep: the mixed-gate case
    (CacheConfig(0.90, 0.90, sigma_min=0.0, capacity=128,
                 judge_latency=8), True),
]


@pytest.fixture(scope="module")
def rewritable_mask():
    rng = np.random.default_rng(11)
    return rng.random(N_REQ) < 0.6


@pytest.mark.parametrize("idx", range(len(RW_CONFIGS)))
def test_rewrite_simulate_matches_reference(fresh_trace, rewritable_mask,
                                            idx):
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    cfg, krites = RW_CONFIGS[idx]
    res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites, key_id=key,
                   rewritable=jnp.asarray(rewritable_mask))
    ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                       key_id=key, rewritable=rewritable_mask)
    _assert_matches(res, ref, f"rewrite cfg{idx}")
    if idx == 0:
        assert ref["rewrites"] > 0, "trace produced no rewrites"
        assert (ref["served_by"] == 5).sum() > 0, \
            "trace produced no rewritten serves"
    if idx == 1:
        assert ref["rewrite_dropped"] > 0, "rate limit never engaged"
    if idx == 3:
        assert ref["rewrites"] == 0 \
            and (ref["served_by"] == 5).sum() == 0


def test_rewrite_sweep_stepwise_matches_reference(fresh_trace,
                                                  rewritable_mask):
    """Mixed-latency sweep (stepwise core) over the rewrite configs —
    including a rewrite-off config sharing the dispatch."""
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    sweep = sweep_from_configs([c for c, _ in RW_CONFIGS],
                               [k for _, k in RW_CONFIGS])
    res = simulate_sweep(jnp.asarray(s_emb), jnp.asarray(s_cls),
                         jnp.asarray(q_emb), jnp.asarray(q_cls), sweep,
                         key_id=key,
                         rewritable=jnp.asarray(rewritable_mask))
    for i, (cfg, krites) in enumerate(RW_CONFIGS):
        ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                           key_id=key, rewritable=rewritable_mask)
        _assert_matches(slice_config(res, i), ref, f"rw sweep cfg{i}")


def test_rewrite_sweep_blocked_matches_reference(fresh_trace,
                                                 rewritable_mask):
    """Uniform-latency sweep (blocked core, three-band dqi encoding)
    over the rewrite configs against the reference."""
    s_emb, s_cls, q_emb, q_cls, key, vol = fresh_trace
    cfgs = [dataclasses.replace(c, judge_latency=16)
            for c, _ in RW_CONFIGS]
    krs = [k for _, k in RW_CONFIGS]
    res = simulate_sweep(jnp.asarray(s_emb), jnp.asarray(s_cls),
                         jnp.asarray(q_emb), jnp.asarray(q_cls),
                         sweep_from_configs(cfgs, krs), key_id=key,
                         rewritable=jnp.asarray(rewritable_mask))
    for i, (cfg, krites) in enumerate(zip(cfgs, krs)):
        ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                           key_id=key, rewritable=rewritable_mask)
        _assert_matches(slice_config(res, i), ref, f"rw blocked cfg{i}")


def test_rewrite_off_is_bit_identical_to_plain(trace, rewritable_mask):
    """Passing a rewritable mask with cfg.rewrite off must reproduce the
    plain run bit-for-bit (the feature-off gate)."""
    s_emb, s_cls, q_emb, q_cls = trace
    cfg, krites = CONFIGS[0]
    plain = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                     jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                     krites=krites)
    off = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites,
                   rewritable=jnp.asarray(rewritable_mask))
    for name in ("served_by", "correct", "static_origin", "stale"):
        assert np.array_equal(np.asarray(getattr(off, name)),
                              np.asarray(getattr(plain, name))), name
    assert int(off.rewrites) == 0 and int(off.rewrite_dropped) == 0


def test_noisy_judge_flips_match_reference(trace):
    """judge_flip (noisy-verifier false approvals) follows the same
    delayed-payload path — must match the reference end to end."""
    s_emb, s_cls, q_emb, q_cls = trace
    rng = np.random.default_rng(3)
    flip = rng.random(N_REQ) < 0.1
    cfg, krites = CONFIGS[1]
    res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                   jnp.asarray(q_emb), jnp.asarray(q_cls), cfg,
                   krites=krites, judge_flip=jnp.asarray(flip))
    ref = ref_simulate(s_emb, s_cls, q_emb, q_cls, cfg, krites,
                       judge_flip=flip)
    _assert_matches(res, ref, "flip")
    assert ref["judge_approved"] > 0
