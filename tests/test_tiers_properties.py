"""Property-based invariants of the dynamic tier (`core/tiers.py`):
upsert idempotence under duplicate dispatch, the written_at
last-writer-wins guard, LRU eviction order under insert, and touch
monotonicity. Runs via the `_hypothesis_compat` shim, so the properties
execute (deterministic examples) even without hypothesis installed.
"""
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import tiers as T


def _rand_tier(rng, cap, d, fill):
    """A tier with `fill` random valid entries written at times 0..fill-1."""
    tier = T.make_dynamic_tier(cap, d)
    for i in range(fill):
        v = rng.standard_normal(d).astype(np.float32)
        v /= np.linalg.norm(v)
        tier = T.insert(tier, jnp.asarray(v), cls=i, answer_ref=i, now=i)
    return tier


def _tiers_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in zip(a, b))


# ---------------------------------------------------------------------------
# upsert idempotence: duplicate VerifyAndPromote dispatch is harmless
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 12), st.integers(0, 50))
def test_prop_upsert_idempotent_under_duplicate_dispatch(seed, fill, now):
    rng = np.random.default_rng(seed)
    cap, d = 16, 8
    tier = _rand_tier(rng, cap, d, fill)
    q = rng.standard_normal(d).astype(np.float32)
    q /= np.linalg.norm(q)
    once = T.upsert(tier, jnp.asarray(q), cls=99, answer_ref=7,
                    now=fill + now, static_origin=True)
    twice = T.upsert(once, jnp.asarray(q), cls=99, answer_ref=7,
                     now=fill + now, static_origin=True)
    # re-delivering the same promotion changes nothing: same slot is
    # dedup-overwritten with identical values
    assert _tiers_equal(once, twice)
    assert int(once.valid.sum()) == int(twice.valid.sum())


# ---------------------------------------------------------------------------
# last-writer-wins guard
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 40), st.integers(0, 40))
def test_prop_upsert_lww_stale_never_overwrites_newer(seed, t_write,
                                                      t_promo):
    rng = np.random.default_rng(seed)
    d = 8
    tier = T.make_dynamic_tier(8, d)
    q = rng.standard_normal(d).astype(np.float32)
    q /= np.linalg.norm(q)
    tier = T.insert(tier, jnp.asarray(q), cls=5, answer_ref=-1,
                    now=t_write)
    after = T.upsert(tier, jnp.asarray(q), cls=5, answer_ref=3,
                     now=t_promo, static_origin=True)
    _, j = T.dynamic_lookup(after, jnp.asarray(q))
    if t_promo < t_write:
        # stale judgment: the newer entry must survive untouched
        assert _tiers_equal(tier, after)
    else:
        assert bool(after.static_origin[j])
        assert int(after.answer_ref[j]) == 3
        assert int(after.written_at[j]) == t_promo


# ---------------------------------------------------------------------------
# LRU eviction order under insert
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 10), st.integers(1, 12))
def test_prop_lru_eviction_order_matches_model(seed, cap, extra):
    """Insert cap+extra distinct orthogonal-ish keys at increasing times:
    the tier must always hold the `cap` most recent, and each eviction
    removes the least recently used — checked against a dict model."""
    rng = np.random.default_rng(seed)
    d = 32
    tier = T.make_dynamic_tier(cap, d)
    model = {}          # insertion id -> last_used
    vecs = {}
    for i in range(cap + extra):
        v = rng.standard_normal(d).astype(np.float32)
        v /= np.linalg.norm(v)
        vecs[i] = v
        tier = T.insert(tier, jnp.asarray(v), cls=i, answer_ref=i, now=i)
        if len(model) == cap:
            lru = min(model, key=lambda k: (model[k], k))
            del model[lru]
        model[i] = i
        assert int(tier.valid.sum()) == len(model)
    # surviving set is exactly the model's: every survivor is findable at
    # similarity ~1, every evictee is gone
    for i, v in vecs.items():
        s, _ = T.dynamic_lookup(tier, jnp.asarray(v))
        if i in model:
            assert float(s) > 0.999, f"entry {i} should have survived"
        else:
            assert float(s) < 0.999, f"entry {i} should have been evicted"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
def test_prop_touch_rescues_from_eviction(seed, cap):
    """A touched (recently used) entry outlives an untouched older one."""
    rng = np.random.default_rng(seed)
    d = 16
    tier = _rand_tier(rng, cap, d, cap)          # full tier, times 0..cap-1
    # touch the oldest entry (slot of time 0) far in the future
    j0 = int(jnp.argmin(jnp.where(tier.valid, tier.last_used, T.BIG)))
    tier = T.touch(tier, j0, now=100)
    v = rng.standard_normal(d).astype(np.float32)
    v /= np.linalg.norm(v)
    tier = T.insert(tier, jnp.asarray(v), cls=77, answer_ref=0, now=101)
    # the touched row survived; the new LRU (originally time 1) was evicted
    assert bool(tier.valid[j0])
    assert int(tier.cls[j0]) != 77 or cap == 1
    assert not bool((tier.last_used == 1).any())


# ---------------------------------------------------------------------------
# touch monotonicity
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 16),
       st.lists(st.integers(0, 15), min_size=1, max_size=20))
def test_prop_touch_monotone_and_isolated(seed, fill, slots):
    """Touching with non-decreasing clocks never decreases last_used,
    touches exactly one row, and leaves every other field untouched."""
    rng = np.random.default_rng(seed)
    cap, d = 16, 8
    tier = _rand_tier(rng, cap, d, fill)
    now = int(tier.last_used.max())
    for s in slots:
        s = s % cap
        now += int(rng.integers(0, 5))
        before = tier
        tier = T.touch(tier, s, now=now)
        assert int(tier.last_used[s]) == now
        assert int(tier.last_used[s]) >= int(before.last_used[s])
        # only last_used changed, and only at slot s
        mask = jnp.arange(cap) != s
        assert bool(jnp.array_equal(tier.last_used[mask],
                                    before.last_used[mask]))
        for f in ("emb", "cls", "answer_ref", "static_origin", "valid",
                  "written_at"):
            assert bool(jnp.array_equal(getattr(tier, f),
                                        getattr(before, f)))
