"""IVF ANN index subsystem (DESIGN.md §11).

Four layers of coverage:

1. **Kernel conformance** — the Pallas ``ivf_scan`` kernel (interpret
   mode) against the pure-jnp oracle (`kernels/ivf_scan/ref.py`), exact
   candidate ids (score desc / global-id-asc tie contract) across
   shape sweeps, plus the jnp fast path's candidate-set agreement.
2. **Rerank exactness** — full-probe ``ivf_search`` must reproduce flat
   search bit-for-bit (same ids, same fp32 scores): with recall forced
   to 1, ANN must be invisible.
3. **Policy differential** — serve/serve_batch decisions with an
   injected ``IVFIndex`` match the flat-index decisions request for
   request on a synthetic trace (the `test_serve_batch` live-workload
   machinery).
4. **Build invariants** (property tests via `_hypothesis_compat`) —
   the packed layout partitions the corpus (every row in exactly one
   band slot) and the int8 quantization error bound holds.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.index.flat import FlatIndex, l2_normalize
from repro.index.ivf import IVFIndex, build_ivf, quantize_rows
from repro.kernels.ivf_scan.ops import ivf_scan, ivf_search
from repro.kernels.ivf_scan.ref import ivf_scan_ref
from repro.kernels.simsearch.ref import simsearch_ref


def _clustered(rng, n, d, n_centers=24, noise=0.3):
    centers = rng.normal(size=(n_centers, d))
    rows = centers[rng.integers(0, n_centers, n)] \
        + noise * rng.normal(size=(n, d))
    return rows.astype(np.float32)


def _queries(rng, corpus, b, noise=0.05):
    q = corpus[rng.choice(len(corpus), b, replace=False)] \
        + noise * rng.normal(size=(b, corpus.shape[1]))
    return q.astype(np.float32)


# ---------------------------------------------------------------------------
# 1. kernel conformance — the interpret-kernel-vs-oracle shape/dtype
# sweep and the padding contract moved to the unified harness in
# `tests/test_kernel_conformance.py` (ivf_scan family); here only the
# jnp fast path's weaker candidate-set contract remains.
# ---------------------------------------------------------------------------

def test_ivf_scan_jnp_path_matches_oracle_candidates():
    """The CPU fast path may reorder exact approx-score ties but must
    produce the same candidate set and scores as the oracle."""
    rng = np.random.default_rng(5)
    corpus = _clustered(rng, 3000, 32)
    q = jnp.asarray(_queries(rng, corpus, 9))
    ivf = build_ivf(corpus, n_clusters=40, iters=4)
    args = (ivf.centroids, ivf.codes, ivf.scales, ivf.row_ids)
    v_ref, i_ref = ivf_scan_ref(q, *args, 6, 24)
    v_j, i_j = ivf_scan(q, *args, nprobe=6, n_candidates=24, force="jnp")
    assert np.array_equal(np.sort(np.asarray(i_j)),
                          np.sort(np.asarray(i_ref)))
    np.testing.assert_allclose(np.sort(np.asarray(v_j)),
                               np.sort(np.asarray(v_ref)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# 2. rerank exactness vs flat search
# ---------------------------------------------------------------------------

def test_full_probe_search_equals_flat():
    rng = np.random.default_rng(1)
    corpus = _clustered(rng, 2048, 32)
    q = jnp.asarray(_queries(rng, corpus, 16))
    ivf = build_ivf(corpus, n_clusters=24, iters=4)
    v_f, i_f = simsearch_ref(q, ivf.corpus, 3)
    v_i, i_i = ivf_search(q, ivf.corpus, ivf.centroids, ivf.codes,
                          ivf.scales, ivf.row_ids, k=3,
                          nprobe=24, n_candidates=256)
    # identical served rows; scores equal to float rounding (the rerank
    # computes the same normalized dot, but XLA may re-block the gemm)
    assert bool(jnp.all(i_f == i_i))
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_i),
                               rtol=0, atol=1e-6)


def test_search_agrees_with_flat_at_realistic_nprobe():
    rng = np.random.default_rng(2)
    corpus = _clustered(rng, 8192, 32, n_centers=64)
    q = jnp.asarray(_queries(rng, corpus, 64))
    ivf = build_ivf(corpus, iters=4)
    v_f, i_f = simsearch_ref(q, ivf.corpus, 1)
    v_i, i_i = ivf_search(q, ivf.corpus, ivf.centroids, ivf.codes,
                          ivf.scales, ivf.row_ids, k=1,
                          nprobe=16, n_candidates=64)
    agree = np.mean(np.asarray(i_f[:, 0] == i_i[:, 0]))
    assert agree >= 0.95, agree


# ---------------------------------------------------------------------------
# 3. policy differential: IVF index vs flat decisions
# ---------------------------------------------------------------------------

def _mk_policies(index):
    from repro.core.policy import BaselinePolicy
    from test_serve_batch import _trace_setup
    s = _trace_setup()
    pol = BaselinePolicy(
        s["cfg"], s["tier"], s["answers"], s["embed_fn"], s["backend_fn"],
        d=s["d"], embed_batch_fn=s["embed_batch_fn"],
        backend_batch_fn=s["backend_batch_fn"], index=index)
    return s, pol


def _full_probe_index(tier):
    """IVF over the trace's static tier with probe/candidate budgets
    that force recall@C = 1, so decisions must match flat exactly."""
    K = 16
    ivf = build_ivf(tier.emb, n_clusters=K, iters=4,
                    corpus_normalized=True)
    return IVFIndex(ivf, nprobe=K,
                    n_candidates=min(256, K * ivf.codes.shape[1]))


@pytest.mark.parametrize("mode", ["scalar", "batch"])
def test_policy_with_ivf_matches_flat_decisions(mode):
    s, flat_pol = _mk_policies(index=None)
    _, ivf_pol = _mk_policies(index=_full_probe_index(s["tier"]))
    n, bs = 300, 32
    if mode == "scalar":
        flat = [flat_pol.serve(p, m)
                for p, m in zip(s["prompts"][:n], s["metas"][:n])]
        ivf = [ivf_pol.serve(p, m)
               for p, m in zip(s["prompts"][:n], s["metas"][:n])]
    else:
        flat, ivf = [], []
        for i in range(0, n, bs):
            flat += flat_pol.serve_batch(s["prompts"][i:i + bs],
                                         s["metas"][i:i + bs])
            ivf += ivf_pol.serve_batch(s["prompts"][i:i + bs],
                                       s["metas"][i:i + bs])
    assert {r.served_by for r in flat} == {"static", "dynamic", "backend"}
    for i, (a, b) in enumerate(zip(flat, ivf)):
        assert a.served_by == b.served_by, i
        assert a.answer == b.answer, i
        assert a.static_origin == b.static_origin, i
        assert a.similarity == b.similarity \
            or abs(a.similarity - b.similarity) < 1e-5, i
    assert flat_pol.events == ivf_pol.events
    assert flat_pol.stats() == ivf_pol.stats()


def test_flat_index_object_matches_default_lookup():
    """FlatIndex is the trivial member of the injection protocol: same
    decisions as the built-in exact path."""
    s, default_pol = _mk_policies(index=None)
    _, flat_pol = _mk_policies(
        index=FlatIndex(s["tier"].emb, corpus_normalized=True))
    n = 200
    a = [default_pol.serve(p, m)
         for p, m in zip(s["prompts"][:n], s["metas"][:n])]
    b = [flat_pol.serve(p, m)
         for p, m in zip(s["prompts"][:n], s["metas"][:n])]
    assert [r.served_by for r in a] == [r.served_by for r in b]
    assert [r.answer for r in a] == [r.answer for r in b]
    assert flat_pol.describe_index().startswith("flat(")
    assert default_pol.describe_index().startswith("flat-exact(")


# ---------------------------------------------------------------------------
# 4. build invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(40, 400),
       st.sampled_from([4, 8, 12]), st.sampled_from([3, 7, 16]))
def test_ivf_partitions_corpus(seed, n, d, k):
    rng = np.random.default_rng(seed)
    corpus = _clustered(rng, n, d, n_centers=max(2, k))
    ivf = build_ivf(corpus, n_clusters=k, iters=3, seed=seed % 997)
    ids = np.asarray(ivf.row_ids)
    real = ids[ids >= 0]
    # every corpus row in exactly one band slot, no duplicates
    assert sorted(real.tolist()) == list(range(n))
    # padding slots carry no stale metadata
    assert float(np.abs(np.asarray(ivf.codes)[ids < 0]).sum()) == 0.0
    assert float(np.asarray(ivf.scales)[ids < 0].sum()) == 0.0
    # centroids normalized
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(ivf.centroids), axis=1), 1.0,
        atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200),
       st.sampled_from([4, 16, 64]))
def test_quantize_dequantize_error_bound(seed, n, d):
    rng = np.random.default_rng(seed)
    rows = np.asarray(
        l2_normalize(jnp.asarray(rng.normal(size=(n, d)).astype(
            np.float32))))
    codes, scales = quantize_rows(rows)
    assert codes.dtype == np.int8
    err = np.abs(rows - codes.astype(np.float32) * scales[:, None])
    # symmetric scalar quantization: per-component error <= scale/2
    # (plus float slack); scale = max|x|/127 <= 1/127 for unit rows
    assert np.all(err <= scales[:, None] / 2 + 1e-6)
    assert np.all(scales <= 1.0 / 127 + 1e-6)


def test_balanced_build_respects_cap_and_recall_survives_spill():
    """Bounded bands must never exceed cap, and near-duplicate queries
    must still find their (possibly spilled) source row."""
    rng = np.random.default_rng(9)
    corpus = _clustered(rng, 4096, 16, n_centers=12)   # heavily skewed
    ivf = build_ivf(corpus, n_clusters=64, iters=4, max_imbalance=1.3)
    K, cap, _ = ivf.codes.shape
    per_band = (np.asarray(ivf.row_ids) >= 0).sum(axis=1)
    assert per_band.max() <= cap
    assert cap <= -(-int(np.ceil(4096 / 64 * 1.3)) // 8) * 8
    q = jnp.asarray(_queries(rng, corpus, 48, noise=0.03))
    v_f, i_f = simsearch_ref(q, ivf.corpus, 1)
    _, cand = ivf_scan(q, ivf.centroids, ivf.codes, ivf.scales,
                       ivf.row_ids, nprobe=16, n_candidates=64)
    got = (np.asarray(cand) == np.asarray(i_f)).any(axis=1)
    assert got.mean() >= 0.95, got.mean()
