"""Batched serving path: ``serve_batch`` must equal the scalar ``serve``
path request-for-request (same answers, served_by, static_origin, same
promotions), and the router must preserve it under concurrency."""
import dataclasses
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.judge import OracleJudge
from repro.core.policy import BaselinePolicy, KritesPolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark
from repro.serving.router import CacheRouter

N = 500
BATCH = 32


def _trace_setup(n=N, capacity=128):
    """Synthetic trace as a live-policy workload: prompt 'q<i>' embeds to
    eval row i, so embeddings are identical across both serving paths."""
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=4000,
                               n_classes=120)
    bench = build_benchmark(spec)
    emb = {f"q{i}": bench.eval_emb[i] for i in range(n)}
    prompts = [f"q{i}" for i in range(n)]
    metas = [{"cls": int(bench.eval_cls[i])} for i in range(n)]
    tier = make_static_tier(jnp.asarray(bench.static_emb),
                            jnp.asarray(bench.static_cls))
    answers = [f"curated-{int(c)}" for c in bench.static_cls]
    cfg = CacheConfig(tau_static=0.88, tau_dynamic=0.88, sigma_min=0.0,
                      capacity=capacity)
    d = bench.static_emb.shape[1]

    def embed_fn(p):
        return emb[p]

    def embed_batch_fn(ps):
        return np.stack([emb[p] for p in ps])

    def backend_fn(p):
        return f"gen({p})"

    def backend_batch_fn(ps):
        return [f"gen({p})" for p in ps]

    return dict(cfg=cfg, tier=tier, answers=answers, d=d,
                prompts=prompts, metas=metas, embed_fn=embed_fn,
                embed_batch_fn=embed_batch_fn, backend_fn=backend_fn,
                backend_batch_fn=backend_batch_fn)


def _assert_rows_equal(scalar, batched):
    assert len(scalar) == len(batched)
    for i, (a, b) in enumerate(zip(scalar, batched)):
        assert a.served_by == b.served_by, i
        assert a.answer == b.answer, i
        assert a.static_origin == b.static_origin, i
        assert a.similarity == b.similarity \
            or abs(a.similarity - b.similarity) < 1e-5, i


def test_serve_batch_matches_scalar_baseline():
    s = _trace_setup()
    mk = lambda: BaselinePolicy(  # noqa: E731
        s["cfg"], s["tier"], s["answers"], s["embed_fn"], s["backend_fn"],
        d=s["d"], embed_batch_fn=s["embed_batch_fn"],
        backend_batch_fn=s["backend_batch_fn"])
    p_scalar, p_batch = mk(), mk()
    scalar = [p_scalar.serve(p, m)
              for p, m in zip(s["prompts"], s["metas"])]
    batched = []
    for i in range(0, N, BATCH):
        batched += p_batch.serve_batch(s["prompts"][i:i + BATCH],
                                       s["metas"][i:i + BATCH])
    _assert_rows_equal(scalar, batched)
    assert p_scalar.events == p_batch.events
    assert p_scalar.stats() == p_batch.stats()
    # the trace must actually exercise all three tiers
    by = {r.served_by for r in scalar}
    assert by == {"static", "dynamic", "backend"}


class _GatedOracle:
    """Oracle judge that blocks until the test opens the gate, so
    promotions land only at controlled (batch) boundaries."""

    def __init__(self):
        self.gate = threading.Event()

    def __call__(self, q_cls, h_cls, **kw):
        self.gate.wait()
        return int(q_cls) == int(h_cls)


def _run_krites_scalar(s, judge):
    pol = KritesPolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                       s["backend_fn"], judge, d=s["d"], n_workers=1)
    out = []
    for i in range(0, N, BATCH):
        for p, m in zip(s["prompts"][i:i + BATCH],
                        s["metas"][i:i + BATCH]):
            out.append(pol.serve(p, m))
        judge.gate.set()
        pol.pool.drain()
        judge.gate.clear()
    judge.gate.set()
    pol.pool.drain()
    pol.pool.stop()
    return pol, out


def _run_krites_batched(s, judge):
    pol = KritesPolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                       s["backend_fn"], judge, d=s["d"], n_workers=1,
                       embed_batch_fn=s["embed_batch_fn"],
                       backend_batch_fn=s["backend_batch_fn"])
    out = []
    for i in range(0, N, BATCH):
        out += pol.serve_batch(s["prompts"][i:i + BATCH],
                               s["metas"][i:i + BATCH])
        judge.gate.set()
        pol.pool.drain()
        judge.gate.clear()
    judge.gate.set()
    pol.pool.drain()
    pol.pool.stop()
    return pol, out


def test_serve_batch_matches_scalar_krites_with_promotions():
    """Full Alg. 2 equivalence: promotions land at the same batch
    boundaries in both paths, so every decision — including dynamic hits
    on promoted entries — must match request for request."""
    s = _trace_setup()
    pol_s, scalar = _run_krites_scalar(s, _GatedOracle())
    pol_b, batched = _run_krites_batched(s, _GatedOracle())
    _assert_rows_equal(scalar, batched)
    assert pol_s.events == pol_b.events
    ss, sb = pol_s.stats(), pol_b.stats()
    for k in ("judge_submitted", "judged", "approved", "static_hit_rate",
              "dynamic_hit_rate", "backend_rate", "static_origin_rate"):
        assert ss[k] == sb[k], k
    # promotions must actually have happened and been served from
    assert sb["approved"] > 0
    assert any(r.served_by == "dynamic" and r.static_origin
               for r in batched)


def test_intra_batch_duplicate_hits_fresh_insert():
    """A duplicate within one batch must see the earlier row's backend
    insert, exactly as the sequential path would."""
    s = _trace_setup()
    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"],
                         backend_batch_fn=s["backend_batch_fn"])
    # find a prompt that misses both tiers when served cold
    probe = BaselinePolicy(s["cfg"], s["tier"], s["answers"],
                           s["embed_fn"], s["backend_fn"], d=s["d"])
    novel = next(p for p, m in zip(s["prompts"], s["metas"])
                 if probe.serve(p, m).served_by == "backend")
    r1, r2 = pol.serve_batch([novel, novel])
    assert r1.served_by == "backend"
    assert r2.served_by == "dynamic" and not r2.static_origin
    assert r2.answer == r1.answer == f"gen({novel})"


def test_grey_zone_promotion_visible_to_later_batch():
    d = 8
    s_emb = np.eye(d, dtype=np.float32)[:4]
    tier = make_static_tier(jnp.asarray(s_emb),
                            jnp.arange(4, dtype=jnp.int32))
    para = s_emb[0] + 0.3 * s_emb[1]
    para /= np.linalg.norm(para)
    assert 0.5 < float(para @ s_emb[0]) < 0.98
    emb = {"para": para.astype(np.float32)}
    cfg = CacheConfig(tau_static=0.98, tau_dynamic=0.98, sigma_min=0.5,
                      capacity=16)
    kr = KritesPolicy(cfg, tier, [f"curated-{i}" for i in range(4)],
                      lambda p: emb[p], lambda p: f"gen({p})",
                      OracleJudge(), d=d)
    r1 = kr.serve_batch(["para"], [{"cls": 0}])[0]
    assert r1.served_by == "backend"
    kr.pool.drain()
    r2 = kr.serve_batch(["para"], [{"cls": 0}])[0]
    assert r2.served_by == "dynamic" and r2.static_origin
    assert r2.answer == "curated-0"
    kr.pool.stop()


def _find_novel(s):
    """A prompt that misses both tiers when served cold."""
    probe = BaselinePolicy(s["cfg"], s["tier"], s["answers"],
                           s["embed_fn"], s["backend_fn"], d=s["d"])
    return next(p for p, m in zip(s["prompts"], s["metas"])
                if probe.serve(p, m).served_by == "backend")


def test_backend_failure_rolls_back_inserts():
    """A failed batched backend call must not leave answerless entries
    in the dynamic tier."""
    s = _trace_setup()
    state = {"fail": True}

    def flaky_batch(ps):
        if state["fail"]:
            raise RuntimeError("backend down")
        return [f"gen({p})" for p in ps]

    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"],
                         backend_batch_fn=flaky_batch)
    novel = _find_novel(s)
    with pytest.raises(RuntimeError):
        pol.serve_batch([novel])
    # a failed batch served nobody, so it must record no events
    assert pol.stats()["requests"] == 0
    # retry after recovery: must go to the backend again (no poisoned
    # dynamic hit serving None)
    state["fail"] = False
    r = pol.serve_batch([novel])[0]
    assert r.served_by == "backend"
    assert r.answer == f"gen({novel})"


def test_router_surfaces_backend_errors():
    s = _trace_setup()

    def broken_batch(ps):
        raise RuntimeError("backend down")

    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"],
                         backend_batch_fn=broken_batch)
    router = CacheRouter(pol, max_batch=4, max_wait_ms=1.0)
    novel = _find_novel(s)
    res = router.submit(novel, timeout_s=10.0)
    assert res is None
    st = router.stats()
    assert st["errors"] >= 1
    assert "backend down" in st["last_error"]
    router.stop()


def test_router_concurrent_matches_policy_decisions():
    s = _trace_setup(n=200)
    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"],
                         embed_batch_fn=s["embed_batch_fn"],
                         backend_batch_fn=s["backend_batch_fn"])
    router = CacheRouter(pol, max_batch=16, max_wait_ms=5.0)
    results = router.submit_many(s["prompts"][:200], s["metas"][:200])
    assert all(r is not None for r in results)
    st = router.stats()
    assert st["requests"] == 200
    assert st["batches"] < 200          # batching actually happened
    assert st["mean_batch_size"] > 1.0
    counts = (st["static_hit_rate"] + st["dynamic_hit_rate"]
              + st["backend_rate"])
    assert abs(counts - 1.0) < 1e-9
    assert "p99_latency_ms" in st
    router.stop()


def test_router_latency_percentiles_under_concurrent_submit():
    """Latency telemetry under concurrent clients: every request lands
    in the (bounded) percentile window, percentiles are ordered, and
    the window cap keeps a long-lived router from sorting its whole
    history."""
    s = _trace_setup(n=160)
    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"],
                         embed_batch_fn=s["embed_batch_fn"],
                         backend_batch_fn=s["backend_batch_fn"])
    router = CacheRouter(pol, max_batch=16, max_wait_ms=2.0,
                         latency_window=100)
    out = {}

    def client(lo, hi):
        for i in range(lo, hi):
            out[i] = router.submit(s["prompts"][i], s["metas"][i])

    threads = [threading.Thread(target=client, args=(k * 40, k * 40 + 40))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(out) == 160 and all(v is not None for v in out.values())
    st = router.stats()
    assert st["requests"] == 160
    assert 0 < st["p50_latency_ms"] <= st["p99_latency_ms"]
    # bounded window: only the last `latency_window` samples retained
    assert len(router._latencies) == 100
    router.stop()


def test_router_threaded_submit():
    s = _trace_setup(n=120)
    pol = BaselinePolicy(s["cfg"], s["tier"], s["answers"], s["embed_fn"],
                         s["backend_fn"], d=s["d"])
    router = CacheRouter(pol, max_batch=8, max_wait_ms=20.0)
    out = {}

    def client(lo, hi):
        for i in range(lo, hi):
            out[i] = router.submit(s["prompts"][i], s["metas"][i])

    threads = [threading.Thread(target=client, args=(k * 30, k * 30 + 30))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(out) == 120 and all(v is not None for v in out.values())
    assert router.stats()["requests"] == 120
    router.stop()
