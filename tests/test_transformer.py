"""LM transformer: decode/prefill consistency, training signal, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MoEConfig, smoke_config
from repro.models import transformer as tr
from repro.models.moe import moe_ffn_einsum, moe_ffn_sort, router_topk


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "glm4-9b",
                                  "qwen2-moe-a2.7b"])
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(smoke_config(arch), dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    lg, cache = tr.prefill(cfg, params, toks[:, :8], max_len=16)
    for t in range(8, 16):
        lg, cache = tr.decode_step(cfg, params, cache, toks[:, t])
    lg_full, _ = tr.prefill(cfg, params, toks)
    assert float(jnp.max(jnp.abs(lg - lg_full))) < 1e-4


def test_unrolled_variant_matches_scan():
    cfg = dataclasses.replace(smoke_config("qwen3-1.7b"), dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    l1 = tr.train_loss(cfg, params, batch, vocab_chunk_seq=16)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2 = tr.train_loss(cfg2, params, batch, vocab_chunk_seq=16)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_train_loss_decreases_tiny_model():
    cfg = dataclasses.replace(
        smoke_config("qwen3-1.7b"), dtype="float32", n_layers=2)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda q: tr.train_loss(cfg, q, batch, vocab_chunk_seq=16))(p)
        return loss, jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    losses = []
    for _ in range(12):
        loss, params = step(params)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_moe_sort_vs_einsum_vs_pertoken():
    E, k, d, F, T = 6, 2, 16, 32, 24
    m = MoEConfig(n_experts=E, top_k=k, d_ff_expert=F,
                  capacity_factor=100.0, n_groups=1)
    key = jax.random.PRNGKey(3)
    p = {"router": jax.random.normal(key, (d, E)),
         "wg": jax.random.normal(jax.random.fold_in(key, 1), (E, d, F)) * .1,
         "wu": jax.random.normal(jax.random.fold_in(key, 2), (E, d, F)) * .1,
         "wd": jax.random.normal(jax.random.fold_in(key, 3), (E, F, d)) * .1}
    x = jax.random.normal(jax.random.fold_in(key, 4), (T, d))
    y1, _ = moe_ffn_sort(x, p, m)
    y2, _ = moe_ffn_einsum(x, p, m)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    # per-token oracle
    idx, w, _ = router_topk(x, p["router"], k)
    for t in range(0, T, 5):
        acc = jnp.zeros(d)
        for j in range(k):
            e = int(idx[t, j])
            h = jax.nn.silu(x[t] @ p["wg"][e]) * (x[t] @ p["wu"][e])
            acc += w[t, j] * (h @ p["wd"][e])
        np.testing.assert_allclose(np.asarray(y1[t]), np.asarray(acc),
                                   rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity factor << 1, overflow tokens produce zero output."""
    E, k, d, F, T = 2, 1, 8, 16, 64
    m = MoEConfig(n_experts=E, top_k=k, d_ff_expert=F,
                  capacity_factor=0.25, n_groups=1)
    key = jax.random.PRNGKey(5)
    p = {"router": jnp.zeros((d, E)).at[:, 0].set(10.0),  # all -> expert 0
         "wg": jnp.ones((E, d, F)) * 0.1,
         "wu": jnp.ones((E, d, F)) * 0.1,
         "wd": jnp.ones((E, F, d)) * 0.1}
    x = jax.random.normal(key, (T, d))
    y, _ = moe_ffn_sort(x, p, m)
    dropped = np.asarray(jnp.all(y == 0.0, axis=1))
    assert dropped.sum() >= T // 2      # most tokens over capacity


def test_group_local_dispatch_matches_single_group():
    E, k, d, F, T = 4, 2, 8, 16, 32
    key = jax.random.PRNGKey(7)
    p = {"router": jax.random.normal(key, (d, E)),
         "wg": jax.random.normal(jax.random.fold_in(key, 1), (E, d, F)) * .1,
         "wu": jax.random.normal(jax.random.fold_in(key, 2), (E, d, F)) * .1,
         "wd": jax.random.normal(jax.random.fold_in(key, 3), (E, F, d)) * .1}
    x = jax.random.normal(jax.random.fold_in(key, 4), (T, d))
    m1 = MoEConfig(n_experts=E, top_k=k, d_ff_expert=F,
                   capacity_factor=100.0, n_groups=1)
    m4 = dataclasses.replace(m1, n_groups=4)
    y1, _ = moe_ffn_sort(x, p, m1)
    y4, _ = moe_ffn_sort(x, p, m4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               rtol=1e-4, atol=1e-5)
