"""Mesh-aware serving path (DESIGN.md §13): the sharded dynamic-tier
twins and the policy's ``mesh=`` mode must be decision-for-decision
identical to single-device serving. Needs >1 device, so everything runs
in a subprocess with forced host devices (the main pytest process must
keep 1 device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_dyn_twins_match_single_device_primitives():
    """The row-sharded masked top-1 and the shard-routed scatters must
    reproduce their single-device twins field for field — including on
    slots owned by every different shard, partially-valid tiers, and
    score ties (lowest-slot rule)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tiers as T
        from repro.core.policy import _bulk_insert
        from repro.index.flat import masked_cosine_topk
        from repro.index.sharded import (sharded_bulk_insert,
                                         sharded_dyn_write,
                                         sharded_masked_topk,
                                         sharded_touch_many,
                                         shard_dynamic_tier)
        from repro.launch.mesh import make_shard_mesh

        mesh = make_shard_mesh(4)
        rng = np.random.default_rng(0)
        C, d, B = 64, 16, 8
        dyn = T.make_dynamic_tier(C, d)
        for i in range(40):   # populate across shards
            v = rng.normal(size=d).astype(np.float32)
            v /= np.linalg.norm(v)
            dyn = T.insert(dyn, jnp.asarray(v), i, i, now=i + 1)
        sdyn = shard_dynamic_tier(dyn, mesh)

        q = rng.normal(size=(B, d)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        # inject an exact tie: two valid slots share one embedding
        emb0 = np.asarray(dyn.emb)
        dup = jnp.asarray(emb0[3])
        dyn_t = dyn._replace(emb=dyn.emb.at[37].set(dup))
        sdyn_t = shard_dynamic_tier(dyn_t, mesh)
        q_tie = np.concatenate([q, np.asarray(dup)[None]])
        vr, ir = masked_cosine_topk(jnp.asarray(q_tie), dyn_t.emb,
                                    dyn_t.valid, k=1,
                                    corpus_normalized=True)
        vs, js = sharded_masked_topk(jnp.asarray(q_tie), sdyn_t.emb,
                                     sdyn_t.valid, mesh, k=1)
        assert bool(jnp.all(ir == js)), (ir, js)
        assert bool(jnp.all(vr == vs)), "scores must be bit-identical"
        assert int(js[-1, 0]) == 3, "tie must resolve to the lowest slot"

        # scalar write on each shard's range
        for slot in (0, 17, 33, 63):
            v = jnp.asarray(q[slot % B])
            a = T._write(dyn, slot, v, jnp.int32(7), jnp.int32(9),
                         jnp.asarray(True), 100 + slot)
            b = sharded_dyn_write(sdyn, slot, v, jnp.int32(7),
                                  jnp.int32(9), jnp.asarray(True),
                                  100 + slot, mesh)
            for fa, fb in zip(a, b):
                assert np.array_equal(np.asarray(fa), np.asarray(fb))

        # bulk insert + touch with slots spanning all shards
        V = jnp.asarray(q)
        slots = np.asarray([2, 18, 34, 50, 2, 2, 2, 2])  # incl. pad dups
        rows = np.asarray([0, 1, 2, 3, 0, 0, 0, 0])
        ts = np.asarray([201, 202, 203, 204, 201, 201, 201, 201],
                        np.int32)
        cls = np.asarray([5, 6, 7, 8, 5, 5, 5, 5], np.int32)
        a = _bulk_insert(dyn, V, slots, rows, ts, cls)
        b = sharded_bulk_insert(sdyn, V, slots, rows, ts, cls, mesh)
        for fa, fb in zip(a, b):
            assert np.array_equal(np.asarray(fa), np.asarray(fb))
        a = T.touch_many(a, slots[:4], ts[:4] + 10)
        b = sharded_touch_many(b, slots[:4], ts[:4] + 10, mesh)
        assert np.array_equal(np.asarray(a.last_used),
                              np.asarray(b.last_used))
        print("ok")
    """))


_SERVE_SETUP = """
    import dataclasses, threading
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, make_static_tier
    from repro.data.synth_traces import LMARENA_LIKE, build_benchmark
    from repro.launch.mesh import make_shard_mesh

    mesh = make_shard_mesh(4)
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=2000,
                               n_classes=120)
    bench = build_benchmark(spec)
    n = 160
    emb = {f"q{i}": bench.eval_emb[i] for i in range(n)}
    prompts = [f"q{i}" for i in range(n)]
    metas = [{"cls": int(bench.eval_cls[i])} for i in range(n)]
    tier = make_static_tier(jnp.asarray(bench.static_emb),
                            jnp.asarray(bench.static_cls))
    answers = [f"curated-{int(c)}" for c in bench.static_cls]
    texts = [f"canonical prompt {i}" for i in range(len(answers))]
    cfg = CacheConfig(0.92, 0.88, sigma_min=0.0, capacity=128)
    d = bench.static_emb.shape[1]
    kw = dict(embed_batch_fn=lambda ps: np.stack([emb[p] for p in ps]),
              backend_batch_fn=lambda ps: [f"gen({p})" for p in ps])

    class Gated:
        def __init__(self):
            self.gate = threading.Event()
        def __call__(self, q_cls, h_cls, **kws):
            self.gate.wait()
            return int(q_cls) == int(h_cls)

    def run(m, batched, index=None):
        j = Gated()
        pol = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                           lambda p: f"gen({p})", j, d=d, n_workers=1,
                           static_texts=texts, mesh=m, index=index,
                           **kw)
        out = []
        for i in range(0, n, 32):
            if batched:
                out += pol.serve_batch(prompts[i:i+32], metas[i:i+32])
            else:
                out += [pol.serve(p, me) for p, me in
                        zip(prompts[i:i+32], metas[i:i+32])]
            j.gate.set(); pol.pool.drain(); j.gate.clear()
        j.gate.set(); pol.pool.drain(); pol.pool.stop()
        return pol, out

    def assert_identical(p1, o1, p2, o2):
        assert p1.events == p2.events
        for a, b in zip(o1, o2):
            assert (a.served_by, a.answer, a.static_origin) \\
                == (b.served_by, b.answer, b.static_origin)
        assert p1.stats() == p2.stats()
"""


def test_sharded_serve_flat_matches_single_device():
    """Full Alg. 2 differential on the exact (flat) static path: the
    mesh policy must match single-device request for request — scalar
    and batched, promotions included — and its host mirrors must equal
    the row-sharded device tier."""
    print(_run(_SERVE_SETUP + """
    for batched in (False, True):
        p1, o1 = run(None, batched)
        p2, o2 = run(mesh, batched)
        assert_identical(p1, o1, p2, o2)
        assert p2.stats()["approved"] > 0
        assert np.array_equal(p2._valid_np, np.asarray(p2.dyn.valid))
        assert np.array_equal(p2._last_used_np,
                              np.asarray(p2.dyn.last_used))
        assert np.array_equal(p2._static_origin_np,
                              np.asarray(p2.dyn.static_origin))
        assert np.array_equal(p2._written_at_np,
                              np.asarray(p2.dyn.written_at))
        sh = p2.shard_stats()
        assert sh["shards"] == 4
        assert sum(sh["shard_occupancy"]) == int(p2._valid_np.sum())
    print("ok")
    """))


def test_sharded_serve_ivf_matches_single_device():
    """Same differential through the ANN static path: single-device
    IVFIndex vs ShardedIVFIndex at full probe (both exact-rerank-equal
    to flat, hence to each other)."""
    print(_run(_SERVE_SETUP + """
    from repro.index.ivf import IVFIndex, build_ivf
    from repro.index.sharded import ShardedIVFIndex
    sivf = ShardedIVFIndex(tier.emb, mesh, nprobe=64, n_candidates=64,
                           n_clusters=8, iters=4)
    ivf = IVFIndex(build_ivf(tier.emb, n_clusters=8, iters=4,
                             corpus_normalized=True),
                   nprobe=64, n_candidates=64)
    for batched in (False, True):
        p1, o1 = run(None, batched, index=ivf)
        p2, o2 = run(mesh, batched, index=sivf)
        assert_identical(p1, o1, p2, o2)
    assert sivf.describe().startswith("sharded-ivf(")
    print("ok")
    """))


def test_sharded_promotion_lands_on_owning_shard():
    """A promotion targeting a slot owned by each shard must land there
    (and only there): the written slot's row appears in exactly that
    shard's partition of the device tier."""
    print(_run(_SERVE_SETUP + """
    j = Gated(); j.gate.set()
    pol = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                       lambda p: f"gen({p})", j, d=d, n_workers=1,
                       static_texts=texts, mesh=mesh, **kw)
    rows_per = cfg.capacity // 4
    rng = np.random.default_rng(5)
    for shard in range(4):
        target = shard * rows_per + 3
        # occupy the LRU order so _host_lru_slot lands on `target`
        pol._valid_np[:] = True
        pol._last_used_np[:] = 10_000
        pol._valid_np[target] = False
        v = rng.normal(size=d).astype(np.float32)
        v /= np.linalg.norm(v)
        pol._promote({"v": v, "h_idx": 0, "enq_t": 20_000 + shard})
        assert bool(pol._valid_np[target])
        emb_np = np.asarray(pol.dyn.emb)
        assert np.allclose(emb_np[target], v, atol=1e-6)
        assert int(np.asarray(pol.dyn.written_at)[target]) \\
            == 20_000 + shard
        assert bool(np.asarray(pol.dyn.static_origin)[target])
    pol.pool.stop()
    print("ok")
    """))


def test_sharded_snapshot_restore_matches_live():
    """Persistence on the mesh path (DESIGN.md §14): snapshot a mesh
    policy mid-run, restore into a fresh mesh policy — the device tier
    is re-sharded onto the mesh field-identically (mirrors included),
    and the restored process serves the rest of the trace decision-
    for-decision like the one that never went down."""
    print(_run(_SERVE_SETUP + """
    import tempfile
    from pathlib import Path
    from repro.serving import persist

    def serve_span(pol, j, lo, hi):
        out = []
        for i in range(lo, hi, 32):
            out += [pol.serve(p, me) for p, me in
                    zip(prompts[i:i+32], metas[i:i+32])]
            j.gate.set(); pol.pool.drain(); j.gate.clear()
        return out

    j1 = Gated()
    p1 = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                      lambda p: f"gen({p})", j1, d=d, n_workers=1,
                      static_texts=texts, mesh=mesh, **kw)
    serve_span(p1, j1, 0, 128)
    assert p1.stats()["approved"] > 0, "prefix produced no promotions"
    snap_dir = Path(tempfile.mkdtemp(prefix="snap-mesh-"))
    persist.save_snapshot(snap_dir, p1)

    j2 = Gated()
    p2 = KritesPolicy(cfg, tier, answers, lambda p: emb[p],
                      lambda p: f"gen({p})", j2, d=d, n_workers=1,
                      static_texts=texts, mesh=mesh, **kw)
    rep = persist.restore_policy(p2, snap_dir)
    assert rep["index"] == "none" and rep["dyn_live"] > 0

    for f in ("emb", "cls", "answer_ref", "static_origin", "valid",
              "last_used", "written_at"):
        assert np.array_equal(np.asarray(getattr(p2.dyn, f)),
                              np.asarray(getattr(p1.dyn, f))), f
    assert np.array_equal(p2._valid_np, p1._valid_np)
    assert np.array_equal(p2._last_used_np, p1._last_used_np)
    assert np.array_equal(p2._static_origin_np, p1._static_origin_np)
    assert np.array_equal(p2._written_at_np, p1._written_at_np)
    assert p2.dyn_answers == p1.dyn_answers and p2.t == p1.t
    sh = p2.shard_stats()
    assert sh["shards"] == 4
    assert sum(sh["shard_occupancy"]) == int(p2._valid_np.sum())

    o1 = serve_span(p1, j1, 128, n)
    o2 = serve_span(p2, j2, 128, n)
    for a, b in zip(o1, o2):
        assert (a.served_by, a.answer, a.static_origin) \\
            == (b.served_by, b.answer, b.static_origin)
    for pol, j in ((p1, j1), (p2, j2)):
        j.gate.set(); pol.pool.drain(); pol.pool.stop()
    assert np.array_equal(p2._valid_np, p1._valid_np)
    assert np.array_equal(p2._written_at_np, p1._written_at_np)
    print("ok")
    """))
