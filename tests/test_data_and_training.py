"""Data pipelines, optimizer, train loop, serving engine, embedder."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.data.graph_data import NeighborSampler, synthetic_graph
from repro.data.lm_data import synthetic_lm_batches
from repro.data.recsys_data import recsys_batches
from repro.data.tokenizer import ByteTokenizer
from repro.embedding.embedder import Embedder
from repro.models.recsys import embedding_bag, embedding_bag_ragged
from repro.training import optimizer as opt_lib
from repro.training.train_loop import TrainConfig, lr_schedule


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    for s in ("hello world", "ünïcødé ✓", ""):
        ids = tok.encode(s)
        assert tok.decode(ids) == s


def test_neighbor_sampler_shapes_and_validity():
    g = synthetic_graph(200, avg_degree=6, d_feat=8, n_classes=4)
    s = NeighborSampler(g, fanout=(5, 3))
    batch = s.sample_batch(np.arange(10))
    assert batch["feat_l0"].shape == (10, 8)
    assert batch["feat_l1"].shape == (10, 5, 8)
    assert batch["feat_l2"].shape == (10, 5, 3, 8)
    assert batch["labels"].shape == (10,)
    # sampled neighbors are real in-neighbors (or self for isolated)
    nbrs = s.sample_neighbors(np.array([0]), 4)
    lo, hi = g.indptr[0], g.indptr[1]
    pool = set(g.indices[lo:hi].tolist()) or {0}
    assert set(nbrs[0].tolist()) <= pool


def test_lm_data_is_learnable_mixture():
    it = synthetic_lm_batches(64, batch=2, seq_len=32)
    b = next(it)
    assert b["tokens"].shape == (2, 32)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


def test_recsys_batches_all_kinds():
    for arch in ("sasrec", "mind", "bst", "wide-deep"):
        cfg = smoke_config(arch)
        b = next(recsys_batches(cfg, batch=4))
        assert all(v.shape[0] == 4 for v in b.values())


def test_embedder_clusters_similar_prompts():
    e = Embedder(d_out=32)
    a1 = e("can my dog eat honey")
    a2 = e("hey, can my dog eat honey")
    b = e("quarterly tax filing deadline")
    assert float(a1 @ a2) > float(a1 @ b) + 0.15


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_prop_embedding_bag_ragged_matches_fixed(seed):
    rng = np.random.default_rng(seed)
    V, d, B, m = 50, 8, 6, 3
    table = jnp.asarray(rng.standard_normal((V, d)).astype(np.float32))
    ids = rng.integers(0, V, (B, m))
    fixed = embedding_bag(table, jnp.asarray(ids))
    ragged = embedding_bag_ragged(
        table, jnp.asarray(ids.reshape(-1)),
        jnp.repeat(jnp.arange(B), m), B)
    np.testing.assert_allclose(np.asarray(fixed), np.asarray(ragged),
                               rtol=1e-5, atol=1e-6)


def test_adamw_converges_on_quadratic():
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt_lib.init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_lib.update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_update():
    cfg = opt_lib.AdamWConfig(lr=1.0, grad_clip=1e-6, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    state = opt_lib.init(params, cfg)
    p2, _, gnorm = opt_lib.update({"w": jnp.full((4,), 1e6)}, state,
                                  params, cfg)
    assert float(gnorm) > 1e5


def test_lr_schedule_shape():
    t = TrainConfig(n_steps=100, warmup_steps=10, lr=1.0,
                    lr_min_ratio=0.1)
    assert float(lr_schedule(t, jnp.int32(0))) == 0.0
    assert abs(float(lr_schedule(t, jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr_schedule(t, jnp.int32(100))) < 0.11


def test_train_loop_loss_decreases_and_restores(tmp_path):
    from repro.models import transformer as tr
    from repro.training.train_loop import train
    cfg = dataclasses.replace(
        smoke_config("qwen3-1.7b"), dtype="float32", n_layers=2)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    data = synthetic_lm_batches(cfg.vocab_size, 4, 32)
    data = ({"tokens": jnp.asarray(b["tokens"]),
             "labels": jnp.asarray(b["labels"])} for b in data)
    tcfg = TrainConfig(n_steps=12, ckpt_dir=str(tmp_path), ckpt_every=6,
                       log_every=4, lr=5e-3, warmup_steps=2)
    loss_fn = lambda p, b: tr.train_loss(cfg, p, b, vocab_chunk_seq=16)
    params, _, hist = train(loss_fn, params, data, tcfg)
    assert hist[-1]["loss"] < hist[0]["loss"]
    # restart path: a new call resumes from step 12 (no steps run)
    from repro.distributed.checkpoint import latest_step
    assert latest_step(tmp_path) == 12


def test_serving_engine_generates():
    from repro.serving.engine import LLMEngine
    eng = LLMEngine(smoke_config("qwen3-1.7b"), max_len=48)
    outs = eng.generate_batch(["hello", "world!"], max_new_tokens=4)
    assert len(outs) == 2
    assert eng.stats.prefills == 2
    # deterministic greedy decode
    outs2 = eng.generate_batch(["hello", "world!"], max_new_tokens=4)
    assert outs == outs2
