"""Unified ops-vs-ref conformance suite for every ``kernels/*`` family.

One harness instead of per-family ad-hoc checks: each family registers a
:class:`Family` spec — input generator, ops entry (the public dispatch
wrapper with its ``force`` backend override), reference oracle, and
comparison contract (score tolerance per dtype, exact index/ordering
rules). The suite then drives every family through the same three
grids:

- **shape sweep** (interpret-mode Pallas vs oracle) — including single
  rows, single blocks, and non-multiple-of-block sizes where the family
  supports them (simsearch pads internally; attention block sizes clamp
  to the sequence);
- **dtype sweep** — fp32 exact-contract + bf16 tolerance where the
  family accepts low precision;
- **edge grid** through the public dispatch (auto backend) — empty
  query batches, single-element inputs, k == N — asserting the
  shape/dtype output contract and agreement with the oracle.

Contract details each family must hold (and the old per-family tests
checked inconsistently): simsearch ties break by lowest corpus index,
ivf_scan candidates order by (score desc, global id asc) with padding
flushed to (NEG, -1), attention outputs are finite and fp32-close to
the blockwise oracle, embedding_bag reduces in fp32.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp
import numpy as np
import pytest

from repro.index.ivf import build_ivf
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fused_serve.ops import fused_serve_probe
from repro.kernels.fused_serve.ref import fused_serve_ref
from repro.kernels.ivf_scan.ops import ivf_scan
from repro.kernels.ivf_scan.ref import NEG, ivf_scan_ref
from repro.kernels.simsearch.ops import cosine_topk
from repro.kernels.simsearch.ref import simsearch_ref


@dataclass(frozen=True)
class Family:
    """One kernel family's conformance spec."""
    name: str
    make: Callable            # (case, dtype, rng) -> inputs dict
    ops: Callable             # (inputs, force) -> outputs
    ref: Callable             # (inputs,) -> outputs
    check: Callable           # (got, want, dtype) -> None (asserts)
    cases: tuple              # interpret-mode shape sweep
    edge_cases: tuple = ()    # public-dispatch edge grid (auto backend)
    dtypes: tuple = ("float32",)


# --------------------------------------------------------------------------
# simsearch — fused cosine top-k
# --------------------------------------------------------------------------

def _arr(x, dtype="float32"):
    """numpy -> device array in ``dtype`` (numpy has no bfloat16)."""
    return jnp.asarray(np.asarray(x, np.float32)).astype(dtype)


def _simsearch_make(case, dtype, rng):
    B, N, d, k, tile = case
    return {"q": _arr(rng.standard_normal((B, d)), dtype),
            "c": _arr(rng.standard_normal((N, d)), dtype),
            "k": k, "tile": tile}


def _simsearch_check(got, want, dtype):
    v, i = got
    v_r, i_r = want
    assert v.shape == v_r.shape and i.shape == i_r.shape
    assert v.dtype == jnp.float32 and i.dtype == jnp.int32
    np.testing.assert_allclose(
        np.asarray(v), np.asarray(v_r),
        rtol=2e-2 if dtype == "bfloat16" else 1e-5, atol=1e-5)
    if dtype == "float32":
        # exact top-k ids, lowest-index tie contract
        assert np.array_equal(np.asarray(i), np.asarray(i_r))


SIMSEARCH = Family(
    name="simsearch",
    make=_simsearch_make,
    ops=lambda x, force: cosine_topk(x["q"], x["c"], k=x["k"],
                                     tile_n=x["tile"], force=force),
    ref=lambda x: simsearch_ref(x["q"], x["c"], x["k"]),
    check=_simsearch_check,
    cases=(
        (4, 256, 32, 1, 128),
        (8, 1000, 64, 4, 256),      # N not a multiple of tile (pad path)
        (16, 512, 128, 8, 64),
        (1, 64, 16, 2, 64),         # single query row
        (3, 130, 8, 3, 128),        # 2-row pad remainder
    ),
    edge_cases=(
        (0, 64, 16, 1, 64),         # empty query batch
        (2, 1, 8, 1, 64),           # single-row corpus
        (2, 5, 8, 5, 64),           # k == N
    ),
    dtypes=("float32", "bfloat16"),
)


# --------------------------------------------------------------------------
# ivf_scan — int8 cluster-band candidate scan
# --------------------------------------------------------------------------

def _ivf_make(case, dtype, rng):
    N, d, B, K, nprobe, C = case
    centers = rng.standard_normal((max(2, K), d))
    rows = (centers[rng.integers(0, max(2, K), N)]
            + 0.3 * rng.standard_normal((N, d))).astype(np.float32)
    q = (rows[rng.integers(0, N, B)]
         + 0.05 * rng.standard_normal((B, d))).astype(np.float32) \
        if B else np.zeros((0, d), np.float32)
    ivf = build_ivf(rows, n_clusters=K, iters=3)
    return {"q": jnp.asarray(q), "ivf": ivf, "nprobe": nprobe, "C": C}


def _ivf_check(got, want, dtype):
    v, i = got
    v_r, i_r = want
    assert v.shape == v_r.shape and i.shape == i_r.shape
    assert i.dtype == jnp.int32
    # exact candidate ids in the (score desc, global id asc) order,
    # padding flushed as (NEG, -1)
    assert np.array_equal(np.asarray(i), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_r),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all((i >= 0) | (v == NEG)))


IVF_SCAN = Family(
    name="ivf_scan",
    make=_ivf_make,
    ops=lambda x, force: ivf_scan(
        x["q"], x["ivf"].centroids, x["ivf"].codes, x["ivf"].scales,
        x["ivf"].row_ids, nprobe=x["nprobe"], n_candidates=x["C"],
        force=force),
    ref=lambda x: ivf_scan_ref(
        x["q"], x["ivf"].centroids, x["ivf"].codes, x["ivf"].scales,
        x["ivf"].row_ids, min(x["nprobe"], x["ivf"].codes.shape[0]),
        min(x["C"], min(x["nprobe"], x["ivf"].codes.shape[0])
            * x["ivf"].codes.shape[1])),
    check=_ivf_check,
    cases=(
        (512, 16, 3, 8, 3, 8),
        (2000, 32, 7, 32, 6, 24),
        (640, 48, 1, 12, 12, 48),    # full probe, single query
        (300, 8, 5, 4, 2, 4),        # tiny, C < nprobe*cap
    ),
    # an empty *corpus* cannot be packed; the edge grid covers an empty
    # query batch and a single-row corpus instead
    edge_cases=(
        (64, 8, 0, 4, 2, 4),         # empty query batch
        (1, 8, 2, 1, 1, 1),          # single-row corpus, one cluster
    ),
)


# --------------------------------------------------------------------------
# flash_attention — causal GQA prefill
# --------------------------------------------------------------------------

def _flash_make(case, dtype, rng):
    B, S, H, K, Dh, bq, bk = case
    mk = lambda h: _arr(rng.standard_normal((B, S, h, Dh)), dtype)  # noqa: E731
    return {"q": mk(H), "k": mk(K), "v": mk(K), "bq": bq, "bk": bk}


def _attn_check(got, want, dtype):
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    assert g.shape == w.shape
    assert np.isfinite(g).all()
    np.testing.assert_allclose(g, w, rtol=tol, atol=tol)


FLASH = Family(
    name="flash_attention",
    make=_flash_make,
    ops=lambda x, force: attention(x["q"], x["k"], x["v"], bq=x["bq"],
                                   bk=x["bk"], force=force),
    ref=lambda x: flash_attention_ref(x["q"], x["k"], x["v"]),
    check=_attn_check,
    cases=(
        (1, 128, 2, 2, 32, 32, 32),
        (2, 256, 4, 2, 64, 64, 128),
        (1, 128, 8, 1, 16, 128, 32),    # MQA, single q block
        (1, 96, 2, 2, 32, 32, 96),      # S not a power of two
    ),
    edge_cases=(
        (1, 1, 2, 2, 16, 512, 512),     # single token (blocks clamp)
        (2, 8, 2, 1, 8, 8, 8),          # tiny everything
    ),
    dtypes=("float32", "bfloat16"),
)


# --------------------------------------------------------------------------
# decode_attention — flash-decoding over KV caches
# --------------------------------------------------------------------------

def _decode_make(case, dtype, rng):
    B, S, H, K, Dh, bs = case
    lens = rng.integers(1, S + 1, B).astype(np.int32)
    return {"q": _arr(rng.standard_normal((B, H, Dh)), dtype),
            "k": _arr(rng.standard_normal((B, S, K, Dh)), dtype),
            "v": _arr(rng.standard_normal((B, S, K, Dh)), dtype),
            "lens": jnp.asarray(lens), "bs": bs}


DECODE = Family(
    name="decode_attention",
    make=_decode_make,
    ops=lambda x, force: decode_attention(x["q"], x["k"], x["v"],
                                          x["lens"], bs=x["bs"],
                                          force=force),
    ref=lambda x: decode_attention_ref(x["q"], x["k"], x["v"],
                                       x["lens"]),
    check=_attn_check,
    cases=(
        (2, 128, 4, 2, 32, 32),
        (3, 256, 8, 2, 32, 64),
        (1, 64, 2, 1, 64, 64),        # MQA, single block
        (2, 96, 4, 4, 16, 32),        # S not a power of two
    ),
    edge_cases=(
        (1, 1, 2, 2, 16, 512),        # cache of one token
        (2, 8, 2, 1, 8, 8),
    ),
)


# --------------------------------------------------------------------------
# embedding_bag — scalar-prefetch gather + weighted reduce
# --------------------------------------------------------------------------

def _bag_make(case, dtype, rng):
    V, d, B, m = case
    ids = rng.integers(0, V, (B, m)).astype(np.int32) if B * m else \
        np.zeros((B, m), np.int32)
    return {"table": _arr(rng.standard_normal((V, d)), dtype),
            "ids": jnp.asarray(ids),
            "w": jnp.asarray(rng.uniform(size=(B, m)).astype(np.float32))}


def _bag_check(got, want, dtype):
    assert got.shape == want.shape
    assert got.dtype == jnp.float32
    tol = 2e-2 if dtype == "bfloat16" else 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


BAG = Family(
    name="embedding_bag",
    make=_bag_make,
    ops=lambda x, force: embedding_bag(x["table"], x["ids"], x["w"],
                                       force=force),
    ref=lambda x: embedding_bag_ref(x["table"], x["ids"], x["w"]),
    check=_bag_check,
    cases=(
        (64, 32, 4, 3),
        (512, 128, 16, 8),
        (100, 16, 1, 1),              # single bag, single id
        (37, 24, 5, 7),               # nothing a multiple of anything
    ),
    edge_cases=(
        (16, 8, 0, 3),                # empty batch
        (1, 8, 2, 2),                 # single-row table
    ),
)


# --------------------------------------------------------------------------
# fused_serve — single-pass static IVF probe + dynamic masked scan
# --------------------------------------------------------------------------

def _fused_make(case, dtype, rng):
    N, d, B, K, nprobe, C, cap_dyn, Cd, valid_frac = case
    centers = rng.standard_normal((max(2, K), d))
    rows = (centers[rng.integers(0, max(2, K), N)]
            + 0.3 * rng.standard_normal((N, d))).astype(np.float32)
    q = (rows[rng.integers(0, N, B)]
         + 0.05 * rng.standard_normal((B, d))).astype(np.float32) \
        if B else np.zeros((0, d), np.float32)
    ivf = build_ivf(rows, n_clusters=K, iters=3)
    dyn = np.zeros((cap_dyn, d), np.float32)
    valid = np.zeros(cap_dyn, bool)
    n_live = int(round(valid_frac * cap_dyn))
    if n_live:
        live = rng.choice(cap_dyn, n_live, replace=False)
        e = rng.standard_normal((n_live, d)).astype(np.float32)
        dyn[live] = e / np.linalg.norm(e, axis=1, keepdims=True)
        valid[live] = True
    return {"q": jnp.asarray(q), "ivf": ivf, "nprobe": nprobe, "C": C,
            "dyn": jnp.asarray(dyn), "valid": jnp.asarray(valid),
            "Cd": Cd}


def _fused_check(got, want, dtype):
    sv, si, dv, di = got
    sv_r, si_r, dv_r, di_r = want
    # static half: the ivf_scan contract verbatim
    _ivf_check((sv, si), (sv_r, si_r), dtype)
    # dynamic half: exact slots in (score desc, slot asc) order,
    # padding/invalid flushed as (NEG, -1)
    assert dv.shape == dv_r.shape and di.shape == di_r.shape
    assert di.dtype == jnp.int32
    assert np.array_equal(np.asarray(di), np.asarray(di_r))
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_r),
                               rtol=1e-5, atol=1e-5)
    assert bool(jnp.all((di >= 0) | (dv == NEG)))


FUSED = Family(
    name="fused_serve",
    make=_fused_make,
    ops=lambda x, force: fused_serve_probe(
        x["q"], x["ivf"].centroids, x["ivf"].codes, x["ivf"].scales,
        x["ivf"].row_ids, x["dyn"], x["valid"], nprobe=x["nprobe"],
        n_candidates=x["C"], n_dyn_candidates=x["Cd"], force=force),
    ref=lambda x: fused_serve_ref(
        x["q"], x["ivf"].centroids, x["ivf"].codes, x["ivf"].scales,
        x["ivf"].row_ids, x["dyn"], x["valid"], x["nprobe"], x["C"],
        x["Cd"]),
    check=_fused_check,
    cases=(
        #  N,  d, B,  K, nprobe,  C, cap, Cd, valid_frac
        (512, 16, 3,  8,      3,  8,  64,  8, 0.6),
        (2000, 32, 7, 32,     6, 24, 256, 16, 0.9),
        (640, 48, 1, 12,     12, 48, 100, 16, 0.5),   # full probe,
        (300,  8, 5,  4,      2,  4,  24,  4, 0.3),   # odd capacity
    ),
    edge_cases=(
        (64,  8, 0,  4,      2,  4,  32,  8, 0.5),    # empty batch
        (64,  8, 3,  4,      2,  4,  32,  8, 0.0),    # all-invalid dyn
        (1,   8, 2,  1,      1,  1,   4,  8, 1.0),    # 1-row corpus,
    ),                                                # Cd > capacity
)


FAMILIES = (SIMSEARCH, IVF_SCAN, FLASH, DECODE, BAG, FUSED)
_BY_NAME = {f.name: f for f in FAMILIES}


def _family_cases(edge=False):
    return [(f.name, c, dt)
            for f in FAMILIES
            for c in (f.edge_cases if edge else f.cases)
            for dt in (("float32",) if edge else f.dtypes)]


def _ids(params):
    return [f"{n}-{'x'.join(map(str, c))}-{dt}" for n, c, dt in params]


_SWEEP = _family_cases(edge=False)
_EDGE = _family_cases(edge=True)


def _rng(name, case, dtype):
    """Deterministic per-case seed (hash() is salted per process)."""
    return np.random.default_rng(
        zlib.crc32(f"{name}|{case}|{dtype}".encode()))


@pytest.mark.parametrize("name,case,dtype", _SWEEP, ids=_ids(_SWEEP))
def test_interpret_kernel_matches_ref(name, case, dtype):
    """Interpret-mode Pallas kernel vs the pure-jnp oracle, per family,
    across the shape/dtype grid."""
    fam = _BY_NAME[name]
    x = fam.make(case, dtype, _rng(name, case, dtype))
    fam.check(fam.ops(x, "interpret"), fam.ref(x), dtype)


@pytest.mark.parametrize("name,case,dtype", _EDGE, ids=_ids(_EDGE))
def test_dispatch_edge_grid_matches_ref(name, case, dtype):
    """Edge shapes (empty batches, single rows, degenerate sizes)
    through the public auto-dispatch entry: must agree with the oracle
    and honor the output shape/dtype contract."""
    fam = _BY_NAME[name]
    x = fam.make(case, dtype, _rng(name, case, dtype))
    fam.check(fam.ops(x, None), fam.ref(x), dtype)


# --------------------------------------------------------------------------
# cross-family ordering contracts (shared tie/padding semantics)
# --------------------------------------------------------------------------

def test_simsearch_tie_breaking_lowest_index():
    """Duplicate corpus rows: the kernel must return the lowest index
    first — the contract the serving path's argmax twin relies on."""
    q = jnp.zeros((1, 8)).at[0, 0].set(1.0)
    near = jnp.zeros((8,)).at[0].set(1.0).at[1].set(0.3)
    exact = jnp.zeros((8,)).at[0].set(1.0)
    orth = jnp.zeros((8,)).at[1].set(1.0)
    c = jnp.stack([near, exact, exact, orth])
    v, i = cosine_topk(q, c, k=3, tile_n=2, force="interpret")
    assert [int(x) for x in i[0]] == [1, 2, 0]


def test_ivf_scan_tie_breaking_lowest_global_id():
    """Duplicate rows across clusters: candidates must order by lowest
    global row id on exact score ties (the rerank depends on it)."""
    rng = np.random.default_rng(0)
    rows = rng.standard_normal((64, 8)).astype(np.float32)
    rows[17] = rows[3]              # exact duplicate, different cluster
    ivf = build_ivf(rows, n_clusters=4, iters=3)
    q = jnp.asarray(rows[3:4])
    _, ids = ivf_scan(q, ivf.centroids, ivf.codes, ivf.scales,
                      ivf.row_ids, nprobe=4, n_candidates=8,
                      force="interpret")
    ids = [int(x) for x in np.asarray(ids)[0]]
    assert ids.index(3) < ids.index(17)


def test_ivf_scan_padding_flushed_as_absent():
    """Requesting more candidates than rows: the tail must come back as
    (NEG, -1) in kernel and oracle alike."""
    rng = np.random.default_rng(1)
    rows = rng.standard_normal((30, 8)).astype(np.float32)
    ivf = build_ivf(rows, n_clusters=3, iters=3)
    q = jnp.asarray(rows[:2])
    C = ivf.codes.shape[0] * ivf.codes.shape[1]
    v_r, i_r = ivf_scan_ref(q, ivf.centroids, ivf.codes, ivf.scales,
                            ivf.row_ids, 3, C)
    v_k, i_k = ivf_scan(q, ivf.centroids, ivf.codes, ivf.scales,
                        ivf.row_ids, nprobe=3, n_candidates=C,
                        force="interpret")
    assert np.array_equal(np.asarray(i_k), np.asarray(i_r))
    assert np.asarray(i_r).min() == -1
    assert bool(jnp.all((i_r >= 0) | (v_r == NEG)))
