"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.simsearch.kernel import simsearch
from repro.kernels.simsearch.ops import cosine_topk
from repro.kernels.simsearch.ref import simsearch_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.embedding_bag.kernel import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


@pytest.mark.parametrize("B,N,d,k,tile", [
    (4, 256, 32, 1, 128),
    (8, 1000, 64, 4, 256),     # padding path
    (16, 512, 128, 8, 64),
    (1, 64, 16, 2, 64),
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_simsearch_sweep(B, N, d, k, tile, dtype):
    key = jax.random.PRNGKey(B * N + k)
    q = jax.random.normal(key, (B, d)).astype(dtype)
    c = jax.random.normal(jax.random.fold_in(key, 1), (N, d)).astype(dtype)
    v_ref, i_ref = simsearch_ref(q, c, k)
    v, i = cosine_topk(q, c, k=k, tile_n=tile, force="interpret")
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                               rtol=2e-2 if dtype == "bfloat16" else 1e-5,
                               atol=1e-5)
    if dtype == "float32":
        assert bool(jnp.all(i == i_ref))


@pytest.mark.parametrize("B,S,H,K,D,bq,bk", [
    (1, 128, 2, 2, 32, 32, 32),
    (2, 256, 4, 2, 64, 64, 128),
    (1, 128, 8, 1, 16, 128, 32),   # MQA, single q block
])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_sweep(B, S, H, K, D, bq, bk, dtype):
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, D)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, S, K, D)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, S, K, D)).astype(dtype)
    out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v)
    tol = 3e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,K,D,bs", [
    (2, 128, 4, 2, 32, 32),
    (3, 256, 8, 2, 32, 64),
    (1, 64, 2, 1, 64, 64),
])
def test_decode_attention_sweep(B, S, H, K, D, bs):
    key = jax.random.PRNGKey(S)
    q = jax.random.normal(key, (B, H, D))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, kc, vc, lens, bs=bs, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("V,d,B,m", [(64, 32, 4, 3), (512, 128, 16, 8),
                                     (100, 16, 1, 1)])
def test_embedding_bag_sweep(V, d, B, m):
    key = jax.random.PRNGKey(V + m)
    table = jax.random.normal(key, (V, d))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (B, m), 0, V)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (B, m))
    out = embedding_bag(table, ids, w, interpret=True)
    ref = embedding_bag_ref(table, ids, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_simsearch_tie_breaking_lowest_index():
    """Duplicate corpus rows: kernel must return the lowest index first."""
    q = jnp.zeros((1, 8)).at[0, 0].set(1.0)
    near = jnp.zeros((8,)).at[0].set(1.0).at[1].set(0.3)
    exact = jnp.zeros((8,)).at[0].set(1.0)
    orth = jnp.zeros((8,)).at[1].set(1.0)
    c = jnp.stack([near, exact, exact, orth])
    v, i = cosine_topk(q, c, k=3, tile_n=2, force="interpret")
    assert [int(x) for x in i[0]] == [1, 2, 0]
