"""Live fault injection: SIGKILL a serving process mid-promotion-burst,
recover from snapshot + promotion WAL, and require the recovered tier —
state and subsequent serving decisions — to be field-identical to a run
that was never interrupted (DESIGN.md §14).

Protocol. A child process builds a deterministic policy (judge workers
disabled so nothing races the kill point), serves a miss prefix that
fills the dynamic tier, snapshots, then applies a fixed burst of
journaled promotions — printing a line after every WAL append
(``APPENDED <seq>``, from inside the append-before-upsert window) and
after every completed upsert (``PROMO <i>``). The parent kills the
child with SIGKILL at a chosen line event, so the crash lands at every
interesting point of the write path:

- after ``SNAP``      — nothing journaled; recovery = snapshot alone;
- after ``APPENDED k``— record k durable, its upsert possibly not
  applied (the window the write-AHEAD ordering exists for);
- after ``PROMO k``   — k upserts applied; the next record may be
  mid-append (torn tail);
- after ``DONE``      — no crash at all: replay-only recovery.

Not every promotion journals: a promotion the LWW guard skips as stale
(a newer write already owns its key) is refused entirely — no tier
write, no WAL record (journaling it would make replay/compaction
re-apply a write the live tier rightly refused). The burst includes
such records on purpose, so the durable-record arithmetic below runs
through ``_n_journaled``, the journal's admission rule in miniature.

Recovery (in the parent, on the child's files): fresh policy ->
``restore_policy`` -> ``replay_into`` (r durable records) -> re-apply
the burst tail ``payloads[r:]`` (the client retry of what never became
durable) -> replay the journal AGAIN (idempotence under double
recovery). The result must match the uninterrupted reference
(snapshot + the full burst) on every tier field and on the decisions
for a probe sweep. Both child and parent build their state from one
shared code block (``COMMON``), so the comparison is apples-to-apples.

The fast subset runs in tier-1; the full kill-point matrix (every k,
both events) is ``@pytest.mark.slow`` — enable with ``--run-slow``.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")
ENV = {
    "PYTHONPATH": SRC,
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "JAX_PLATFORMS": "cpu",
    "PYTHONUNBUFFERED": "1",
}

# Shared between the child process (exec'd as part of its -c script) and
# the parent (exec'd into a namespace): the deterministic world both
# sides must agree on. 32 orthonormal pool vectors (pairwise sim 0, so
# every decision threshold is unambiguous); static tier = P[:8]; the
# prompt space p0..p23 = P[8:32]; a 16-record promotion burst whose
# keys overlap the served prefix (dedup/LWW overwrite), include
# out-of-order re-promotions of one key (the LWW guard paths), and end
# with two REWRITE-verdict promotions whose tailored text exists only
# in the payload/WAL record (rewrite durability, DESIGN.md §18).
COMMON = textwrap.dedent("""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import tiers as T
    from repro.core.policy import KritesPolicy

    D, S, CAP, N_PREFIX = 32, 8, 24, 12

    def _pool(n, d, seed=0):
        rng = np.random.default_rng(seed)
        q, _ = np.linalg.qr(rng.normal(size=(d, n)))
        return np.ascontiguousarray(q.T, np.float32)

    P = _pool(32, D)
    PROMPTS = {f"p{i}": P[8 + i] for i in range(24)}

    def mk_policy(wal=None):
        tier = T.StaticTier(emb=jnp.asarray(P[:S]),
                            cls=jnp.arange(S, dtype=jnp.int32),
                            answer_ref=jnp.arange(S, dtype=jnp.int32))
        cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=CAP)
        return KritesPolicy(cfg, tier, [f"a{i}" for i in range(S)],
                            embed_fn=lambda p: PROMPTS[p],
                            backend_fn=lambda p: "gen(" + p + ")",
                            judge_fn=lambda **kw: True, d=D,
                            n_workers=0, wal=wal)

    def payloads():
        rng = np.random.default_rng(7)
        keys = rng.integers(8, 24, size=12)
        hs = rng.integers(0, S, size=12)
        ts = 100 + rng.permutation(24)[:12]
        out = [{"v": P[int(k)], "h_idx": int(h), "enq_t": int(t)}
               for k, h, t in zip(keys, hs, ts)]
        # LWW churn on one key: a later re-promotion that must win and
        # an earlier (stale) one that must lose on any replay order
        out.append({"v": P[int(keys[0])], "h_idx": int(hs[1]),
                    "enq_t": 200})
        out.append({"v": P[int(keys[0])], "h_idx": int(hs[2]),
                    "enq_t": 50})
        # REWRITE verdicts (DESIGN.md §18): fresh keys (P[24]/P[25] =
        # prompts p16/p17, untouched by the prefix and the burst above)
        # so both are always admitted, and the crash matrix gets kill
        # points inside the rewrite append->upsert window. The tailored
        # text and the query-class key live only in the payload/WAL
        # record -- recovery must reconstruct both.
        out.append({"v": P[24], "h_idx": int(hs[3]), "enq_t": 300,
                    "outcome": "rewrite", "rewritten": "tailored(p16)",
                    "judge_args": {"q_cls": 116}})
        out.append({"v": P[25], "h_idx": int(hs[4]), "enq_t": 301,
                    "outcome": "rewrite", "rewritten": "tailored(p17)",
                    "judge_args": {"q_cls": 117}})
        return out
""")

N_BURST = 16          # len(payloads()) — pinned by a test below
N_DURABLE = 13        # _n_journaled(payloads()) — the 3 LWW-stale
                      # records (two out-of-order re-promotions and the
                      # enq_t=50 churn tail) never reach the WAL; both
                      # rewrite records (fresh keys) always do


def _n_journaled(burst) -> int:
    """How many of ``burst``'s records the WAL admits, applied in
    order: a record is journaled (and upserted) unless an earlier
    record already wrote its key with a strictly newer ``enq_t`` —
    the policy's LWW guard, which now runs BEFORE the append. Keys
    here are orthonormal, so dedup is exact-match; the served prefix
    (written_at <= N_PREFIX) never outranks the burst (enq_t >= 50);
    capacity covers every distinct key, so no eviction breaks the
    per-key bookkeeping."""
    latest: dict = {}
    n = 0
    for p in burst:
        key = p["v"].tobytes()
        if key in latest and latest[key] > p["enq_t"]:
            continue
        latest[key] = p["enq_t"]
        n += 1
    return n

CHILD = COMMON + textwrap.dedent("""
    import sys
    from pathlib import Path
    from repro.core.promo_wal import PromotionWAL
    from repro.serving import persist

    snap = Path(sys.argv[1])

    class HookedWAL(PromotionWAL):
        # the print lands between the (fsynced) append and the tier
        # upsert: the parent killing on this line crashes the process
        # inside the write-ahead window
        def append(self, rec):
            seq = super().append(rec)
            print(f"APPENDED {seq}", flush=True)
            return seq

    pol = mk_policy(wal=HookedWAL(snap / "promo.wal", fsync_every=1))
    for i in range(N_PREFIX):
        pol.serve(f"p{i}")
    persist.save_snapshot(snap, pol)
    print("SNAP", flush=True)
    for i, p in enumerate(payloads()):
        pol._promote(p)
        print(f"PROMO {i + 1}", flush=True)
    print("DONE", flush=True)
""")

_NS: dict = {}


def _ns():
    """Parent-side instance of the shared world (lazy: exec once)."""
    if not _NS:
        exec(COMMON, _NS)
    return _NS


def _run_child(tmp: Path, event: str, k):
    """Run the child; SIGKILL it right after it prints the ``k``-th
    ``event`` line (``DONE``/``SNAP`` take ``k=None``/0). Returns the
    lines seen before the kill."""
    proc = subprocess.Popen(
        [sys.executable, "-c", CHILD, str(tmp)],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=ENV)
    seen, n_event = [], 0
    deadline = time.monotonic() + 300
    try:
        for line in proc.stdout:
            assert time.monotonic() < deadline, "child wedged"
            line = line.strip()
            seen.append(line)
            if line == "DONE":
                assert event == "DONE", \
                    f"child finished before {event} {k}: {seen}"
                proc.wait(timeout=60)
                return seen
            if line.startswith(event):
                n_event += 1
                if event == "SNAP" or n_event == k:
                    os.kill(proc.pid, signal.SIGKILL)
                    break
        else:
            pytest.fail(f"child exited before {event} {k}: {seen}\n"
                        f"{proc.stderr.read()}")
        proc.wait(timeout=60)
        assert "SNAP" in seen, "killed before the snapshot existed"
        return seen
    finally:
        proc.stderr.close()
        proc.stdout.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)


def _state(pol) -> tuple:
    return (np.asarray(pol.dyn.emb).tobytes(),
            pol._valid_np.tolist(), pol._written_at_np.tolist(),
            pol._last_used_np.tolist(), pol._static_origin_np.tolist(),
            pol._rewritten_np.tolist(),
            np.asarray(pol.dyn.cls).tolist(),
            np.asarray(pol.dyn.answer_ref).tolist(),
            list(pol.dyn_answers), pol.t)


def _decisions(pol):
    out = []
    for i in range(24):
        r = pol.serve(f"p{i}")
        out.append((r.served_by, str(r.answer), bool(r.static_origin),
                    round(float(r.similarity), 5)))
    return out


def _check_recovery(tmp: Path):
    """Recover from the (possibly crashed) child's files and compare
    to the uninterrupted reference, state- and decision-wise."""
    from repro.core.promo_wal import replay_into
    from repro.serving import persist

    ns = _ns()
    burst = ns["payloads"]()
    assert len(burst) == N_BURST
    assert _n_journaled(burst) == N_DURABLE

    recovered = ns["mk_policy"]()
    persist.restore_policy(recovered, tmp)
    rep = replay_into(recovered, tmp / "promo.wal")
    r = rep["replayed"]          # durable records; SIGKILL may have
    assert 0 <= r <= N_DURABLE   # torn the tail (rep["clean"] False)
    # Client retry of everything possibly lost. The journal admits a
    # subsequence of the burst, so its r records cover AT LEAST the
    # first r burst entries — burst[r:] is a superset of what never
    # became durable, and re-applying already-applied records is a
    # no-op under the same LWW/dedup guards replay relies on.
    for p in burst[r:]:
        recovered._promote(p, journal=False)
    mid = _state(recovered)
    # double recovery: replaying the same journal again must be a no-op
    rep2 = replay_into(recovered, tmp / "promo.wal")
    assert rep2["replayed"] == r
    assert _state(recovered) == mid, "second replay changed state"

    reference = ns["mk_policy"]()
    persist.restore_policy(reference, tmp)
    for p in burst:
        reference._promote(p, journal=False)

    assert _state(recovered) == _state(reference), \
        f"recovered state != uninterrupted (r={r} durable records)"
    dec = _decisions(recovered)
    assert dec == _decisions(reference), \
        f"post-recovery decisions diverge (r={r})"
    # the rewrite records' tailored text must survive the crash intact:
    # p16/p17 repeat the rewritten keys, so they serve the REWRITE
    # entries (answer_ref=-2 provenance) with the exact journaled text
    for i in (16, 17):
        assert dec[i] == ("rewritten", f"tailored(p{i})", True, 1.0), \
            f"rewritten entry for p{i} lost/garbled: {dec[i]}"
    return r


# the fast subset: one kill per distinct write-path region (APPENDED 12
# = inside the FIRST REWRITE record's append->upsert window: the
# tailored text is durable, its upsert possibly unapplied)
FAST_POINTS = [("SNAP", 0), ("APPENDED", 9), ("APPENDED", 12),
               ("PROMO", 5), ("DONE", None)]


@pytest.mark.parametrize("event,k", FAST_POINTS,
                         ids=[f"{e}-{k}" for e, k in FAST_POINTS])
def test_sigkill_recovery(tmp_path, event, k):
    _run_child(tmp_path, event, k)
    r = _check_recovery(tmp_path)
    burst = _ns()["payloads"]()
    if event == "DONE":
        # every ADMITTED record was durable; the LWW-stale ones never
        # journaled in the first place
        assert r == N_DURABLE
    elif event == "APPENDED":
        assert r >= k            # APPENDED lines count journal appends
    elif event == "PROMO":
        # promotions 1..k fully applied => their admitted subset is
        # durable (the k+1-th append may be torn)
        assert r >= _n_journaled(burst[:k])


@pytest.mark.slow
@pytest.mark.parametrize(
    "event,k",
    [("PROMO", k) for k in range(1, N_BURST + 1)]
    + [("APPENDED", k) for k in range(1, N_DURABLE + 1)],
    ids=lambda v: str(v))
def test_sigkill_recovery_matrix(tmp_path, event, k):
    """Every kill point in the burst, on both sides of the
    append->upsert window (the full fault-injection matrix)."""
    _run_child(tmp_path, event, k)
    _check_recovery(tmp_path)
