"""Degenerate-embedding guard (zero-norm / non-finite keys).

``l2_normalize`` maps a zero embedding to zero and passes NaN/inf
through. Before the guard, the serving path inserted such rows into the
dynamic tier on a backend miss — and one non-finite key poisons every
later masked argmax over the tier (NaN similarity against everything).
The guard serves these requests via the backend without caching them
and without a grey-zone trigger, on both the scalar and batched paths.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.judge import OracleJudge
from repro.core.policy import KritesPolicy, _usable_rows
from repro.core.tiers import CacheConfig, make_static_tier

D = 8


def _static(n=4):
    emb = np.eye(D, dtype=np.float32)[:n]
    tier = make_static_tier(jnp.asarray(emb),
                            jnp.arange(n, dtype=jnp.int32))
    answers = [f"curated-{i}" for i in range(n)]
    texts = [f"canonical prompt {i}" for i in range(n)]
    return tier, answers, texts


def _para(i=0, j=1, w=0.3):
    v = np.eye(D, dtype=np.float32)[i] + w * np.eye(D, dtype=np.float32)[j]
    return (v / np.linalg.norm(v)).astype(np.float32)


def _policy(emb_map):
    tier, answers, texts = _static()
    return KritesPolicy(
        CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=4), tier, answers,
        lambda p: emb_map[p], lambda p: f"gen({p})", OracleJudge(), d=D,
        n_workers=0, static_texts=texts)


def test_usable_rows_mask():
    good = _para()
    rows = np.stack([good, np.zeros(D, np.float32),
                     np.full(D, np.nan, np.float32),
                     np.full(D, np.inf, np.float32)])
    # the mask is evaluated post-normalization in the policy; emulate
    from repro.index.flat import l2_normalize
    rows = np.asarray(l2_normalize(jnp.asarray(rows)))
    assert _usable_rows(rows).tolist() == [True, False, False, False]


def test_scalar_zero_embedding_served_by_backend_not_cached():
    emb = {"z": np.zeros(D, np.float32), "p": _para(0, 1, 0.6)}
    pol = _policy(emb)
    res = pol.serve("z")
    assert res.served_by == "backend" and res.answer == "gen(z)"
    assert not pol._valid_np.any(), "degenerate key was cached"
    assert pol.pool.stats.submitted == 0, "degenerate grey trigger"
    # the cache still works for normal traffic afterwards
    assert pol.serve("p").served_by == "backend"     # miss -> insert
    assert pol.serve("p").served_by == "dynamic"     # cached fine


def test_scalar_nan_embedding_does_not_poison_cache():
    emb = {"bad": np.full(D, np.nan, np.float32),
           "p": _para(0, 1, 0.6)}
    pol = _policy(emb)
    assert pol.serve("p").served_by == "backend"     # insert good key
    assert pol.serve("bad").served_by == "backend"
    assert pol.serve("bad").answer == "gen(bad)"
    # old code: the NaN row lands in the tier, every later masked
    # argmax sees NaN sims and the dynamic hit below disappears
    r = pol.serve("p")
    assert r.served_by == "dynamic" and r.answer == "gen(p)"
    assert int(pol._valid_np.sum()) == 1


def test_batch_mixed_good_and_degenerate_rows():
    emb = {"a": _para(0, 1, 0.5), "z": np.zeros(D, np.float32),
           "n": np.full(D, np.nan, np.float32), "b": _para(2, 3, 0.5)}
    pol = _policy(emb)
    res = pol.serve_batch(["a", "z", "n", "b"])
    assert [r.served_by for r in res] == ["backend"] * 4
    assert [r.answer for r in res] == \
        ["gen(a)", "gen(z)", "gen(n)", "gen(b)"]
    # only the two good rows were cached
    assert int(pol._valid_np.sum()) == 2
    assert sorted(a for a in pol.dyn_answers if a is not None) == \
        ["gen(a)", "gen(b)"]
    # a repeat batch hits the cache for good rows, backend for bad ones
    res2 = pol.serve_batch(["a", "n", "b"])
    assert [r.served_by for r in res2] == ["dynamic", "backend", "dynamic"]
    assert res2[0].answer == "gen(a)" and res2[2].answer == "gen(b)"
    assert int(pol._valid_np.sum()) == 2


def test_batch_all_degenerate_rows():
    emb = {"z": np.zeros(D, np.float32),
           "n": np.full(D, np.nan, np.float32)}
    pol = _policy(emb)
    res = pol.serve_batch(["z", "n"])
    assert [r.served_by for r in res] == ["backend", "backend"]
    assert [r.answer for r in res] == ["gen(z)", "gen(n)"]
    assert not pol._valid_np.any()
    assert pol.pool.stats.submitted == 0
