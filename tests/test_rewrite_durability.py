"""Durability + tier-contract properties of REWRITE promotions
(DESIGN.md §18).

A REWRITE verdict lands a *tailored* answer keyed to the triggering
query's embedding and class, with the ``answer_ref == -2`` provenance
sentinel. It must honor every contract the APPROVE path honors:

- **LWW**: a rewrite whose task enqueued before a newer write on the
  same key is stale state — skipped entirely (no tier write, no WAL
  record, no mirror flip);
- **dedup**: a rewrite within ``dup_threshold`` of a live entry
  overwrites that row in place instead of taking a second slot;
- **WAL round-trip**: the journal record carries the tailored text and
  the query-class key (neither derivable from the static tier), and
  ``replay_into`` reconstructs the full entry — provenance sentinel,
  class, text — on a fresh process;
- **snapshot round-trip**: the rewritten mirror survives
  save/restore (format 4 stores it; restores of older snapshots
  derive it from the ``answer_ref == -2`` column);
- **live end-to-end**: a grey-zone trigger with a rewriting judge
  serves its OWN request unchanged (backend — the critical-path
  invariant), and only the later repeat serves the tailored text as
  ``served_by == "rewritten"``; degradations (no budget) count on
  ``rewrite_rate_limited`` and leave no rewritten entry.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import tiers as T
from repro.core.judge import OracleJudge, template_rewriter
from repro.core.policy import KritesPolicy
from repro.core.promo_wal import PromotionWAL, replay_into
from repro.serving import persist

D, S = 32, 8


def _pool(n, d, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(d, n)))
    return np.ascontiguousarray(q.T, np.float32)


P = _pool(32, D)
GREY = {f"g{i}": (0.8 * P[i % S] + 0.6 * P[8 + i]).astype(np.float32)
        for i in range(16)}


def mk_policy(wal=None, rewriter=template_rewriter, rewritable=True,
              n_workers=0, **cfg_kw):
    tier = T.StaticTier(emb=jnp.asarray(P[:S]),
                        cls=jnp.arange(S, dtype=jnp.int32),
                        answer_ref=jnp.arange(S, dtype=jnp.int32))
    cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=16,
                        rewrite=True, **cfg_kw)
    judge = OracleJudge(
        rewritable=(lambda qc, hc, qt, ht: True) if rewritable else None)
    return KritesPolicy(cfg, tier, [f"a{i}" for i in range(S)],
                        embed_fn=lambda p: GREY[p],
                        backend_fn=lambda p: "gen(" + p + ")",
                        judge_fn=judge, d=D, n_workers=n_workers,
                        wal=wal, rewriter=rewriter)


def _rw_payload(v, enq_t, text, q_cls=42, h_idx=0):
    return {"v": np.asarray(v, np.float32), "h_idx": h_idx,
            "enq_t": enq_t, "outcome": "rewrite", "rewritten": text,
            "judge_args": {"q_cls": q_cls}}


def test_rewrite_never_clobbers_newer_lww_entry(tmp_path):
    pol = mk_policy(wal=PromotionWAL(tmp_path / "p.wal", fsync_every=1))
    pol.serve("g0")                       # miss write-back, written_at=1
    before = (list(pol.dyn_answers), pol._rewritten_np.copy(),
              np.asarray(pol.dyn.answer_ref).copy())

    # stale rewrite: enqueued BEFORE the write-back landed
    pol._promote(_rw_payload(GREY["g0"], enq_t=0, text="stale-tailored"))
    assert list(pol.dyn_answers) == before[0]
    assert (pol._rewritten_np == before[1]).all()
    assert (np.asarray(pol.dyn.answer_ref) == before[2]).all()
    assert pol.wal.seq == 0, "LWW-skipped rewrite must not journal"

    # fresh rewrite on the same key: overwrites in place (dedup), flips
    # provenance, journals
    pol._promote(_rw_payload(GREY["g0"], enq_t=5, text="fresh-tailored"))
    slot = int(np.flatnonzero(pol._rewritten_np)[0])
    assert pol.dyn_answers[slot] == "fresh-tailored"
    assert int(np.asarray(pol.dyn.answer_ref)[slot]) == -2
    assert int(np.asarray(pol.dyn.cls)[slot]) == 42
    assert pol.wal.seq == 1
    assert int(pol._valid_np.sum()) == 1, "dedup must not take a 2nd slot"
    pol.wal.close()


def test_rewrite_dedups_within_threshold():
    pol = mk_policy()
    pol._promote(_rw_payload(GREY["g1"], enq_t=1, text="v1", q_cls=7))
    assert int(pol._valid_np.sum()) == 1
    # re-promotion of the same key (idempotent retry / straggler dup):
    # in-place overwrite, still one slot, newest text wins
    pol._promote(_rw_payload(GREY["g1"], enq_t=2, text="v2", q_cls=7))
    assert int(pol._valid_np.sum()) == 1
    slot = int(np.flatnonzero(pol._valid_np)[0])
    assert pol.dyn_answers[slot] == "v2"
    assert pol._rewritten_np[slot]
    # a distinct key takes its own slot
    pol._promote(_rw_payload(GREY["g2"], enq_t=3, text="other", q_cls=8))
    assert int(pol._valid_np.sum()) == 2


def test_wal_replay_reconstructs_rewritten_entry(tmp_path):
    wal = PromotionWAL(tmp_path / "p.wal", fsync_every=1)
    pol = mk_policy(wal=wal)
    pol._promote(_rw_payload(GREY["g3"], enq_t=10, text="tailored-g3",
                             q_cls=33))
    state = (list(pol.dyn_answers), pol._rewritten_np.copy(),
             np.asarray(pol.dyn.cls).copy(),
             np.asarray(pol.dyn.answer_ref).copy())
    wal.close()

    fresh = mk_policy()
    rep = replay_into(fresh, tmp_path / "p.wal")
    assert rep["replayed"] == 1 and rep["clean"]
    assert list(fresh.dyn_answers) == state[0]
    assert (fresh._rewritten_np == state[1]).all()
    assert (np.asarray(fresh.dyn.cls) == state[2]).all()
    assert (np.asarray(fresh.dyn.answer_ref) == state[3]).all()
    # the reconstructed entry actually serves: repeat of g3 gets the
    # tailored text from the dynamic tier, attributed to "rewritten"
    r = fresh.serve("g3")
    assert (r.served_by, r.answer, r.static_origin) == \
        ("rewritten", "tailored-g3", True)


def test_snapshot_roundtrips_rewritten_mirror(tmp_path):
    pol = mk_policy()
    pol._promote(_rw_payload(GREY["g4"], enq_t=4, text="snap-tailored",
                             q_cls=44))
    persist.save_snapshot(tmp_path, pol)
    fresh = mk_policy()
    persist.restore_policy(fresh, tmp_path)
    assert (fresh._rewritten_np == pol._rewritten_np).all()
    r = fresh.serve("g4")
    assert (r.served_by, r.answer) == ("rewritten", "snap-tailored")


def test_live_rewrite_serves_only_later_repeats():
    pol = mk_policy(n_workers=2)
    # first-seen grey query with a foreign class: the judge would
    # reject, the rewritable predicate upgrades to REWRITE
    r1 = pol.serve("g5", meta={"cls": 99})
    assert r1.served_by == "backend", \
        "the triggering request must never see its own verdict"
    assert r1.answer == "gen(g5)"
    pol.pool.drain()
    st = pol.stats()
    assert st["rewritten"] == 1 and st["approved"] == 0

    r2 = pol.serve("g5")
    assert r2.served_by == "rewritten"
    assert r2.answer == template_rewriter("g5", "a5", "a5")
    assert r2.static_origin
    assert round(float(r2.similarity), 6) == 1.0
    # a rewritten hit is a promoted pointer: the dedup gate must not
    # re-submit it for judging
    assert pol.pool.stats.submitted == 1
    assert pol.stats()["rewritten_hit_rate"] == 0.5
    pol.pool.stop()


def test_rewrite_rate_limit_degrades_to_reject():
    pol = mk_policy(n_workers=2, rewrite_rate=0.0)
    pol.serve("g6", meta={"cls": 99})
    pol.pool.drain()
    st = pol.stats()
    assert st["rewrite_rate_limited"] == 1
    assert st["rejected"] == 1 and st["rewritten"] == 0
    assert not pol._rewritten_np.any()
    r = pol.serve("g6")     # repeat serves the plain write-back
    assert (r.served_by, r.answer, r.static_origin) == \
        ("dynamic", "gen(g6)", False)
    pol.pool.stop()


def test_missing_rewriter_counts_rewrite_failed():
    pol = mk_policy(n_workers=2, rewriter=None)
    pol.serve("g7", meta={"cls": 99})
    pol.pool.drain()
    st = pol.stats()
    assert st["rewrite_failed"] == 1
    assert st["rejected"] == 1 and st["rewritten"] == 0
    assert not pol._rewritten_np.any()
    pol.pool.stop()
