"""Async VerifyAndPromote pool: dedup, rate limiting, retry, ordering."""
import threading
import time

from repro.core.async_queue import VerifyAndPromotePool


def test_basic_judge_and_promote():
    promoted = []
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: p["ok"],
        promote_fn=lambda p: promoted.append(p["id"]))
    for i in range(10):
        pool.submit(key=("q", i), payload={"ok": i % 2 == 0, "id": i})
    pool.drain()
    pool.stop()
    assert sorted(promoted) == [0, 2, 4, 6, 8]
    assert pool.stats.judged == 10 and pool.stats.approved == 5


def test_dedup_inflight():
    gate = threading.Event()
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: gate.wait(2) or True,
        promote_fn=lambda p: None, n_workers=1)
    assert pool.submit(("a", 1), {"x": 1})
    assert not pool.submit(("a", 1), {"x": 1})   # deduped while inflight
    gate.set()
    pool.drain()
    pool.stop()
    assert pool.stats.deduped == 1


def test_rate_limit():
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: True, promote_fn=lambda p: None,
        rate_per_s=0.0001)
    accepted = sum(pool.submit(("k", i), {}) for i in range(20))
    pool.stop()
    assert accepted <= 1
    assert pool.stats.rate_limited >= 19


def test_retry_then_success():
    attempts = {"n": 0}

    def judge(p):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return True

    done = []
    pool = VerifyAndPromotePool(judge_fn=judge,
                                promote_fn=lambda p: done.append(1),
                                n_workers=1, backoff_s=0.01)
    pool.submit(("k", 0), {})
    pool.drain(5)
    pool.stop()
    assert done == [1]
    assert pool.stats.retried == 2


def test_never_blocks_serving_path():
    """submit() must return fast even with a slow judge."""
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: time.sleep(0.5) or True,
        promote_fn=lambda p: None, n_workers=1)
    t0 = time.monotonic()
    for i in range(50):
        pool.submit(("k", i), {})
    assert time.monotonic() - t0 < 0.2
    pool.stop()
