"""Async VerifyAndPromote pool: dedup, rate limiting, retry, ordering."""
import threading
import time

from repro.core.async_queue import VerifyAndPromotePool


def test_basic_judge_and_promote():
    promoted = []
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: p["ok"],
        promote_fn=lambda p: promoted.append(p["id"]))
    for i in range(10):
        pool.submit(key=("q", i), payload={"ok": i % 2 == 0, "id": i})
    pool.drain()
    pool.stop()
    assert sorted(promoted) == [0, 2, 4, 6, 8]
    assert pool.stats.judged == 10 and pool.stats.approved == 5


def test_dedup_inflight():
    gate = threading.Event()
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: gate.wait(2) or True,
        promote_fn=lambda p: None, n_workers=1)
    assert pool.submit(("a", 1), {"x": 1})
    assert not pool.submit(("a", 1), {"x": 1})   # deduped while inflight
    gate.set()
    pool.drain()
    pool.stop()
    assert pool.stats.deduped == 1


def test_rate_limit():
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: True, promote_fn=lambda p: None,
        rate_per_s=0.0001)
    accepted = sum(pool.submit(("k", i), {}) for i in range(20))
    pool.stop()
    assert accepted <= 1
    assert pool.stats.rate_limited >= 19


def test_retry_then_success():
    attempts = {"n": 0}

    def judge(p):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return True

    done = []
    pool = VerifyAndPromotePool(judge_fn=judge,
                                promote_fn=lambda p: done.append(1),
                                n_workers=1, backoff_s=0.01)
    pool.submit(("k", 0), {})
    pool.drain(5)
    pool.stop()
    assert done == [1]
    assert pool.stats.retried == 2


def test_promote_failure_retries_until_it_lands():
    """A transient promote_fn failure must hit the retry path, not be
    dropped: the inflight key stays live until the promote lands, so
    first-completion-wins bookkeeping can't eat the retry, approved
    counts only landed promotions, and drain() waits through the
    backoff."""
    attempts = {"n": 0}
    done = []

    def promote(p):
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient promote failure")
        done.append(p["id"])

    pool = VerifyAndPromotePool(judge_fn=lambda p: True,
                                promote_fn=promote, n_workers=1,
                                backoff_s=0.01)
    pool.submit(("k", 0), {"id": 0})
    pool.drain(5)
    pool.stop()
    assert done == [0]
    assert pool.stats.retried == 2
    assert pool.stats.approved == 1
    assert pool.stats.duplicate_completions == 0


def test_straggler_redispatch_first_completion_wins():
    """A task wedged past the deadline is re-dispatched to another
    worker; the re-dispatched copy completes and promotes, and when the
    wedged original finally finishes it finds the key already completed
    and must NOT promote again (first completion wins; the upsert is
    idempotent anyway, but the duplicate is detected and counted)."""
    gate = threading.Event()
    stuck_started = threading.Event()
    promoted = []
    calls = {"n": 0}
    lock = threading.Lock()

    def judge(p):
        with lock:
            calls["n"] += 1
            wedged = calls["n"] == 1
        if wedged:
            stuck_started.set()
            gate.wait(10)                 # first dispatch straggles
        return True

    pool = VerifyAndPromotePool(
        judge_fn=judge, promote_fn=lambda p: promoted.append(p["id"]),
        n_workers=2, straggler_deadline_s=0.15)
    assert pool.submit(("k", 0), {"id": 0})
    assert stuck_started.wait(2)

    # the reaper re-enqueues; the free worker completes the duplicate
    t0 = time.monotonic()
    while not promoted and time.monotonic() - t0 < 5:
        time.sleep(0.01)
    assert promoted == [0], "re-dispatched copy should have completed"
    assert pool.stats.redispatched >= 1

    gate.set()                            # release the wedged original
    t0 = time.monotonic()
    while pool.stats.duplicate_completions < 1 \
            and time.monotonic() - t0 < 5:
        time.sleep(0.01)
    pool.drain(5)
    pool.stop()
    assert promoted == [0], "late duplicate must not promote again"
    assert pool.stats.duplicate_completions >= 1
    assert pool.stats.approved == 1       # one winning completion
    assert pool.stats.judged >= 2         # both copies ran the judge


def test_backoff_is_not_redispatched_and_does_not_block_workers():
    """Reaper vs retry-backoff regression (fails on the old code, two
    ways). The old retry path slept the backoff inside the worker and
    re-enqueued without resetting the inflight dispatch clock ``e[0]``,
    so (a) ``_reap_stragglers`` re-dispatched a task that was merely
    backing off — duplicate judge calls counted as ``redispatched`` —
    and (b) the sleep blocked the worker slot for the whole backoff.
    Now the retry parks on a deadline heap with the dispatch clock
    pushed to its ready time: no spurious redispatch, and the single
    worker stays free for other tasks during the backoff."""
    calls = {"k0": 0}
    promoted = []
    other_done = threading.Event()

    def judge(p):
        if p["id"] == 0:
            calls["k0"] += 1
            if calls["k0"] == 1:
                raise RuntimeError("transient")   # -> 2.0 s backoff
        return True

    def promote(p):
        promoted.append(p["id"])
        if p["id"] == 1:
            other_done.set()

    # backoff (1.0 * 2^1 = 2.0 s) far exceeds the straggler deadline
    # (0.2 s): the old code's reaper fires several times during it
    pool = VerifyAndPromotePool(judge_fn=judge, promote_fn=promote,
                                n_workers=1, backoff_s=1.0,
                                straggler_deadline_s=0.2)
    t0 = time.monotonic()
    pool.submit(("k", 0), {"id": 0})
    time.sleep(0.05)                  # let the failing attempt start
    pool.submit(("k", 1), {"id": 1})
    # the single worker must process task 1 while task 0 backs off
    assert other_done.wait(1.0), \
        "worker slot was blocked for the backoff duration"
    assert time.monotonic() - t0 < 2.0     # well inside k0's backoff
    assert pool.stats.redispatched == 0, \
        "reaper re-dispatched a task that was merely backing off"

    pool.drain(10)                    # k0 retries after its backoff
    pool.stop()
    assert sorted(promoted) == [0, 1]
    assert pool.stats.redispatched == 0
    assert pool.stats.retried == 1
    assert pool.stats.approved == 2
    assert pool.stats.duplicate_completions == 0
    assert pool.stats.judged == 2     # k0 success + k1 (fail doesn't count)


def test_straggler_key_free_for_resubmission_after_completion():
    """Once the winner completes, the key leaves the inflight set: a
    fresh submit of the same key must be accepted, not deduped."""
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: True, promote_fn=lambda p: None, n_workers=1)
    assert pool.submit(("k", 1), {})
    pool.drain(5)
    assert pool.submit(("k", 1), {})      # same key, new task
    pool.drain(5)
    pool.stop()
    assert pool.stats.deduped == 0
    assert pool.stats.judged == 2


def test_concurrent_submit_dedup_and_counters_consistent():
    """Hammer submit() from many threads with overlapping keys: every
    submission is accounted exactly once (accepted, deduped, or
    rate-limited) and every accepted task completes."""
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: True, promote_fn=lambda p: None, n_workers=2)
    n_threads, per = 8, 50

    def client(k):
        for i in range(per):
            pool.submit(("key", i % 17), {"id": i})

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    pool.drain(10)
    pool.stop()
    s = pool.stats
    assert s.submitted == n_threads * per
    accepted = s.submitted - s.deduped - s.rate_limited - s.dropped_full
    assert s.judged == accepted
    assert s.approved == accepted


def test_never_blocks_serving_path():
    """submit() must return fast even with a slow judge."""
    pool = VerifyAndPromotePool(
        judge_fn=lambda p: time.sleep(0.5) or True,
        promote_fn=lambda p: None, n_workers=1)
    t0 = time.monotonic()
    for i in range(50):
        pool.submit(("k", i), {})
    assert time.monotonic() - t0 < 0.2
    pool.stop()
