"""simulate_sweep equivalence contract (DESIGN.md §10): per config,
decision-for-decision equal to sequential `simulate` calls — even though
the sweep runs one max-capacity tier with per-config masks and one
shared ring — plus SweepConfig construction and summary helpers.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulate import (SimResult, simulate, simulate_sweep,
                                 slice_config, summarize, summarize_sweep,
                                 sweep_from_configs, sweep_grid)
from repro.core.tiers import CacheConfig


def _mk_trace(n=1500, s=64, d=24, seed=11):
    rng = np.random.default_rng(seed)
    s_emb = rng.standard_normal((s, d)).astype(np.float32)
    s_emb /= np.linalg.norm(s_emb, axis=1, keepdims=True)
    s_cls = np.arange(s, dtype=np.int32)
    q = rng.standard_normal((n, d)).astype(np.float32)
    mix = rng.random(n) < 0.7
    tgt = rng.integers(0, s, n)
    q[mix] = 0.35 * q[mix] + 0.65 * s_emb[tgt[mix]]
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    cls = np.where(mix & (rng.random(n) < 0.8), tgt,
                   rng.integers(0, s, n)).astype(np.int32)
    return (jnp.asarray(s_emb), jnp.asarray(s_cls), jnp.asarray(q),
            jnp.asarray(cls))


# heterogeneous grid: thresholds, sigma, capacity, latency, rate, policy
SWEPT = [
    (CacheConfig(0.92, 0.92, sigma_min=0.0, capacity=96,
                 judge_latency=4), True),
    (CacheConfig(0.88, 0.90, sigma_min=0.4, capacity=32,
                 judge_latency=24, judge_rate=0.2), True),
    (CacheConfig(0.95, 0.85, sigma_min=0.6, capacity=128,
                 judge_latency=1), True),
    (CacheConfig(0.92, 0.92, sigma_min=0.0, capacity=96,
                 judge_latency=4), False),
    (CacheConfig(0.90, 0.90, sigma_min=0.2, capacity=64,
                 judge_latency=70), True),
    (CacheConfig(0.92, 0.90, sigma_min=0.1, capacity=96,
                 judge_latency=4, dedup=False), True),
]


@pytest.fixture(scope="module")
def sweep_and_sequential():
    args = _mk_trace()
    sweep = sweep_from_configs([c for c, _ in SWEPT],
                               [k for _, k in SWEPT])
    res = simulate_sweep(*args, sweep)
    seq = [simulate(*args, cfg, krites=kr) for cfg, kr in SWEPT]
    return res, seq


def test_sweep_equals_sequential_decision_for_decision(
        sweep_and_sequential):
    res, seq = sweep_and_sequential
    for i, one in enumerate(seq):
        got = slice_config(res, i)
        for field in SimResult._fields:
            a, b = np.asarray(getattr(one, field)), \
                np.asarray(getattr(got, field))
            assert np.array_equal(a, b), (
                f"config {i} field {field}: sweep != sequential")


def test_summarize_sweep_equals_per_config_summaries(
        sweep_and_sequential):
    res, seq = sweep_and_sequential
    rows = summarize_sweep(res)
    assert len(rows) == len(seq)
    for row, one in zip(rows, seq):
        assert row == summarize(one)


def test_result_shapes_carry_config_axis(sweep_and_sequential):
    res, _ = sweep_and_sequential
    k = len(SWEPT)
    assert res.served_by.shape[0] == k
    assert res.correct.shape == res.served_by.shape
    assert res.judge_calls.shape == (k,)


def test_sweep_grid_is_row_major_cartesian():
    base = CacheConfig(0.9, 0.9, capacity=16)
    sweep = sweep_grid(base, krites=True, tau_static=[0.8, 0.9],
                       tau_dynamic=[0.7, 0.75, 0.8])
    assert sweep.n == 6
    ts = np.asarray(sweep.tau_static)
    td = np.asarray(sweep.tau_dynamic)
    assert np.allclose(ts, [0.8] * 3 + [0.9] * 3)
    assert np.allclose(td, [0.7, 0.75, 0.8] * 2)
    # un-swept fields come from base
    assert np.all(np.asarray(sweep.capacity) == 16)
    assert np.all(np.asarray(sweep.krites))


def test_mixed_dedup_sweep_applies_each_configs_flag():
    """dedup is swept per config: a repeated grey-zone query keeps being
    judged with dedup=False but is judged ~once with dedup=True (the
    promoted pointer suppresses re-enqueue). Both must match their
    sequential runs inside one mixed sweep."""
    rng = np.random.default_rng(2)
    d = 16
    s_emb = rng.standard_normal((4, d)).astype(np.float32)
    s_emb /= np.linalg.norm(s_emb, axis=1, keepdims=True)
    s_cls = jnp.arange(4, dtype=jnp.int32)
    para = s_emb[0] + 0.30 * s_emb[1]
    para /= np.linalg.norm(para)
    q = jnp.asarray(np.repeat(para[None], 200, axis=0))
    cls = jnp.zeros((200,), jnp.int32)
    cfgs = [CacheConfig(0.995, 0.995, judge_latency=1, dedup=True),
            CacheConfig(0.995, 0.995, judge_latency=1, dedup=False)]
    res = simulate_sweep(jnp.asarray(s_emb), s_cls, q, cls,
                         sweep_from_configs(cfgs, True))
    seq = [simulate(jnp.asarray(s_emb), s_cls, q, cls, c, krites=True)
           for c in cfgs]
    for i in range(2):
        got = slice_config(res, i)
        for field in SimResult._fields:
            assert np.array_equal(np.asarray(getattr(seq[i], field)),
                                  np.asarray(getattr(got, field)))
    # and the flag actually changes behavior
    assert int(seq[1].judge_calls) > int(seq[0].judge_calls) + 50


def test_sweep_capacity_exceeding_tier_raises():
    args = _mk_trace(n=100)
    sweep = sweep_from_configs([CacheConfig(0.9, 0.9, capacity=64)], True)
    with pytest.raises(ValueError, match="capacity"):
        simulate_sweep(*args, sweep, max_capacity=32)


def test_single_config_sweep_equals_simulate():
    args = _mk_trace(n=700, seed=5)
    cfg = CacheConfig(0.9, 0.88, sigma_min=0.3, capacity=48,
                      judge_latency=12)
    one = simulate(*args, cfg, krites=True)
    via_sweep = slice_config(
        simulate_sweep(*args, sweep_from_configs([cfg], True)), 0)
    for field in SimResult._fields:
        assert np.array_equal(np.asarray(getattr(one, field)),
                              np.asarray(getattr(via_sweep, field)))
