"""Run the suite with or without ``hypothesis`` installed.

Property-based tests import ``given, settings, st`` from this shim instead
of from ``hypothesis`` directly. When hypothesis is available they run as
normal property tests; when it is missing they are collected but skipped,
and every example-based test in the same module still runs (a plain
``pytest.importorskip`` at module scope would skip those too).
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the decorated test never runs)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*_a, **_k):
        return lambda fn: fn

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped(*args, **kwargs):
                pass  # pragma: no cover
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
