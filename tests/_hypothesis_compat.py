"""Run the suite with or without ``hypothesis`` installed.

Property-based tests import ``given, settings, st`` from this shim
instead of from ``hypothesis`` directly. When hypothesis is available
they run as full property tests (shrinking, example database, the
works). When it is missing they still RUN — the fallback draws a fixed
number of deterministic pseudo-random examples per test (seeded from the
test's qualified name, so failures reproduce) instead of being skipped.
A plain ``pytest.importorskip`` at module scope would skip every
example-based test in the same module too; the old shim skipped just
the property tests, which silently dropped their coverage on machines
without hypothesis — the mini-runner keeps them counting.

The fallback implements only the strategy surface this suite uses:
``st.integers``, ``st.floats``, ``st.booleans``, ``st.sampled_from``,
``st.lists``, ``st.tuples``, plus ``.map`` / ``.filter``.
"""
from __future__ import annotations

import zlib

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred, _tries: int = 100):
            def draw(rng):
                for _ in range(_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too restrictive "
                                 "for the hypothesis-fallback runner")
            return _Strategy(draw)

    class _St:
        @staticmethod
        def integers(min_value=-2**31, max_value=2**31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(
                lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem._draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s._draw(rng) for s in strats))

    st = _St()

    def given(*strats, **kw_strats):
        if kw_strats:
            raise TypeError("fallback @given supports positional "
                            "strategies only")

        def deco(fn):
            def runner(*args, **kwargs):
                n = getattr(runner, "_compat_max_examples",
                            _FALLBACK_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = tuple(s._draw(rng) for s in strats)
                    try:
                        fn(*args, *drawn, **kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"falsifying example #{i} (fallback "
                            f"runner, seed={seed}): {drawn!r}") from e
                return None
            # keep identity for reporting, but hide the parameter list —
            # pytest would otherwise read the strategy args as fixtures
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco

    def settings(max_examples: int = _FALLBACK_MAX_EXAMPLES, **_kw):
        def deco(fn):
            # @settings sits above @given, so fn is the runner; stash
            # the budget where the runner reads it at call time
            fn._compat_max_examples = max_examples
            return fn
        return deco
