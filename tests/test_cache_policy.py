"""Krites policy semantics: tiers, simulator, and invariant properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import tiers as T
from repro.core.simulate import (DYN_HIT_PROMOTED, MISS, STATIC_HIT,
                                 simulate, summarize)
from repro.core.tiers import CacheConfig


def _mk_static(n=4, d=8, seed=0):
    rng = np.random.default_rng(seed)
    emb = rng.standard_normal((n, d)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    return jnp.asarray(emb), jnp.arange(n, dtype=jnp.int32)


# ---------------------------------------------------------------------------
# dynamic tier unit behavior
# ---------------------------------------------------------------------------

def test_lru_insert_and_evict():
    tier = T.make_dynamic_tier(2, 4)
    v = jnp.eye(4)
    tier = T.insert(tier, v[0], 0, 0, now=1)
    tier = T.insert(tier, v[1], 1, 1, now=2)
    assert bool(tier.valid.all())
    # touch slot of v[0] -> v[1] becomes LRU and gets evicted next
    s, j = T.dynamic_lookup(tier, v[0])
    tier = T.touch(tier, j, now=3)
    tier = T.insert(tier, v[2], 2, 2, now=4)
    s0, _ = T.dynamic_lookup(tier, v[0])
    s1, _ = T.dynamic_lookup(tier, v[1])
    assert float(s0) > 0.99          # survived
    assert float(s1) < 0.99          # evicted


def test_upsert_idempotent_overwrite():
    tier = T.make_dynamic_tier(4, 4)
    v = jnp.asarray([1.0, 0, 0, 0])
    tier = T.insert(tier, v, cls=7, answer_ref=-1, now=1)
    before = int(tier.valid.sum())
    # promotion on an (almost) identical key overwrites in place
    tier = T.upsert(tier, v, cls=7, answer_ref=3, now=2,
                    static_origin=True)
    assert int(tier.valid.sum()) == before
    _, j = T.dynamic_lookup(tier, v)
    assert bool(tier.static_origin[j])
    assert int(tier.answer_ref[j]) == 3


def test_upsert_lww_guard():
    tier = T.make_dynamic_tier(4, 4)
    v = jnp.asarray([1.0, 0, 0, 0])
    tier = T.insert(tier, v, cls=7, answer_ref=-1, now=10)  # newer write
    tier2 = T.upsert(tier, v, cls=7, answer_ref=3, now=5,
                     static_origin=True)  # stale promotion
    _, j = T.dynamic_lookup(tier2, v)
    assert not bool(tier2.static_origin[j])  # stale write skipped


def test_ttl_eviction():
    tier = T.make_dynamic_tier(4, 4)
    v = jnp.eye(4)
    tier = T.insert(tier, v[0], 0, 0, now=0)
    tier = T.insert(tier, v[1], 1, 1, now=50)
    tier = T.evict_expired(tier, now=100, ttl=60)
    assert int(tier.valid.sum()) == 1


def test_ttl_zero_disables_eviction():
    """CacheConfig.ttl documents 0 = disabled; the sweep must be a
    no-op then — not "expire everything", which `age <= 0` would do."""
    tier = T.make_dynamic_tier(4, 4)
    v = jnp.eye(4)
    tier = T.insert(tier, v[0], 0, 0, now=0)
    tier = T.insert(tier, v[1], 1, 1, now=50)
    tier = T.evict_expired(tier, now=10**9, ttl=0)
    assert int(tier.valid.sum()) == 2


# ---------------------------------------------------------------------------
# simulator semantics
# ---------------------------------------------------------------------------

def _run(q_emb, q_cls, cfg, krites, static=None):
    s_emb, s_cls = static if static is not None else _mk_static()
    return simulate(s_emb, s_cls, jnp.asarray(q_emb),
                    jnp.asarray(q_cls, jnp.int32), cfg, krites=krites,
                    capacity=16)


def test_static_hit_exact_repeat():
    s_emb, s_cls = _mk_static()
    q = np.repeat(np.asarray(s_emb[:1]), 3, axis=0)
    res = _run(q, [0, 0, 0], CacheConfig(0.9, 0.9), False,
               static=(s_emb, s_cls))
    assert (np.asarray(res.served_by) == STATIC_HIT).all()
    assert np.asarray(res.static_origin).all()


def test_miss_then_dynamic_hit():
    s_emb, s_cls = _mk_static()
    rng = np.random.default_rng(1)
    v = rng.standard_normal(8).astype(np.float32)
    v /= np.linalg.norm(v)
    # make sure v is far from the static tier
    q = np.stack([v, v])
    res = _run(q, [9, 9], CacheConfig(0.99, 0.9), False,
               static=(s_emb, s_cls))
    sb = np.asarray(res.served_by)
    assert sb[0] == MISS and sb[1] != MISS
    assert bool(res.correct.all())


def test_promotion_after_judge_latency():
    """Grey-zone query -> judged (approved) -> repeat hits promoted entry."""
    s_emb, s_cls = _mk_static()
    base = np.asarray(s_emb[0])
    # paraphrase at sim ~0.95 of static[0]
    para = base + 0.33 * np.asarray(s_emb[1])
    para /= np.linalg.norm(para)
    sim = float(para @ base)
    assert 0.9 < sim < 0.99
    cfg = CacheConfig(tau_static=0.995, tau_dynamic=0.995, sigma_min=0.0,
                      judge_latency=2)
    q = np.stack([para] * 6)
    res_b = _run(q, [0] * 6, cfg, False, static=(s_emb, s_cls))
    res_k = _run(q, [0] * 6, cfg, True, static=(s_emb, s_cls))
    # baseline: first is a miss, repeats hit the *dynamic-origin* entry
    assert not np.asarray(res_b.static_origin).any()
    # krites: after latency 2, repeats serve the promoted static answer
    sbk = np.asarray(res_k.served_by)
    assert (sbk[3:] == DYN_HIT_PROMOTED).all()
    assert int(res_k.promotions) >= 1
    assert np.asarray(res_k.static_origin)[3:].all()


def test_judge_rejects_wrong_class():
    s_emb, s_cls = _mk_static()
    base = np.asarray(s_emb[0])
    para = base + 0.33 * np.asarray(s_emb[1])
    para /= np.linalg.norm(para)
    cfg = CacheConfig(0.995, 0.995, judge_latency=1)
    q = np.stack([para] * 5)
    res = _run(q, [42] * 5, cfg, True, static=(s_emb, s_cls))  # class 42 != 0
    assert int(res.promotions) == 0
    assert not np.asarray(res.static_origin).any()
    assert bool(res.correct.all())   # dynamic-origin repeats are correct


def test_sigma_min_gates_judging():
    s_emb, s_cls = _mk_static()
    base = np.asarray(s_emb[0])
    para = base + 0.33 * np.asarray(s_emb[1])
    para /= np.linalg.norm(para)
    sim = float(para @ base)
    cfg = CacheConfig(0.995, 0.995, sigma_min=sim + 0.001,
                      judge_latency=1)
    res = _run(np.stack([para] * 4), [0] * 4, cfg, True,
               static=(s_emb, s_cls))
    assert int(res.judge_calls) == 0


# ---------------------------------------------------------------------------
# properties (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(10, 60))
def test_prop_serving_path_identical_static_and_totals(seed, n):
    """Krites must not change static hits; totals match when the dynamic
    tier is large enough that promotions never evict live entries."""
    rng = np.random.default_rng(seed)
    s_emb, s_cls = _mk_static(6, 8, seed)
    q = rng.standard_normal((n, 8)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    cls = rng.integers(0, 6, n)
    cfg = CacheConfig(0.9, 0.9, judge_latency=3)
    rb = simulate(s_emb, s_cls, jnp.asarray(q), jnp.asarray(cls), cfg,
                  krites=False, capacity=4 * n)
    rk = simulate(s_emb, s_cls, jnp.asarray(q), jnp.asarray(cls), cfg,
                  krites=True, capacity=4 * n)
    assert (np.asarray(rb.served_by == STATIC_HIT)
            == np.asarray(rk.served_by == STATIC_HIT)).all()
    assert (np.asarray(rb.served_by == MISS)
            == np.asarray(rk.served_by == MISS)).all()
    # static-origin can only grow
    assert np.asarray(rk.static_origin).sum() \
        >= np.asarray(rb.static_origin).sum()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_prop_disabled_greyzone_equals_baseline(seed):
    rng = np.random.default_rng(seed)
    s_emb, s_cls = _mk_static(4, 8, seed)
    q = rng.standard_normal((30, 8)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    cls = rng.integers(0, 4, 30)
    cfg = CacheConfig(0.9, 0.9, sigma_min=2.0)   # empty grey zone
    rb = simulate(s_emb, s_cls, jnp.asarray(q), jnp.asarray(cls), cfg,
                  krites=False, capacity=64)
    rk = simulate(s_emb, s_cls, jnp.asarray(q), jnp.asarray(cls), cfg,
                  krites=True, capacity=64)
    assert (np.asarray(rb.served_by) == np.asarray(rk.served_by)).all()
    assert int(rk.judge_calls) == 0


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(4, 32))
def test_prop_tier_capacity_never_exceeded(seed, cap):
    rng = np.random.default_rng(seed)
    tier = T.make_dynamic_tier(cap, 4)
    for i in range(3 * cap):
        v = rng.standard_normal(4).astype(np.float32)
        v /= np.linalg.norm(v)
        if i % 3 == 0:
            tier = T.upsert(tier, jnp.asarray(v), i, i, now=i,
                            static_origin=True)
        else:
            tier = T.insert(tier, jnp.asarray(v), i, i, now=i)
        assert int(tier.valid.sum()) <= cap
    assert int(tier.valid.sum()) == cap
