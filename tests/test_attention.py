"""Attention implementations vs naive oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.attention import (causal_attention,
                                    causal_attention_masked,
                                    decode_attention)


def naive_causal(q, k, v):
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k) * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, S, H, D)


@pytest.mark.parametrize("B,S,H,K,D,chunk", [
    (2, 64, 4, 4, 16, 16),     # MHA
    (1, 96, 8, 2, 32, 32),     # GQA 4:1
    (2, 128, 4, 1, 8, 64),     # MQA
    (1, 50, 2, 2, 16, 32),     # non-divisible seq (gcd fallback)
])
def test_causal_triangular_matches_naive(B, S, H, K, D, chunk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    ref = naive_causal(q, k, v)
    out = causal_attention(q, k, v, chunk=chunk)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_masked_variant_matches_triangular():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, 2, 16))
    a = causal_attention(q, k, v, chunk=16)
    b = causal_attention_masked(q, k, v, chunk=16)
    assert jnp.max(jnp.abs(a - b)) < 2e-5


def test_decode_matches_full_attention_last_position():
    """decode(q_S | cache of S-1 keys) == causal attention row S-1."""
    key = jax.random.PRNGKey(4)
    B, S, H, K, D = 2, 32, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    full = causal_attention(q, k, v, chunk=8)
    dec = decode_attention(q[:, -1:], k, v,
                           jnp.full((B,), S, jnp.int32))
    assert jnp.max(jnp.abs(dec[:, 0] - full[:, -1])) < 2e-5


def test_decode_length_masking():
    key = jax.random.PRNGKey(5)
    B, S, H, K, D = 2, 16, 2, 2, 8
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    lens = jnp.array([5, 16])
    out = decode_attention(q, k, v, lens)
    # zeroing cache beyond length must not change the output
    pos = jnp.arange(S)[None, :, None, None]
    k2 = jnp.where(pos < lens[:, None, None, None], k, 123.0)
    v2 = jnp.where(pos < lens[:, None, None, None], v, -55.0)
    out2 = decode_attention(q, k2, v2, lens)
    assert jnp.max(jnp.abs(out - out2)) < 1e-6
