"""L1 exact-match front tier + freshness subsystem conformance
(``core/exact_tier.py``, ``core/freshness.py``, DESIGN.md §16).

Four contracts, each with its own section:

1. Canonicalization properties — equal canonical forms (case folds,
   whitespace runs, composed/decomposed unicode) always alias one L1
   entry; distinct canonical forms never do. Property-based via the
   ``_hypothesis_compat`` shim, so the tests run with or without
   hypothesis installed.
2. TTL monotonicity properties — a longer cache life never expires an
   entry sooner (0 = unbounded sits at the top of the order), liveness
   is downward-closed in time, and ``tiers.evict_expired``'s per-entry
   path is bit-identical to the legacy global-``ttl`` wrapper on the
   induced ``expires_at = written_at + ttl`` stamps.
3. Live-policy serving — the headline acceptance gates: ZERO embedder
   calls on a pure-repeat trace (scalar and batched), decision
   agreement 1.0 vs a no-L1 twin on non-repeat traffic, volatile
   bypass leaving the cache untouched, and L1/dynamic entries dying on
   their per-class TTL.
4. Crash recovery — SIGKILL a serving child after it snapshots a
   policy holding live + expired L1 entries and TTL-stamped dynamic
   entries; the warm restore must drop the expired entries (no
   resurrection), serve the live ones from L1, and make every
   subsequent decision field-identically to an uninterrupted policy.

Determinism: orthonormal prompt pools (pairwise similarity 0, so every
threshold decision is unambiguous), judge workers disabled.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import textwrap
import time
import unicodedata
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import tiers as T
from repro.core.exact_tier import ExactTier, canonicalize
from repro.core.freshness import (FreshnessPolicy, STABLE, UNKNOWN,
                                  VOLATILE, classify)
from repro.core.policy import KritesPolicy

from _hypothesis_compat import given, settings, st

# ---------------------------------------------------------------------------
# 1. canonicalization properties
# ---------------------------------------------------------------------------

# tokens chosen to exercise every canonicalization axis: casefold
# beyond lower() ("Straße"/"STRASSE"), composed vs decomposed accents
# ("café" vs "café"), plain ASCII, and a non-letter token
_TOKENS = ["Straße", "café", "café", "WEATHER", "émigré",
           "hello", "42nd", "ß"]
_WS = [" ", "  ", "\t", "\n", " \t ", " ", "\r\n"]
_CASERS = [str.lower, str.upper, str.title, lambda s: s]


def _variant(tokens, seps, casers, nfd):
    """One surface form of ``tokens``: per-token case mutation, a
    chosen whitespace run between tokens, optional NFD re-encoding of
    the whole string."""
    parts = [c(t) for t, c in zip(tokens, casers)]
    out = seps[0].join([""] + parts) + seps[1]     # ragged edges too
    return unicodedata.normalize("NFD", out) if nfd else out


_tok_lists = st.lists(st.sampled_from(_TOKENS), min_size=1, max_size=5)
_two_seps = st.tuples(st.sampled_from(_WS), st.sampled_from(_WS))
_case_picks = st.lists(st.sampled_from(_CASERS), min_size=5, max_size=5)


@settings(max_examples=60)
@given(_tok_lists, _two_seps, _case_picks, st.booleans())
def test_canonicalize_collapses_surface_variants(tokens, seps, casers,
                                                 nfd):
    base = canonicalize(" ".join(tokens))
    var = _variant(tokens, seps, casers, nfd)
    assert canonicalize(var) == base
    # idempotence: canonical forms are fixed points
    assert canonicalize(base) == base
    # canonical forms carry no leading/trailing/doubled whitespace
    assert base == " ".join(base.split())


@settings(max_examples=60)
@given(_tok_lists, _two_seps, _case_picks, st.booleans())
def test_l1_aliases_equal_canonical_forms(tokens, seps, casers, nfd):
    """put() under one surface form, get() under another: same entry."""
    tier = ExactTier(capacity=8)
    base = " ".join(tokens)
    tier.put(canonicalize(base), "answer-0", content_t=3, now=1)
    var = _variant(tokens, seps, casers, nfd)
    e = tier.get(canonicalize(var), now=2)
    assert e is not None and e.answer == "answer-0"
    assert e.content_t == 3
    assert len(tier) == 1          # one entry, not a variant per form


@settings(max_examples=60)
@given(_tok_lists, _tok_lists)
def test_l1_never_aliases_distinct_canonical_forms(toks_a, toks_b):
    ka = canonicalize(" ".join(toks_a))
    kb = canonicalize(" ".join(toks_b))
    if ka == kb:                   # same canonical form: out of scope
        return
    tier = ExactTier(capacity=8)
    tier.put(ka, "A", now=1)
    tier.put(kb, "B", now=2)
    assert tier.get(ka, now=3).answer == "A"
    assert tier.get(kb, now=3).answer == "B"
    assert len(tier) == 2


def test_classify_is_surface_form_invariant():
    """The staleness class keys off canonical tokens, so phrasing noise
    (case, whitespace, unicode form) never flips a class."""
    assert classify("what is the PRICE of eggs") == VOLATILE
    assert classify("  what\tis the price of eggs ") == VOLATILE
    assert classify("DEFINE perihelion") == STABLE
    assert classify("tell me about turtles") == UNKNOWN


# ---------------------------------------------------------------------------
# 2. TTL monotonicity properties
# ---------------------------------------------------------------------------

def _lifetime(ttl: int) -> float:
    """Effective cache life under the 0-means-never contract."""
    return float("inf") if ttl == 0 else float(ttl)


def _live(exp: int, now: int) -> bool:
    """The subsystem-wide liveness rule (tiers.live_mask, ExactTier.get,
    the simulator, the numpy oracle): live while now <= expires_at."""
    return exp == 0 or now <= exp


@settings(max_examples=80)
@given(st.integers(0, 64), st.integers(0, 64), st.integers(1, 100),
       st.integers(0, 200))
def test_ttl_monotone_longer_life_never_dies_sooner(ttl_a, ttl_b, wr,
                                                    dt):
    """If ttl_b grants at least ttl_a's lifetime, then at every probe
    tick an entry live under ttl_a is live under ttl_b."""
    if _lifetime(ttl_b) < _lifetime(ttl_a):
        ttl_a, ttl_b = ttl_b, ttl_a
    f_a = FreshnessPolicy(ttl_volatile=ttl_a)
    f_b = FreshnessPolicy(ttl_volatile=ttl_b)
    exp_a = f_a.expires_at("price now", wr)
    exp_b = f_b.expires_at("price now", wr)
    now = wr + dt
    if _live(exp_a, now):
        assert _live(exp_b, now), (ttl_a, ttl_b, wr, now)


@settings(max_examples=80)
@given(st.integers(0, 64), st.integers(1, 100), st.integers(0, 100),
       st.integers(0, 100))
def test_ttl_liveness_downward_closed_in_time(ttl, wr, d1, d2):
    """An entry dead at some tick never comes back later — and the
    ExactTier probe agrees with the pure liveness predicate."""
    exp = wr + ttl if ttl > 0 else 0
    n1, n2 = wr + min(d1, d2), wr + max(d1, d2)
    if not _live(exp, n1):
        assert not _live(exp, n2)
    tier = ExactTier(capacity=4)
    tier.put("k", "v", expires_at=exp, now=wr)
    assert (tier.get("k", now=n1) is not None) == _live(exp, n1)


@settings(max_examples=40)
@given(st.integers(0, 2**31 - 1), st.integers(1, 64), st.integers(1, 200))
def test_evict_expired_per_entry_matches_legacy_ttl(seed, ttl, now):
    """Satellite pin: the per-entry ``expires_at`` path of
    ``tiers.evict_expired`` is bit-identical to the legacy global-ttl
    wrapper on the stamps it induces, and ttl=0 stays a no-op."""
    rng = np.random.default_rng(seed)
    cap = 16
    tier = T.make_dynamic_tier(cap, 4)._replace(
        valid=jnp.asarray(rng.integers(0, 2, cap).astype(bool)),
        written_at=jnp.asarray(rng.integers(0, 200, cap), jnp.int32))
    legacy = T.evict_expired(tier, now=now, ttl=ttl)
    per_entry = T.evict_expired(
        tier._replace(expires_at=(tier.written_at + ttl)
                      .astype(jnp.int32)), now=now)
    assert np.array_equal(np.asarray(legacy.valid),
                          np.asarray(per_entry.valid))
    # ttl=0 = disabled: nothing dies, no matter how old
    untouched = T.evict_expired(tier, now=10**9, ttl=0)
    assert np.array_equal(np.asarray(untouched.valid),
                          np.asarray(tier.valid))
    # exp=0 rows never expire on the per-entry path either
    never = T.evict_expired(tier, now=10**9)
    assert np.array_equal(np.asarray(never.valid),
                          np.asarray(tier.valid))


# ---------------------------------------------------------------------------
# 3. live-policy serving gates
# ---------------------------------------------------------------------------

D, S = 32, 6


def _pool(n, d, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(d, n)))
    return np.ascontiguousarray(q.T, np.float32)


P = _pool(32, D)
# prompt texts carry their freshness class; embeddings are orthonormal
# to the static tier and each other, so every one is a semantic miss
VOL_PROMPTS = [f"price of item {i}" for i in range(4)]          # volatile
STA_PROMPTS = [f"define object {i}" for i in range(12)]         # stable
UNK_PROMPTS = [f"tell me about thing {i}" for i in range(10)]   # unknown
ALL_PROMPTS = VOL_PROMPTS + STA_PROMPTS + UNK_PROMPTS
EMB = {p: P[S + i] for i, p in enumerate(ALL_PROMPTS)}


def _mk(l1=None, freshness=None, capacity=16, embed_fn=None):
    tier = T.StaticTier(emb=jnp.asarray(P[:S]),
                        cls=jnp.arange(S, dtype=jnp.int32),
                        answer_ref=jnp.arange(S, dtype=jnp.int32))
    cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=capacity)
    return KritesPolicy(cfg, tier, [f"a{i}" for i in range(S)],
                        embed_fn=embed_fn or (lambda p: EMB[p]),
                        backend_fn=lambda p: f"gen({p})",
                        judge_fn=lambda **kw: True, d=D, n_workers=0,
                        l1=l1, freshness=freshness)


def _dec(r):
    return (r.served_by, str(r.answer), bool(r.static_origin),
            round(float(r.similarity), 5), bool(r.meta.get("stale")))


def test_pure_repeat_trace_costs_zero_embed_calls_scalar():
    """The headline L1 gate: after the cold pass, byte-identical (up to
    canonicalization) repeats never reach the embedder or either
    semantic lookup."""
    calls = []

    def embed(p):
        calls.append(p)
        return EMB[p]

    pol = _mk(l1=64, embed_fn=embed)
    base = UNK_PROMPTS[:8]
    cold = [pol.serve(p) for p in base]
    assert len(calls) == len(base)
    assert all(r.served_by == "backend" for r in cold)

    for _ in range(3):
        for p, c in zip(base, cold):
            r = pol.serve(p)
            assert r.served_by == "l1"
            assert r.answer == c.answer
    assert len(calls) == len(base), "repeats paid the embedder"
    assert pol._l1_hits == 3 * len(base)

    # canonical variants are repeats too — EMB has no entry for these
    # surface forms, so touching the embedder would KeyError
    for var in ("  Tell me ABOUT thing 0 ", "tell\tme about thing 1",
                unicodedata.normalize("NFD", "Tell me about thing 2")):
        assert pol.serve(var).served_by == "l1"
    assert len(calls) == len(base)


def test_pure_repeat_batch_costs_zero_embed_calls():
    """Batched twin: a warm pure-repeat batch embeds nothing; a cold
    batch with in-batch exact duplicates embeds each canonical form
    once (the producer row) and serves the dups from it."""
    calls = []

    def embed(p):
        calls.append(p)
        return EMB[p]

    pol = _mk(l1=64, embed_fn=embed)
    base = UNK_PROMPTS[:6]
    cold = pol.serve_batch(base)
    assert len(calls) == len(base)

    warm = pol.serve_batch(list(base) + ["TELL me about thing 0  "])
    assert len(calls) == len(base), "warm batch paid the embedder"
    assert all(r.served_by == "l1" for r in warm)
    assert [r.answer for r in warm[:-1]] == [r.answer for r in cold]
    assert warm[-1].answer == cold[0].answer

    # in-batch duplicates: one embed for the producer, dups ride along
    pol2 = _mk(l1=64, embed_fn=embed)
    n0 = len(calls)
    rs = pol2.serve_batch(["define object 0", "DEFINE object 0",
                           "define  object 0"])
    assert len(calls) == n0 + 1
    assert rs[0].served_by == "backend"
    assert [r.served_by for r in rs[1:]] == ["l1", "l1"]
    assert {r.answer for r in rs} == {rs[0].answer}


@pytest.mark.parametrize("batched", [False, True],
                         ids=["scalar", "batched"])
def test_l1_decision_agreement_on_non_repeat_traffic(batched):
    """Acceptance gate: on traffic with no exact repeats the L1 policy
    and its no-L1 twin make field-identical decisions — the front tier
    is invisible to semantic serving. Both twins share the freshness
    TTLs so the expiry path is exercised under agreement too."""
    fresh = dict(volatile_bypass=False, ttl_volatile=4, ttl_stable=0,
                 ttl_unknown=0)
    with_l1 = _mk(l1=64, freshness=FreshnessPolicy(**fresh), capacity=8)
    without = _mk(l1=None, freshness=FreshnessPolicy(**fresh), capacity=8)

    # every prompt distinct (capacity 8 < 26 prompts: LRU churn and
    # volatile TTL deaths both happen mid-trace)
    trace = [p for pair in zip(ALL_PROMPTS[::-1], ALL_PROMPTS)
             for p in pair][:26]
    seen = set()
    trace = [p for p in trace if not (p in seen or seen.add(p))]
    if batched:
        got = [_dec(r) for r in with_l1.serve_batch(trace)]
        want = [_dec(r) for r in without.serve_batch(trace)]
    else:
        got = [_dec(with_l1.serve(p)) for p in trace]
        want = [_dec(without.serve(p)) for p in trace]
    agreement = sum(g == w for g, w in zip(got, want)) / len(trace)
    assert agreement == 1.0, list(zip(got, want))
    assert with_l1._l1_hits == 0            # nothing repeated
    assert with_l1.l1.stats()["l1_misses"] > 0   # but L1 was probed
    assert np.array_equal(with_l1._valid_np, without._valid_np)
    assert np.array_equal(with_l1._expires_np, without._expires_np)


def test_volatile_bypass_serves_backend_and_touches_nothing():
    calls = []

    def embed(p):
        calls.append(p)
        return EMB[p]

    pol = _mk(l1=16, freshness=FreshnessPolicy(volatile_bypass=True,
                                               ttl_volatile=4),
              embed_fn=embed)
    r = pol.serve(VOL_PROMPTS[0])
    assert r.served_by == "backend"
    assert r.meta.get("bypass") == "volatile"
    assert calls == []                      # no embed
    assert len(pol.l1) == 0                 # no L1 write-back
    assert not pol._valid_np.any()          # no dynamic write
    assert pol._l1_bypass == 1
    # repeats stay bypassed: still no cache, still no embed
    assert pol.serve(VOL_PROMPTS[0]).served_by == "backend"
    assert calls == [] and len(pol.l1) == 0
    # batched path agrees
    rs = pol.serve_batch([VOL_PROMPTS[1], UNK_PROMPTS[0]])
    assert rs[0].meta.get("bypass") == "volatile"
    assert rs[1].served_by == "backend" and "bypass" not in rs[1].meta
    assert calls == [UNK_PROMPTS[0]]
    assert pol._l1_bypass == 3


def test_per_class_ttl_expires_l1_and_dynamic_entries():
    """Volatile entries die after ttl_volatile ticks on BOTH tiers;
    stable entries (ttl 0) never do."""
    pol = _mk(l1=16, freshness=FreshnessPolicy(volatile_bypass=False,
                                               ttl_volatile=3,
                                               ttl_stable=0))
    pol.serve(VOL_PROMPTS[0])               # t=1, expires_at=4
    pol.serve(STA_PROMPTS[0])               # t=2, never expires
    assert pol.serve(VOL_PROMPTS[0]).served_by == "l1"   # t=3 <= 4
    for p in UNK_PROMPTS[:4]:               # t=4..7: clock past expiry
        pol.serve(p)
    r = pol.serve(VOL_PROMPTS[0])           # t=8 > 4: dead everywhere
    assert r.served_by == "backend"
    assert pol.l1.stats()["l1_ttl_evictions"] >= 1
    assert pol._ttl_evictions >= 1          # dynamic twin died eagerly
    assert pol.serve(STA_PROMPTS[0]).served_by == "l1"   # still live


def test_stale_accounting_flags_drifted_volatile_hits():
    """With a drift clock, a volatile L1 hit whose content dates from
    an earlier epoch is served but flagged + counted stale."""
    pol = _mk(l1=16, freshness=FreshnessPolicy(volatile_bypass=False,
                                               ttl_volatile=64,
                                               drift_every=4))
    pol.serve(VOL_PROMPTS[0])               # t=1: content epoch 0
    r = pol.serve(VOL_PROMPTS[0])           # t=2: same epoch — fresh
    assert r.served_by == "l1" and "stale" not in r.meta
    for p in UNK_PROMPTS[:3]:               # advance to t=5 (epoch 1)
        pol.serve(p)
    r = pol.serve(VOL_PROMPTS[0])           # t=6: epoch drifted
    assert r.served_by == "l1" and r.meta.get("stale") is True
    assert pol._stale_serves == 1
    # stable hits never flag, whatever the epoch distance
    pol.serve(STA_PROMPTS[0])
    for p in UNK_PROMPTS[3:8]:
        pol.serve(p)
    r = pol.serve(STA_PROMPTS[0])
    assert r.served_by == "l1" and "stale" not in r.meta
    assert pol._stale_serves == 1


# ---------------------------------------------------------------------------
# 4. SIGKILL crash recovery with live + expired L1/TTL state
# ---------------------------------------------------------------------------

SRC = str(Path(__file__).resolve().parent.parent / "src")
ENV = {
    "PYTHONPATH": SRC,
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "JAX_PLATFORMS": "cpu",
    "PYTHONUNBUFFERED": "1",
}

# Shared world: child process (snapshot side) and parent (recovery +
# reference side) exec the same block, so the comparison is
# apples-to-apples. The drive leaves the snapshot holding every
# interesting freshness state at t=14: two EXPIRED L1 entries (early
# volatile, exp 4/5, never re-touched so lazily still present), two
# LIVE TTL-stamped L1 + dynamic entries (late volatile, exp 16/17),
# ten unbounded stable entries, and >0 eager dynamic TTL evictions.
COMMON = textwrap.dedent("""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import tiers as T
    from repro.core.freshness import FreshnessPolicy
    from repro.core.policy import KritesPolicy

    D, S = 32, 4

    def _pool(n, d, seed=0):
        rng = np.random.default_rng(seed)
        q, _ = np.linalg.qr(rng.normal(size=(d, n)))
        return np.ascontiguousarray(q.T, np.float32)

    P = _pool(32, D)
    VOL_OLD = [f"price of relic {i}" for i in range(2)]
    STA = [f"define artifact {i}" for i in range(10)]
    VOL_NEW = [f"price of gadget {i}" for i in range(2)]
    NEW = [f"tell me about widget {i}" for i in range(6)]
    ALL = VOL_OLD + STA + VOL_NEW + NEW
    EMB = {p: P[S + i] for i, p in enumerate(ALL)}

    def mk_policy():
        tier = T.StaticTier(emb=jnp.asarray(P[:S]),
                            cls=jnp.arange(S, dtype=jnp.int32),
                            answer_ref=jnp.arange(S, dtype=jnp.int32))
        cfg = T.CacheConfig(0.95, 0.9, sigma_min=0.3, capacity=16)
        return KritesPolicy(
            cfg, tier, [f"a{i}" for i in range(S)],
            embed_fn=lambda p: EMB[p],
            backend_fn=lambda p: "gen(" + p + ")",
            judge_fn=lambda **kw: True, d=D, n_workers=0,
            l1=64, freshness=FreshnessPolicy(volatile_bypass=False,
                                             ttl_volatile=3,
                                             ttl_stable=0,
                                             ttl_unknown=0))

    def drive_prefix(pol):
        for p in VOL_OLD:         # t=1,2  -> expires_at 4,5
            pol.serve(p)
        for p in STA:             # t=3..12 -> never expire
            pol.serve(p)
        for p in VOL_NEW:         # t=13,14 -> expires_at 16,17 (live)
            pol.serve(p)
""")

CHILD = COMMON + textwrap.dedent("""
    import sys
    from pathlib import Path
    from repro.serving import persist

    snap = Path(sys.argv[1])
    pol = mk_policy()
    drive_prefix(pol)
    persist.save_snapshot(snap, pol)
    print("SNAP", flush=True)
    for p in NEW:                 # post-snapshot tail: lost to the kill
        pol.serve(p)
    print("DONE", flush=True)
""")

_NS: dict = {}


def _ns():
    if not _NS:
        exec(COMMON, _NS)
    return _NS


def _run_child_killed_after_snap(tmp: Path):
    proc = subprocess.Popen([sys.executable, "-c", CHILD, str(tmp)],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True, env=ENV)
    try:
        deadline = time.monotonic() + 300
        for line in proc.stdout:
            assert time.monotonic() < deadline, "child wedged"
            if line.strip() == "SNAP":
                os.kill(proc.pid, signal.SIGKILL)
                break
            assert line.strip() != "DONE", "missed the kill window"
        else:
            pytest.fail(f"child died early:\n{proc.stderr.read()}")
        proc.wait(timeout=60)
    finally:
        proc.stderr.close()
        proc.stdout.close()
        if proc.poll() is None:
            proc.kill()
            proc.wait(timeout=60)


def test_sigkill_freshness_recovery(tmp_path):
    from repro.serving import persist

    _run_child_killed_after_snap(tmp_path)
    ns = _ns()

    # the snapshot itself holds the expired L1 rows (lazy expiry): 14
    # entries saved, exactly the two early-volatile ones already dead
    snap = persist.load_snapshot(tmp_path)
    l1_saved = snap.extra["l1"]
    assert len(l1_saved) == 14
    t_snap = 14
    dead_keys = {k for k, *_rest, exp, _wr in
                 [(e[0], e[4], e[5]) for e in l1_saved]
                 if 0 < exp < t_snap}
    assert dead_keys == {f"price of relic {i}" for i in range(2)}

    restored = ns["mk_policy"]()
    rep = persist.restore_policy(restored, snap)
    # no resurrection: expired L1 entries dropped at restore time
    assert rep["l1_restored"] == 12
    assert all(not (0 < e.expires_at < restored.t)
               for e in restored.l1._od.values())
    assert restored.t == t_snap

    # uninterrupted reference: same prefix, never crashed
    reference = ns["mk_policy"]()
    ns["drive_prefix"](reference)
    assert np.array_equal(restored._valid_np, reference._valid_np)
    assert np.array_equal(restored._expires_np, reference._expires_np)
    assert np.array_equal(restored._written_at_np,
                          reference._written_at_np)
    assert reference._ttl_evictions > 0     # early volatile dyn rows died

    # decision sweep: live L1 entries serve, expired ones re-resolve,
    # TTL'd entries keep dying on schedule — field-identical throughout
    probe = (ns["STA"][:3]                  # live L1 -> 'l1'
             + ["DEFINE  artifact 0"]       # canonical variant -> 'l1'
             + ns["VOL_OLD"]                # expired -> semantic path
             + ns["VOL_NEW"]                # exp 16/17 vs ticks 21,22
             + ns["NEW"]                    # fresh misses
             + ns["NEW"][:2])               # then repeats -> 'l1'
    got = [_dec(restored.serve(p)) for p in probe]
    want = [_dec(reference.serve(p)) for p in probe]
    assert got == want
    assert got[0][0] == "l1" and got[3][0] == "l1"
    assert got[4][0] != "l1" and got[5][0] != "l1"   # stayed dead
    assert got[-2][0] == "l1" and got[-1][0] == "l1"
    assert np.array_equal(restored._valid_np, reference._valid_np)
    assert np.array_equal(restored._expires_np, reference._expires_np)
