"""Online per-segment threshold adaptation (DESIGN.md §17).

Contracts pinned here:

1. Pure selection arithmetic — candidate grids keep the live point at
   their center, and ``choose_candidate`` walks the measured frontier
   with feasibility / hysteresis / repair / explore exactly as
   documented.
2. Direction — a window whose frontier says "lower tau wins within the
   error budget" moves the live point down by exactly the bounded step;
   a frozen controller never sweeps.
3. Adaptive-off differential — a policy with a frozen (or absent)
   controller is BIT-IDENTICAL to the pinned-threshold policy on the
   scalar and batched serving paths: same events, same answers, same
   host mirrors, agreement 1.0.
4. Oracle differential — the live controller loop (window recording,
   judge/feedback label rewrites, shadow sweep, epsilon-greedy
   selection, bounded nudges) matches the pure-numpy twin
   ``ref_policy.ref_adaptive`` field-identically: served stream, tau
   trajectories, adaptation/move/explore/regret counters.
5. Persistence — controller state (window ring, live thresholds,
   counters, LCG) survives a snapshot + SIGKILL + restore, and the
   recovered service's subsequent decisions are identical to a twin
   that never crashed.
6. Telemetry — live per-segment operating points and regret counters
   surface through ``CacheRouter.stats()``; ``CacheRouter.feedback``
   reaches the window.

All embeddings are L2-normalize fixpoints over one-hot mixtures, so
device and numpy matmuls agree bit-for-bit and every threshold sits
>= 3e-3 away from any similarity the trace can produce.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from ref_policy import (DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED, MISS,
                        STATIC_HIT, ref_adaptive)

from repro.core.adaptive import (AdaptiveController, AdaptiveParams,
                                 N_SEGMENTS, candidate_grid,
                                 choose_candidate, segment_of)
from repro.core.judge import OracleJudge
from repro.core.policy import BaselinePolicy, KritesPolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.index.flat import l2_normalize

D = 32
N_CLASSES = 8
# similarity levels sit 5e-3 off the 0.01 grid every reachable
# threshold lives on (taus move in max_step=0.02 hops from 0.95 and
# candidates sit grid_radius=0.04 away), so no decision is ever within
# an ulp of a boundary
SIM_LEVELS = (0.915, 0.925, 0.935, 0.945)
SEG_PREFIX = {0: "how to", 1: "latest", 2: "definition of"}

CODE_NAME = {MISS: "backend", STATIC_HIT: "static",
             DYN_HIT_DYNAMIC: "dynamic", DYN_HIT_PROMOTED: "dynamic"}


def _unit_fix(V):
    """L2-normalize to a fixpoint: the returned rows renormalize to
    themselves bit-for-bit, so the live policy's ``l2_normalize`` of an
    embed output is the identity and oracle inputs match exactly."""
    Vj = jnp.asarray(V, jnp.float32)
    for _ in range(8):
        V2 = l2_normalize(Vj)
        if bool(jnp.array_equal(V2, Vj)):
            return np.asarray(Vj)
        Vj = V2
    raise AssertionError("l2_normalize fixpoint not reached")


def _static(d=D, n=N_CLASSES):
    emb = np.eye(d, dtype=np.float32)[:n]
    tier = make_static_tier(jnp.asarray(emb), jnp.arange(n))
    return tier, [f"curated-{i}" for i in range(n)], emb


def _workload(n, seed=0, d=D, no_meta_every=7):
    """Deterministic mixed-segment trace: request i is a paraphrase of
    static class ``cls[i]`` at one of SIM_LEVELS, perturbed along a
    private orthogonal direction, phrased with its segment's keyword.
    Every ``no_meta_every``-th request declares no class (meta None /
    q_label −1): the window label must fall back to the static
    neighbor's class."""
    rng = np.random.default_rng(seed)
    base = np.eye(d, dtype=np.float32)
    cls = rng.integers(0, N_CLASSES, n)
    dirs = N_CLASSES + (np.arange(n) % (d - N_CLASSES))
    lvl = np.asarray(SIM_LEVELS, np.float64)[
        rng.integers(0, len(SIM_LEVELS), n)]
    V = (lvl[:, None] * base[cls]
         + np.sqrt(1.0 - lvl ** 2)[:, None] * base[dirs])
    V = _unit_fix(V.astype(np.float32))
    segs = (np.arange(n) % 3).astype(np.int64)
    prompts = [f"{SEG_PREFIX[int(s)]} q{i}" for i, s in enumerate(segs)]
    for i, s in enumerate(segs):          # the keying the policies use
        assert segment_of(prompts[i]) == int(s)
    labels = cls.astype(np.int64).copy()
    metas = []
    for i in range(n):
        if no_meta_every and i % no_meta_every == no_meta_every - 1:
            labels[i] = -1
            metas.append(None)
        else:
            metas.append({"cls": int(cls[i])})
    embed = {p: V[i] for i, p in enumerate(prompts)}
    return V, cls, labels, segs, prompts, metas, embed.__getitem__


def _params(**kw):
    base = dict(window=96, adapt_every=32, min_segment=16,
                shadow_capacity=64, error_budget=0.06)
    base.update(kw)
    return AdaptiveParams(**base)


# ---------------------------------------------------------------------------
# 1. pure selection arithmetic
# ---------------------------------------------------------------------------

def test_candidate_grid_center_survives_clipping():
    p = AdaptiveParams()
    cands, ci = candidate_grid(0.99, 0.99, p)     # center at tau_hi
    assert len(cands) == p.grid_points ** 2
    assert cands[ci] == (0.99, 0.99)
    assert all(p.tau_lo <= ts <= p.tau_hi
               and p.tau_lo <= td <= p.tau_hi for ts, td in cands)
    cands, ci = candidate_grid(0.9, 0.88, p)
    assert cands[ci] == (0.9, 0.88)
    # odd grid: one candidate strictly below and one strictly above
    # the center on each axis
    assert min(ts for ts, _ in cands) < 0.9 < max(ts for ts, _ in cands)


def test_choose_candidate_reasons():
    p = AdaptiveParams(hysteresis=0.01, error_budget=0.02)
    n = 100       # budget = 2 errors
    # greedy: a feasible candidate beats the center by > hysteresis
    k, why = choose_candidate([5, 40, 10], [0, 1, 0], n, 2, p, None)
    assert (k, why) == (1, "greedy")
    # hold: gain below the hysteresis band
    k, why = choose_candidate([39, 40, 10], [0, 1, 0], n, 0, p, None)
    assert (k, why) == (0, "hold")
    # infeasible candidates are ignored even when they dominate on hits
    k, why = choose_candidate([5, 90, 10], [0, 50, 0], n, 0, p, None)
    assert (k, why) == (2, "greedy")
    # repair: nothing within budget -> minimum error wins
    k, why = choose_candidate([50, 40, 30], [9, 7, 3], n, 0, p, None)
    assert (k, why) == (2, "repair")
    # explore indexes uniformly into the feasible set only
    k, why = choose_candidate([5, 90, 10], [0, 50, 0], n, 0, p, 3)
    assert why == "explore" and k in (0, 2)


# ---------------------------------------------------------------------------
# 2. direction + cadence on a synthetic window
# ---------------------------------------------------------------------------

def _synthetic_controller(frozen=False, d=40):
    """Window full of sim-0.92 paraphrases of class 0: every candidate
    below 0.92 serves all of them correctly, the 0.95 center serves
    none — the frontier says 'move down'."""
    base = np.eye(d, dtype=np.float32)
    p = AdaptiveParams(window=32, adapt_every=32, min_segment=8,
                       shadow_capacity=64)
    cfg = CacheConfig(0.95, 0.95, capacity=64)
    ctl = AdaptiveController(cfg, d=d, params=p, frozen=frozen)
    V = _unit_fix(0.92 * base[0]
                  + np.sqrt(1 - 0.92 ** 2) * base[4:36])
    for i in range(p.window):
        ctl.record(V[i], 0, 0)
    return ctl, base[:4], np.arange(4, dtype=np.int32)


def test_controller_moves_down_bounded():
    ctl, s_emb, s_cls = _synthetic_controller()
    lock = threading.Lock()
    assert ctl.maybe_adapt(lock, s_emb, s_cls)
    p = ctl.params
    assert ctl.adaptations == 1 and ctl.moves == 1
    # the frontier winner is 0.04 below, the move is clamped to 0.02
    assert ctl.tau_static[0] == pytest.approx(0.95 - p.max_step)
    assert ctl.tau_dynamic[0] == pytest.approx(0.95 - p.max_step)
    assert ctl.regret[0] == 32        # hits the pinned point left behind
    # inactive segments never move
    assert ctl.tau_static[1] == 0.95 and ctl.tau_static[2] == 0.95
    # cadence: the counter reset means an immediate re-check is a no-op
    assert not ctl.maybe_adapt(lock, s_emb, s_cls)


def test_frozen_controller_never_sweeps():
    ctl, s_emb, s_cls = _synthetic_controller(frozen=True)
    assert not ctl.maybe_adapt(threading.Lock(), s_emb, s_cls)
    assert ctl.adaptations == 0 and ctl.moves == 0
    assert ctl.tau_static == [0.95] * N_SEGMENTS
    s = ctl.stats()
    assert s["adaptive_frozen"] and s["adaptive_window_fill"] == 32


# ---------------------------------------------------------------------------
# 3. adaptive-off differential: frozen == pinned, bit for bit
# ---------------------------------------------------------------------------

def _mirror_state(pol):
    return (pol._valid_np.copy(), pol._last_used_np.copy(),
            pol._written_at_np.copy(), pol._static_origin_np.copy(),
            np.asarray(pol.dyn.emb).copy(), list(pol.dyn_answers))


def _assert_twin_state(a, b):
    for x, y in zip(_mirror_state(a), _mirror_state(b)):
        if isinstance(x, list):
            assert x == y
        else:
            assert np.array_equal(x, y)


def test_frozen_is_bit_identical_to_pinned_scalar():
    tier, answers, _ = _static()
    _, _, _, _, prompts, metas, embed = _workload(120, seed=1)
    cfg = CacheConfig(0.93, 0.9, sigma_min=0.3, capacity=64)

    def build(adaptive):
        return KritesPolicy(cfg, tier, answers, embed,
                            lambda p: f"gen({p})", OracleJudge(), d=D,
                            n_workers=1, adaptive=adaptive)

    pinned = build(None)
    frozen = build(AdaptiveController(cfg, d=D, params=_params(),
                                      frozen=True))
    for p, m in zip(prompts, metas):
        ra = pinned.serve(p, meta=m)
        rb = frozen.serve(p, meta=m)
        assert (ra.answer, ra.served_by, ra.static_origin) == \
               (rb.answer, rb.served_by, rb.static_origin)
        # drain so async promotions land at the same request boundary
        # in both twins — determinism, not a serving requirement
        pinned.pool.drain()
        frozen.pool.drain()
    agreement = np.mean([ea == eb for ea, eb in
                         zip(pinned.events, frozen.events)])
    assert agreement == 1.0
    _assert_twin_state(pinned, frozen)
    s = frozen.stats()
    assert s["adaptive_adaptations"] == 0 and s["adaptive_moves"] == 0
    assert s["tau_static_unknown"] == cfg.tau_static
    pinned.pool.stop()
    frozen.pool.stop()


def test_frozen_is_bit_identical_to_pinned_batch():
    tier, answers, _ = _static()
    _, _, _, _, prompts, metas, embed = _workload(128, seed=2)
    cfg = CacheConfig(0.93, 0.9, capacity=64)

    def build(adaptive):
        return BaselinePolicy(
            cfg, tier, answers, embed, lambda p: f"gen({p})", d=D,
            backend_batch_fn=lambda ps: [f"gen({p})" for p in ps],
            adaptive=adaptive)

    pinned = build(None)
    frozen = build(AdaptiveController(cfg, d=D, params=_params(),
                                      frozen=True))
    B = 16
    for i in range(0, len(prompts), B):
        ra = pinned.serve_batch(prompts[i:i + B], metas[i:i + B])
        rb = frozen.serve_batch(prompts[i:i + B], metas[i:i + B])
        assert [(r.answer, r.served_by) for r in ra] == \
               [(r.answer, r.served_by) for r in rb]
    assert pinned.events == frozen.events
    _assert_twin_state(pinned, frozen)


# ---------------------------------------------------------------------------
# 4. oracle differential: live controller == numpy twin
# ---------------------------------------------------------------------------

def _run_live_adaptive(n, seed, params, feedback=None):
    tier, answers, _ = _static()
    _, _, labels, segs, prompts, metas, embed = _workload(n, seed=seed)
    cfg = CacheConfig(0.95, 0.95, capacity=64)
    ctl = AdaptiveController(cfg, d=D, params=params)
    pol = BaselinePolicy(cfg, tier, answers, embed,
                         lambda p: f"gen({p})", d=D, adaptive=ctl)
    events = []
    for t, (p, m) in enumerate(zip(prompts, metas)):
        res = pol.serve(p, meta=m)
        events.append(res.served_by)
        if feedback is not None and feedback[t]:
            assert pol.feedback(res.meta["adapt_seq"], False)
    return pol, ctl, events, labels, segs


@pytest.mark.parametrize("epsilon", [0.0, 0.6])
def test_adaptive_matches_numpy_oracle(epsilon):
    n, seed = 224, 3
    params = _params(epsilon=epsilon)
    feedback = np.zeros(n, bool)
    feedback[28::29] = True               # sparse wrong-answer reports
    pol, ctl, events, labels, segs = _run_live_adaptive(
        n, seed, params, feedback)
    V, _, _, _, _, _, _ = _workload(n, seed=seed)
    tier, _, _ = _static()
    ref = ref_adaptive(np.asarray(tier.emb), np.asarray(tier.cls),
                       V, labels, segs, CacheConfig(0.95, 0.95,
                                                    capacity=64),
                       params=params, feedback=feedback)
    # the serving stream, decision for decision
    assert events == [CODE_NAME[int(c)] for c in ref["served_by"]]
    # the tau trajectories and every controller counter, field-identical
    assert ctl.tau_static == ref["tau_static"]
    assert ctl.tau_dynamic == ref["tau_dynamic"]
    assert ctl.adaptations == ref["adaptations"] > 0
    assert ctl.moves == ref["moves"]
    assert ctl.explores == ref["explores"]
    assert ctl.regret == ref["regret"]
    assert ctl._count == ref["count"] == n
    if epsilon == 0.0:
        # the workload's frontier sits below the pinned 0.95: the
        # controller must actually have walked down
        assert ref["moves"] > 0
        assert min(ctl.tau_static) < 0.95
    else:
        assert ref["explores"] > 0
    assert ctl.feedbacks == int(feedback.sum())


# ---------------------------------------------------------------------------
# 5. persistence: snapshot + SIGKILL + restore
# ---------------------------------------------------------------------------

CRASH_N1, CRASH_N2 = 160, 48


def _crash_build():
    """One deterministic adaptive serving stack, shared (via import)
    by the test process, the SIGKILL child and the never-crashed twin."""
    tier, answers, _ = _static()
    _, _, _, _, prompts, metas, embed = _workload(CRASH_N1 + CRASH_N2,
                                                  seed=5)
    cfg = CacheConfig(0.95, 0.95, capacity=64)
    ctl = AdaptiveController(cfg, d=D, params=_params())
    pol = BaselinePolicy(cfg, tier, answers, embed,
                         lambda p: f"gen({p})", d=D, adaptive=ctl)
    return pol, prompts, metas


_CHILD = """
import sys, time
sys.path.insert(0, {tests!r})
from test_adaptive import _crash_build, CRASH_N1
from repro.serving.persist import save_snapshot

pol, prompts, metas = _crash_build()
for p, m in zip(prompts[:CRASH_N1], metas[:CRASH_N1]):
    pol.serve(p, meta=m)
save_snapshot(sys.argv[1], pol, step=0)
print("SNAP", flush=True)
time.sleep(300)      # parent SIGKILLs here: no clean shutdown ever runs
"""


def test_adaptive_state_survives_sigkill_restore(tmp_path):
    from repro.serving.persist import restore_policy, save_snapshot

    here = str(Path(__file__).resolve().parent)
    env = {"PYTHONPATH": str(Path(here).parent / "src"),
           "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
           "JAX_PLATFORMS": "cpu", "PYTHONUNBUFFERED": "1",
           "HOME": os.environ.get("HOME", "/tmp")}
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD.format(tests=here),
         str(tmp_path / "snap")],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env)
    try:
        deadline = time.monotonic() + 300
        for line in proc.stdout:
            assert time.monotonic() < deadline, "child wedged"
            if line.strip() == "SNAP":
                os.kill(proc.pid, signal.SIGKILL)
                break
        else:
            pytest.fail(f"child died early: {proc.stderr.read()}")
        proc.wait(timeout=60)
    finally:
        proc.stderr.close()
        proc.stdout.close()
        if proc.poll() is None:
            proc.kill()

    # the twin that never crashed
    twin, prompts, metas = _crash_build()
    for p, m in zip(prompts[:CRASH_N1], metas[:CRASH_N1]):
        twin.serve(p, meta=m)
    assert twin.adaptive.moves > 0      # the prefix really adapted

    # recover the killed service into a fresh stack
    rec, _, _ = _crash_build()
    report = restore_policy(rec, tmp_path / "snap")
    assert report["adaptive_restored"]

    ra, rs = rec.adaptive.to_state()
    ta, ts = twin.adaptive.to_state()
    assert rs == ts
    for k in ra:
        assert np.array_equal(ra[k], ta[k]), f"adaptive array {k}"
    assert rec.adaptive.tau_static == twin.adaptive.tau_static
    assert rec.adaptive.tau_dynamic == twin.adaptive.tau_dynamic

    # and the recovered service keeps making the twin's decisions,
    # including the next adaptation
    for p, m in zip(prompts[CRASH_N1:], metas[CRASH_N1:]):
        rr = rec.serve(p, meta=m)
        rt = twin.serve(p, meta=m)
        assert (rr.answer, rr.served_by) == (rt.answer, rt.served_by)
    assert rec.adaptive.adaptations == twin.adaptive.adaptations
    assert rec.adaptive.tau_static == twin.adaptive.tau_static

    # geometry guard: a resized window must refuse the snapshot
    bad = AdaptiveController(CacheConfig(0.95, 0.95, capacity=64), d=D,
                             params=_params(window=48))
    with pytest.raises(ValueError):
        bad.load_state(ra, rs)

    # round-trip idempotence on the recovered stack
    save_snapshot(tmp_path / "snap2", rec, step=0)
    rec2, _, _ = _crash_build()
    restore_policy(rec2, tmp_path / "snap2")
    a2, s2 = rec2.adaptive.to_state()
    ra, rs = rec.adaptive.to_state()
    assert s2 == rs and all(np.array_equal(a2[k], ra[k]) for k in a2)


# ---------------------------------------------------------------------------
# 6. router telemetry + feedback plumbing
# ---------------------------------------------------------------------------

def test_router_stats_and_feedback():
    from repro.serving.router import CacheRouter

    tier, answers, _ = _static()
    _, _, _, _, prompts, metas, embed = _workload(24, seed=7)
    cfg = CacheConfig(0.93, 0.93, capacity=64)
    ctl = AdaptiveController(cfg, d=D, params=_params())
    pol = BaselinePolicy(cfg, tier, answers, embed,
                         lambda p: f"gen({p})", d=D,
                         backend_batch_fn=lambda ps:
                             [f"gen({p})" for p in ps],
                         adaptive=ctl)
    router = CacheRouter(pol, max_batch=8, max_wait_ms=1.0)
    try:
        results = [router.submit(p, meta=m)
                   for p, m in zip(prompts, metas)]
        assert all(r is not None for r in results)
        # wrong-answer report lands in the controller window
        assert router.feedback(results[0], False)
        assert ctl.feedbacks == 1
        # a rotated-out / absent seq is a no-op
        assert not router.feedback(0, False)
        s = router.stats()
        for name in ("unknown", "volatile", "stable"):
            assert s[f"tau_static_{name}"] == cfg.tau_static
            assert f"adaptive_regret_{name}" in s
        assert s["adaptive_window_fill"] == len(prompts)
    finally:
        router.stop()
