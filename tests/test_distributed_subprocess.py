"""Distributed behaviors that need >1 device: run in a subprocess with
forced host devices (the main pytest process must keep 1 device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str) -> str:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=420,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_topk_matches_oracle():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.index.sharded import sharded_cosine_topk
        from repro.kernels.simsearch.ref import simsearch_ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (5, 16))
        c = jax.random.normal(jax.random.fold_in(key, 1), (256, 16))
        with mesh:
            v, i = jax.jit(lambda a, b: sharded_cosine_topk(
                a, b, mesh, k=3))(q, c)
        vr, ir = simsearch_ref(q, c, 3)
        assert bool(jnp.all(i == ir)), (i, ir)
        assert float(jnp.max(jnp.abs(v - vr))) < 1e-5
        print("ok")
    """))


def test_sharded_ivf_topk_matches_flat_oracle():
    """Per-shard IVF scan + k-candidate merge: with full probing the
    merged result must equal exact flat search over the whole corpus
    (the sharded twin of the rerank-exactness argument, DESIGN.md §11)."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.index.sharded import (build_sharded_ivf,
                                         sharded_ivf_topk)
        from repro.index.flat import l2_normalize
        from repro.kernels.simsearch.ref import simsearch_ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(3)
        N, d = 4096, 32
        centers = rng.normal(size=(64, d))
        corpus = (centers[rng.integers(0, 64, N)]
                  + 0.3 * rng.normal(size=(N, d))).astype(np.float32)
        q = (corpus[rng.choice(N, 9)]
             + 0.05 * rng.normal(size=(9, d))).astype(np.float32)
        sivf = build_sharded_ivf(corpus, 4, n_clusters=16, iters=4)
        with mesh:
            v, i = jax.jit(lambda qq: sharded_ivf_topk(
                qq, sivf, mesh, k=3, nprobe=16, n_candidates=64))(
                    jnp.asarray(q))
        cn = np.asarray(l2_normalize(jnp.asarray(corpus)))
        vr, ir = simsearch_ref(q, cn, 3)
        assert bool(jnp.all(i == ir)), (i, ir)
        assert float(jnp.max(jnp.abs(v - vr))) < 1e-5
        print("ok")
    """))


def test_local_candidate_retrieval_matches_reference():
    print(_run("""
        import jax, jax.numpy as jnp
        from repro.index.sharded import sharded_topk_local_candidates
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(1)
        V, d, N, k = 64, 8, 32, 5
        table = jax.random.normal(key, (V, d))
        u = jax.random.normal(jax.random.fold_in(key, 1), (2, d))
        # range-partitioned candidate ids: shard s owns rows [s*16,(s+1)*16)
        ids = jnp.concatenate(
            [jnp.arange(s * 16, s * 16 + 8) for s in range(4)])
        with mesh:
            v, gi = jax.jit(lambda u, t, i: sharded_topk_local_candidates(
                u, t, i, mesh, k=k))(u, table, ids)
        cand = table[ids]
        ref = jnp.einsum("bd,nd->bn", u, cand)
        rv, ri = jax.lax.top_k(ref, k)
        assert float(jnp.max(jnp.abs(v - rv))) < 1e-5
        assert bool(jnp.all(gi == jnp.take(ids, ri)))
        print("ok")
    """))


def test_small_mesh_train_step_lowers_with_shardings():
    """End-to-end lowering of a (reduced) LM train step on a 2x4 mesh
    with the production sharding rules — the dry-run path in miniature."""
    print(_run("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.distributed import sharding as shd
        from repro.distributed.act_sharding import use_dp_axes
        from repro.models import transformer as tr
        from repro.training import optimizer as opt
        cfg = dataclasses.replace(
            smoke_config("qwen3-1.7b"), d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ns = lambda s: NamedSharding(mesh, s)
        p_specs = shd.lm_param_specs(cfg)
        p_shard = jax.tree.map(ns, p_specs,
                               is_leaf=lambda x: isinstance(x, P))
        params = jax.eval_shape(lambda k: tr.init_params(cfg, k),
                                jax.random.PRNGKey(0))
        opt_abs = jax.eval_shape(
            lambda p: opt.init(p, opt.AdamWConfig()), params)
        o_shard = {"mu": p_shard, "nu": p_shard, "master": p_shard,
                   "step": ns(P())}
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        b_shard = {k: ns(P(("data",), None)) for k in batch}
        step0 = opt.make_train_step(
            lambda p, b: tr.train_loss(cfg, p, b, vocab_chunk_seq=32),
            opt.AdamWConfig())
        def step(p, o, b):
            with use_dp_axes(("data",)):
                return step0(p, o, b)
        with mesh:
            c = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                        donate_argnums=(0, 1)).lower(
                params, opt_abs, batch).compile()
        assert c.cost_analysis() is not None
        print("compiled ok on", mesh.devices.size, "devices")
    """))


def test_checkpoint_elastic_reshard():
    """Save on one sharding, restore onto a different mesh shape."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import checkpoint as ck
        mesh1 = jax.make_mesh((8,), ("data",))
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        t = {"w": jax.device_put(x, NamedSharding(mesh1, P("data")))}
        with tempfile.TemporaryDirectory() as d:
            ck.save(d, 1, t)
            out = ck.restore(d, 1, t, shardings={
                "w": NamedSharding(mesh2, P("data", "model"))})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
        assert len(out["w"].sharding.device_set) == 8
        print("ok")
    """))
