"""Per-arch smoke tests: every assigned architecture at reduced scale runs
one forward/train step on CPU with correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig
from repro.models import gnn, recsys, transformer as tr

LM = [a for a, c in ARCHS.items() if isinstance(c, LMConfig)]
GNN = [a for a, c in ARCHS.items() if isinstance(c, GNNConfig)]
REC = [a for a, c in ARCHS.items() if isinstance(c, RecSysConfig)]
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", LM)
def test_lm_smoke(arch):
    cfg = smoke_config(arch)
    params = tr.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    loss = tr.train_loss(cfg, params, {"tokens": toks,
                                       "labels": jnp.roll(toks, -1, 1)},
                         vocab_chunk_seq=8)
    assert loss.shape == () and not bool(jnp.isnan(loss))
    logits, cache = tr.prefill(cfg, params, toks, max_len=32)
    assert logits.shape == (2, cfg.vocab_size)
    assert cache["k"].shape == (cfg.n_layers, 2, 32, cfg.n_kv_heads,
                                cfg.head_dim)
    lg, cache = tr.decode_step(cfg, params, cache, toks[:, -1])
    assert lg.shape == (2, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(lg)))
    assert int(cache["length"][0]) == 25


@pytest.mark.parametrize("arch", GNN)
@pytest.mark.parametrize("kind", ["full_graph", "minibatch", "molecule"])
def test_gnn_smoke(arch, kind):
    cfg = smoke_config(arch)
    params = gnn.init_params(cfg, KEY)
    if kind == "full_graph":
        batch = {"feats": jax.random.normal(KEY, (30, cfg.d_feat)),
                 "edges": jax.random.randint(KEY, (90, 2), 0, 30),
                 "labels": jax.random.randint(KEY, (30,), 0,
                                              cfg.n_classes)}
        logits = gnn.full_graph_forward(cfg, params, batch["feats"],
                                        batch["edges"])
        assert logits.shape == (30, cfg.n_classes)
        loss = gnn.full_graph_loss(cfg, params, batch)
    elif kind == "minibatch":
        B, f1, f2 = 6, 5, 3
        batch = {"feat_l0": jax.random.normal(KEY, (B, cfg.d_feat)),
                 "feat_l1": jax.random.normal(KEY, (B, f1, cfg.d_feat)),
                 "feat_l2": jax.random.normal(KEY, (B, f1, f2,
                                                    cfg.d_feat)),
                 "labels": jax.random.randint(KEY, (B,), 0,
                                              cfg.n_classes)}
        loss = gnn.minibatch_loss(cfg, params, batch)
    else:
        G, N, E = 5, 8, 12
        batch = {"feats": jax.random.normal(KEY, (G, N, cfg.d_feat)),
                 "edges": jax.random.randint(KEY, (G, E, 2), 0, N),
                 "edge_mask": jnp.ones((G, E), bool),
                 "labels": jax.random.randint(KEY, (G,), 0,
                                              cfg.n_classes)}
        loss = gnn.batched_graphs_loss(cfg, params, batch)
    assert not bool(jnp.isnan(loss))
    g = jax.grad(lambda p: {"full_graph": gnn.full_graph_loss,
                            "minibatch": gnn.minibatch_loss,
                            "molecule": gnn.batched_graphs_loss
                            }[kind](cfg, p, batch))(params)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


@pytest.mark.parametrize("arch", REC)
def test_recsys_smoke(arch):
    from repro.data.recsys_data import recsys_batches
    cfg = smoke_config(arch)
    params = recsys.init_params(cfg, KEY)
    batch = {k: jnp.asarray(v)
             for k, v in next(recsys_batches(cfg, batch=6)).items()}
    loss = recsys.train_loss(cfg, params, batch)
    assert loss.shape == () and not bool(jnp.isnan(loss))
    g = jax.grad(lambda p: recsys.train_loss(cfg, p, batch))(params)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
    # serve + retrieval paths
    batch["cands"] = jax.random.randint(KEY, (6, 7), 1, cfg.n_items)
    batch["cand_ids"] = jnp.arange(32)
    scores = recsys.serve_scores(cfg, params, batch)
    assert scores.shape[0] == 6 and not bool(jnp.any(jnp.isnan(scores)))
    vals, ids = recsys.retrieval(cfg, params, batch, k=5)
    assert vals.shape == (6, 5) == ids.shape
