"""Dynamic-tier lookup benchmark: flat masked scan vs the segmented
incremental ANN index (DESIGN.md §12), over live-entry count x
promotion rate.

The dynamic tier grows online as the judge approves promotions, so its
lookup is the one scan that cannot be pre-built offline. The flat path
costs B*C*d per micro-batch at capacity C regardless of how the tier
got there; the segmented index serves the same lookup from a small
fp32 tail plus int8 cluster-major segments (the ``kernels/ivf_scan``
band scan) with exact fp32 rerank, so steady-state cost is
~B*(K + nprobe*cap + tail)*d and stays nearly flat in C.

Per (live entries, promotion-rate) operating point:
- ``us_per_call`` / ``speedup_vs_flat`` — jitted end-to-end lookup
  wall time (same query batch, warm compile) against the flat masked
  scan over the same tier;
- ``decision_agreement`` — fraction of queries whose served decision
  matches the flat scan exactly (same hit/miss verdict at the cache
  threshold tau and, on hits, the same served slot);
- ``tail_live``/``segments``/``seals``/``merges`` — index shape after
  the promotion churn (the compaction schedule at work).

State per point: the live set is bulk-loaded as one merged segment
(the post-compaction steady state), then ``rate * live`` promotion
writes are replayed through ``record_write`` — overwriting occupied
slots exactly as LRU eviction + upsert do — so the measured index
carries a real mix of tail, sealed segments, and tombstones.

    PYTHONPATH=src python -m benchmarks.dyn_index [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh): a small live set with
heavy churn, asserting decision agreement 1.0 vs flat and that no
tombstoned (overwritten) slot is ever served.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (clustered_cache_workload,
                               decision_agreement, timed_median)

TAU = 0.85
D = 64
B = 32
RATES = (0.02, 0.1)
NPROBES = (8, 16, 32)


def _make_state(n_live: int, rng, d: int = D, b: int = B):
    """Clustered live set + cache-like queries (near-duplicate heavy):
    the shared ANN-benchmark workload over the dynamic tier's rows."""
    return clustered_cache_workload(n_live, rng, b, d)


def _make_tier(rows: np.ndarray, capacity: int):
    from repro.core.tiers import DynamicTier
    n, d = rows.shape
    emb = np.zeros((capacity, d), np.float32)
    emb[:n] = rows
    valid = np.zeros(capacity, bool)
    valid[:n] = True
    return DynamicTier(
        emb=jnp.asarray(emb), cls=jnp.zeros(capacity, jnp.int32),
        answer_ref=jnp.full(capacity, -1, jnp.int32),
        static_origin=jnp.zeros(capacity, bool),
        valid=jnp.asarray(valid),
        last_used=jnp.zeros(capacity, jnp.int32),
        written_at=jnp.zeros(capacity, jnp.int32),
        expires_at=jnp.zeros(capacity, jnp.int32))


def _apply_churn(tier, index, rng, n_writes: int):
    """Replay promotion churn: each write lands a fresh normalized key
    in an occupied slot (upsert/LRU overwrite), through both the tier
    and the index, exercising tombstones + seal + merge."""
    from repro.core import tiers as T
    capacity = tier.emb.shape[0]
    slots = rng.integers(0, capacity, n_writes)
    vecs = rng.normal(size=(n_writes, tier.emb.shape[1])).astype(
        np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    # tier update as one scatter (last write per slot wins, like the
    # batched serving path); index updates replay write-for-write
    last = {}
    for i, s in enumerate(slots):
        last[int(s)] = i
    order = np.asarray(sorted(last, key=last.get))
    tier = tier._replace(
        emb=tier.emb.at[order].set(vecs[[last[int(s)] for s in order]]),
        valid=tier.valid.at[order].set(True))
    for i, s in enumerate(slots):
        index.record_write(int(s), vecs[i])
    return tier


def _time(fn, reps: int = 5) -> float:
    return timed_median(fn, reps)


def _agreement(v_flat, i_flat, v_seg, i_seg, tau=TAU) -> float:
    return decision_agreement(v_flat, i_flat, v_seg, i_seg, tau)


def _bench_one(n_live: int, rate: float, rng, reps: int = 5,
               tail_rows: int = 4096, nprobes=NPROBES):
    from repro.core.tiers import dynamic_lookup_batch
    from repro.index.segmented import SegmentedIndex

    rows, q_np = _make_state(n_live, rng)
    q = jnp.asarray(q_np)
    tier = _make_tier(rows, n_live)

    t0 = time.perf_counter()
    index = SegmentedIndex(n_live, D, tail_rows=tail_rows,
                           n_candidates=64)
    index.bulk_load(np.arange(n_live, dtype=np.int32), rows)
    tier = _apply_churn(tier, index, rng, int(rate * n_live))
    build_s = time.perf_counter() - t0

    flat_t = _time(lambda: dynamic_lookup_batch(tier, q), reps)
    v_f, i_f = jax.device_get(dynamic_lookup_batch(tier, q))

    st = index.stats()
    out = []
    for nprobe in nprobes:
        index.nprobe = nprobe
        seg_t = _time(lambda: dynamic_lookup_batch(tier, q, index=index),
                      reps)
        v_s, i_s = jax.device_get(
            dynamic_lookup_batch(tier, q, index=index))
        out.append({
            "name": f"dyn_index/L{n_live}_rate{rate}_nprobe{nprobe}",
            "us_per_call": round(1e6 * seg_t, 1),
            "flat_us_per_call": round(1e6 * flat_t, 1),
            "speedup_vs_flat": round(flat_t / seg_t, 2),
            "decision_agreement": _agreement(v_f, i_f, v_s, i_s),
            "live": st["live"], "tail_live": st["tail_live"],
            "segments": st["segments"], "seals": st["seals"],
            "merges": st["merges"], "tombstones": st["tombstones"],
            "build_s": round(build_s, 2), "B": B, "d": D,
        })
    return out


def run(scale: str = "small"):
    sizes = [65_536, 262_144]
    if scale == "full":
        sizes.append(524_288)
    rng = np.random.default_rng(0)
    return [row for n in sizes for rate in RATES
            for row in _bench_one(n, rate, rng)]


def smoke() -> None:
    """CI gate: small live set, heavy churn; segmented decisions must
    agree with the flat masked scan and never serve overwritten slots."""
    from repro.core.tiers import dynamic_lookup_batch
    from repro.index.segmented import SegmentedIndex

    rng = np.random.default_rng(0)
    n_live = 8192
    rows, q_np = _make_state(n_live, rng)
    q = jnp.asarray(q_np)
    tier = _make_tier(rows, n_live)
    # covering budgets (full probe, candidate budget >= any segment's
    # live rows, tail fully scanned): recall is 1 by construction, so
    # the agreement==1.0 gate is structural, not empirical
    index = SegmentedIndex(n_live, D, tail_rows=512, nprobe=None,
                           n_candidates=2 * n_live, tail_candidates=512,
                           compact_every=3)
    index.bulk_load(np.arange(n_live, dtype=np.int32), rows)
    tier = _apply_churn(tier, index, rng, 2048)

    v_f, i_f = jax.device_get(dynamic_lookup_batch(tier, q))
    v_s, i_s = jax.device_get(dynamic_lookup_batch(tier, q, index=index))
    agree = _agreement(v_f, i_f, v_s, i_s)
    st = index.stats()
    assert st["seals"] >= 4 and st["tombstones"] > 0, st
    assert (v_f >= TAU).any(), "smoke workload produced no cache hits"
    assert agree == 1.0, f"decision agreement {agree} < 1.0"
    assert np.array_equal(i_f, i_s), "served slots diverge from flat"
    print(f"[OK] dyn_index smoke: live={st['live']} "
          f"segs={st['segments']} seals={st['seals']} "
          f"merges={st['merges']} tombstones={st['tombstones']}, "
          f"decision agreement {agree:.3f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: churned small index + decision-"
                         "agreement asserts vs the flat masked scan")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    else:
        for r in run(scale=a.scale):
            print(r)
