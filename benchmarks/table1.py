"""Paper Table 1: static-origin served fraction, baseline vs Krites,
plus the Figure-1a hit-composition check (total hit rate unchanged).

Reproduces: Table 1 (both synthetic workloads, tuned thresholds from
scripts/calibrate.py) and the Figure-1a invariant that Krites leaves the
total hit rate and the direct static hit rate unchanged.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only table1 [--scale full]
"""
from __future__ import annotations

from benchmarks.common import default_cfg, get_benchmark, run_policy_sweep

PAPER = {  # from Table 1
    "lmarena_like": {"baseline": 0.082, "krites": 0.194, "gain": 1.365},
    "search_like": {"baseline": 0.022, "krites": 0.086, "gain": 2.903},
}


def run(scale: str = "small"):
    rows = []
    for wl in ("lmarena_like", "search_like"):
        bench = get_benchmark(wl, scale)
        # baseline and Krites share one sweep dispatch (DESIGN.md §10)
        cfg = default_cfg(wl)
        (b, k), _, _ = run_policy_sweep(bench, [cfg, cfg],
                                        krites=[False, True])
        gain = k["static_origin_rate"] / max(b["static_origin_rate"],
                                             1e-9) - 1
        rows.append({
            "name": f"table1/{wl}",
            "us_per_call": round(k["us_per_req"], 2),
            "baseline_static_origin": round(b["static_origin_rate"], 4),
            "krites_static_origin": round(k["static_origin_rate"], 4),
            "relative_gain_pct": round(100 * gain, 1),
            "paper_baseline": PAPER[wl]["baseline"],
            "paper_krites": PAPER[wl]["krites"],
            "paper_gain_pct": round(100 * PAPER[wl]["gain"], 1),
            "total_hit_delta": round(
                abs(k["total_hit_rate"] - b["total_hit_rate"]), 4),
            "error_baseline": round(b["error_rate"], 4),
            "error_krites": round(k["error_rate"], 4),
            "judge_calls": k["judge_calls"],
            "promotions": k["promotions"],
        })
    return rows
