"""Live load harness: streaming trace replay against a long-lived
serve process at a target QPS (DESIGN.md §14).

Unlike the in-process benchmarks, this drives ``launch/serve.py
--serve-stdio`` over its JSON-lines protocol from a *separate* process
— the same topology a production deployment has — with **open-loop**
pacing: each request has a scheduled send time on a fixed QPS grid and
its latency is measured from that schedule, so a stalled service
accrues queueing delay instead of silently slowing the generator
(no coordinated omission). Reported per window:

- p50/p99 end-to-end latency (schedule -> reply),
- tier hit-rate drift (static / dynamic / backend shares over time —
  the dynamic share should climb as promotions land),
- judge-queue depth + WAL seq, sampled via interleaved ``stats`` ops.

    PYTHONPATH=src python -m benchmarks.load_service --qps 50 \
        --duration 20 [--snapshot-dir DIR] [--snapshot-mid]

``--smoke`` is the CI gate (scripts/ci.sh): a short burst against a
snapshotting service, a mid-run snapshot, a clean shutdown, then a
restart from the snapshot that must come back warm (restored clock
advances, no cold backend storm) and keep serving.

``--restore-bench`` measures warm snapshot restore vs cold index
rebuild at a >=256k-row static tier (EXPERIMENTS.md): the time to
re-install the packed IVF layout from disk vs re-running k-means +
quantization over the corpus.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

SRC = str(Path(__file__).resolve().parent.parent / "src")


class _Pending:
    __slots__ = ("sched", "reply", "recv_t", "done")

    def __init__(self, sched: float):
        self.sched = sched
        self.reply = None
        self.recv_t = 0.0
        self.done = threading.Event()


class ServeClient:
    """Client for the ``--serve-stdio`` JSON-lines protocol: spawns the
    service, tags every message with an id, and matches replies on a
    reader thread (receive-timestamping them for latency accounting)."""

    def __init__(self, extra_args=(), env_extra=None, start_timeout=300.0):
        env = dict(os.environ,
                   PYTHONPATH=SRC + (os.pathsep + os.environ["PYTHONPATH"]
                                     if os.environ.get("PYTHONPATH")
                                     else ""))
        env.setdefault("JAX_PLATFORMS", "cpu")
        env.update(env_extra or {})
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve", "--serve-stdio",
             *extra_args],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.DEVNULL, text=True, bufsize=1, env=env)
        self._lock = threading.Lock()
        self._pending: dict = {}
        self._next_id = 0
        self._ready = None
        self._ready_ev = threading.Event()
        self._reader = threading.Thread(target=self._read, daemon=True)
        self._reader.start()
        if not self._ready_ev.wait(start_timeout):
            self.kill()
            raise TimeoutError("service did not come up")

    @property
    def ready(self) -> dict:
        return self._ready or {}

    def _read(self):
        for line in self.proc.stdout:
            try:
                obj = json.loads(line)
            except ValueError:
                continue
            if self._ready is None and obj.get("ready"):
                self._ready = obj
                self._ready_ev.set()
                continue
            now = time.monotonic()
            with self._lock:
                p = self._pending.pop(obj.get("id"), None)
            if p is not None:
                p.reply, p.recv_t = obj, now
                p.done.set()

    def send(self, msg: dict, sched: float = None) -> _Pending:
        p = _Pending(time.monotonic() if sched is None else sched)
        with self._lock:
            msg["id"] = self._next_id
            self._next_id += 1
            self._pending[msg["id"]] = p
        self.proc.stdin.write(json.dumps(msg) + "\n")
        self.proc.stdin.flush()
        return p

    def call(self, msg: dict, timeout: float = 300.0) -> dict:
        p = self.send(msg)
        if not p.done.wait(timeout):
            raise TimeoutError(f"no reply to {msg}")
        return p.reply

    def shutdown(self, timeout: float = 30.0) -> int:
        try:
            self.call({"op": "shutdown"}, timeout)
        except Exception:  # noqa: BLE001 — fall through to kill
            pass
        try:
            self.proc.wait(timeout)
        except subprocess.TimeoutExpired:
            self.kill()
        return self.proc.returncode

    def kill(self):
        self.proc.kill()
        self.proc.wait(10)


def _trace(n: int, seed: int = 0):
    """The launcher's demo workload, regenerated here so the harness
    and the service agree on the intent set without sharing state."""
    from repro.launch.serve import DEMO_INTENTS, DEMO_PREFIXES
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        c = int(rng.integers(0, len(DEMO_INTENTS)))
        p = DEMO_PREFIXES[int(rng.integers(0, len(DEMO_PREFIXES)))] \
            + DEMO_INTENTS[c]
        out.append((p, c))
    return out


def run_load(client: ServeClient, qps: float, duration_s: float, *,
             window_s: float = 2.0, stats_every_s: float = 1.0,
             snapshot_at_s: float = None, seed: int = 0) -> dict:
    """Open-loop replay at ``qps`` for ``duration_s``; returns windowed
    latency/hit-rate series plus judge-depth samples."""
    n = max(1, int(qps * duration_s))
    trace = _trace(n, seed)
    pend = []
    depth_samples = []
    stop = threading.Event()

    def _poll_stats():
        while not stop.is_set():
            try:
                st = client.call({"op": "stats"}, 60.0)["stats"]
            except Exception:  # noqa: BLE001 — service shutting down
                return
            depth_samples.append({
                "t": round(time.monotonic() - start, 2),
                "judge_queued": st.get("judge_queued", 0),
                "judge_inflight": st.get("judge_inflight", 0),
                "wal_seq": st.get("wal_seq"),
            })
            stop.wait(stats_every_s)

    start = time.monotonic() + 0.05
    poller = threading.Thread(target=_poll_stats, daemon=True)
    poller.start()
    snap_reply = None
    for k, (prompt, cls) in enumerate(trace):
        sched = start + k / qps
        delay = sched - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        if snapshot_at_s is not None and sched - start >= snapshot_at_s:
            snap_reply = client.call({"op": "snapshot"})
            snapshot_at_s = None
        pend.append(client.send(
            {"op": "serve", "prompt": prompt, "cls": cls}, sched=sched))

    for p in pend:
        p.done.wait(300.0)
    stop.set()
    poller.join(5.0)

    # windowed aggregation off the scheduled (open-loop) timeline
    n_win = max(1, int(np.ceil(duration_s / window_s)))
    wins = [{"lat": [], "stale": 0, "promoted": 0,
             "by": {"l1": 0, "static": 0, "dynamic": 0,
                    "rewritten": 0, "backend": 0}}
            for _ in range(n_win)]
    lost = 0
    for k, p in enumerate(pend):
        if p.reply is None:
            lost += 1
            continue
        w = wins[min(int((p.sched - start) / window_s), n_win - 1)]
        w["lat"].append(p.recv_t - p.sched)
        by = p.reply["served_by"]
        w["by"][by] = w["by"].get(by, 0) + 1
        w["stale"] += bool(p.reply.get("stale"))
        # dynamic hits serving promoted (static-origin) content — the
        # per-window hit-source attribution splits the dynamic tier by
        # content origin (DESIGN.md §16)
        w["promoted"] += (by in ("dynamic", "rewritten")
                          and bool(p.reply.get("static_origin")))
    windows = []
    for i, w in enumerate(wins):
        m = sum(w["by"].values())
        lat = np.asarray(w["lat"])
        windows.append({
            "t0_s": round(i * window_s, 2),
            "n": m,
            "p50_ms": round(1e3 * float(np.percentile(lat, 50)), 2)
            if len(lat) else None,
            "p99_ms": round(1e3 * float(np.percentile(lat, 99)), 2)
            if len(lat) else None,
            "l1_rate": round(w["by"]["l1"] / m, 3) if m else None,
            "static_rate": round(w["by"]["static"] / m, 3) if m else None,
            "dynamic_rate": round(w["by"]["dynamic"] / m, 3)
            if m else None,
            "rewritten_rate": round(w["by"]["rewritten"] / m, 3)
            if m else None,
            "promoted_rate": round(w["promoted"] / m, 3) if m else None,
            "backend_rate": round(w["by"]["backend"] / m, 3)
            if m else None,
            "stale_rate": round(w["stale"] / m, 3) if m else None,
        })
    lat_all = np.asarray([p.recv_t - p.sched for p in pend
                          if p.reply is not None])
    return {
        "requests": n, "lost": lost, "qps": qps,
        "p50_ms": round(1e3 * float(np.percentile(lat_all, 50)), 2),
        "p99_ms": round(1e3 * float(np.percentile(lat_all, 99)), 2),
        "windows": windows,
        "depth_samples": depth_samples,
        "snapshot": snap_reply,
        # drift = how far the last window's tier mix moved from the
        # first full window's (promotions shifting traffic off backend)
        "hit_rate_drift": _drift(windows),
    }


def _drift(windows):
    full = [w for w in windows if w["n"]]
    if len(full) < 2:
        return None
    a, b = full[0], full[-1]
    return {k: round(b[k] - a[k], 3)
            for k in ("l1_rate", "static_rate", "dynamic_rate",
                      "rewritten_rate", "backend_rate")}


# ---------------------------------------------------------------------------
# restore benchmark (EXPERIMENTS.md: warm restore vs cold rebuild)
# ---------------------------------------------------------------------------

def restore_bench(n_rows: int = 262_144, d: int = 64,
                  capacity: int = 4096) -> dict:
    """Warm snapshot restore vs cold IVF rebuild at a ``n_rows`` static
    tier. Cold = k-means + int8 quantization over the corpus (what a
    restart without persistence pays); warm = reading the packed layout
    off disk, hash-verifying it, and re-wiring it to the live tier."""
    import jax.numpy as jnp

    from benchmarks.common import clustered_cache_workload
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, StaticTier
    from repro.index.ivf import IVFIndex, build_ivf
    from repro.serving import persist

    rng = np.random.default_rng(0)
    corpus_np, _ = clustered_cache_workload(n_rows, rng, 8, d)
    corpus = jnp.asarray(corpus_np)

    t0 = time.monotonic()
    index = IVFIndex(build_ivf(corpus, corpus_normalized=True))
    index.topk(corpus[:1], 1)   # include first-dispatch in cold cost
    cold_s = time.monotonic() - t0

    static = StaticTier(emb=corpus,
                        cls=jnp.zeros(n_rows, jnp.int32),
                        answer_ref=jnp.arange(n_rows, dtype=jnp.int32))
    cfg = CacheConfig(0.9, 0.85, sigma_min=0.3, capacity=capacity)

    def mk(idx):
        return KritesPolicy(cfg, static, [""] * n_rows,
                            embed_fn=lambda p: np.zeros(d, np.float32),
                            backend_fn=lambda p: "", d=d,
                            judge_fn=lambda **kw: True, n_workers=0,
                            index=idx)

    tmp = tempfile.mkdtemp(prefix="restore-bench-")
    try:
        pol = mk(index)
        t0 = time.monotonic()
        persist.save_snapshot(tmp, pol)
        save_s = time.monotonic() - t0

        fresh = mk(None)
        t0 = time.monotonic()
        rep = persist.restore_policy(fresh, tmp)
        fresh.index.topk(corpus[:1], 1)
        warm_s = time.monotonic() - t0
        assert rep["index"] == "warm", rep
        snap_bytes = sum(f.stat().st_size
                         for f in Path(tmp).rglob("*") if f.is_file())
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return {"rows": n_rows, "cold_build_s": round(cold_s, 2),
            "snapshot_save_s": round(save_s, 2),
            "warm_restore_s": round(warm_s, 2),
            "speedup": round(cold_s / warm_s, 1),
            "snapshot_mb": round(snap_bytes / 1e6, 1)}


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _service_args(snap_dir, capacity=512):
    return ["--snapshot-dir", snap_dir, "--capacity", str(capacity)]


def smoke() -> None:
    """CI gate: load -> snapshot -> shutdown -> warm restart -> serve."""
    tmp = tempfile.mkdtemp(prefix="load-smoke-")
    try:
        client = ServeClient(_service_args(tmp))
        res = run_load(client, qps=40, duration_s=3.0, window_s=1.0,
                       snapshot_at_s=1.5)
        rc = client.shutdown()
        assert rc == 0, f"service exit code {rc}"
        assert res["lost"] == 0, f"lost {res['lost']} replies"
        assert res["snapshot"] and res["snapshot"]["ok"], res["snapshot"]
        assert res["depth_samples"], "no stats samples collected"
        t_before = res["snapshot"]["t"]

        client = ServeClient(_service_args(tmp))
        ready = client.ready
        # warm restart: the restored logical clock must resume past the
        # mid-run snapshot, not from zero
        assert ready["t"] >= t_before > 0, ready
        res2 = run_load(client, qps=40, duration_s=1.0, window_s=1.0,
                        seed=1)
        assert res2["lost"] == 0
        # a warm cache serves the same workload without a cold-start
        # backend storm
        w = [x for x in res2["windows"] if x["n"]][0]
        assert w["backend_rate"] <= 0.5, w
        assert client.shutdown() == 0
        print(f"load_service smoke OK: {res['requests']} + "
              f"{res2['requests']} reqs, restart t={ready['t']}, "
              f"restart backend_rate={w['backend_rate']}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run(scale: str = "small"):
    """benchmarks.run registry entry."""
    tmp = tempfile.mkdtemp(prefix="load-bench-")
    try:
        dur = 6.0 if scale == "small" else 20.0
        client = ServeClient(_service_args(tmp))
        res = run_load(client, qps=50, duration_s=dur,
                       snapshot_at_s=dur / 2)
        client.shutdown()
        rows = [{
            "name": f"load_service/qps50-{int(dur)}s",
            "us_per_call": round(1e3 * res["p50_ms"], 1),
            "p99_ms": res["p99_ms"], "lost": res["lost"],
            "hit_rate_drift": res["hit_rate_drift"],
            "max_judge_queued": max((s["judge_queued"]
                                     for s in res["depth_samples"]),
                                    default=0),
        }]
        if scale == "full":
            rb = restore_bench()
            rows.append({"name": f"load_service/restore-{rb['rows']}",
                         "us_per_call": round(1e6 * rb["warm_restore_s"],
                                              1), **rb})
        return rows
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=50.0)
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--window", type=float, default=2.0)
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--snapshot-dir", default=None,
                    help="persist the service under this dir (default: "
                         "a throwaway tmp dir)")
    ap.add_argument("--snapshot-mid", action="store_true",
                    help="take a snapshot halfway through the run "
                         "(shows its latency cost in the p99 window)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--restore-bench", action="store_true",
                    help="measure warm restore vs cold IVF rebuild at "
                         "a 262144-row static tier (EXPERIMENTS.md)")
    ap.add_argument("--restore-rows", type=int, default=262_144)
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.restore_bench:
        print(json.dumps(restore_bench(args.restore_rows), indent=1))
        return

    tmp = None
    snap_dir = args.snapshot_dir
    if snap_dir is None:
        tmp = tempfile.mkdtemp(prefix="load-service-")
        snap_dir = tmp
    try:
        client = ServeClient(_service_args(snap_dir, args.capacity))
        print(f"service up (pid {client.ready.get('pid')}, "
              f"t={client.ready.get('t')})")
        res = run_load(client, args.qps, args.duration,
                       window_s=args.window,
                       snapshot_at_s=args.duration / 2
                       if args.snapshot_mid else None)
        client.shutdown()
        print(f"\n{res['requests']} requests @ {args.qps} qps | "
              f"p50 {res['p50_ms']}ms p99 {res['p99_ms']}ms | "
              f"lost {res['lost']}")
        print(f"{'t0':>6} {'n':>5} {'p50ms':>8} {'p99ms':>8} "
              f"{'static':>7} {'dyn':>6} {'backend':>8}")
        for w in res["windows"]:
            if not w["n"]:
                continue
            print(f"{w['t0_s']:>6} {w['n']:>5} {w['p50_ms']:>8} "
                  f"{w['p99_ms']:>8} {w['static_rate']:>7} "
                  f"{w['dynamic_rate']:>6} {w['backend_rate']:>8}")
        print(f"drift first->last window: {res['hit_rate_drift']}")
        if res["depth_samples"]:
            mx = max(s["judge_queued"] + s["judge_inflight"]
                     for s in res["depth_samples"])
            print(f"judge depth: max {mx}, samples "
                  f"{len(res['depth_samples'])}, final wal_seq "
                  f"{res['depth_samples'][-1]['wal_seq']}")
    finally:
        if tmp:
            shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
