"""Batched vs scalar live serving path: requests/sec and p50/p99 latency.

Reproduces: no single paper table — this measures the repo's batched
serving-path extension (DESIGN.md §7) that keeps the paper's critical-path
contract (embed -> top-k -> threshold check, §2) while amortizing every
fast primitive over a micro-batch, the scaling direction the paper's
"unchanged critical path" claim depends on under heavy traffic.

Method: the same synthetic request stream (prompt -> precomputed trace
embedding, constant-time backend) is served once through scalar
``BaselinePolicy.serve`` and once through ``serve_batch`` at several batch
sizes; both paths produce identical per-request decisions (asserted in
tests/test_serve_batch.py), so the ratio is pure serving-path overhead.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only serve_batched
    PYTHONPATH=src python -m benchmarks.serve_batched        # standalone
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.policy import BaselinePolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.data.synth_traces import LMARENA_LIKE, build_benchmark

BATCH_SIZES = (8, 32)


def _setup(n_requests: int):
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=8000,
                               n_classes=400)
    bench = build_benchmark(spec)
    n = min(n_requests, len(bench.eval_cls))
    emb = bench.eval_emb[:n]
    prompts = [f"q{i}" for i in range(n)]
    table = {p: emb[i] for i, p in enumerate(prompts)}
    metas = [{"cls": int(bench.eval_cls[i])} for i in range(n)]
    tier = make_static_tier(jnp.asarray(bench.static_emb),
                            jnp.asarray(bench.static_cls))
    answers = [f"curated-{int(c)}" for c in bench.static_cls]
    d = bench.static_emb.shape[1]

    def policy():
        return BaselinePolicy(
            CacheConfig(0.88, 0.88, capacity=2048), tier, answers,
            embed_fn=lambda p: table[p],
            backend_fn=lambda p: f"gen({p})", d=d,
            embed_batch_fn=lambda ps: np.stack([table[p] for p in ps]),
            backend_batch_fn=lambda ps: [f"gen({p})" for p in ps])

    return prompts, metas, policy


def _pcts(lat):
    lat = np.asarray(lat)
    return (round(1e3 * float(np.percentile(lat, 50)), 3),
            round(1e3 * float(np.percentile(lat, 99)), 3))


def run(scale: str = "small"):
    n = 1024 if scale == "small" else 8000
    prompts, metas, mk_policy = _setup(n)
    rows = []

    # scalar reference path
    pol = mk_policy()
    pol.serve(prompts[0], metas[0])          # warm the jit caches
    lat = []
    t0 = time.perf_counter()
    for p, m in zip(prompts, metas):
        s = time.perf_counter()
        pol.serve(p, m)
        lat.append(time.perf_counter() - s)
    scalar_wall = time.perf_counter() - t0
    scalar_rps = n / scalar_wall
    p50, p99 = _pcts(lat)
    rows.append({"name": "serve_batched/scalar",
                 "us_per_call": round(1e6 * scalar_wall / n, 2),
                 "requests_per_s": round(scalar_rps, 1),
                 "p50_ms": p50, "p99_ms": p99})

    for bs in BATCH_SIZES:
        pol = mk_policy()
        pol.serve_batch(prompts[:bs], metas[:bs])   # warm the jit caches
        pol = mk_policy()
        lat = []
        t0 = time.perf_counter()
        for i in range(0, n, bs):
            s = time.perf_counter()
            pol.serve_batch(prompts[i:i + bs], metas[i:i + bs])
            lat += [time.perf_counter() - s] * min(bs, n - i)
        wall = time.perf_counter() - t0
        rps = n / wall
        p50, p99 = _pcts(lat)
        rows.append({"name": f"serve_batched/batch{bs}",
                     "us_per_call": round(1e6 * wall / n, 2),
                     "requests_per_s": round(rps, 1),
                     "speedup_vs_scalar": round(rps / scalar_rps, 2),
                     "p50_ms": p50, "p99_ms": p99})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
