"""§5 verifier fidelity: noisy judge with false-approve rate eps — the
incremental cache error from promotions is bounded by eps * p_prom.

The scan simulator's judge is the oracle; we model the noisy judge by
post-hoc flipping approvals with probability eps_fa / eps_fr using the
same deterministic hash scheme as core.judge.NoisyOracleJudge, re-running
the simulation with the flipped equivalence labels for promoted pairs.
Implemented as a sweep over eps using a modified class-label channel.

Reproduces: the §5 verifier-fidelity bound (added cache error
<= eps_fa * promoted traffic) as an eps sweep.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only verifier_fidelity
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import default_cfg, get_benchmark
from repro.core.simulate import simulate, summarize


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    cfg = default_cfg(wl)
    args = dict(static_emb=jnp.asarray(bench.static_emb),
                static_cls=jnp.asarray(bench.static_cls),
                q_emb=jnp.asarray(bench.eval_emb), cfg=cfg)
    q_cls = np.asarray(bench.eval_cls)

    base = summarize(simulate(q_cls=jnp.asarray(q_cls), krites=False,
                              **args))
    oracle = summarize(simulate(q_cls=jnp.asarray(q_cls), krites=True,
                                **args))
    rows = [{
        "name": f"verifier/{wl}/eps=0.0",
        "us_per_call": 0.0,
        "error_rate": oracle["error_rate"],
        "static_origin_rate": oracle["static_origin_rate"],
        "bound_eps_pprom": 0.0,
    }]

    rng = np.random.default_rng(7)
    for eps in (0.02, 0.05, 0.10):
        # false approvals: a fraction eps of judged pairs get the
        # neighbor's class accepted even when wrong. We emulate by
        # flipping the query class of eps of requests to their static
        # NN's class *for the judge channel only* — conservative upper
        # bound on promotion error (serving correctness still scored
        # against the true class).
        flip = rng.random(len(q_cls)) < eps
        res = simulate(q_cls=jnp.asarray(q_cls), krites=True,
                       judge_flip=jnp.asarray(flip), **args)
        s = summarize(res)
        p_prom = s["promoted_hit_rate"]
        added = s["error_rate"] - oracle["error_rate"]
        rows.append({
            "name": f"verifier/{wl}/eps={eps}",
            "us_per_call": 0.0,
            "error_rate": s["error_rate"],
            "added_error_vs_oracle": round(added, 5),
            "static_origin_rate": s["static_origin_rate"],
            "p_prom": round(p_prom, 4),
            "bound_eps_pprom": round(eps * p_prom, 5),
            "ratio_to_bound": round(added / max(eps * p_prom, 1e-9), 2),
            # Beyond-paper observation: the measured added error runs
            # ~1.2-1.3x the paper's heuristic eps*p_prom bound. Falsely
            # approved pairs live in confusable embedding regions whose
            # keys attract MORE than proportional hit traffic, so the
            # "promotions attract average traffic" assumption behind the
            # bound is mildly violated. Operators should budget
            # ~1.5x eps*p_prom. See EXPERIMENTS.md §Reproduction.
        })
    return rows
