"""§5 verifier fidelity: noisy judge with false-approve rate eps — the
incremental cache error from promotions is bounded by eps * p_prom.

The scan simulator's judge is the oracle; we model the noisy judge by
post-hoc flipping approvals with probability eps_fa / eps_fr using the
same deterministic hash scheme as core.judge.NoisyOracleJudge, re-running
the simulation with the flipped equivalence labels for promoted pairs.
Implemented as a sweep over eps using a modified class-label channel.

Reproduces: the §5 verifier-fidelity bound (added cache error
<= eps_fa * promoted traffic) as an eps sweep.

The final row is the *live-path payload fidelity gate*: verification
fidelity starts with the judge actually seeing the inputs it is defined
over, so a small trace is served through the live ``KritesPolicy``
(static texts plumbed in) with a recording ``OracleJudge(
require_texts=True)`` — every grey-zone submission must carry the full
non-empty ``(q_text, h_text, answer)`` triple, and the oracle decisions
must be unchanged by the extra payload.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only verifier_fidelity
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import default_cfg, get_benchmark
from repro.core.simulate import simulate, summarize


def live_payload_fidelity(n: int = 256) -> dict:
    """Serve a small live trace and audit every judge payload."""
    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, make_static_tier
    from repro.data.synth_traces import LMARENA_LIKE, build_benchmark

    spec = dataclasses.replace(LMARENA_LIKE, n_requests=4000,
                               n_classes=120)
    bench = build_benchmark(spec)
    emb = {f"q{i}": bench.eval_emb[i] for i in range(n)}
    tier = make_static_tier(jnp.asarray(bench.static_emb),
                            jnp.asarray(bench.static_cls))
    answers = [f"curated answer {int(c)}" for c in bench.static_cls]
    texts = [f"canonical prompt {i}" for i in range(len(answers))]
    oracle = OracleJudge(require_texts=True)
    seen: list = []

    def judge(q_cls, h_cls, q_text="", h_text="", answer=""):
        seen.append((q_text, h_text, answer))
        return oracle(q_cls, h_cls, q_text, h_text, answer)

    pol = KritesPolicy(
        CacheConfig(0.92, 0.88, sigma_min=0.0, capacity=512),
        tier, answers, lambda p: emb[p], lambda p: f"gen({p})", judge,
        d=bench.static_emb.shape[1], n_workers=1, static_texts=texts,
        backend_batch_fn=lambda ps: [f"gen({p})" for p in ps])
    for i in range(0, n, 32):
        pol.serve_batch([f"q{j}" for j in range(i, min(i + 32, n))],
                        [{"cls": int(bench.eval_cls[j])}
                         for j in range(i, min(i + 32, n))])
    pol.pool.drain()
    pol.pool.stop()
    s = pol.stats()
    complete = [bool(q and h and a) for q, h, a in seen]
    return {
        "name": "verifier/live_payload_fidelity",
        "us_per_call": 0.0,
        "judged": s["judged"],
        "payload_complete_rate": float(np.mean(complete))
        if complete else 0.0,
        "approved": s["approved"],
    }


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    cfg = default_cfg(wl)
    args = dict(static_emb=jnp.asarray(bench.static_emb),
                static_cls=jnp.asarray(bench.static_cls),
                q_emb=jnp.asarray(bench.eval_emb), cfg=cfg)
    q_cls = np.asarray(bench.eval_cls)

    base = summarize(simulate(q_cls=jnp.asarray(q_cls), krites=False,
                              **args))
    oracle = summarize(simulate(q_cls=jnp.asarray(q_cls), krites=True,
                                **args))
    rows = [{
        "name": f"verifier/{wl}/eps=0.0",
        "us_per_call": 0.0,
        "error_rate": oracle["error_rate"],
        "static_origin_rate": oracle["static_origin_rate"],
        "bound_eps_pprom": 0.0,
    }]

    rng = np.random.default_rng(7)
    for eps in (0.02, 0.05, 0.10):
        # false approvals: a fraction eps of judged pairs get the
        # neighbor's class accepted even when wrong. We emulate by
        # flipping the query class of eps of requests to their static
        # NN's class *for the judge channel only* — conservative upper
        # bound on promotion error (serving correctness still scored
        # against the true class).
        flip = rng.random(len(q_cls)) < eps
        res = simulate(q_cls=jnp.asarray(q_cls), krites=True,
                       judge_flip=jnp.asarray(flip), **args)
        s = summarize(res)
        p_prom = s["promoted_hit_rate"]
        added = s["error_rate"] - oracle["error_rate"]
        rows.append({
            "name": f"verifier/{wl}/eps={eps}",
            "us_per_call": 0.0,
            "error_rate": s["error_rate"],
            "added_error_vs_oracle": round(added, 5),
            "static_origin_rate": s["static_origin_rate"],
            "p_prom": round(p_prom, 4),
            "bound_eps_pprom": round(eps * p_prom, 5),
            "ratio_to_bound": round(added / max(eps * p_prom, 1e-9), 2),
            # Beyond-paper observation: the measured added error runs
            # ~1.2-1.3x the paper's heuristic eps*p_prom bound. Falsely
            # approved pairs live in confusable embedding regions whose
            # keys attract MORE than proportional hit traffic, so the
            # "promotions attract average traffic" assumption behind the
            # bound is mildly violated. Operators should budget
            # ~1.5x eps*p_prom. See EXPERIMENTS.md §Reproduction.
        })
    rows.append(live_payload_fidelity())
    return rows
