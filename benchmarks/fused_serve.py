"""Fused single-pass serve pipeline vs the dispatched lookups
(DESIGN.md §15), over corpus size at the serving micro-batch.

The policy hot path needs BOTH tier decisions per micro-batch: the
static top-1 (flat matmul or IVF probe + rerank) and the masked
dynamic top-1. Dispatched, that is two device round trips; the fused
pipeline (``kernels/fused_serve``) emits ``(s_static, h_idx, s_dyn,
j)`` in one. This benchmark measures that gap two ways:

- lookup-path rows — jitted wall time of the two dispatched calls
  (``static_lookup_batch`` + ``dynamic_lookup_batch``, flat and IVF
  static variants) against one ``serve_lookup_batch`` with a
  ``FusedServe``, same query batch, plus decision agreement of the
  fused pair of decisions against exact flat search at the cache
  threshold;
- policy rows — end-to-end ``KritesPolicy.serve_batch`` µs/request
  (embed + lookups + host mirrors) for dispatched-flat,
  dispatched-IVF and fused policies on an identical warm stream, with
  per-request answer agreement against the dispatched-flat policy.

    PYTHONPATH=src python -m benchmarks.fused_serve [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh): a small-corpus run that
hard-asserts agreement — >= 0.99 at a realistic probe budget, and
exactly 1.0 at a full-coverage budget (recall 1.0 by construction, so
any disagreement is a serving-path bug, not an ANN miss).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (clustered_cache_workload,
                               decision_agreement, timed_median)

TAU = 0.85
D = 64
B = 32              # serving micro-batch (ISSUE operating point)
DYN_CAP = 2048
NPROBE = 8
C_STATIC = 64
C_DYN = 64


def _workload(n_rows: int, rng, b: int = B, d: int = D):
    """Static corpus + queries + a partially-filled dynamic tier whose
    live rows include near-duplicates of some queries (dyn hits)."""
    from repro.core.tiers import DynamicTier

    corpus_np, q_np = clustered_cache_workload(n_rows, rng, b, d)
    n_live = int(0.75 * DYN_CAP)
    live = rng.normal(size=(n_live, d)).astype(np.float32)
    # a third of the batch gets a near-dup inside the dynamic tier
    for k in range(b // 3):
        live[k] = q_np[k] + 0.03 * rng.normal(size=d).astype(np.float32)
    live /= np.linalg.norm(live, axis=1, keepdims=True)
    emb = np.zeros((DYN_CAP, d), np.float32)
    emb[:n_live] = live
    valid = np.arange(DYN_CAP) < n_live
    clocks = np.arange(DYN_CAP, dtype=np.int32)
    dyn = DynamicTier(
        emb=jnp.asarray(emb),
        cls=jnp.asarray(clocks),
        answer_ref=jnp.where(jnp.asarray(valid), clocks, -1),
        static_origin=jnp.zeros((DYN_CAP,), bool),
        valid=jnp.asarray(valid),
        last_used=jnp.asarray(clocks),
        written_at=jnp.asarray(clocks),
        expires_at=jnp.zeros((DYN_CAP,), jnp.int32),
    )
    return corpus_np, q_np, jax.block_until_ready(dyn)


def _bench_lookups(n_rows: int, rng, reps: int = 5):
    from repro.core.tiers import (dynamic_lookup_batch, make_static_tier,
                                  serve_lookup_batch, static_lookup_batch)
    from repro.index.ivf import IVFIndex, build_ivf
    from repro.kernels.fused_serve import FusedServe

    corpus_np, q_np, dyn = _workload(n_rows, rng)
    corpus, q = jnp.asarray(corpus_np), jnp.asarray(q_np)
    tier = make_static_tier(
        corpus, jnp.arange(n_rows, dtype=jnp.int32))
    ivf = build_ivf(corpus_np, corpus_normalized=True)
    K, cap, _ = ivf.codes.shape
    index = IVFIndex(ivf, nprobe=NPROBE, n_candidates=C_STATIC)
    fused = FusedServe(ivf, nprobe=NPROBE, n_candidates=C_STATIC,
                       n_dyn_candidates=C_DYN)

    def dispatched(idx):
        def fn():
            a = static_lookup_batch(tier, q, index=idx)
            b_ = dynamic_lookup_batch(dyn, q)
            return jax.block_until_ready((a, b_))
        return fn

    t_flat = timed_median(dispatched(None), reps)
    t_ivf = timed_median(dispatched(index), reps)
    t_fus = timed_median(
        lambda: jax.block_until_ready(
            serve_lookup_batch(tier, dyn, q, fused)), reps)

    (vs_f, is_f), (vd_f, id_f) = (
        jax.device_get(static_lookup_batch(tier, q)),
        jax.device_get(dynamic_lookup_batch(dyn, q)))
    ss, hi, sd, j = jax.device_get(serve_lookup_batch(tier, dyn, q, fused))
    agree_s = decision_agreement(vs_f, is_f, ss, hi, TAU)
    agree_d = decision_agreement(vd_f, id_f, sd, j, TAU)

    def row(name, t, extra=None):
        r = {"name": f"fused_serve/N{n_rows}_{name}",
             "us_per_call": round(1e6 * t, 1),
             "us_per_req": round(1e6 * t / B, 2),
             "B": B, "d": D, "dyn_capacity": DYN_CAP}
        r.update(extra or {})
        return r

    return [
        row("dispatched_flat", t_flat, {"dispatches": 2}),
        row("dispatched_ivf", t_ivf,
            {"dispatches": 2, "nprobe": NPROBE, "C": C_STATIC}),
        row("fused", t_fus, {
            "dispatches": 1, "nprobe": NPROBE, "C": C_STATIC,
            "Cd": C_DYN, "K": int(K), "cap": int(cap),
            "speedup_vs_flat": round(t_flat / t_fus, 2),
            "speedup_vs_ivf": round(t_ivf / t_fus, 2),
            "agreement_static": agree_s, "agreement_dyn": agree_d,
            "agreement": round(min(agree_s, agree_d), 4)}),
    ]


def _make_policy(corpus_np, emb_map, **kw):
    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, make_static_tier

    n = corpus_np.shape[0]
    tier = make_static_tier(jnp.asarray(corpus_np),
                            jnp.arange(n, dtype=jnp.int32))
    # sigma_min == tau_static: empty grey zone, so no judge traffic
    # perturbs the timing loop
    return KritesPolicy(
        CacheConfig(TAU, TAU, sigma_min=TAU, capacity=DYN_CAP),
        tier, [f"curated-{i}" for i in range(n)],
        lambda p: emb_map[p], lambda p: f"gen({p})", OracleJudge(),
        d=D, n_workers=0,
        embed_batch_fn=lambda ps: np.stack([emb_map[p] for p in ps]),
        backend_batch_fn=lambda ps: [f"gen({p})" for p in ps], **kw)


def _bench_policy(n_rows: int, rng, reps: int = 5):
    """End-to-end serve_batch µs/request, dispatched vs fused, on the
    same warm stream (first batch inserts misses; timed repeats are all
    static/dynamic hits — the steady-state serving regime)."""
    from repro.index.ivf import IVFIndex, build_ivf
    from repro.kernels.fused_serve import FusedServe

    corpus_np, q_np, _ = _workload(n_rows, rng)
    prompts = [f"q{i}" for i in range(B)]
    emb_map = dict(zip(prompts, q_np))
    ivf = build_ivf(corpus_np, corpus_normalized=True)

    pols = {
        "dispatched_flat": _make_policy(corpus_np, emb_map),
        "dispatched_ivf": _make_policy(
            corpus_np, emb_map,
            index=IVFIndex(ivf, nprobe=NPROBE, n_candidates=C_STATIC)),
        "fused": _make_policy(
            corpus_np, emb_map,
            fused=FusedServe(ivf, nprobe=NPROBE, n_candidates=C_STATIC,
                             n_dyn_candidates=C_DYN)),
    }
    rows, answers = [], {}
    for name, pol in pols.items():
        warm = pol.serve_batch(prompts)          # misses insert here
        t = timed_median(lambda: pol.serve_batch(prompts), reps)
        res = pol.serve_batch(prompts)
        answers[name] = [(r.served_by, str(r.answer)) for r in res]
        rows.append({
            "name": f"fused_serve/N{n_rows}_policy_{name}",
            "us_per_call": round(1e6 * t, 1),
            "us_per_req": round(1e6 * t / B, 2),
            "B": B, "d": D,
            "warm_backend_rows": sum(r.served_by == "backend"
                                     for r in warm),
        })
    base = answers["dispatched_flat"]
    for r in rows:
        name = r["name"].rsplit("policy_", 1)[1]
        r["answer_agreement"] = round(
            float(np.mean([a == b for a, b
                           in zip(answers[name], base)])), 4)
        if name == "fused":
            r["speedup_vs_flat"] = round(
                rows[0]["us_per_req"] / r["us_per_req"], 2)
            r["speedup_vs_ivf"] = round(
                rows[1]["us_per_req"] / r["us_per_req"], 2)
    for pol in pols.values():
        pol.pool.stop()
    return rows


def run(scale: str = "small"):
    sizes = [65_536, 262_144]
    if scale == "full":
        sizes.append(1_048_576)
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        rows.extend(_bench_lookups(n, rng))
        rows.extend(_bench_policy(n, rng))
    return rows


def smoke() -> None:
    """CI gate: fused decisions agree with the dispatched lookups on a
    small corpus — >= 0.95 at a realistic probe budget and exactly 1.0
    at full coverage (every cluster probed, candidate budgets covering
    the corpus and the whole dynamic tier: recall 1.0 by construction,
    so the exact rerank makes any disagreement a pipeline bug)."""
    from repro.core.tiers import (dynamic_lookup_batch, make_static_tier,
                                  serve_lookup_batch, static_lookup_batch)
    from repro.index.ivf import build_ivf
    from repro.kernels.fused_serve import FusedServe

    n = 4096
    rng = np.random.default_rng(0)
    corpus_np, q_np, dyn = _workload(n, rng)
    corpus, q = jnp.asarray(corpus_np), jnp.asarray(q_np)
    tier = make_static_tier(corpus, jnp.arange(n, dtype=jnp.int32))
    ivf = build_ivf(corpus_np, iters=4, corpus_normalized=True)
    K, cap, _ = ivf.codes.shape

    vs, is_ = jax.device_get(static_lookup_batch(tier, q))
    vd, id_ = jax.device_get(dynamic_lookup_batch(dyn, q))

    realistic = FusedServe(ivf, nprobe=NPROBE, n_candidates=C_STATIC,
                           n_dyn_candidates=C_DYN)
    exact = FusedServe(ivf, nprobe=K, n_candidates=K * cap,
                       n_dyn_candidates=DYN_CAP)

    ss, hi, sd, j = jax.device_get(
        serve_lookup_batch(tier, dyn, q, realistic))
    # the realistic budget can drop a query to ANN recall (any IVF
    # config can); the *hard* 1.0 gate below removes recall from the
    # equation so it isolates serving-path bugs
    a_s = decision_agreement(vs, is_, ss, hi, TAU)
    a_d = decision_agreement(vd, id_, sd, j, TAU)
    assert a_s >= 0.95, f"static decision agreement {a_s} < 0.95"
    assert a_d >= 0.95, f"dynamic decision agreement {a_d} < 0.95"

    ss, hi, sd, j = jax.device_get(
        serve_lookup_batch(tier, dyn, q, exact))
    a_se = decision_agreement(vs, is_, ss, hi, TAU)
    a_de = decision_agreement(vd, id_, sd, j, TAU)
    assert a_se == 1.0, f"full-coverage static agreement {a_se} != 1.0"
    assert a_de == 1.0, f"full-coverage dynamic agreement {a_de} != 1.0"
    np.testing.assert_allclose(sd, vd, atol=1e-6)
    print(f"[OK] fused_serve smoke: agreement {min(a_s, a_d):.3f} at "
          f"nprobe={NPROBE}, 1.000 at full coverage (K={K})")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: fused-vs-dispatched decision "
                         "agreement asserts (1.0 at full coverage)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    else:
        for r in run(scale=a.scale):
            print(r)
