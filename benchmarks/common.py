"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.simulate import (simulate, simulate_sweep, summarize,
                                 summarize_sweep, sweep_from_configs)
from repro.core.tiers import CacheConfig
from repro.data.synth_traces import (LMARENA_LIKE, SEARCH_LIKE,
                                     build_benchmark)

# tuned per-workload thresholds (scripts/calibrate.py, error budget 1-2%)
TSTAR = {"lmarena_like": 0.88, "search_like": 0.86}

_SMALL = {
    "lmarena_like": dict(n_requests=16_000, n_classes=2_400),
    "search_like": dict(n_requests=24_000, n_classes=8_000),
}


def get_benchmark(name: str, scale: str = "small"):
    spec = {"lmarena_like": LMARENA_LIKE,
            "search_like": SEARCH_LIKE}[name]
    if scale == "small":
        spec = dataclasses.replace(spec, **_SMALL[name])
    return build_benchmark(spec)


def run_policies(bench, cfg: CacheConfig, policies=("baseline", "krites")):
    args = dict(static_emb=jnp.asarray(bench.static_emb),
                static_cls=jnp.asarray(bench.static_cls),
                q_emb=jnp.asarray(bench.eval_emb),
                q_cls=jnp.asarray(bench.eval_cls), cfg=cfg)
    out = {}
    for pol in policies:
        t0 = time.time()
        res = simulate(krites=(pol == "krites"), **args)
        s = summarize(res)
        s["wall_s"] = round(time.time() - t0, 2)
        s["us_per_req"] = 1e6 * s["wall_s"] / s["requests"]
        out[pol] = (res, s)
    return out


def run_policy_sweep(bench, cfgs, krites, rewritable=None):
    """Evaluate many (CacheConfig, krites) variants over one trace in a
    single ``simulate_sweep`` dispatch (DESIGN.md §10).

    ``krites`` is a bool or a per-config list; ``rewritable`` is the
    optional per-request rewrite channel (consulted only by configs
    with ``rewrite=True``, DESIGN.md §18). Returns (per-config
    summaries, shared wall seconds, us per simulated request summed over
    all configs)."""
    t0 = time.time()
    res = simulate_sweep(jnp.asarray(bench.static_emb),
                         jnp.asarray(bench.static_cls),
                         jnp.asarray(bench.eval_emb),
                         jnp.asarray(bench.eval_cls),
                         sweep_from_configs(cfgs, krites),
                         rewritable=rewritable)
    rows = summarize_sweep(res)
    wall = time.time() - t0
    us = 1e6 * wall / (len(cfgs) * bench.eval_emb.shape[0])
    for r in rows:
        r["wall_s"] = round(wall, 2)
        r["us_per_req"] = us
    return rows, wall, us


def clustered_cache_workload(n_rows: int, rng, b: int, d: int,
                             n_centers: int | None = None):
    """Clustered corpus + cache-like queries, shared by the ANN index
    benchmarks (`ann_index`, `dyn_index`): most queries are noisy
    near-duplicates of corpus rows (hits at the cache threshold), the
    rest fresh directions (misses). Returns (rows (n, d), q (b, d)),
    both L2-normalized."""
    n_centers = n_centers or max(64, n_rows // 256)
    centers = rng.normal(size=(n_centers, d)).astype(np.float32)
    rows = centers[rng.integers(0, n_centers, n_rows)] \
        + 0.35 * rng.normal(size=(n_rows, d)).astype(np.float32)
    rows /= np.linalg.norm(rows, axis=1, keepdims=True)

    n_dup = int(0.7 * b)
    src = rng.choice(n_rows, n_dup, replace=False)
    dup = rows[src] + 0.05 * rng.normal(size=(n_dup, d)).astype(np.float32)
    fresh = rng.normal(size=(b - n_dup, d)).astype(np.float32)
    q = np.concatenate([dup, fresh]).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    return rows, q


def timed_median(fn, reps: int = 5) -> float:
    """Median wall seconds of ``fn()`` after a compile/warmup call."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def decision_agreement(v_exact, i_exact, v_ann, i_ann,
                       tau: float) -> float:
    """Fraction of queries whose served decision matches exact search:
    same hit/miss verdict at the cache threshold and, on hits, the
    same served row/slot."""
    hit_e, hit_a = v_exact >= tau, v_ann >= tau
    same = (hit_e == hit_a) & (~hit_e | (i_exact == i_ann))
    return float(np.mean(same))


def default_cfg(name: str, **kw) -> CacheConfig:
    t = TSTAR[name]
    base = dict(tau_static=t, tau_dynamic=t, sigma_min=0.0,
                capacity=8192, judge_latency=64)
    base.update(kw)
    return CacheConfig(**base)
