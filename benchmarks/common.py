"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp

from repro.core.simulate import (simulate, simulate_sweep, summarize,
                                 summarize_sweep, sweep_from_configs)
from repro.core.tiers import CacheConfig
from repro.data.synth_traces import (LMARENA_LIKE, SEARCH_LIKE,
                                     build_benchmark)

# tuned per-workload thresholds (scripts/calibrate.py, error budget 1-2%)
TSTAR = {"lmarena_like": 0.88, "search_like": 0.86}

_SMALL = {
    "lmarena_like": dict(n_requests=16_000, n_classes=2_400),
    "search_like": dict(n_requests=24_000, n_classes=8_000),
}


def get_benchmark(name: str, scale: str = "small"):
    spec = {"lmarena_like": LMARENA_LIKE,
            "search_like": SEARCH_LIKE}[name]
    if scale == "small":
        spec = dataclasses.replace(spec, **_SMALL[name])
    return build_benchmark(spec)


def run_policies(bench, cfg: CacheConfig, policies=("baseline", "krites")):
    args = dict(static_emb=jnp.asarray(bench.static_emb),
                static_cls=jnp.asarray(bench.static_cls),
                q_emb=jnp.asarray(bench.eval_emb),
                q_cls=jnp.asarray(bench.eval_cls), cfg=cfg)
    out = {}
    for pol in policies:
        t0 = time.time()
        res = simulate(krites=(pol == "krites"), **args)
        s = summarize(res)
        s["wall_s"] = round(time.time() - t0, 2)
        s["us_per_req"] = 1e6 * s["wall_s"] / s["requests"]
        out[pol] = (res, s)
    return out


def run_policy_sweep(bench, cfgs, krites):
    """Evaluate many (CacheConfig, krites) variants over one trace in a
    single ``simulate_sweep`` dispatch (DESIGN.md §10).

    ``krites`` is a bool or a per-config list. Returns (per-config
    summaries, shared wall seconds, us per simulated request summed over
    all configs)."""
    t0 = time.time()
    res = simulate_sweep(jnp.asarray(bench.static_emb),
                         jnp.asarray(bench.static_cls),
                         jnp.asarray(bench.eval_emb),
                         jnp.asarray(bench.eval_cls),
                         sweep_from_configs(cfgs, krites))
    rows = summarize_sweep(res)
    wall = time.time() - t0
    us = 1e6 * wall / (len(cfgs) * bench.eval_emb.shape[0])
    for r in rows:
        r["wall_s"] = round(wall, 2)
        r["us_per_req"] = us
    return rows, wall, us


def default_cfg(name: str, **kw) -> CacheConfig:
    t = TSTAR[name]
    base = dict(tau_static=t, tau_dynamic=t, sigma_min=0.0,
                capacity=8192, judge_latency=64)
    base.update(kw)
    return CacheConfig(**base)
