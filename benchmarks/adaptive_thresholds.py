"""Online threshold adaptation under distribution drift (DESIGN.md §17).

The scenario the controller exists for: a service calibrated offline
(``scripts/calibrate.py``) pins ``tau_static = tau_dynamic = 0.93`` and
serves paraphrase traffic that embeds at ~0.96 similarity to its
curated neighbor — comfortably above threshold. Then the traffic style
shifts (new phrasing, new client population): the same intents now
embed at ~0.875. The pinned operating point loses every static hit
*permanently* — the offline calibration has no way to notice. The
adaptive controller's shadow sweeps see the frontier move inside one
request window and walk each segment's live point down in bounded
steps until the service is serving again.

Three twins serve the SAME drift trace through ``serve_batch``
(router-shaped micro-batches, full Krites pipeline with async
verification drained at batch boundaries for run-to-run determinism):

- ``pinned``   — no controller (today's behavior);
- ``adaptive`` — live controller, default-conservative steps;
- ``frozen``   — controller attached but frozen: must be
  decision-identical to ``pinned`` (the adaptive-off contract).

Reported per phase (pre-drift / post-drift): hit rate (static +
dynamic serves), error rate (wrong-class serves), final per-segment
operating points and controller counters.

    PYTHONPATH=src python -m benchmarks.adaptive_thresholds [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh) and gates:
1. adaptive post-drift hit rate >= pinned post-drift hit rate, at
   equal-or-lower error (in practice pinned ~0, adaptive recovers);
2. the frozen twin's serving decisions are identical to pinned —
   zero critical-path changes from merely attaching the controller;
3. the controller actually moved (adaptations > 0, taus below pinned).
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core.adaptive import (AdaptiveController, AdaptiveParams,
                                 SEGMENT_NAMES)
from repro.core.judge import OracleJudge
from repro.core.policy import KritesPolicy
from repro.core.tiers import CacheConfig, make_static_tier
from repro.index.flat import l2_normalize

D = 64
N_CLASSES = 16
BATCH = 16
TAU_PINNED = 0.93
SIM_PRE, SIM_POST = 0.96, 0.875   # >= 15e-3 from every reachable tau
SEG_PREFIX = {0: "how to", 1: "latest", 2: "definition of"}

PARAMS = AdaptiveParams(window=256, adapt_every=64, grid_points=3,
                        grid_radius=0.08, max_step=0.04,
                        min_segment=32, shadow_capacity=128,
                        error_budget=0.05)


def _unit(V):
    """One pass of the policy's own normalizer. Unlike the oracle
    differentials (tests/test_adaptive.py) this benchmark never
    compares against numpy bit-for-bit, and every decision margin is
    >= 15e-3, so ulp-level renormalization drift is irrelevant."""
    return np.asarray(l2_normalize(jnp.asarray(V, jnp.float32)))


def _drift_trace(n_pre: int, n_post: int, seed: int = 0):
    """Mixed-segment paraphrase stream over one-hot class centroids:
    request i embeds at ``level`` similarity to centroid ``cls[i]``,
    with the off-centroid mass on a per-request random direction in the
    spare subspace (so no two requests share a cache key). The level
    drops from SIM_PRE to SIM_POST at the drift point."""
    n = n_pre + n_post
    rng = np.random.default_rng(seed)
    base = np.eye(D, dtype=np.float32)
    cls = rng.integers(0, N_CLASSES, n)
    lvl = np.where(np.arange(n) < n_pre, SIM_PRE, SIM_POST)
    U = rng.normal(size=(n, D - N_CLASSES))
    U /= np.linalg.norm(U, axis=1, keepdims=True)
    V = lvl[:, None] * base[cls]
    V[:, N_CLASSES:] += np.sqrt(1.0 - lvl ** 2)[:, None] * U
    V = _unit(V.astype(np.float32))
    segs = np.arange(n) % 3
    prompts = [f"{SEG_PREFIX[int(s)]} intent {i}"
               for i, s in enumerate(segs)]
    metas = [{"cls": int(c)} for c in cls]
    embed = {p: V[i] for i, p in enumerate(prompts)}
    return prompts, metas, cls, embed.__getitem__


def _build(embed, adaptive):
    tier = make_static_tier(
        jnp.asarray(np.eye(D, dtype=np.float32)[:N_CLASSES]),
        jnp.arange(N_CLASSES))
    cfg = CacheConfig(TAU_PINNED, TAU_PINNED, sigma_min=0.3,
                      capacity=512)
    return KritesPolicy(cfg, tier,
                        [f"curated-{i}" for i in range(N_CLASSES)],
                        embed, lambda p: f"gen({p})", OracleJudge(),
                        d=D, n_workers=1,
                        backend_batch_fn=lambda ps:
                            [f"gen({p})" for p in ps],
                        adaptive=adaptive)


def _serve(pol, prompts, metas, cls):
    """Serve in micro-batches; returns (events, errors, wall_s). A
    served answer is an error when its curated class disagrees with the
    request's true class (backend generations are class-exact here)."""
    events, errors = [], 0
    t0 = time.time()
    for i in range(0, len(prompts), BATCH):
        rs = pol.serve_batch(prompts[i:i + BATCH], metas[i:i + BATCH])
        for j, r in enumerate(rs):
            events.append(r.served_by)
            if r.answer.startswith("curated-") and \
                    int(r.answer.split("-")[1]) != int(cls[i + j]):
                errors += 1
        # drain the async verifier at the batch boundary so promotion
        # apply points are identical across the three twins
        pol.pool.drain()
    return events, errors, time.time() - t0


def _hit_rate(events, lo, hi):
    span = events[lo:hi]
    return sum(e != "backend" for e in span) / max(len(span), 1)


def run(scale: str = "small"):
    row, _ = _run_impl(scale)
    return [row]


def _run_impl(scale: str = "small"):
    mult = 1 if scale == "small" else 4
    n_pre, n_post = 384 * mult, 768 * mult
    prompts, metas, cls, embed = _drift_trace(n_pre, n_post)

    out = {}
    for name in ("pinned", "adaptive"):
        ctl = (AdaptiveController(
            CacheConfig(TAU_PINNED, TAU_PINNED, capacity=512), d=D,
            params=PARAMS) if name == "adaptive" else None)
        pol = _build(embed, ctl)
        events, errors, wall = _serve(pol, prompts, metas, cls)
        pol.pool.stop()
        out[name] = {
            "events": events, "wall": wall,
            "pre_hit": _hit_rate(events, 0, n_pre),
            "post_hit": _hit_rate(events, n_pre, len(events)),
            "err": errors / len(events), "ctl": ctl,
        }

    a, p = out["adaptive"], out["pinned"]
    row = {
        "name": f"adaptive_thresholds/drift_{scale}",
        "us_per_call": round(1e6 * a["wall"] / len(prompts), 1),
        "n_pre": n_pre, "n_post": n_post,
        "pinned_pre_hit": round(p["pre_hit"], 4),
        "pinned_post_hit": round(p["post_hit"], 4),
        "adaptive_pre_hit": round(a["pre_hit"], 4),
        "adaptive_post_hit": round(a["post_hit"], 4),
        "pinned_err": round(p["err"], 4),
        "adaptive_err": round(a["err"], 4),
        "adaptations": a["ctl"].adaptations,
        "moves": a["ctl"].moves,
    }
    for s, seg in enumerate(SEGMENT_NAMES):
        row[f"tau_static_{seg}"] = round(a["ctl"].tau_static[s], 4)
    return row, p["events"]


def smoke() -> None:
    r, pinned_events = _run_impl(scale="small")

    # gate 1: drift recovery at equal-or-lower error
    assert r["adaptive_post_hit"] >= r["pinned_post_hit"], \
        (r["adaptive_post_hit"], r["pinned_post_hit"])
    assert r["adaptive_post_hit"] > r["pinned_post_hit"] + 0.2, \
        "controller failed to recover meaningful hit rate after drift"
    assert r["adaptive_err"] <= r["pinned_err"] + 1e-9
    assert r["adaptations"] > 0 and r["moves"] > 0
    assert min(r[f"tau_static_{s}"] for s in SEGMENT_NAMES) \
        < TAU_PINNED, "no segment walked below the pinned point"

    # gate 2: a frozen controller changes zero serving decisions
    n_pre, n_post = r["n_pre"], r["n_post"]
    prompts, metas, cls, embed = _drift_trace(n_pre, n_post)
    frozen = _build(embed, AdaptiveController(
        CacheConfig(TAU_PINNED, TAU_PINNED, capacity=512), d=D,
        params=PARAMS, frozen=True))
    f_events, f_errors, _ = _serve(frozen, prompts, metas, cls)
    frozen.pool.stop()
    assert f_events == pinned_events, \
        "frozen controller altered critical-path decisions"
    assert frozen.adaptive.adaptations == 0

    print(f"[OK] drift recovery: pinned post-hit "
          f"{r['pinned_post_hit']:.3f} -> adaptive "
          f"{r['adaptive_post_hit']:.3f} at err "
          f"{r['adaptive_err']:.4f} (<= pinned {r['pinned_err']:.4f}), "
          f"{r['adaptations']} sweeps / {r['moves']} moves")
    print(f"[OK] frozen controller: decision-identical to pinned over "
          f"{len(f_events)} requests")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"],
                    default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: drift recovery + frozen "
                         "decision-identity gates")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    else:
        for row in run(scale=a.scale):
            print(row)
