"""L1 exact-match front tier + freshness benchmark (DESIGN.md §16):
repeat-rate x volatile-fraction sweep over the live serving path.

Production cache traffic is repeat-heavy: a large fraction of requests
are byte-identical (up to whitespace/case) re-asks of something served
minutes ago. The L1 front tier turns each of those into one O(1) dict
probe — no embedder forward, no static top-k, no dynamic scan — so the
win scales with the repeat rate. The freshness layer bounds what that
speed costs in correctness: volatile queries either bypass the cache
(zero stale serves by construction) or expire on a short per-class
TTL.

Per (repeat_rate, volatile_frac) operating point, both policies serve
the SAME prompt stream through ``serve_batch`` (router-shaped
micro-batches) with the real hashing-n-gram embedder:

- ``us_per_call`` / ``us_no_l1`` / ``speedup_vs_no_l1`` — wall time per
  request with the L1 front tier vs the identical policy without it;
- ``l1_hit_rate`` — fraction of requests the front tier absorbed;
- ``stale_rate_ttl`` — stale volatile serves under TTL-only freshness
  (short ``ttl_volatile``, drift clock on, no bypass);
- ``stale_rate_bypass`` — same stream with ``volatile_bypass`` on
  (must be 0: bypassed queries never touch a cached answer).

    PYTHONPATH=src python -m benchmarks.l1_freshness [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh): asserts zero stale serves
with bypass on, decision agreement 1.0 vs the no-L1 twin on non-repeat
traffic, and zero embedder calls on the repeated suffix of a
pure-repeat stream.
"""
from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import tiers as T
from repro.core.freshness import FreshnessPolicy
from repro.core.policy import KritesPolicy

D = 64
BATCH = 8
REPEAT_RATES = (0.0, 0.5, 0.9)
VOLATILE_FRACS = (0.0, 0.3)
DRIFT_EVERY = 64


def _mk_prompt(i: int, volatile: bool) -> str:
    # the freshness class rides in the text itself, exactly as live
    # traffic would carry it ("price"/"today" are volatile triggers)
    return (f"price of item {i} today" if volatile
            else f"explain the design of component {i}")


def _trace(n: int, repeat_rate: float, volatile_frac: float, rng):
    """Prompt stream with an expected exact-repeat fraction: each
    request re-asks a uniformly random earlier prompt with probability
    ``repeat_rate``, else introduces a fresh one (volatile with
    probability ``volatile_frac``)."""
    prompts, fresh = [], 0
    for _ in range(n):
        if prompts and rng.random() < repeat_rate:
            prompts.append(prompts[int(rng.integers(len(prompts)))])
        else:
            prompts.append(_mk_prompt(fresh,
                                      rng.random() < volatile_frac))
            fresh += 1
    return prompts


def _mk_policy(embed, l1, freshness, capacity: int = 2048):
    intents = [f"how do i {v} my {nn}" for v in
               ("fix", "update", "reset", "clean", "sell")
               for nn in ("bike", "laptop", "router", "phone")]
    tier = T.make_static_tier(
        jnp.asarray(embed.batch(intents)),
        jnp.arange(len(intents), dtype=jnp.int32))
    cfg = T.CacheConfig(0.92, 0.88, sigma_min=0.3, capacity=capacity,
                        l1=l1 is not None,
                        volatile_bypass=bool(freshness
                                             and freshness.volatile_bypass),
                        ttl_volatile=freshness.ttl_volatile
                        if freshness else 0,
                        ttl_stable=freshness.ttl_stable
                        if freshness else 0)
    return KritesPolicy(cfg, tier,
                        [f"[curated] {p}" for p in intents], embed,
                        backend_fn=lambda p: f"gen({p})",
                        judge_fn=lambda **kw: True, d=D, n_workers=0,
                        l1=l1, freshness=freshness)


def _drive(policy, prompts, batch: int = BATCH) -> float:
    t0 = time.perf_counter()
    for lo in range(0, len(prompts), batch):
        policy.serve_batch(prompts[lo:lo + batch])
    return time.perf_counter() - t0


def _warm(policy) -> None:
    """Compile every semantic sub-batch size before the timed loop.
    The L1 front (and the volatile bypass) shrink the embedded
    sub-batch, so a repeat-heavy stream walks through the whole size
    ladder — the embedder forward and the pre-pad normalize compile
    per raw size — unlike the no-L1 twin, which only ever sees the
    full batch. Without this, the L1 side would be charged XLA compile
    time the steady state never pays."""
    for bs in range(1, BATCH + 1):
        policy.serve_batch([_mk_prompt(100_000 + 64 * bs + j, False)
                            for j in range(bs)])


def _bench_one(repeat_rate: float, volatile_frac: float, n: int,
               embed) -> dict:
    rng = np.random.default_rng(17)
    prompts = _trace(n, repeat_rate, volatile_frac, rng)
    ttl_fresh = FreshnessPolicy(volatile_bypass=False, ttl_volatile=16,
                                ttl_stable=0, ttl_unknown=0,
                                drift_every=DRIFT_EVERY)
    byp_fresh = FreshnessPolicy(volatile_bypass=True, ttl_volatile=16,
                                ttl_stable=0, ttl_unknown=0,
                                drift_every=DRIFT_EVERY)

    # scratch pass over this exact trace first: the point's one-off XLA
    # compiles (TTL-death scatter counts, LRU touch counts, sub-batch
    # sizes) land on a throwaway policy instead of whichever timed twin
    # happens to run first
    for l1_cap in (4096, None):
        scratch = _mk_policy(embed, l1_cap, ttl_fresh)
        _warm(scratch)
        _drive(scratch, prompts)

    with_l1 = _mk_policy(embed, 4096, ttl_fresh)
    _warm(with_l1)
    t0, h0, s0, e0 = (with_l1.t, with_l1._l1_hits,
                      with_l1._stale_serves, with_l1._ttl_evictions)
    l1_s = _drive(with_l1, prompts)

    no_l1 = _mk_policy(embed, None, ttl_fresh)
    _warm(no_l1)
    plain_s = _drive(no_l1, prompts)

    bypass = _mk_policy(embed, 4096, byp_fresh)
    _warm(bypass)
    b0 = bypass.t
    _drive(bypass, prompts)

    return {
        "name": f"l1_freshness/rep{repeat_rate}_vol{volatile_frac}",
        "us_per_call": round(1e6 * l1_s / n, 1),
        "us_no_l1": round(1e6 * plain_s / n, 1),
        "speedup_vs_no_l1": round(plain_s / l1_s, 2),
        "l1_hit_rate": round((with_l1._l1_hits - h0) / n, 3),
        "stale_rate_ttl": round((with_l1._stale_serves - s0) / n, 4),
        "stale_rate_bypass": round(
            bypass._stale_serves / max(bypass.t - b0, 1), 4),
        "bypassed_volatile": bypass._l1_bypass,
        "ttl_evictions": with_l1._ttl_evictions - e0,
        "requests": n, "batch": BATCH, "d": D,
    }


def run(scale: str = "small"):
    from repro.embedding.embedder import Embedder
    n = 512 if scale == "small" else 4096
    embed = Embedder(d_out=D)
    return [_bench_one(r, v, n, embed) for r in REPEAT_RATES
            for v in VOLATILE_FRACS]


def smoke() -> None:
    """CI gate (scripts/ci.sh): the three freshness invariants on live
    traffic — bypass means zero stale serves, the L1 front tier is
    decision-invisible on non-repeat traffic, and pure repeats never
    reach the embedder."""
    from repro.embedding.embedder import Embedder

    rng = np.random.default_rng(3)
    base = Embedder(d_out=D)
    calls = {"n": 0}

    class CountingEmbedder:
        def __call__(self, p):
            calls["n"] += 1
            return base(p)

        def batch(self, ps):
            calls["n"] += len(ps)
            return base.batch(ps)

    embed = CountingEmbedder()
    fresh = FreshnessPolicy(volatile_bypass=True, ttl_volatile=16,
                            ttl_stable=0, ttl_unknown=0,
                            drift_every=32)

    # 1) zero stale serves with volatile bypass on, repeat-heavy stream
    prompts = _trace(320, 0.7, 0.4, rng)
    pol = _mk_policy(embed, 4096, fresh)
    _drive(pol, prompts)
    assert pol._stale_serves == 0, \
        f"{pol._stale_serves} stale serves under volatile bypass"
    assert pol._l1_bypass > 0, "smoke stream produced no volatile traffic"
    assert pol._l1_hits > 0, "smoke stream produced no L1 hits"
    n_bypassed, n_l1_hits = pol._l1_bypass, pol._l1_hits

    # 2) decision agreement 1.0 vs the no-L1 twin on non-repeat traffic
    distinct = _trace(128, 0.0, 0.3, rng)
    with_l1 = _mk_policy(embed, 4096, fresh)
    no_l1 = _mk_policy(embed, None, fresh)
    dec = [[(r.served_by, str(r.answer), bool(r.static_origin),
             round(float(r.similarity), 5)) for r in p.serve_batch(distinct)]
           for p in (with_l1, no_l1)]
    agree = sum(a == b for a, b in zip(*dec)) / len(distinct)
    assert agree == 1.0, f"decision agreement {agree} < 1.0"
    assert with_l1._l1_hits == 0, "non-repeat stream hit L1"

    # 3) zero embedder calls on the repeated suffix of a pure-repeat run
    uniq = [_mk_prompt(i, False) for i in range(24)]
    pol = _mk_policy(embed, 4096, None)
    pol.serve_batch(uniq)
    n0 = calls["n"]
    for _ in range(3):
        pol.serve_batch(uniq)
    assert calls["n"] == n0, \
        f"pure repeats paid {calls['n'] - n0} embedder calls"
    print(f"[OK] l1_freshness smoke: bypassed={n_bypassed} "
          f"agreement={agree:.3f} l1_hits={n_l1_hits} "
          f"embed_calls_on_repeats=0")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: zero stale serves under bypass + "
                         "decision-agreement-1.0 + zero-embed repeats")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    else:
        for r in run(scale=a.scale):
            print(r)
