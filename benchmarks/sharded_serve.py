"""Sharded serving benchmark: the mesh-aware static-tier lookup
(DESIGN.md §13) swept over shard count x tier size, with a hard
decision-agreement gate against the single-device path.

Two claims are measured:

- **scaling shape** — per-call wall time of the row-sharded exact
  lookup (``sharded_static_lookup``: per-shard fused scan + tiny
  k-candidate merge) at 1 -> 8 shards per tier size. On a real TPU/GPU
  mesh each shard scans 1/S of the rows; the CPU host-device mesh used
  here shares one socket across shards, so the measured speedup is a
  lower bound (host devices still scan their partitions on separate
  threads) and chiefly demonstrates the merge + partition overhead
  stays small enough for the layout to win (see EXPERIMENTS.md).
- **decision agreement** — the merged (score, index) pairs must produce
  exactly the decisions of single-device search on every query
  (agreement 1.0): per-row scores are bit-identical (the dot product is
  over the unpartitioned d axis) and the stable shard merge keeps the
  lowest-index tie rule.

    PYTHONPATH=src python -m benchmarks.sharded_serve [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh): a full serving-path
differential — ``BaselinePolicy``/``KritesPolicy`` with ``mesh=`` vs
single-device on the same trace, scalar and batched — asserting
decision agreement 1.0. Registered in ``benchmarks.run``; when the
parent process holds only one device (the harness), the sweep re-execs
itself in a subprocess with a forced 8-device host platform.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np   # noqa: E402

SHARDS = (1, 2, 4, 8)
SIZES_SMALL = (65_536, 262_144)
SIZES_FULL = (65_536, 262_144, 1_048_576)
TAU = 0.85
B = 32
D = 64


def _bench(scale: str = "small"):
    import jax
    import jax.numpy as jnp

    from benchmarks.common import (clustered_cache_workload,
                                   decision_agreement, timed_median)
    from repro.index.sharded import sharded_static_lookup
    from repro.kernels.simsearch.ops import cosine_topk
    from repro.launch.mesh import make_shard_mesh

    rng = np.random.default_rng(0)
    rows = []
    for n_rows in (SIZES_FULL if scale == "full" else SIZES_SMALL):
        corpus_np, q_np = clustered_cache_workload(n_rows, rng, B, D)
        corpus, q = jnp.asarray(corpus_np), jnp.asarray(q_np)
        flat_t = timed_median(lambda: cosine_topk(q, corpus, k=1))
        v_f, i_f = jax.device_get(cosine_topk(q, corpus, k=1))
        v_f, i_f = v_f[:, 0], i_f[:, 0]
        for n_shards in SHARDS:
            if n_shards > len(jax.devices()):
                continue
            if n_shards == 1:
                t, v_s, i_s = flat_t, v_f, i_f
            else:
                mesh = make_shard_mesh(n_shards)
                lookup = sharded_static_lookup(mesh, corpus)
                t = timed_median(lambda: lookup(q))
                v_s, i_s = jax.device_get(lookup(q))
            rows.append({
                "name": f"sharded_serve/N{n_rows}_shards{n_shards}",
                "us_per_call": round(1e6 * t, 1),
                "flat_us_per_call": round(1e6 * flat_t, 1),
                "speedup_vs_flat": round(flat_t / t, 2),
                "decision_agreement": decision_agreement(
                    v_f, i_f, v_s, i_s, TAU),
                "B": B, "d": D,
            })
    return rows


def run(scale: str = "small"):
    """Entry for ``benchmarks.run``. The harness process usually holds a
    single CPU device (jax initialized long before this module), so the
    sweep re-execs in a child with the forced host-device mesh."""
    import jax

    if len(jax.devices()) >= max(SHARDS):
        return _bench(scale)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env = dict(os.environ,
               PYTHONPATH=src + os.pathsep + os.environ.get("PYTHONPATH",
                                                            ""),
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={max(SHARDS)}",
               JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.sharded_serve", "--json",
         "--scale", scale],
        capture_output=True, text=True, timeout=1800, env=env,
        cwd=str(Path(__file__).resolve().parents[1]))
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-1000:])
    for line in out.stdout.splitlines():
        if line.startswith("ROWS_JSON:"):
            return json.loads(line[len("ROWS_JSON:"):])
    raise RuntimeError("sharded_serve subprocess emitted no rows")


def smoke(n_shards: int = 8, n: int = 160) -> None:
    """CI gate: full serving-path differential, sharded vs single device
    (scalar + batch), asserting decision agreement 1.0."""
    import dataclasses
    import threading

    import jax
    import jax.numpy as jnp

    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, make_static_tier
    from repro.data.synth_traces import LMARENA_LIKE, build_benchmark
    from repro.launch.mesh import make_shard_mesh

    assert len(jax.devices()) >= n_shards, \
        (f"smoke needs {n_shards} devices — run standalone so the "
         f"module-level XLA_FLAGS host-device override applies")
    mesh = make_shard_mesh(n_shards)
    spec = dataclasses.replace(LMARENA_LIKE, n_requests=4000,
                               n_classes=120)
    bench = build_benchmark(spec)
    emb = {f"q{i}": bench.eval_emb[i] for i in range(n)}
    prompts = [f"q{i}" for i in range(n)]
    metas = [{"cls": int(bench.eval_cls[i])} for i in range(n)]
    tier = make_static_tier(jnp.asarray(bench.static_emb),
                            jnp.asarray(bench.static_cls))
    answers = [f"curated-{int(c)}" for c in bench.static_cls]
    texts = [f"canonical prompt {i}" for i in range(len(answers))]
    cfg = CacheConfig(0.92, 0.88, sigma_min=0.0, capacity=128)

    class GatedOracle:
        """Oracle that blocks until the driver opens the gate, so
        promotions land at identical (chunk-boundary) points in both
        policies and the decision streams stay comparable."""

        def __init__(self):
            self.gate = threading.Event()
            self.oracle = OracleJudge(require_texts=True)

        def __call__(self, q_cls, h_cls, **kw):
            self.gate.wait()
            return self.oracle(q_cls, h_cls, **kw)

    def mk(m):
        judge = GatedOracle()
        pol = KritesPolicy(
            cfg, tier, answers, lambda p: emb[p], lambda p: f"gen({p})",
            judge, d=bench.static_emb.shape[1],
            n_workers=1, static_texts=texts, mesh=m,
            embed_batch_fn=lambda ps: np.stack([emb[p] for p in ps]),
            backend_batch_fn=lambda ps: [f"gen({p})" for p in ps])
        return pol, judge

    def drive(pol, judge, batched):
        out = []
        for i in range(0, n, 32):
            chunk = slice(i, i + 32)
            if batched:
                out += pol.serve_batch(prompts[chunk], metas[chunk])
            else:
                out += [pol.serve(p, m) for p, m in
                        zip(prompts[chunk], metas[chunk])]
            judge.gate.set()       # promotions land at chunk boundaries
            pol.pool.drain()
            judge.gate.clear()
        judge.gate.set()
        pol.pool.drain()
        pol.pool.stop()
        return pol, out

    for batched in (False, True):
        p1, r1 = drive(*mk(None), batched)
        p2, r2 = drive(*mk(mesh), batched)
        agree = np.mean([(a.served_by, a.answer, a.static_origin)
                         == (b.served_by, b.answer, b.static_origin)
                         for a, b in zip(r1, r2)])
        mode = "batch" if batched else "scalar"
        assert p1.events == p2.events, f"{mode}: event streams differ"
        assert agree == 1.0, f"{mode}: decision agreement {agree} < 1.0"
        assert p2.stats()["approved"] > 0, f"{mode}: no promotions"
        # the sharded write path must keep host mirrors == device tier
        assert np.array_equal(p2._valid_np, np.asarray(p2.dyn.valid))
        assert np.array_equal(p2._static_origin_np,
                              np.asarray(p2.dyn.static_origin))
        sh = p2.shard_stats()
        assert sh["shards"] == n_shards
        assert sum(sh["shard_occupancy"]) == int(p2._valid_np.sum())
        print(f"[OK] sharded serve smoke ({mode}): shards={n_shards}, "
              f"decision agreement {agree:.3f}, "
              f"approved={p2.stats()['approved']}, "
              f"occupancy={sh['shard_occupancy']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: sharded-vs-single serving "
                         "differential with agreement-1.0 asserts")
    ap.add_argument("--json", action="store_true",
                    help="emit rows as one ROWS_JSON line (subprocess "
                         "protocol for benchmarks.run)")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    elif a.json:
        print("ROWS_JSON:" + json.dumps(_bench(scale=a.scale)))
    else:
        for r in _bench(scale=a.scale):
            print(r)
