"""Kernel-substrate microbenchmarks (CPU wall time of the jnp twin path +
derived TPU roofline estimates for the Pallas target shapes).

The simsearch row corresponds to the paper's cache-lookup hot path at the
production static-tier size; TPU time estimates use the §Roofline
constants (197 TF bf16, 819 GB/s HBM).

Reproduces: no paper table directly — this is the kernel-substrate
baseline for the serving-path cost model (DESIGN.md §9) used by the
latency and roofline analyses.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only kernels
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.simsearch.ref import simsearch_ref
from repro.models.attention import causal_attention, decode_attention

PEAK, HBM = 197e12, 819e9


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(scale: str = "small"):
    rows = []
    key = jax.random.PRNGKey(0)

    # simsearch: B queries x N corpus (static tier lookup)
    B, N, d, k = (64, 16384, 64, 4) if scale == "small" \
        else (256, 131072, 64, 4)
    q = jax.random.normal(key, (B, d))
    c = jax.random.normal(jax.random.fold_in(key, 1), (N, d))
    f = jax.jit(lambda q, c: simsearch_ref(q, c, k))
    t = _time(f, q, c)
    flops = 2 * B * N * d
    bytes_ = (B * d + N * d) * 4 + B * N * 4
    rows.append({
        "name": f"kernel/simsearch/B{B}xN{N}xd{d}",
        "us_per_call": round(t * 1e6, 1),
        "gflops_cpu": round(flops / t / 1e9, 2),
        "tpu_compute_us": round(flops / PEAK * 1e6, 2),
        "tpu_memory_us": round(bytes_ / HBM * 1e6, 2),
        "tpu_bound": "memory" if bytes_ / HBM > flops / PEAK
        else "compute",
    })

    # flash attention jnp twin (prefill block)
    Bq, S, H, K, D = (1, 1024, 8, 2, 64) if scale == "small" \
        else (4, 4096, 16, 8, 128)
    qq = jax.random.normal(key, (Bq, S, H, D), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(key, 2), (Bq, S, K, D))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (Bq, S, K, D))
    f = jax.jit(lambda a, b, c2: causal_attention(a, b, c2, 256))
    t = _time(f, qq, kk, vv)
    flops = 2 * 2 * Bq * S * S * H * D / 2   # causal half
    rows.append({
        "name": f"kernel/flash_attention/S{S}xH{H}",
        "us_per_call": round(t * 1e6, 1),
        "gflops_cpu": round(flops / t / 1e9, 2),
        "tpu_compute_us": round(flops / PEAK * 1e6, 2),
    })

    # decode attention (split-K twin)
    Bd, Sd = (8, 8192) if scale == "small" else (32, 32768)
    qd = jax.random.normal(key, (Bd, 1, H, D))
    kd = jax.random.normal(jax.random.fold_in(key, 4), (Bd, Sd, K, D))
    vd = jax.random.normal(jax.random.fold_in(key, 5), (Bd, Sd, K, D))
    lens = jnp.full((Bd,), Sd, jnp.int32)
    f = jax.jit(decode_attention)
    t = _time(f, qd, kd, vd, lens)
    bytes_ = 2 * Bd * Sd * K * D * 4
    rows.append({
        "name": f"kernel/decode_attention/B{Bd}xS{Sd}",
        "us_per_call": round(t * 1e6, 1),
        "tpu_memory_us": round(bytes_ / HBM * 1e6, 2),
        "tpu_bound": "memory",
    })

    # embedding bag (jnp twin)
    V, dd, Bb, m = (100_000, 32, 4096, 4) if scale == "small" \
        else (1_000_000, 32, 65536, 4)
    table = jax.random.normal(key, (V, dd))
    ids = jax.random.randint(jax.random.fold_in(key, 6), (Bb, m), 0, V)
    w = jnp.ones((Bb, m)) / m
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    f = jax.jit(embedding_bag_ref)
    t = _time(f, table, ids, w)
    bytes_ = Bb * m * dd * 4 + Bb * dd * 4
    rows.append({
        "name": f"kernel/embedding_bag/B{Bb}xm{m}",
        "us_per_call": round(t * 1e6, 1),
        "tpu_memory_us": round(bytes_ / HBM * 1e6, 2),
        "tpu_bound": "memory",
    })
    return rows
