"""Multi-config sweep: one vmapped-sweep dispatch vs the sequential
per-config simulate loop, plus the threshold-sensitivity surface.

Reproduces: the paper's Figure-3-style threshold analysis (hit rate and
error rate over the tau_static x tau_dynamic plane) and quantifies the
speedup that makes dense grids cheap (DESIGN.md §10): the sweep shares
one hoisted static-tier lookup and one compiled program across all
configs, while the sequential loop re-runs both per config. Target:
>= 5x wall-clock at 64 configs on CPU (measured ~6-10x; grows with
static-tier size and trace length).

Both paths are warmed first, so the reported speedup is steady-state
compute, not compilation. The sequential baseline benefits from the
same traced-config refactor (no per-config recompilation) — against the
pre-refactor static-argument jit it would also recompile 64 times.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only sweep
    PYTHONPATH=src python -m benchmarks.sweep --configs 16   # CI smoke
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import TSTAR, get_benchmark
from repro.core.simulate import simulate, simulate_sweep, sweep_grid
from repro.core.tiers import CacheConfig

# capacity regime for the grid benchmark: small dynamic tier (the
# capacity-pressure corner of the paper's ablations) keeps the sequential
# loop overhead-bound, which is exactly the regime dense sweeps target
CAPACITY = 64


def _grid(wl: str, side: int, capacity: int):
    t = TSTAR[wl]
    taus = np.round(np.linspace(t - 0.06, t + 0.08, side), 4)
    base = CacheConfig(tau_static=t, tau_dynamic=t, sigma_min=0.0,
                       capacity=capacity, judge_latency=64)
    return taus, base, sweep_grid(base, krites=True,
                                  tau_static=taus, tau_dynamic=taus)


def run(scale: str = "small", wl: str = "lmarena_like", side: int = 8,
        capacity: int = CAPACITY, sequential: bool = True):
    bench = get_benchmark(wl, scale)
    taus, base, sweep = _grid(wl, side, capacity)
    K = sweep.n
    args = (jnp.asarray(bench.static_emb), jnp.asarray(bench.static_cls),
            jnp.asarray(bench.eval_emb), jnp.asarray(bench.eval_cls))
    n_req = bench.eval_emb.shape[0]

    # --- one-dispatch sweep (warm, then timed) ---
    t0 = time.time()
    res = simulate_sweep(*args, sweep)
    jax.block_until_ready(res)
    sweep_cold = time.time() - t0
    t0 = time.time()
    res = simulate_sweep(*args, sweep)
    jax.block_until_ready(res)
    sweep_s = time.time() - t0

    # --- sequential per-config loop (warm, then timed) ---
    seq_s = float("nan")
    if sequential:
        cfg0 = dataclasses.replace(base, tau_static=float(taus[0]),
                                   tau_dynamic=float(taus[0]))
        jax.block_until_ready(simulate(*args, cfg0, krites=True))
        t0 = time.time()
        for ts in taus:
            for td in taus:
                cfg = dataclasses.replace(base, tau_static=float(ts),
                                          tau_dynamic=float(td))
                r = simulate(*args, cfg, krites=True)
        jax.block_until_ready(r)
        seq_s = time.time() - t0

    # --- threshold-sensitivity surface (Figure-3-style) ---
    sb = np.asarray(res.served_by)                     # (K, N)
    hit = (sb != 0).mean(axis=1)
    err = ((sb != 0) & ~np.asarray(res.correct)).mean(axis=1)
    rows = [{
        "name": f"sweep/{wl}/K={K}",
        "us_per_call": round(1e6 * sweep_s / (K * n_req), 3),
        "configs": K,
        "requests": n_req,
        "capacity": capacity,
        "sweep_wall_s": round(sweep_s, 3),
        "sweep_compile_s": round(sweep_cold - sweep_s, 3),
        "sequential_wall_s": round(seq_s, 3),
        "speedup": round(seq_s / sweep_s, 2),
    }, {
        "name": f"sweep/{wl}/surface",
        "us_per_call": 0,
        "tau_grid": taus.tolist(),
        "hit_rate": np.round(hit.reshape(side, side), 4).tolist(),
        "error_rate": np.round(err.reshape(side, side), 4).tolist(),
    }]
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", type=int, default=64,
                    help="grid size (squared down to side*side)")
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--wl", default="lmarena_like")
    ap.add_argument("--no-sequential", action="store_true",
                    help="skip the sequential baseline (smoke mode)")
    a = ap.parse_args()
    side = max(2, int(np.sqrt(a.configs)))
    for row in run(scale=a.scale, wl=a.wl, side=side,
                   sequential=not a.no_sequential):
        print(row)
