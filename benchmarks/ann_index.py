"""ANN static-tier benchmark: exact flat lookup vs IVF quantized scan +
exact rerank (DESIGN.md §11), over corpus size x nprobe.

Reproduces the scaling argument behind the index subsystem: the flat
lookup's cost is linear in curated-corpus size, the IVF path's is
~``B*(K + nprobe*cap)*d``, so past ~10^5 rows the ANN index wins while
the exact rerank keeps served decisions agreeing with flat search.

Reported per (corpus size, nprobe) operating point:
- ``us_per_call`` and ``speedup_vs_flat`` — jitted end-to-end lookup
  wall time (same query batch, warm compile) against the flat/simsearch
  path;
- ``recall_at_C`` — fraction of queries whose true (flat) top-1 row
  survives into the candidate set;
- ``decision_agreement`` — fraction of queries where the served
  decision matches flat search exactly: same hit/miss verdict at the
  cache threshold and, on hits, the same served row.

    PYTHONPATH=src python -m benchmarks.ann_index [--smoke]

``--smoke`` is the CI entry (scripts/ci.sh): a small-corpus build +
scan + decision-agreement check with hard asserts.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (clustered_cache_workload,
                               decision_agreement, timed_median)

TAU = 0.85          # cache threshold separating near-dup hits from misses
NPROBES = (2, 4, 8, 16)
D = 64
B = 32              # in-flight query batch


def _make_workload(n_rows: int, rng, n_centers: int | None = None,
                   b: int = B, d: int = D):
    return clustered_cache_workload(n_rows, rng, b, d,
                                    n_centers=n_centers)


def _time(fn, reps: int = 5) -> float:
    return timed_median(fn, reps)


def _decision_agreement(v_flat, i_flat, v_ivf, i_ivf, tau=TAU) -> float:
    return decision_agreement(v_flat, i_flat, v_ivf, i_ivf, tau)


def _bench_one(n_rows: int, rng, nprobes=NPROBES, reps: int = 5,
               iters: int = 6):
    from repro.index.ivf import build_ivf
    from repro.kernels.ivf_scan.ops import ivf_scan, ivf_search
    from repro.kernels.simsearch.ops import cosine_topk

    corpus_np, q_np = _make_workload(n_rows, rng)
    corpus, q = jnp.asarray(corpus_np), jnp.asarray(q_np)

    flat_t = _time(lambda: cosine_topk(q, corpus, k=1), reps)
    v_f, i_f = jax.device_get(cosine_topk(q, corpus, k=1))
    v_f, i_f = v_f[:, 0], i_f[:, 0]

    t0 = time.perf_counter()
    ivf = build_ivf(corpus_np, iters=iters, corpus_normalized=True)
    build_s = time.perf_counter() - t0
    K, cap, _ = ivf.codes.shape

    rows = []
    for nprobe in nprobes:
        if nprobe > K:
            continue
        args = (ivf.centroids, ivf.codes, ivf.scales, ivf.row_ids)
        ivf_t = _time(lambda: ivf_search(q, corpus, *args, k=1,
                                         nprobe=nprobe), reps)
        v_i, i_i = jax.device_get(
            ivf_search(q, corpus, *args, k=1, nprobe=nprobe))
        _, cand = jax.device_get(ivf_scan(q, *args, nprobe=nprobe))
        got = (cand == i_f[:, None]).any(axis=1)
        hits = v_f >= TAU     # queries the cache would actually serve
        rows.append({
            "name": f"ann_index/N{n_rows}_nprobe{nprobe}",
            "us_per_call": round(1e6 * ivf_t, 1),
            "flat_us_per_call": round(1e6 * flat_t, 1),
            "speedup_vs_flat": round(flat_t / ivf_t, 2),
            "recall_at_C": float(np.mean(got)),
            "hit_recall_at_C": float(np.mean(got[hits]))
            if hits.any() else 1.0,
            "decision_agreement": _decision_agreement(
                v_f, i_f, v_i[:, 0], i_i[:, 0]),
            "K": int(K), "cap": int(cap),
            "build_s": round(build_s, 2), "B": B, "d": D,
        })
    return rows


def run(scale: str = "small"):
    sizes = [65_536, 262_144]
    if scale == "full":
        sizes.append(1_048_576)
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        rows.extend(_bench_one(n, rng))
    return rows


def smoke() -> None:
    """CI gate: build + scan + decision-agreement on a small corpus."""
    from repro.index.ivf import build_ivf
    from repro.kernels.ivf_scan.ops import ivf_scan, ivf_search
    from repro.kernels.simsearch.ops import cosine_topk

    rng = np.random.default_rng(0)
    corpus_np, q_np = _make_workload(8192, rng, b=32)
    corpus, q = jnp.asarray(corpus_np), jnp.asarray(q_np)
    ivf = build_ivf(corpus_np, iters=4, corpus_normalized=True)

    ids = np.asarray(ivf.row_ids).ravel()
    assert sorted(ids[ids >= 0].tolist()) == list(range(8192)), \
        "packed layout must partition the corpus"

    v_f, i_f = jax.device_get(cosine_topk(q, corpus, k=1))
    args = (ivf.centroids, ivf.codes, ivf.scales, ivf.row_ids)
    v_i, i_i = jax.device_get(
        ivf_search(q, corpus, *args, k=1, nprobe=32, n_candidates=64))
    _, cand = jax.device_get(ivf_scan(q, *args, nprobe=32,
                                      n_candidates=64))
    got = (cand == i_f[:, 0:1]).any(axis=1)
    hits = v_f[:, 0] >= TAU
    hit_recall = float(np.mean(got[hits]))
    agree = _decision_agreement(v_f[:, 0], i_f[:, 0],
                                v_i[:, 0], i_i[:, 0])
    assert hits.any(), "smoke workload produced no cache hits"
    assert hit_recall >= 0.99, f"hit recall@C {hit_recall} < 0.99"
    assert agree >= 0.99, f"decision agreement {agree} < 0.99"
    print(f"[OK] ivf smoke: {ivf.codes.shape[0]} clusters, hit "
          f"recall@C {hit_recall:.3f}, decision agreement {agree:.3f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small-corpus build + scan + "
                         "decision-agreement asserts")
    a = ap.parse_args()
    if a.smoke:
        smoke()
    else:
        for r in run(scale=a.scale):
            print(r)
