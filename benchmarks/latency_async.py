"""§5 'Blocking verified caching': async Krites vs a blocking judge on
the serving path. Latency model over the simulated stream:

    hit latency      = L_cache
    miss latency     = L_cache + L_backend
    blocking variant adds L_judge to every grey-zone request.

Reports mean/p99 with the paper's point: Krites keeps baseline latency
exactly; blocking pays judge latency on the critical path.

Reproduces: the §5 "Blocking verified caching" comparison (the paper's
unchanged-critical-path-latency claim, quantified with the latency model
above).

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only latency_async
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import default_cfg, get_benchmark, run_policies
from repro.core.simulate import MISS

L_CACHE_MS = 5.0
L_BACKEND_MS = 800.0
L_JUDGE_MS = 250.0


def _latencies(res, grey_mask, blocking: bool):
    sb = np.asarray(res.served_by)
    lat = np.full(sb.shape, L_CACHE_MS)
    lat[sb == MISS] += L_BACKEND_MS
    if blocking:
        lat[grey_mask] += L_JUDGE_MS
    return lat


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    cfg = default_cfg(wl)
    out = run_policies(bench, cfg)

    # grey-zone mask from the static sims (same hoisted lookup)
    import jax.numpy as jnp
    from repro.core.simulate import _static_sims
    s, _ = _static_sims(jnp.asarray(bench.static_emb),
                        jnp.asarray(bench.eval_emb))
    grey = (np.asarray(s) >= cfg.sigma_min) \
        & (np.asarray(s) < cfg.tau_static)

    rows = []
    for pol, blocking in (("baseline", False), ("krites_async", False),
                          ("blocking_verified", True)):
        res = out["baseline" if pol == "baseline" else "krites"][0]
        lat = _latencies(res, grey, blocking)
        rows.append({
            "name": f"latency/{wl}/{pol}",
            "us_per_call": round(float(lat.mean()) * 1e3, 1),
            "mean_ms": round(float(lat.mean()), 2),
            "p50_ms": round(float(np.percentile(lat, 50)), 2),
            "p99_ms": round(float(np.percentile(lat, 99)), 2),
            "greyzone_frac": round(float(grey.mean()), 3),
        })
    return rows
