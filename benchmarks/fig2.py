"""Paper Figure 2: static-origin coverage vs requests processed (cold
dynamic cache) for both workloads and both policies.

Reproduces: Figure 2 — the cumulative static-origin served fraction as a
function of requests processed, showing Krites' coverage climbing as
verified promotions land while the baseline plateaus.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only fig2 [--scale full]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import default_cfg, get_benchmark, run_policies
from repro.core.simulate import coverage_curve


def run(scale: str = "small", n_points: int = 12):
    rows = []
    for wl in ("lmarena_like", "search_like"):
        bench = get_benchmark(wl, scale)
        out = run_policies(bench, default_cfg(wl))
        for pol in ("baseline", "krites"):
            res, s = out[pol]
            pts, cum = coverage_curve(res, n_points)
            rows.append({
                "name": f"fig2/{wl}/{pol}",
                "us_per_call": round(s["us_per_req"], 2),
                "requests": [int(p) for p in np.asarray(pts)],
                "static_origin_cum": [round(float(c), 4)
                                      for c in np.asarray(cum)],
                "final": round(float(np.asarray(cum)[-1]), 4),
            })
    return rows
