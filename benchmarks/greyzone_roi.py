"""§3.4 / §5.1: grey-zone ROI — sweep sigma_min, measure judge volume vs
recovered static-origin traffic; plus judge rate-limit throttling.

Reproduces: the paper's §3.4 grey-zone-width analysis (judge calls per
request vs recovered curated traffic as sigma_min sweeps the zone shut)
and the §5.1(iii) rate-limited-judge ablation.

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only greyzone_roi
"""
from __future__ import annotations

from benchmarks.common import default_cfg, get_benchmark, run_policies


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    rows = []
    base = run_policies(bench, default_cfg(wl),
                        policies=("baseline",))["baseline"][1]
    for sigma in (0.0, 0.3, 0.5, 0.6, 0.7, 0.8):
        cfg = default_cfg(wl, sigma_min=sigma)
        k = run_policies(bench, cfg, policies=("krites",))["krites"][1]
        recovered = k["static_origin_rate"] - base["static_origin_rate"]
        rows.append({
            "name": f"greyzone_roi/{wl}/sigma={sigma}",
            "us_per_call": round(k["us_per_req"], 2),
            "judge_calls": k["judge_calls"],
            "judge_calls_per_req": round(
                k["judge_calls"] / k["requests"], 4),
            "promotions": k["promotions"],
            "recovered_static_origin": round(recovered, 4),
            "roi_serves_per_judge_call": round(
                recovered * k["requests"] / max(k["judge_calls"], 1), 3),
        })
    # throttled judge (rate limit budget), paper §5.1 (iii)
    for rate in (1.0, 0.2, 0.05):
        cfg = default_cfg(wl, judge_rate=rate)
        k = run_policies(bench, cfg, policies=("krites",))["krites"][1]
        rows.append({
            "name": f"greyzone_roi/{wl}/rate={rate}",
            "us_per_call": round(k["us_per_req"], 2),
            "judge_calls": k["judge_calls"],
            "enq_dropped": k["enq_dropped"],
            "static_origin_rate": round(k["static_origin_rate"], 4),
        })
    return rows
