"""§3.4 / §5.1: grey-zone ROI — sweep sigma_min, measure judge volume vs
recovered static-origin traffic; plus judge rate-limit throttling and
the TweakLLM-style rewrite coverage/cost frontier (DESIGN.md §18).

Reproduces: the paper's §3.4 grey-zone-width analysis (judge calls per
request vs recovered curated traffic as sigma_min sweeps the zone shut),
the §5.1(iii) rate-limited-judge ablation, and — new with the
multi-outcome verdict pipeline — the rewrite frontier: the same config
with ``rewrite`` off vs on at several rewriter budgets, reporting the
measured coverage (static-or-verified serve fraction) gain against the
no-rewrite baseline *in the same table*, at the shared error budget.

The entire grid — 1 baseline + 6 sigma_min points + 3 judge rates +
1 no-rewrite twin + 3 rewrite budgets — runs as two ``simulate_sweep``
dispatches (DESIGN.md §10).

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only greyzone_roi

``--smoke`` runs the rewrite critical-path gates on a constructed
orthonormal workload instead (wired into scripts/ci.sh):

  (i)  decision agreement 1.0 on first-seen prompts between the
       rewrite-on run and its rewrite-off twin — rewriting must never
       change what the triggering request is served;
  (ii) rewritten entries are served only to *later* repeats (every
       REWRITTEN_HIT lands on a repeat index, and at least one does).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import default_cfg, get_benchmark, run_policy_sweep

SIGMAS = (0.0, 0.3, 0.5, 0.6, 0.7, 0.8)
RATES = (1.0, 0.2, 0.05)
REWRITE_RATES = (1.0, 0.25, 0.05)   # rewriter token-bucket budgets
REWRITABLE_FRAC = 0.5               # of would-reject grey pairs


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    base_cfg = default_cfg(wl)
    cfgs = ([base_cfg]
            + [dataclasses.replace(base_cfg, sigma_min=s) for s in SIGMAS]
            + [dataclasses.replace(base_cfg, judge_rate=r) for r in RATES])
    krites = [False] + [True] * (len(SIGMAS) + len(RATES))
    sums, _, us = run_policy_sweep(bench, cfgs, krites)

    base = sums[0]
    rows = []
    for sigma, k in zip(SIGMAS, sums[1:1 + len(SIGMAS)]):
        recovered = k["static_origin_rate"] - base["static_origin_rate"]
        rows.append({
            "name": f"greyzone_roi/{wl}/sigma={sigma}",
            "us_per_call": round(us, 2),
            "judge_calls": k["judge_calls"],
            "judge_calls_per_req": round(
                k["judge_calls"] / k["requests"], 4),
            "promotions": k["promotions"],
            "recovered_static_origin": round(recovered, 4),
            "roi_serves_per_judge_call": round(
                recovered * k["requests"] / max(k["judge_calls"], 1), 3),
        })
    # throttled judge (rate limit budget), paper §5.1 (iii)
    for rate, k in zip(RATES, sums[1 + len(SIGMAS):]):
        rows.append({
            "name": f"greyzone_roi/{wl}/rate={rate}",
            "us_per_call": round(us, 2),
            "judge_calls": k["judge_calls"],
            "enq_dropped": k["enq_dropped"],
            "static_origin_rate": round(k["static_origin_rate"], 4),
        })

    # rewrite coverage/cost frontier (§18): one no-rewrite twin + the
    # same config at several rewriter budgets, same trace + same
    # rewritable channel, one dispatch — coverage gain at the budget
    rng = np.random.default_rng(7)
    rewritable = rng.random(bench.eval_emb.shape[0]) < REWRITABLE_FRAC
    rw_base = dataclasses.replace(base_cfg, sigma_min=0.5)
    rw_cfgs = [rw_base] + [dataclasses.replace(rw_base, rewrite=True,
                                               rewrite_rate=r)
                           for r in REWRITE_RATES]
    rw_sums, _, us2 = run_policy_sweep(bench, rw_cfgs, True,
                                       rewritable=rewritable)
    off = rw_sums[0]
    rows.append({
        "name": f"greyzone_roi/{wl}/rewrite=off",
        "us_per_call": round(us2, 2),
        "judge_calls": off["judge_calls"],
        "coverage": round(off["static_origin_rate"], 4),
        "error_rate": round(off["error_rate"], 4),
    })
    for r, k in zip(REWRITE_RATES, rw_sums[1:]):
        rows.append({
            "name": f"greyzone_roi/{wl}/rewrite={r}",
            "us_per_call": round(us2, 2),
            "judge_calls": k["judge_calls"],
            "rewrites": k["rewrites"],
            "rewrite_dropped": k["rewrite_dropped"],
            "rewritten_hit_rate": round(k["rewritten_hit_rate"], 4),
            "coverage": round(k["static_origin_rate"], 4),
            "coverage_gain_vs_off": round(
                k["static_origin_rate"] - off["static_origin_rate"], 4),
            "error_rate": round(k["error_rate"], 4),
        })
    return rows


# ---------------------------------------------------------------------------
# --smoke: rewrite critical-path gates (scripts/ci.sh)
# ---------------------------------------------------------------------------

def _smoke_world(n_unique: int = 40, d: int = 96):
    """Constructed workload with fully controlled similarities.

    Static tier: 8 orthonormal rows (classes 0..7). Grey query i is
    0.8 * P[s] + 0.6 * P[16 + i] — exactly 0.8 to its static neighbor
    (inside the grey zone at tau=0.9, sigma=0.5), 0.64 to any other
    query sharing the neighbor (below tau_dynamic=0.88), and 1.0 to its
    own exact repeat. Every query's class differs from its neighbor's
    (the judge would reject) and every request is rewritable, so with
    ``rewrite`` on each judged task promotes a rewritten entry. Phase 1
    (t < n_unique) is all first-seen prompts; phase 2 repeats them.
    """
    assert 16 + n_unique <= d
    P = np.eye(d, dtype=np.float32)
    static_emb = P[:8]
    static_cls = np.arange(8, dtype=np.int32)
    s_of = np.arange(n_unique) % 8
    uniq = (0.8 * P[s_of] + 0.6 * P[16 + np.arange(n_unique)]
            ).astype(np.float32)
    q_emb = np.concatenate([uniq, uniq])          # phase 2 = repeats
    # class 100+i: never equal to the neighbor's class (would-reject)
    q_cls = np.concatenate([100 + s_of, 100 + s_of]).astype(np.int32)
    return static_emb, static_cls, q_emb, q_cls, n_unique


def smoke() -> dict:
    import jax.numpy as jnp

    from repro.core.simulate import REWRITTEN_HIT, simulate
    from repro.core.tiers import CacheConfig

    s_emb, s_cls, q_emb, q_cls, n1 = _smoke_world()
    n = q_emb.shape[0]
    rewritable = np.ones(n, bool)
    mk = lambda rw: CacheConfig(
        tau_static=0.9, tau_dynamic=0.88, sigma_min=0.5, capacity=128,
        judge_latency=2, rewrite=rw)
    runs = {}
    for rw in (False, True):
        res = simulate(jnp.asarray(s_emb), jnp.asarray(s_cls),
                       jnp.asarray(q_emb), jnp.asarray(q_cls), mk(rw),
                       krites=True, rewritable=jnp.asarray(rewritable))
        runs[rw] = res
    sb_off = np.asarray(runs[False].served_by)
    sb_on = np.asarray(runs[True].served_by)

    # gate (i): first-seen prompts decided identically with rewrite on —
    # serving decisions never depend on the triggering request's verdict
    first = slice(0, n1)
    agreement = float(np.mean(sb_off[first] == sb_on[first]))
    assert agreement == 1.0, (
        f"rewrite changed {np.sum(sb_off[first] != sb_on[first])} "
        f"first-seen decisions (agreement {agreement})")

    # gate (ii): rewritten entries served only to later repeats
    rw_hits = np.flatnonzero(sb_on == REWRITTEN_HIT)
    assert rw_hits.size > 0, "smoke produced no rewritten serves"
    assert (rw_hits >= n1).all(), (
        f"rewritten serve on a first-seen prompt at t={rw_hits.min()}")
    rewrites = int(runs[True].rewrites)
    assert rewrites > 0
    out = {"first_seen_agreement": agreement,
           "rewrites": rewrites,
           "rewritten_serves": int(rw_hits.size),
           "rewritten_serves_on_repeats": int((rw_hits >= n1).sum())}
    print("[OK] greyzone_roi --smoke: "
          f"first-seen agreement {agreement} (gate 1.0), "
          f"{rewrites} rewrites, {rw_hits.size} rewritten serves, "
          f"all on repeat indices")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="run the rewrite critical-path gates "
                         "(first-seen agreement 1.0; rewritten serves "
                         "only on later repeats)")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    else:
        for row in run():
            print(row)
