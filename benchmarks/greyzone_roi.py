"""§3.4 / §5.1: grey-zone ROI — sweep sigma_min, measure judge volume vs
recovered static-origin traffic; plus judge rate-limit throttling.

Reproduces: the paper's §3.4 grey-zone-width analysis (judge calls per
request vs recovered curated traffic as sigma_min sweeps the zone shut)
and the §5.1(iii) rate-limited-judge ablation.

The entire grid — 1 baseline + 6 sigma_min points + 3 judge rates — runs
as a single ``simulate_sweep`` dispatch (DESIGN.md §10).

Invocation:

    PYTHONPATH=src python -m benchmarks.run --only greyzone_roi
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import default_cfg, get_benchmark, run_policy_sweep

SIGMAS = (0.0, 0.3, 0.5, 0.6, 0.7, 0.8)
RATES = (1.0, 0.2, 0.05)


def run(scale: str = "small", wl: str = "lmarena_like"):
    bench = get_benchmark(wl, scale)
    base_cfg = default_cfg(wl)
    cfgs = ([base_cfg]
            + [dataclasses.replace(base_cfg, sigma_min=s) for s in SIGMAS]
            + [dataclasses.replace(base_cfg, judge_rate=r) for r in RATES])
    krites = [False] + [True] * (len(SIGMAS) + len(RATES))
    sums, _, us = run_policy_sweep(bench, cfgs, krites)

    base = sums[0]
    rows = []
    for sigma, k in zip(SIGMAS, sums[1:1 + len(SIGMAS)]):
        recovered = k["static_origin_rate"] - base["static_origin_rate"]
        rows.append({
            "name": f"greyzone_roi/{wl}/sigma={sigma}",
            "us_per_call": round(us, 2),
            "judge_calls": k["judge_calls"],
            "judge_calls_per_req": round(
                k["judge_calls"] / k["requests"], 4),
            "promotions": k["promotions"],
            "recovered_static_origin": round(recovered, 4),
            "roi_serves_per_judge_call": round(
                recovered * k["requests"] / max(k["judge_calls"], 1), 3),
        })
    # throttled judge (rate limit budget), paper §5.1 (iii)
    for rate, k in zip(RATES, sums[1 + len(SIGMAS):]):
        rows.append({
            "name": f"greyzone_roi/{wl}/rate={rate}",
            "us_per_call": round(us, 2),
            "judge_calls": k["judge_calls"],
            "enq_dropped": k["enq_dropped"],
            "static_origin_rate": round(k["static_origin_rate"], 4),
        })
    return rows
