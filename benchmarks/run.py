"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale small|full] [--only X]

Registered modules (see each module's docstring for what it reproduces):
``table1``, ``fig2``, ``greyzone_roi``, ``latency_async``,
``verifier_fidelity``, ``kernels``, ``serve_batched``, ``sweep``,
``ann_index``, ``dyn_index``, ``sharded_serve``, ``load_service``,
``fused_serve``, ``l1_freshness``, ``adaptive_thresholds``.

Prints ``name,us_per_call,derived`` CSV rows (derived = remaining fields
as compact JSON) and writes results/benchmarks.json.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["small", "full"], default="small")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()

    from benchmarks import (adaptive_thresholds, ann_index, dyn_index,
                            fig2, fused_serve, greyzone_roi,
                            kernels_bench, l1_freshness, latency_async,
                            load_service, serve_batched, sharded_serve,
                            sweep, table1, verifier_fidelity)
    modules = {
        "table1": table1, "fig2": fig2, "greyzone_roi": greyzone_roi,
        "latency_async": latency_async,
        "verifier_fidelity": verifier_fidelity,
        "kernels": kernels_bench,
        "serve_batched": serve_batched,
        "sweep": sweep,
        "ann_index": ann_index,
        "dyn_index": dyn_index,
        "sharded_serve": sharded_serve,
        "load_service": load_service,
        "fused_serve": fused_serve,
        "l1_freshness": l1_freshness,
        "adaptive_thresholds": adaptive_thresholds,
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    # results/ is gitignored, so it does not exist on fresh clones;
    # create it up front (not just before the final write) so modules
    # that emit their own artifacts can rely on it too
    RESULTS.mkdir(parents=True, exist_ok=True)

    print("name,us_per_call,derived")
    all_rows = []
    for mod_name, mod in modules.items():
        t0 = time.time()
        try:
            rows = mod.run(scale=args.scale)
        except Exception as e:  # noqa: BLE001
            rows = [{"name": f"{mod_name}/ERROR", "us_per_call": -1,
                     "error": str(e)[:300]}]
        for r in rows:
            derived = {k: v for k, v in r.items()
                       if k not in ("name", "us_per_call")}
            print(f"{r['name']},{r.get('us_per_call', 0)},"
                  f"\"{json.dumps(derived)}\"")
        all_rows.extend(rows)

    (RESULTS / "benchmarks.json").write_text(json.dumps(all_rows, indent=1))


if __name__ == "__main__":
    main()
