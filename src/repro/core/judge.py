"""Judges for VerifyAndPromote.

- OracleJudge: ground-truth equivalence classes (the paper's §4 setup).
- NoisyOracleJudge: oracle + configurable false-approve/false-reject rates
  (the §5 verifier-fidelity analysis: added error <= eps * p_prom).
- LLMJudge: a real model-backed judge for the live end-to-end example —
  scores semantic equivalence with the embedding model + a margin test, or
  any user-supplied callable (e.g. a tiny LM scoring yes/no).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


class OracleJudge:
    """approve iff query and static neighbor share an equivalence class.

    The paper's judge is defined over the ``(q_text, h_text, answer)``
    triple — the class-id comparison is the oracle shortcut the
    simulator uses. The live serving path now plumbs the real texts
    into every grey-zone payload (``KritesPolicy(static_texts=)``);
    ``require_texts=True`` makes this judge refuse payloads that lost
    them (used by tests and the verifier-fidelity benchmark to pin the
    contract).
    """

    def __init__(self, require_texts: bool = False, freshness=None):
        self.require_texts = require_texts
        # a core.freshness.FreshnessPolicy; when given, this judge also
        # emits a per-entry TTL verdict alongside every approval
        self.freshness = freshness

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> bool:
        if self.require_texts and not (q_text and h_text and answer):
            raise ValueError(
                f"judge payload missing verification texts: "
                f"q_text={q_text!r} h_text={h_text!r} answer={answer!r}")
        return int(q_cls) == int(h_cls)

    def assign_ttl(self, q_text: str = "", h_text: str = "",
                   answer: str = "") -> int:
        """TTL verdict for an approved promotion (DESIGN.md §16): how
        many request ticks the promoted entry should live, judged from
        the query's staleness-risk class (0 = unbounded). The verdict
        rides the promotion payload into the WAL and the dynamic
        tier's ``expires_at`` column."""
        if self.freshness is None:
            return 0
        return int(self.freshness.ttl_for_text(q_text or h_text))


@dataclass
class NoisyOracleJudge:
    """Oracle with false-approve rate eps_fa and false-reject rate eps_fr.

    Deterministic per (q, h) pair (hash-seeded), so dedup/retry behave
    like a real, consistent judge rather than a coin flip per call.
    """
    eps_fa: float = 0.0
    eps_fr: float = 0.0
    seed: int = 0

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> bool:
        truth = int(q_cls) == int(h_cls)
        h = hashlib.blake2s(
            f"{self.seed}|{q_cls}|{h_cls}|{q_text}|{h_text}".encode(),
            digest_size=8).digest()
        u = int.from_bytes(h, "little") / 2**64
        if truth:
            return u >= self.eps_fr
        return u < self.eps_fa


class LLMJudge:
    """Model-backed judge for the live stack.

    ``score_fn(q_text, h_text, answer) -> float`` returns an equivalence
    score in [0, 1]; approve when >= threshold. The e2e example wires this
    to the tiny-LM scorer in serving/llm_judge_backend.py.
    """

    def __init__(self, score_fn: Callable[[str, str, str], float],
                 threshold: float = 0.5):
        self.score_fn = score_fn
        self.threshold = threshold

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> bool:
        return float(self.score_fn(q_text, h_text, answer)) \
            >= self.threshold
