"""Judges for VerifyAndPromote: structured verdicts + rewriters.

The paper's asynchronous judge emits promote-or-reject; TweakLLM
(PAPERS.md) adds a third outcome — *tailor the cached response to the
new prompt* — so the verdict is now a first-class type:

- ``Verdict``: outcome in {APPROVE, REJECT, REWRITE} + the tailored
  text (rewrite), a TTL verdict, and a confidence. ``bool(verdict)``
  is "approved" so verdicts drop into boolean call sites.
- ``as_verdict``: auto-wraps plain ``bool`` judge returns — every
  legacy injected judge callable keeps working unchanged.
- OracleJudge: ground-truth equivalence classes (the paper's §4 setup);
  an optional ``rewritable`` predicate upgrades would-be rejects to
  REWRITE (the oracle model of "a cheap rewriter can tailor this").
- NoisyOracleJudge: oracle + configurable false-approve/false-reject
  rates (the §5 verifier-fidelity analysis: added error <= eps*p_prom).
- LLMJudge: a real model-backed judge for the live end-to-end example —
  scores semantic equivalence; an optional ``rewrite_threshold`` opens
  a near-miss band [rewrite_threshold, threshold) that verdicts REWRITE.
- ``template_rewriter``: the deterministic reference ``RewriterFn``
  (prompt-tagged tailoring) the launchers and tests wire in; a real
  deployment substitutes a small LM.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Optional

# verdict outcomes (string tags: they ride WAL records and snapshots)
APPROVE = "approve"
REJECT = "reject"
REWRITE = "rewrite"
OUTCOMES = (APPROVE, REJECT, REWRITE)

# RewriterFn protocol: (q_text, h_text, answer) -> tailored answer text.
# Runs OFF the critical path (pool worker thread), rate-budgeted like
# the judge; an empty return or an exception counts as rewrite_failed
# and the verdict downgrades to REJECT.
RewriterFn = Callable[[str, str, str], str]


@dataclass(frozen=True)
class Verdict:
    """One judge decision. ``text`` is only meaningful for REWRITE (the
    tailored answer); ``ttl`` of None defers to the policy's freshness
    TTL assignment; ``confidence`` is advisory telemetry."""
    outcome: str = APPROVE
    text: str = ""
    ttl: Optional[int] = None
    confidence: float = 1.0

    def __post_init__(self):
        if self.outcome not in OUTCOMES:
            raise ValueError(f"unknown verdict outcome {self.outcome!r}")

    @property
    def approved(self) -> bool:
        return self.outcome == APPROVE

    def __bool__(self) -> bool:
        # verdicts drop into legacy boolean call sites: truthy == "this
        # exact cached answer is approved as-is"
        return self.outcome == APPROVE


def as_verdict(result) -> Verdict:
    """Auto-wrap a judge return: plain bools (every pre-verdict judge
    callable) become APPROVE/REJECT verdicts; Verdicts pass through."""
    if isinstance(result, Verdict):
        return result
    return Verdict(APPROVE if result else REJECT)


def template_rewriter(q_text: str, h_text: str, answer: str) -> str:
    """Reference rewriter: deterministically tailor the cached answer to
    the new prompt by prefixing the prompt context — the cheapest
    possible stand-in for TweakLLM's small-model rewrite, sufficient for
    the demo launchers and for provenance tests (the output differs from
    the cached answer and embeds the triggering prompt)."""
    return f"[tailored to: {q_text}] {answer}" if q_text else str(answer)


class OracleJudge:
    """approve iff query and static neighbor share an equivalence class.

    The paper's judge is defined over the ``(q_text, h_text, answer)``
    triple — the class-id comparison is the oracle shortcut the
    simulator uses. The live serving path now plumbs the real texts
    into every grey-zone payload (``KritesPolicy(static_texts=)``);
    ``require_texts=True`` makes this judge refuse payloads that lost
    them (used by tests and the verifier-fidelity benchmark to pin the
    contract).

    ``rewritable(q_cls, h_cls, q_text, h_text) -> bool`` (optional)
    is the oracle's rewrite model: a pair that fails the equivalence
    test but passes the predicate verdicts REWRITE instead of REJECT
    (mirrors the simulator's per-request ``rewritable`` channel).
    """

    def __init__(self, require_texts: bool = False, freshness=None,
                 rewritable: Optional[Callable] = None):
        self.require_texts = require_texts
        # a core.freshness.FreshnessPolicy; when given, this judge also
        # emits a per-entry TTL verdict alongside every approval
        self.freshness = freshness
        self.rewritable = rewritable

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> Verdict:
        if self.require_texts and not (q_text and h_text and answer):
            raise ValueError(
                f"judge payload missing verification texts: "
                f"q_text={q_text!r} h_text={h_text!r} answer={answer!r}")
        if int(q_cls) == int(h_cls):
            return Verdict(APPROVE)
        if self.rewritable is not None \
                and self.rewritable(q_cls, h_cls, q_text, h_text):
            return Verdict(REWRITE)
        return Verdict(REJECT)

    def assign_ttl(self, q_text: str = "", h_text: str = "",
                   answer: str = "") -> int:
        """TTL verdict for an approved promotion (DESIGN.md §16): how
        many request ticks the promoted entry should live, judged from
        the query's staleness-risk class (0 = unbounded). The verdict
        rides the promotion payload into the WAL and the dynamic
        tier's ``expires_at`` column."""
        if self.freshness is None:
            return 0
        return int(self.freshness.ttl_for_text(q_text or h_text))


@dataclass
class NoisyOracleJudge:
    """Oracle with false-approve rate eps_fa and false-reject rate eps_fr.

    Deterministic per (q, h) pair (hash-seeded), so dedup/retry behave
    like a real, consistent judge rather than a coin flip per call.
    """
    eps_fa: float = 0.0
    eps_fr: float = 0.0
    seed: int = 0

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> Verdict:
        truth = int(q_cls) == int(h_cls)
        h = hashlib.blake2s(
            f"{self.seed}|{q_cls}|{h_cls}|{q_text}|{h_text}".encode(),
            digest_size=8).digest()
        u = int.from_bytes(h, "little") / 2**64
        approve = (u >= self.eps_fr) if truth else (u < self.eps_fa)
        return Verdict(APPROVE if approve else REJECT)


class LLMJudge:
    """Model-backed judge for the live stack.

    ``score_fn(q_text, h_text, answer) -> float`` returns an equivalence
    score in [0, 1]; approve when >= threshold. The e2e example wires this
    to the tiny-LM scorer in serving/llm_judge_backend.py.

    ``rewrite_threshold`` (optional, < threshold) opens the TweakLLM
    near-miss band: scores in [rewrite_threshold, threshold) verdict
    REWRITE — close enough that a cheap rewriter can tailor the cached
    answer, not close enough to serve as-is.
    """

    def __init__(self, score_fn: Callable[[str, str, str], float],
                 threshold: float = 0.5,
                 rewrite_threshold: Optional[float] = None):
        if rewrite_threshold is not None \
                and not rewrite_threshold < threshold:
            raise ValueError(
                f"rewrite_threshold {rewrite_threshold} must be below "
                f"threshold {threshold}")
        self.score_fn = score_fn
        self.threshold = threshold
        self.rewrite_threshold = rewrite_threshold

    def __call__(self, q_cls: int, h_cls: int, q_text: str = "",
                 h_text: str = "", answer: str = "") -> Verdict:
        s = float(self.score_fn(q_text, h_text, answer))
        if s >= self.threshold:
            return Verdict(APPROVE, confidence=s)
        if self.rewrite_threshold is not None \
                and s >= self.rewrite_threshold:
            return Verdict(REWRITE, confidence=s)
        return Verdict(REJECT, confidence=s)
