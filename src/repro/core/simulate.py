"""Trace-driven simulator for Algorithm 1 (baseline) and Algorithm 2
(Krites), as one jittable ``lax.scan`` over the request stream.

Faithful to the paper's evaluation (§4):
- serving decisions use fixed thresholds tau_static / tau_dynamic;
- Krites only adds the grey-zone trigger + an asynchronous
  VerifyAndPromote whose judge is the *oracle* over ground-truth
  equivalence classes (approve iff query and static neighbor share a
  class);
- the async pool is modeled as a delay line: a task enqueued at request t
  completes at request t + judge_latency (queue depth affects promotion
  lag only — never the serving decision of the triggering request, which
  is decided before the queue is touched).

The static-tier lookup is hoisted out of the scan (the static tier is
immutable) into one batched matmul — on TPU this is the fused
``kernels/simsearch`` kernel; the per-step dynamic lookup stays inside the
scan because the tier mutates.

Outputs both aggregate counters and a per-request event stream (for the
Figure-2 coverage-vs-requests curves).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tiers as T
from repro.index.flat import l2_normalize

# served-by codes in the event stream
MISS, STATIC_HIT, DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED = 0, 1, 2, 3


class SimState(NamedTuple):
    dyn: T.DynamicTier
    # pending VerifyAndPromote delay line (length = judge_latency)
    p_valid: jax.Array   # (L,) bool
    p_emb: jax.Array     # (L, d)
    p_qcls: jax.Array    # (L,) int32
    p_hcls: jax.Array    # (L,) int32 static neighbor's class
    p_href: jax.Array    # (L,) int32 static answer handle
    p_flip: jax.Array    # (L,) bool — noisy-judge false approvals
    budget: jax.Array    # token bucket for judge rate limiting
    t: jax.Array
    judge_calls: jax.Array
    judge_approved: jax.Array
    promotions: jax.Array
    enq_dropped: jax.Array


class SimResult(NamedTuple):
    served_by: jax.Array        # (N,) int8 event codes
    correct: jax.Array          # (N,) bool (True for misses too)
    static_origin: jax.Array    # (N,) bool — curated answer served
    judge_calls: jax.Array
    judge_approved: jax.Array
    promotions: jax.Array
    enq_dropped: jax.Array


def _static_sims(static_emb: jax.Array, q_emb: jax.Array,
                 chunk: int = 2048):
    """Batched static-tier NN for the whole trace (hoisted lookup)."""
    n = q_emb.shape[0]
    pad = (-n) % chunk
    qp = jnp.pad(q_emb, ((0, pad), (0, 0)))

    def body(_, q):
        sims = q @ static_emb.T
        idx = jnp.argmax(sims, axis=1)
        return None, (jnp.take_along_axis(sims, idx[:, None], 1)[:, 0],
                      idx.astype(jnp.int32))

    _, (s, i) = jax.lax.scan(body, None,
                             qp.reshape(-1, chunk, q_emb.shape[1]))
    return s.reshape(-1)[:n], i.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("cfg", "krites", "capacity"))
def simulate(static_emb, static_cls, q_emb, q_cls, cfg: T.CacheConfig,
             krites: bool, capacity: int | None = None,
             judge_flip=None) -> SimResult:
    """Run the policy over a request stream.

    static_emb (S, d) [normalized], static_cls (S,);
    q_emb (N, d) [normalized], q_cls (N,).
    judge_flip (N,) bool (optional): requests whose VerifyAndPromote is
    *falsely approved* regardless of class (noisy-verifier study, §5).
    """
    N, d = q_emb.shape
    if judge_flip is None:
        judge_flip = jnp.zeros((N,), bool)
    C = capacity or cfg.capacity
    L = max(1, cfg.judge_latency)

    s_static, h_idx = _static_sims(static_emb, q_emb)
    h_cls = static_cls[h_idx]

    state = SimState(
        dyn=T.make_dynamic_tier(C, d),
        p_valid=jnp.zeros((L,), bool),
        p_emb=jnp.zeros((L, d), jnp.float32),
        p_qcls=jnp.zeros((L,), jnp.int32),
        p_hcls=jnp.zeros((L,), jnp.int32),
        p_href=jnp.zeros((L,), jnp.int32),
        p_flip=jnp.zeros((L,), bool),
        budget=jnp.float32(1.0),
        t=jnp.int32(0),
        judge_calls=jnp.int32(0),
        judge_approved=jnp.int32(0),
        promotions=jnp.int32(0),
        enq_dropped=jnp.int32(0),
    )

    def step(st: SimState, xs):
        q, qc, ss, hc, hr, fl = xs
        t = st.t
        dyn = st.dyn

        # ---- 1. async completions due now (slot t mod L, enqueued t-L) —
        # processed before serving, consistent with "completed earlier".
        slot = jnp.mod(t, L)
        due = jnp.logical_and(st.p_valid[slot], t >= L)
        approve = jnp.logical_and(
            due, jnp.logical_or(st.p_qcls[slot] == st.p_hcls[slot],
                                st.p_flip[slot]))
        promoted_dyn = T.upsert(dyn, st.p_emb[slot], st.p_hcls[slot],
                                st.p_href[slot], now=t, static_origin=True)
        dyn = jax.tree.map(lambda a, b: jnp.where(approve, b, a), dyn,
                           promoted_dyn)
        judge_calls = st.judge_calls + due.astype(jnp.int32)
        judge_approved = st.judge_approved + approve.astype(jnp.int32)
        promotions = st.promotions + approve.astype(jnp.int32)
        p_valid = st.p_valid.at[slot].set(False)

        # ---- 2. serving path (identical for baseline and Krites) ----
        static_hit = ss >= cfg.tau_static
        s_dyn, j_dyn = T.dynamic_lookup(dyn, q)
        dyn_hit = jnp.logical_and(~static_hit, s_dyn >= cfg.tau_dynamic)
        miss = jnp.logical_and(~static_hit, ~dyn_hit)

        served_cls = jnp.where(static_hit, hc,
                               jnp.where(dyn_hit, dyn.cls[j_dyn], qc))
        is_promoted = jnp.logical_and(dyn_hit, dyn.static_origin[j_dyn])
        served_by = jnp.where(
            static_hit, STATIC_HIT,
            jnp.where(is_promoted, DYN_HIT_PROMOTED,
                      jnp.where(dyn_hit, DYN_HIT_DYNAMIC, MISS))
        ).astype(jnp.int8)
        correct = served_cls == qc
        static_origin = jnp.logical_or(static_hit, is_promoted)

        # LRU touch on dynamic hit
        touched = T.touch(dyn, j_dyn, t)
        dyn = jax.tree.map(lambda a, b: jnp.where(dyn_hit, b, a), dyn,
                           touched)
        # baseline write-back on miss (backend answer has the query's class)
        inserted = T.insert(dyn, q, qc, jnp.int32(-1), now=t,
                            static_origin=False)
        dyn = jax.tree.map(lambda a, b: jnp.where(miss, b, a), dyn,
                           inserted)

        # ---- 3. grey-zone trigger (Krites only; off-path) ----
        grey = jnp.logical_and(ss >= cfg.sigma_min, ss < cfg.tau_static)
        want = jnp.logical_and(grey, bool(krites))
        if cfg.dedup:
            # skip if a promoted pointer already serves this query
            want = jnp.logical_and(
                want, ~jnp.logical_and(is_promoted,
                                       s_dyn >= cfg.tau_dynamic))
        budget = jnp.minimum(st.budget + cfg.judge_rate, 1e9)
        can = jnp.logical_and(want, budget >= 1.0)
        budget = jnp.where(can, budget - 1.0, budget)
        dropped = jnp.logical_and(want, ~can)

        p_valid = p_valid.at[slot].set(can)
        p_emb = st.p_emb.at[slot].set(jnp.where(can, q, st.p_emb[slot]))
        p_qcls = st.p_qcls.at[slot].set(
            jnp.where(can, qc, st.p_qcls[slot]))
        p_hcls = st.p_hcls.at[slot].set(
            jnp.where(can, hc, st.p_hcls[slot]))
        p_href = st.p_href.at[slot].set(
            jnp.where(can, hr, st.p_href[slot]))
        p_flip = st.p_flip.at[slot].set(
            jnp.where(can, fl, st.p_flip[slot]))

        new_state = SimState(
            dyn=dyn, p_valid=p_valid, p_emb=p_emb, p_qcls=p_qcls,
            p_hcls=p_hcls, p_href=p_href, p_flip=p_flip,
            budget=budget, t=t + 1,
            judge_calls=judge_calls, judge_approved=judge_approved,
            promotions=promotions,
            enq_dropped=st.enq_dropped + dropped.astype(jnp.int32))
        return new_state, (served_by, correct, static_origin)

    xs = (q_emb, q_cls.astype(jnp.int32), s_static, h_cls, h_idx,
          judge_flip)
    final, (served_by, correct, static_origin) = jax.lax.scan(
        step, state, xs)
    return SimResult(served_by, correct, static_origin,
                     final.judge_calls, final.judge_approved,
                     final.promotions, final.enq_dropped)


# ---------------------------------------------------------------------------
# derived metrics
# ---------------------------------------------------------------------------

def summarize(res: SimResult) -> dict:
    n = res.served_by.shape[0]
    sb = res.served_by
    hit = sb != MISS
    out = {
        "requests": n,
        "static_hit_rate": float(jnp.mean(sb == STATIC_HIT)),
        "dyn_hit_rate": float(jnp.mean((sb == DYN_HIT_DYNAMIC)
                                       | (sb == DYN_HIT_PROMOTED))),
        "promoted_hit_rate": float(jnp.mean(sb == DYN_HIT_PROMOTED)),
        "total_hit_rate": float(jnp.mean(hit)),
        "static_origin_rate": float(jnp.mean(res.static_origin)),
        "error_rate": float(jnp.mean(jnp.logical_and(hit, ~res.correct))),
        "judge_calls": int(res.judge_calls),
        "judge_approved": int(res.judge_approved),
        "promotions": int(res.promotions),
        "enq_dropped": int(res.enq_dropped),
    }
    return out


def coverage_curve(res: SimResult, n_points: int = 100):
    """Cumulative static-origin served fraction vs requests (Figure 2)."""
    so = res.static_origin.astype(jnp.float32)
    cum = jnp.cumsum(so) / (jnp.arange(so.shape[0]) + 1)
    pts = jnp.linspace(0, so.shape[0] - 1, n_points).astype(jnp.int32)
    return pts, cum[pts]
