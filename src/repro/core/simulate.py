"""Trace-driven simulator for Algorithm 1 (baseline) and Algorithm 2
(Krites), as one jittable ``lax.scan`` over the request stream — plus
``simulate_sweep``, the vmapped multi-config variant that evaluates an
entire grid of configs in a single device dispatch (DESIGN.md §10).

Faithful to the paper's evaluation (§4):
- serving decisions use fixed thresholds tau_static / tau_dynamic;
- Krites only adds the grey-zone trigger + an asynchronous
  VerifyAndPromote whose judge is the *oracle* over ground-truth
  equivalence classes (approve iff query and static neighbor share a
  class);
- the async pool is modeled as a fixed-size pending ring: a task
  enqueued at request t carries ``due_at = t + judge_latency`` and is
  completed at the first step >= due_at, at most one completion per step
  (queue depth affects promotion lag only — never the serving decision
  of the triggering request, which is decided before the queue is
  touched).

Every decision input (thresholds, sigma_min, judge rate, capacity,
latency, the dedup flag, the Krites flag itself) is a *traced* value,
so one compiled program serves any config, and batching over those
scalars yields the sweep path. Only array shapes (trace length,
embedding dim, tier capacity, ring size) are static.

The static-tier lookup is hoisted out of the scan (the static tier is
immutable) into one batched matmul — on TPU this is the fused
``kernels/simsearch`` kernel; the per-step dynamic lookup stays inside the
scan because the tier mutates. For the sweep the hoisted lookup is shared
across all configs (it is config-independent).

Outputs both aggregate counters and a per-request event stream (for the
Figure-2 coverage-vs-requests curves).
"""
from __future__ import annotations

import functools
import itertools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiers as T

# served-by codes in the event stream
MISS, STATIC_HIT, DYN_HIT_DYNAMIC, DYN_HIT_PROMOTED, L1_HIT = 0, 1, 2, 3, 4
# a dynamic-tier hit on a REWRITE-promoted tailored variant
# (DESIGN.md §18); its answer_ref carries the -2 sentinel
REWRITTEN_HIT = 5

# "never expires" sentinel for the sim's L1 expiry column (0 = empty
# slot, so an unbounded entry needs a finite stand-in; request clocks
# are bounded by the trace length, far below 2**30)
_L1_NEVER = jnp.int32(1 << 30)


class SimState(NamedTuple):
    """Scan carry: every leaf has a leading (K,) config axis.

    The pending VerifyAndPromote queue is a *bit ring* of R slots: bit
    (k, t mod R) records whether config k enqueued a task at step t. The
    payload (query embedding, classes, handles, flip bit) is never stored
    — the task enqueued at step t is exactly request t of the shared
    trace, so at completion time it is re-gathered from the trace at
    index t - judge_latency. This keeps the carry small and the per-step
    ring traffic to one column write + one gather.

    The L1 exact-match front (DESIGN.md §16) carries four (K, nk)
    columns keyed by the trace's ``key_id`` (nk = number of distinct
    exact-duplicate keys; 1 when L1 is off): the entry's expiry clock
    (0 = empty), the content clock its answer was produced at (the
    drift-staleness epoch), and the stored correctness/provenance bits.
    """
    dyn: T.DynamicTier   # batched: (K, C, d) / (K, C) leaves
    ring: jax.Array      # (K, R) bool enqueue bits
    budget: jax.Array    # (K,) token bucket for judge rate limiting
    t: jax.Array
    judge_calls: jax.Array     # (K,)
    judge_approved: jax.Array  # (K,)
    promotions: jax.Array      # (K,)
    enq_dropped: jax.Array     # (K,)
    l1_exp: jax.Array          # (K, nk) i32 expiry (0 = empty slot)
    l1_w: jax.Array            # (K, nk) i32 content clock
    l1_ok: jax.Array           # (K, nk) bool stored correctness
    l1_so: jax.Array           # (K, nk) bool stored static_origin
    ttl_evicted: jax.Array     # (K,) dynamic entries dead by expiry
    bypassed: jax.Array        # (K,) volatile requests sent straight back
    rbud: jax.Array            # (K,) token bucket for rewrite budgeting
    rewrites: jax.Array        # (K,) REWRITE verdicts promoted
    rewrite_dropped: jax.Array  # (K,) rewrites lost to an empty bucket


class SimResult(NamedTuple):
    served_by: jax.Array        # (N,) int8 event codes ((K, N) for sweeps)
    correct: jax.Array          # (N,) bool (True for misses too)
    static_origin: jax.Array    # (N,) bool — curated answer served
    stale: jax.Array            # (N,) bool — hit served across a drift
    judge_calls: jax.Array      # epoch (freshness accounting, §16)
    judge_approved: jax.Array
    promotions: jax.Array
    enq_dropped: jax.Array
    ttl_evicted: jax.Array
    bypassed: jax.Array
    # rewrite pipeline counters (DESIGN.md §18); defaulted so hand-built
    # SimResults (tests) predating the verdict refactor keep working
    rewrites: jax.Array = np.int32(0)
    rewrite_dropped: jax.Array = np.int32(0)


class SweepConfig(NamedTuple):
    """One row per config; every field is a (K,) array.

    Each scalar maps onto the matching :class:`tiers.CacheConfig` field;
    ``krites`` is the Algorithm-1-vs-2 switch (the grey-zone trigger),
    swept like any other knob so baseline and Krites share one dispatch.
    """
    tau_static: jax.Array    # (K,) f32
    tau_dynamic: jax.Array   # (K,) f32
    sigma_min: jax.Array     # (K,) f32
    judge_rate: jax.Array    # (K,) f32
    capacity: jax.Array      # (K,) i32, each <= tier's static max capacity
    judge_latency: jax.Array  # (K,) i32, each <= static ring size
    krites: jax.Array        # (K,) bool
    dedup: jax.Array         # (K,) bool — skip judging on promoted hits
    l1: jax.Array            # (K,) bool — exact-match front tier on
    volatile_bypass: jax.Array  # (K,) bool — volatile queries skip cache
    ttl_volatile: jax.Array  # (K,) i32 entry lifetime, volatile queries
    ttl_stable: jax.Array    # (K,) i32 entry lifetime, everything else
    dup_threshold: jax.Array  # (K,) f32 promotion near-dup overwrite gate
    rewrite: jax.Array       # (K,) bool — TweakLLM rewrite outcome on
    rewrite_rate: jax.Array  # (K,) f32 rewrite token budget per request

    @property
    def n(self) -> int:
        return int(self.tau_static.shape[0])


def sweep_from_configs(cfgs: Sequence[T.CacheConfig],
                       krites) -> SweepConfig:
    """Pack CacheConfigs (+ per-config or shared ``krites`` flag) into a
    SweepConfig."""
    kr = np.broadcast_to(np.asarray(krites, bool), (len(cfgs),))
    return SweepConfig(
        tau_static=jnp.asarray([c.tau_static for c in cfgs], jnp.float32),
        tau_dynamic=jnp.asarray([c.tau_dynamic for c in cfgs],
                                jnp.float32),
        sigma_min=jnp.asarray([c.sigma_min for c in cfgs], jnp.float32),
        judge_rate=jnp.asarray([c.judge_rate for c in cfgs], jnp.float32),
        capacity=jnp.asarray([c.capacity for c in cfgs], jnp.int32),
        judge_latency=jnp.asarray([c.judge_latency for c in cfgs],
                                  jnp.int32),
        krites=jnp.asarray(kr),
        dedup=jnp.asarray([c.dedup for c in cfgs], bool),
        l1=jnp.asarray([c.l1 for c in cfgs], bool),
        volatile_bypass=jnp.asarray([c.volatile_bypass for c in cfgs],
                                    bool),
        ttl_volatile=jnp.asarray([c.ttl_volatile for c in cfgs],
                                 jnp.int32),
        ttl_stable=jnp.asarray([c.ttl_stable for c in cfgs], jnp.int32),
        dup_threshold=jnp.asarray(
            [getattr(c, "dup_threshold", 0.9999) for c in cfgs],
            jnp.float32),
        rewrite=jnp.asarray([getattr(c, "rewrite", False) for c in cfgs],
                            bool),
        rewrite_rate=jnp.asarray(
            [getattr(c, "rewrite_rate", 1.0) for c in cfgs], jnp.float32),
    )


def sweep_grid(base: T.CacheConfig, krites=True, **axes) -> SweepConfig:
    """Cartesian product over ``axes`` (CacheConfig field name -> values),
    every other field taken from ``base``. Row-major: the last axis
    varies fastest, like ``itertools.product``."""
    import dataclasses
    names = list(axes)
    cfgs = [dataclasses.replace(base, **dict(zip(names, combo)))
            for combo in itertools.product(*(axes[n] for n in names))]
    return sweep_from_configs(cfgs, krites)


def _static_sims(static_emb: jax.Array, q_emb: jax.Array,
                 chunk: int = 2048):
    """Batched static-tier NN for the whole trace (hoisted lookup)."""
    n = q_emb.shape[0]
    pad = (-n) % chunk
    qp = jnp.pad(q_emb, ((0, pad), (0, 0)))

    def body(_, q):
        sims = q @ static_emb.T
        idx = jnp.argmax(sims, axis=1)
        return None, (jnp.take_along_axis(sims, idx[:, None], 1)[:, 0],
                      idx.astype(jnp.int32))

    _, (s, i) = jax.lax.scan(body, None,
                             qp.reshape(-1, chunk, q_emb.shape[1]))
    return s.reshape(-1)[:n], i.reshape(-1)[:n]


def _make_batched_tier(K: int, C: int, d: int) -> T.DynamicTier:
    """K per-config dynamic tiers as one batched struct-of-arrays."""
    return T.DynamicTier(
        emb=jnp.zeros((K, C, d), jnp.float32),
        cls=jnp.zeros((K, C), jnp.int32),
        answer_ref=jnp.full((K, C), -1, jnp.int32),
        static_origin=jnp.zeros((K, C), bool),
        valid=jnp.zeros((K, C), bool),
        last_used=jnp.zeros((K, C), jnp.int32),
        written_at=jnp.zeros((K, C), jnp.int32),
        expires_at=jnp.zeros((K, C), jnp.int32),
    )


def _lru_slots(live, last_used, cap) -> jax.Array:
    """Batched :func:`tiers._lru_slot`: first non-live row, else LRU,
    restricted to rows [0, cap_k) per config. (K,) int32. ``live`` is
    validity net of per-entry expiry (an expired row is reclaimable,
    exactly like the live policy after its eager sweep)."""
    C = live.shape[1]
    key = jnp.where(live, last_used, -T.BIG)
    key = jnp.where(jnp.arange(C)[None, :] < cap[:, None], key, T.BIG)
    return jnp.argmin(key, axis=1).astype(jnp.int32)


def _row_write(dyn: T.DynamicTier, ks, slot, cond, q, cls, ref, so,
               now, wa=None, exp=0) -> T.DynamicTier:
    """Conditionally write one tier row per config: semantically
    ``jnp.where(cond, T._write(...), dyn)`` but touching a single row per
    field (a K-row scatter) instead of copying whole tiers — the
    difference between O(K*d) and O(K*C*d) write traffic per scan step.

    ``q`` is (K, d) or broadcastable; ``cls``/``ref`` are (K,) or
    scalar; ``cond``/``slot`` are (K,). ``now`` stamps the LRU clock;
    ``wa`` (default ``now``) stamps ``written_at`` — promotions pass
    their *enqueue* time so the LWW guard clock matches the live
    policy's while the LRU clock stays the apply time. ``exp`` ((K,) or
    scalar) stamps the per-entry expiry clock (0 = never)."""
    qk = jnp.broadcast_to(q, dyn.emb.shape[:1] + dyn.emb.shape[2:])
    cond2 = cond[:, None]

    def upd(arr, new):
        old = arr[ks, slot]
        c = cond2 if arr.ndim == 3 else cond
        return arr.at[ks, slot].set(jnp.where(c, new, old))

    return T.DynamicTier(
        emb=upd(dyn.emb, qk),
        cls=upd(dyn.cls, jnp.broadcast_to(jnp.asarray(cls, jnp.int32),
                                          ks.shape)),
        answer_ref=upd(dyn.answer_ref,
                       jnp.broadcast_to(jnp.asarray(ref, jnp.int32),
                                        ks.shape)),
        static_origin=upd(dyn.static_origin, so),
        valid=upd(dyn.valid, True),
        last_used=upd(dyn.last_used, now),
        written_at=upd(dyn.written_at, now if wa is None else wa),
        expires_at=upd(dyn.expires_at,
                       jnp.broadcast_to(jnp.asarray(exp, jnp.int32),
                                        ks.shape)),
    )


def _scan_core(s_static, h_cls, h_idx, q_emb, q_cls, judge_flip,
               volatile, key_id, rewritable,
               tau_s, tau_d, sigma, rate, cap, lat, kr, dd,
               l1f, vbp, ttl_v, ttl_s, dupt, rw, rrate,
               C: int, R: int, D: int, nk: int,
               use_l1: bool, use_ttl: bool, use_rw: bool) -> SimResult:
    """All K configs' full-trace scan, in explicit batched form — the
    general path that supports *per-config* judge_latency (uniform
    sweeps take :func:`_scan_core_blocked` instead).

    Config scalars arrive as (K,) traced arrays; only shapes (K, C, R,
    nk, trace length) and the feature gates (D, use_l1, use_ttl) are
    static — with every freshness feature off, the compiled program is
    the pre-§16 one. Each step does one serving lookup (one gemv over
    the batched tier, shared query) and one promotion-dedup lookup
    (batched per-config queries). The tier row promoted this step is
    excluded from the shared pre-write pass and patched back in as one
    O(d) candidate, which reproduces the post-write argmax exactly
    (lowest-index tie-break included). See DESIGN.md §10.

    Freshness semantics (§16), matching the live policy and the numpy
    reference: per-entry expiry is *lazy* — an entry with
    ``0 < expires_at < t`` is masked from every lookup and becomes an
    immediate LRU reclaim candidate, which is observationally identical
    to the live policy's eager sweep; ``ttl_evicted`` counts each such
    death once, at its first expired step. Volatile bypass serves the
    backend with no cache side effects at all; an L1 hit serves the
    stored answer with no tier traffic; both are decided before the
    semantic path.
    """
    N, d = q_emb.shape
    K = tau_s.shape[0]
    ks = jnp.arange(K)
    lat = jnp.clip(jnp.asarray(lat, jnp.int32), 1, R)

    state = SimState(
        dyn=_make_batched_tier(K, C, d),
        ring=jnp.zeros((K, R), bool),
        budget=jnp.full((K,), 1.0, jnp.float32),
        t=jnp.int32(0),
        judge_calls=jnp.zeros((K,), jnp.int32),
        judge_approved=jnp.zeros((K,), jnp.int32),
        promotions=jnp.zeros((K,), jnp.int32),
        enq_dropped=jnp.zeros((K,), jnp.int32),
        l1_exp=jnp.zeros((K, nk), jnp.int32),
        l1_w=jnp.zeros((K, nk), jnp.int32),
        l1_ok=jnp.zeros((K, nk), bool),
        l1_so=jnp.zeros((K, nk), bool),
        ttl_evicted=jnp.zeros((K,), jnp.int32),
        bypassed=jnp.zeros((K,), jnp.int32),
        rbud=jnp.zeros((K,), jnp.float32),
        rewrites=jnp.zeros((K,), jnp.int32),
        rewrite_dropped=jnp.zeros((K,), jnp.int32),
    )

    def epoch(x):
        return x // D

    def step(st: SimState, xs):
        q, qc, ss, hc, vol, kid = xs
        t = st.t
        dyn = st.dyn

        # ---- 0. per-entry expiry: the lazy mask + the once-per-death
        # eviction count (an entry dies the first step past its expiry;
        # counted before any write can reuse its slot this step)
        if use_ttl:
            exp = dyn.expires_at
            live = jnp.logical_and(
                dyn.valid, jnp.logical_or(exp == 0, t <= exp))
            ttl_evicted = st.ttl_evicted + jnp.sum(
                jnp.logical_and(dyn.valid,
                                jnp.logical_and(exp > 0, t == exp + 1)),
                axis=1).astype(jnp.int32)
        else:
            live = dyn.valid
            ttl_evicted = st.ttl_evicted

        # ---- 1. async completion due now. The task due at step t is the
        # one enqueued at t - latency (exactly one candidate per step:
        # one enqueue per step, constant per-config latency), so its
        # payload is re-gathered from the shared trace.
        idx_due = t - lat                                   # (K,)
        due = jnp.logical_and(st.ring[ks, jnp.mod(idx_due, R)],
                              idx_due >= 0)
        src = jnp.clip(idx_due, 0)
        p_qc, p_hc, p_hr = q_cls[src], h_cls[src], h_idx[src]
        approve = jnp.logical_and(
            due, jnp.logical_or(p_qc == p_hc, judge_flip[src]))
        # REWRITE verdict (DESIGN.md §18): a would-reject pair whose
        # ``rewritable`` channel is set promotes the *tailored* variant
        # instead — keyed to the query's embedding and class, with the
        # answer_ref = -2 provenance sentinel. The rewrite token bucket
        # refills every step at this completion point and spends one
        # token per rewrite (the numpy reference mirrors both exactly).
        if use_rw:
            rbud = jnp.minimum(st.rbud + rrate, 1e9)
            rw_want = jnp.logical_and(
                jnp.logical_and(due,
                                ~jnp.logical_or(p_qc == p_hc,
                                                judge_flip[src])),
                jnp.logical_and(rewritable[src], rw))
            rw_can = jnp.logical_and(rw_want, rbud >= 1.0)
            rbud = jnp.where(rw_can, rbud - 1.0, rbud)
        else:
            rbud = st.rbud
            rw_want = rw_can = jnp.zeros((K,), bool)
        promo = jnp.logical_or(approve, rw_can)

        # ---- tier passes: serving sims (shared query) + promotion-dedup
        # sims (per-config delayed queries) ----
        emb2 = dyn.emb.reshape(K * C, d)
        promo_qk = q_emb[src]                               # (K, d)
        s_serve_raw = (emb2 @ q).reshape(K, C)
        s_promo_raw = jnp.einsum('kcd,kd->kc', dyn.emb, promo_qk)

        # inlined T.upsert semantics (dedup overwrite + LWW guard) as one
        # conditional K-row write, on the pre-write tier
        s_promo = jnp.where(live, s_promo_raw, -jnp.inf)
        j_dup = jnp.argmax(s_promo, axis=1)
        dup = jnp.take_along_axis(s_promo, j_dup[:, None], 1)[:, 0] \
            >= dupt
        pslot = jnp.where(dup, j_dup, _lru_slots(live,
                                                 dyn.last_used, cap))
        # LWW guard against the task's *enqueue* time (idx_due), and the
        # promotion's own written_at records that enqueue time, while its
        # LRU clock is the apply step t — the live `_promote` clock split
        stale_w = jnp.logical_and(dup,
                                  dyn.written_at[ks, j_dup] > idx_due)
        do_promote = jnp.logical_and(promo, ~stale_w)
        if use_ttl:
            # the judge's TTL verdict: expiry anchors at enqueue time
            # (it is what the promotion WAL records); a verdict that
            # outlived its own TTL is dropped, like the live _promote
            tau_p = jnp.where(volatile[src], ttl_v, ttl_s)
            exp_p = jnp.where(tau_p > 0, idx_due + tau_p, 0)
            do_promote = jnp.logical_and(
                do_promote,
                ~jnp.logical_and(exp_p > 0, exp_p < t))
        else:
            exp_p = jnp.zeros((K,), jnp.int32)
        p_cls = jnp.where(rw_can, p_qc, p_hc) if use_rw else p_hc
        p_ref = jnp.where(rw_can, jnp.int32(-2), p_hr) if use_rw else p_hr
        dyn = _row_write(dyn, ks, pslot, do_promote, promo_qk, p_cls,
                         p_ref, True, t, wa=idx_due, exp=exp_p)
        judge_calls = st.judge_calls + due.astype(jnp.int32)
        judge_approved = st.judge_approved + approve.astype(jnp.int32)
        promotions = st.promotions + promo.astype(jnp.int32)
        rewrites = st.rewrites + rw_can.astype(jnp.int32)
        rewrite_dropped = st.rewrite_dropped \
            + jnp.logical_and(rw_want, ~rw_can).astype(jnp.int32)

        # ---- 1b. freshness front: volatile bypass, then the L1 exact-
        # match probe — both decided before the semantic path, with no
        # tier traffic (matching the live serve() ordering)
        byp = jnp.logical_and(vbp, vol)                     # (K,)
        if use_l1:
            le = st.l1_exp[:, kid]                          # (K,)
            l1hit = jnp.logical_and(
                l1f, jnp.logical_and(~byp,
                                     jnp.logical_and(le > 0, t <= le)))
            l1_ok_col = st.l1_ok[:, kid]
            l1_so_col = st.l1_so[:, kid]
            l1_w_col = st.l1_w[:, kid]
        else:
            l1hit = jnp.zeros((K,), bool)
            l1_ok_col = l1_so_col = jnp.zeros((K,), bool)
            l1_w_col = jnp.zeros((K,), jnp.int32)
        front = jnp.logical_or(byp, l1hit)

        # ---- 2. serving path (identical for baseline and Krites).
        # The shared sims are pre-promotion: mask out the row just
        # promoted (its sims entry is stale) and compare its fresh
        # similarity as the one external candidate. Exactly reproduces
        # argmax over the post-write tier, including first-index
        # tie-breaking, because the candidate is the only changed row.
        promoted_col = jnp.logical_and(
            do_promote[:, None], jnp.arange(C)[None, :] == pslot[:, None])
        s_serve = jnp.where(jnp.logical_and(live, ~promoted_col),
                            s_serve_raw, -jnp.inf)
        j0 = jnp.argmax(s_serve, axis=1)
        s0 = jnp.take_along_axis(s_serve, j0[:, None], 1)[:, 0]
        patch_sim = promo_qk @ q                            # (K,)
        cand = jnp.logical_and(
            do_promote,
            jnp.logical_or(patch_sim > s0,
                           jnp.logical_and(patch_sim == s0, pslot < j0)))
        s_dyn = jnp.where(cand, patch_sim, s0)
        j_dyn = jnp.where(cand, pslot, j0).astype(jnp.int32)

        static_hit_sem = ss >= tau_s
        dyn_hit_sem = jnp.logical_and(~static_hit_sem, s_dyn >= tau_d)
        static_hit = jnp.logical_and(static_hit_sem, ~front)
        dyn_hit = jnp.logical_and(dyn_hit_sem, ~front)
        miss_wb = jnp.logical_and(
            ~front, jnp.logical_and(~static_hit_sem, ~dyn_hit_sem))

        cls_j = dyn.cls[ks, j_dyn]
        wa_j = dyn.written_at[ks, j_dyn]
        served_cls = jnp.where(static_hit, hc,
                               jnp.where(dyn_hit, cls_j, qc))
        is_promoted = jnp.logical_and(dyn_hit,
                                      dyn.static_origin[ks, j_dyn])
        # rewritten provenance rides the answer_ref = -2 sentinel; a
        # rewritten row is a promoted row (static_origin True) with the
        # more specific event code
        if use_rw:
            is_rewritten = jnp.logical_and(
                dyn_hit, dyn.answer_ref[ks, j_dyn] == -2)
        else:
            is_rewritten = jnp.zeros((K,), bool)
        served_by = jnp.where(
            l1hit, L1_HIT,
            jnp.where(static_hit, STATIC_HIT,
                      jnp.where(is_rewritten, REWRITTEN_HIT,
                                jnp.where(is_promoted, DYN_HIT_PROMOTED,
                                          jnp.where(dyn_hit,
                                                    DYN_HIT_DYNAMIC,
                                                    MISS))))
        ).astype(jnp.int8)
        correct = jnp.where(l1hit, l1_ok_col, served_cls == qc)
        static_origin = jnp.where(
            l1hit, l1_so_col, jnp.logical_or(static_hit, is_promoted))

        # drift staleness: a volatile query served content produced in
        # an earlier drift epoch (static corpus content is epoch 0;
        # backend answers are current by definition)
        if D > 0:
            stale = jnp.logical_and(vol, jnp.where(
                l1hit, epoch(t) != epoch(l1_w_col),
                jnp.where(static_hit, epoch(t) != 0,
                          jnp.where(dyn_hit, epoch(t) != epoch(wa_j),
                                    False))))
        else:
            stale = jnp.zeros((K,), bool)

        # LRU touch on dynamic hit (single-row conditional update)
        dyn = dyn._replace(last_used=dyn.last_used.at[ks, j_dyn].set(
            jnp.where(dyn_hit, t, dyn.last_used[ks, j_dyn])))
        # baseline write-back on miss (backend answer has the query's
        # class); its lifetime is the query's staleness-risk TTL
        if use_ttl:
            live2 = jnp.logical_and(
                dyn.valid, jnp.logical_or(dyn.expires_at == 0,
                                          t <= dyn.expires_at))
            tau_q = jnp.where(vol, ttl_v, ttl_s)
            exp_i = jnp.where(tau_q > 0, t + tau_q, 0)
        else:
            live2 = dyn.valid
            tau_q = jnp.zeros((K,), jnp.int32)
            exp_i = jnp.zeros((K,), jnp.int32)
        dyn = _row_write(dyn, ks,
                         _lru_slots(live2, dyn.last_used, cap),
                         miss_wb, q, qc, jnp.int32(-1), False, t,
                         exp=exp_i)

        # ---- 2b. L1 write-back: every semantic serve lands in the L1
        # under the query's exact key (never refreshed by later hits —
        # the stored content clock is what staleness is judged against)
        if use_l1:
            do_l1w = jnp.logical_and(
                l1f, jnp.logical_and(~byp, ~l1hit))
            content_t = jnp.where(static_hit, 0,
                                  jnp.where(dyn_hit, wa_j, t))
            exp_l1 = jnp.where(tau_q > 0, t + tau_q, _L1_NEVER)
            l1_exp = st.l1_exp.at[:, kid].set(
                jnp.where(do_l1w, exp_l1, st.l1_exp[:, kid]))
            l1_w = st.l1_w.at[:, kid].set(
                jnp.where(do_l1w, content_t, l1_w_col))
            l1_ok = st.l1_ok.at[:, kid].set(
                jnp.where(do_l1w, correct, l1_ok_col))
            l1_so = st.l1_so.at[:, kid].set(
                jnp.where(do_l1w, static_origin, l1_so_col))
        else:
            l1_exp, l1_w = st.l1_exp, st.l1_w
            l1_ok, l1_so = st.l1_ok, st.l1_so

        # ---- 3. grey-zone trigger (Krites only; off-path). Front-
        # resolved requests never embed, so they can never trigger.
        grey = jnp.logical_and(ss >= sigma, ss < tau_s)
        want = jnp.logical_and(jnp.logical_and(grey, kr), ~front)
        # dedup: skip if a promoted pointer already serves this query
        want = jnp.logical_and(
            want, ~jnp.logical_and(
                dd, jnp.logical_and(is_promoted, s_dyn >= tau_d)))
        budget = jnp.minimum(st.budget + rate, 1e9)
        can = jnp.logical_and(want, budget >= 1.0)
        budget = jnp.where(can, budget - 1.0, budget)
        # enqueue = set bit (k, t mod R); the slot's previous occupant was
        # consumed at its due step (R >= latency), so plain overwrite
        ring = st.ring.at[:, jnp.mod(t, R)].set(can)

        new_state = SimState(
            dyn=dyn, ring=ring, budget=budget, t=t + 1,
            judge_calls=judge_calls, judge_approved=judge_approved,
            promotions=promotions,
            enq_dropped=st.enq_dropped
            + jnp.logical_and(want, ~can).astype(jnp.int32),
            l1_exp=l1_exp, l1_w=l1_w, l1_ok=l1_ok, l1_so=l1_so,
            ttl_evicted=ttl_evicted,
            bypassed=st.bypassed + byp.astype(jnp.int32),
            rbud=rbud, rewrites=rewrites,
            rewrite_dropped=rewrite_dropped)
        return new_state, (served_by, correct, static_origin, stale)

    # the pending-queue payloads (h_idx, judge_flip, classes) are
    # re-gathered from the closed-over trace at completion time, so the
    # per-step xs carry only what the serving decision itself reads
    xs = (q_emb, q_cls, s_static, h_cls, volatile, key_id)
    final, (served_by, correct, static_origin, stale) = jax.lax.scan(
        step, state, xs)
    # ys stack as (N, K): transpose to the (K, N) config-major layout
    return SimResult(served_by.T, correct.T, static_origin.T, stale.T,
                     final.judge_calls, final.judge_approved,
                     final.promotions, final.enq_dropped,
                     final.ttl_evicted, final.bypassed,
                     final.rewrites, final.rewrite_dropped)


_BLOCK = 64  # blocked-core window; per-block sims buffer = 2*B*K*C fp32


def _scan_core_blocked(s_static, h_cls, h_idx, q_emb, q_cls, judge_flip,
                       volatile, key_id, rewritable,
                       tau_s, tau_d, sigma, rate, cap, lat, kr, dd,
                       l1f, vbp, ttl_v, ttl_s, dupt, rw, rrate,
                       C: int, R: int, D: int, nk: int,
                       use_l1: bool, use_ttl: bool,
                       use_rw: bool) -> SimResult:
    """Blocked variant of :func:`_scan_core` for the common case where
    every swept config shares one judge_latency.

    The per-step tier pass of the stepwise core is memory-bound: each
    request re-reads all K*C*d tier embeddings twice (serving + dedup
    lookup) through a gemv. Here the trace is processed in windows of
    B = _BLOCK requests and the tier embeddings are read once per
    window via two gemms:

      snap = [Q_block ; Q_block_delayed] @ tier_snapshot.T   (2B, K*C)
      QQ   = Qstack @ Qstack.T                               (2B, 2B)

    which is exact because *every row written during a window is a trace
    element*: a miss inserts the current query q_t, a promotion inserts
    the delayed query q_{t-latency} (the task enqueued at t-latency IS
    request t-latency). A per-row registry ``dqi`` records which Qstack
    row overwrote a tier row this window, in three bands: [0, B) miss
    write-backs, [B, 2B) APPROVE promotions, [2B, 3B) REWRITE
    promotions (DESIGN.md §18) — the rewrite band shares the delayed
    query's embedding (Qstack row ``dqi - B``) but carries the query's
    class and the answer_ref = -2 provenance sentinel. A step's true
    similarity is
    then QQ[s, dqi] for window-written rows and snap[s] otherwise, and the
    full-array argmax keeps the exact lowest-index tie-break of the
    sequential simulator. Embeddings are materialized once at window end
    (one masked gather). Per-step work drops from O(K*C*d) to O(K*C),
    and the gemms run at matmul (not gemv) throughput — this is what
    buys the sweep its order-of-magnitude over the sequential loop
    (benchmarks/sweep.py).

    Freshness (§16): expiry is a third per-row carry ``expw`` (the
    window-current ``expires_at``, alive only when ``use_ttl``) because
    liveness must be consulted at every lookup/LRU decision; the L1
    front carries its four (K, nk) columns across steps like the
    stepwise core. All of it is gated on static flags so a
    freshness-free sweep compiles to the original program.
    """
    N, d = q_emb.shape
    K = tau_s.shape[0]
    B = _BLOCK
    NB = -(-N // B) * B
    ks = jnp.arange(K)
    lat0 = jnp.clip(jnp.asarray(lat, jnp.int32)[0], 1, R)

    pad = NB - N
    q_emb_p = jnp.pad(q_emb, ((0, pad), (0, 0)))
    q_cls_p = jnp.pad(q_cls, (0, pad))
    h_cls_p = jnp.pad(h_cls, (0, pad))
    h_idx_p = jnp.pad(h_idx, (0, pad))
    flip_p = jnp.pad(judge_flip, (0, pad))
    vol_p = jnp.pad(volatile, (0, pad))
    kid_p = jnp.pad(key_id, (0, pad))
    ss_p = jnp.pad(s_static, (0, pad), constant_values=-jnp.inf)
    # front-padded twins so the delayed window t0-lat .. t0+B-1-lat can be
    # dynamic-sliced with a nonnegative start (R >= lat); the zero rows
    # are only addressed while nothing is due (idx_due < 0)
    q_del_src = jnp.concatenate([jnp.zeros((R, d), q_emb.dtype), q_emb_p])
    qc_del_src = jnp.concatenate([jnp.zeros((R,), jnp.int32), q_cls_p])
    hc_del_src = jnp.concatenate([jnp.zeros((R,), jnp.int32), h_cls_p])
    hr_del_src = jnp.concatenate([jnp.zeros((R,), jnp.int32), h_idx_p])
    fl_del_src = jnp.concatenate([jnp.zeros((R,), bool), flip_p])
    vl_del_src = jnp.concatenate([jnp.zeros((R,), bool), vol_p])
    rw_p = jnp.pad(rewritable, (0, pad))
    rw_del_src = jnp.concatenate([jnp.zeros((R,), bool), rw_p])

    state = SimState(
        dyn=_make_batched_tier(K, C, d),
        ring=jnp.zeros((K, R), bool),
        budget=jnp.full((K,), 1.0, jnp.float32),
        t=jnp.int32(0),
        judge_calls=jnp.zeros((K,), jnp.int32),
        judge_approved=jnp.zeros((K,), jnp.int32),
        promotions=jnp.zeros((K,), jnp.int32),
        enq_dropped=jnp.zeros((K,), jnp.int32),
        l1_exp=jnp.zeros((K, nk), jnp.int32),
        l1_w=jnp.zeros((K, nk), jnp.int32),
        l1_ok=jnp.zeros((K, nk), bool),
        l1_so=jnp.zeros((K, nk), bool),
        ttl_evicted=jnp.zeros((K,), jnp.int32),
        bypassed=jnp.zeros((K,), jnp.int32),
        rbud=jnp.zeros((K,), jnp.float32),
        rewrites=jnp.zeros((K,), jnp.int32),
        rewrite_dropped=jnp.zeros((K,), jnp.int32),
    )

    iota_c = jnp.arange(C)[None, :]

    def epoch(x):
        return x // D

    def block(st: SimState, xs):
        qb, qcb, ssb, hcb, volb, kidb = xs   # (B, ...) current window
        t0 = st.t
        dyn = st.dyn

        # delayed window (promotion payloads), sliced once per block
        start = t0 - lat0 + R
        q_del = jax.lax.dynamic_slice(q_del_src, (start, 0), (B, d))
        p_qc = jax.lax.dynamic_slice(qc_del_src, (start,), (B,))
        p_hc = jax.lax.dynamic_slice(hc_del_src, (start,), (B,))
        p_hr = jax.lax.dynamic_slice(hr_del_src, (start,), (B,))
        p_fl = jax.lax.dynamic_slice(fl_del_src, (start,), (B,))
        p_vl = jax.lax.dynamic_slice(vl_del_src, (start,), (B,))
        p_rw = jax.lax.dynamic_slice(rw_del_src, (start,), (B,))

        qstack = jnp.concatenate([qb, q_del])            # (2B, d)
        snap = (qstack @ dyn.emb.reshape(K * C, d).T
                ).reshape(2 * B, K, C)
        qq = qstack @ qstack.T                           # (2B, 2B)

        # window-start snapshots (read-only inside the window). The only
        # per-step (K, C) carries are `key` (the LRU ordering) and `dqi`
        # (which Qstack row rewrote a tier row this window, -1 if none);
        # everything else about a rewritten row — validity, class,
        # provenance, write time, embedding — is *derived from dqi* at
        # read time and materialized once at window end. Mutating a
        # (K, C) carry costs a full copy per step on CPU, so carrying two
        # instead of seven is most of the blocked core's speedup.
        valid0, cls0, so0, wa0 = (dyn.valid, dyn.cls, dyn.static_origin,
                                  dyn.written_at)
        # rewrite provenance snapshot: rows not rewritten this window
        # read the tier's answer_ref == -2 sentinel (§18)
        rw0 = dyn.answer_ref == -2
        key0 = jnp.where(iota_c < cap[:, None],
                         jnp.where(valid0, dyn.last_used, -T.BIG), T.BIG)
        # window-current expiry carry (only consulted when use_ttl): a
        # real (K, C) carry rather than a dqi-derivation because every
        # lookup and LRU decision reads liveness, and the write points
        # already update key/dqi at the same spots
        exp0 = dyn.expires_at if use_ttl \
            else jnp.zeros((K, 1), jnp.int32)

        def wa_of(dqi_row, wa_snap):
            """Current written_at of gathered rows. A miss row written
            this window (dqi < B) carries its write step t0 + dqi; a
            promotion row (dqi >= B, applied at step t0 + dqi - B)
            carries its *enqueue* time, lat0 earlier — the live
            ``_promote`` clock split (LWW compares enqueue times)."""
            w = jnp.mod(dqi_row, B)
            wa_win = jnp.where(dqi_row < B, t0 + w, t0 + w - lat0)
            return jnp.where(dqi_row >= 0, wa_win, wa_snap)

        def qrow(dqi_arr):
            # Qstack row of a window-written tier row: the rewrite band
            # [2B, 3B) shares the delayed query's embedding at dqi - B
            if use_rw:
                dqi_arr = jnp.where(dqi_arr < 2 * B, dqi_arr, dqi_arr - B)
            return jnp.clip(dqi_arr, 0)

        def step(carry, sxs):
            (key, dqi, expw, ring, budget, jc, ja, pr, drop, tev, byc,
             l1e, l1w, l1ok, l1so, rbud, rwc, rwd) = carry
            (s_idx, qc, ss, hc, vol, kid, snap_cur, snap_del, qq_cur,
             qq_del, pqc, phc, phr, pfl, pvl, prw) = sxs
            t = t0 + s_idx
            active = t < N
            written = dqi >= 0
            dq = qrow(dqi)
            valid = jnp.logical_or(valid0, written)
            if use_ttl:
                live = jnp.logical_and(
                    valid, jnp.logical_or(expw == 0, t <= expw))
                tev = tev + jnp.where(active, jnp.sum(
                    jnp.logical_and(valid, jnp.logical_and(
                        expw > 0, t == expw + 1)),
                    axis=1).astype(jnp.int32), 0)
            else:
                live = valid

            # ---- 1. async completion due now (= request t - latency) --
            idx_due = t - lat0
            due = jnp.logical_and(
                ring[:, jnp.mod(idx_due, R)],
                jnp.logical_and(idx_due >= 0, active))
            approve = jnp.logical_and(
                due, jnp.logical_or(pqc == phc, pfl))
            # rewrite verdict (§18): a would-reject whose request was
            # marked rewritable spends the rewrite token bucket and
            # promotes a tailored variant instead of dropping the work
            if use_rw:
                rbud_new = jnp.minimum(rbud + rrate, 1e9)
                rw_want = jnp.logical_and(
                    jnp.logical_and(due,
                                    ~jnp.logical_or(pqc == phc, pfl)),
                    jnp.logical_and(prw, rw))
                rw_can = jnp.logical_and(rw_want, rbud_new >= 1.0)
                rbud_new = jnp.where(rw_can, rbud_new - 1.0, rbud_new)
                rbud = jnp.where(active, rbud_new, rbud)
            else:
                rw_want = rw_can = jnp.zeros((K,), bool)
            promo = jnp.logical_or(approve, rw_can)

            # promotion-dedup lookup on the combined sims (T.upsert
            # semantics: near-dup overwrite + LWW guard). The LRU argmin
            # rides in the same fused reduction as a -key lane: int32
            # keys here are {-BIG, lu <= N < 2^24, BIG}, all exact in
            # f32, and argmax(-key) keeps argmin's first-index tie-break.
            # Expired rows are masked from the dedup sims and demoted to
            # immediate-reclaim (-BIG) in the key lane.
            s_promo = jnp.where(live,
                                jnp.where(written, qq_del[dq], snap_del),
                                -jnp.inf)
            if use_ttl:
                key_eff = jnp.where(
                    jnp.logical_and(key < T.BIG, ~live), -T.BIG, key)
            else:
                key_eff = key
            both = jnp.stack([s_promo, -key_eff.astype(jnp.float32)], 1)
            jj = jnp.argmax(both, axis=2).astype(jnp.int32)   # (K, 2)
            j_dup = jj[:, 0]
            dup = jnp.take_along_axis(s_promo, j_dup[:, None], 1)[:, 0] \
                >= dupt
            pslot = jnp.where(dup, j_dup, jj[:, 1])
            stale_w = jnp.logical_and(
                dup, wa_of(dqi[ks, j_dup], wa0[ks, j_dup]) > idx_due)
            do_promote = jnp.logical_and(promo, ~stale_w)
            if use_ttl:
                tau_p = jnp.where(pvl, ttl_v, ttl_s)
                exp_p = jnp.where(tau_p > 0, idx_due + tau_p, 0)
                do_promote = jnp.logical_and(
                    do_promote,
                    ~jnp.logical_and(exp_p > 0, exp_p < t))
            p_hot = jnp.logical_and(do_promote[:, None],
                                    iota_c == pslot[:, None])
            key = jnp.where(p_hot, t, key)
            if use_rw:
                dqi = jnp.where(
                    p_hot,
                    jnp.where(rw_can, 2 * B + s_idx, B + s_idx)[:, None],
                    dqi)
            else:
                dqi = jnp.where(p_hot, B + s_idx, dqi)
            if use_ttl:
                expw = jnp.where(p_hot, exp_p[:, None], expw)
            written = dqi >= 0
            dq = qrow(dqi)
            valid = jnp.logical_or(valid0, written)
            if use_ttl:
                live = jnp.logical_and(
                    valid, jnp.logical_or(expw == 0, t <= expw))
            else:
                live = valid
            jc = jc + due.astype(jnp.int32)
            ja = ja + approve.astype(jnp.int32)
            pr = pr + promo.astype(jnp.int32)
            rwc = rwc + rw_can.astype(jnp.int32)
            rwd = rwd + jnp.logical_and(rw_want, ~rw_can).astype(
                jnp.int32)

            # ---- 1b. freshness front (bypass + L1 probe), decided
            # before the semantic path like the live serve()
            byp = jnp.logical_and(jnp.logical_and(vbp, vol), active)
            if use_l1:
                le = l1e[:, kid]
                l1hit = jnp.logical_and(
                    jnp.logical_and(l1f, active),
                    jnp.logical_and(~byp, jnp.logical_and(le > 0,
                                                          t <= le)))
                l1_ok_col, l1_so_col = l1ok[:, kid], l1so[:, kid]
                l1_w_col = l1w[:, kid]
            else:
                l1hit = jnp.zeros((K,), bool)
                l1_ok_col = l1_so_col = jnp.zeros((K,), bool)
                l1_w_col = jnp.zeros((K,), jnp.int32)
            front = jnp.logical_or(byp, l1hit)

            # ---- 2. serving path (sees this step's promotion: dqi was
            # updated above, so the promoted row reads QQ, not snap) ----
            s_serve = jnp.where(live,
                                jnp.where(written, qq_cur[dq], snap_cur),
                                -jnp.inf)
            j_dyn = jnp.argmax(s_serve, axis=1).astype(jnp.int32)
            s_dyn = jnp.take_along_axis(s_serve, j_dyn[:, None], 1)[:, 0]

            static_hit = jnp.logical_and(ss >= tau_s, ~front)
            dyn_hit = jnp.logical_and(
                jnp.logical_and(~(ss >= tau_s), s_dyn >= tau_d), ~front)
            miss = jnp.logical_and(
                active, jnp.logical_and(
                    ~front, jnp.logical_and(~(ss >= tau_s),
                                            ~(s_dyn >= tau_d))))
            dyn_hit = jnp.logical_and(dyn_hit, active)

            # winning row's class/provenance, derived from dqi: window
            # rows carry the writing request's payload
            dqi_j = dqi[ks, j_dyn]
            w_j = jnp.mod(dqi_j, B)
            # promotion bands carry the delayed payload: the static
            # neighbor's class for APPROVE, the query's own class for
            # REWRITE (the tailored answer targets the new prompt)
            cls_win = jnp.where(dqi_j < 2 * B, p_hc[w_j], p_qc[w_j]) \
                if use_rw else p_hc[w_j]
            cls_j = jnp.where(dqi_j < 0, cls0[ks, j_dyn],
                              jnp.where(dqi_j < B, qcb[jnp.clip(w_j, 0)],
                                        cls_win))
            so_j = jnp.where(dqi_j < 0, so0[ks, j_dyn], dqi_j >= B)
            wa_j = wa_of(dqi_j, wa0[ks, j_dyn])
            if use_rw:
                is_rewritten = jnp.logical_and(
                    dyn_hit,
                    jnp.where(dqi_j < 0, rw0[ks, j_dyn],
                              dqi_j >= 2 * B))
            else:
                is_rewritten = jnp.zeros((K,), bool)

            served_cls = jnp.where(static_hit, hc,
                                   jnp.where(dyn_hit, cls_j, qc))
            is_promoted = jnp.logical_and(dyn_hit, so_j)
            served_by = jnp.where(
                l1hit, L1_HIT,
                jnp.where(static_hit, STATIC_HIT,
                          jnp.where(is_rewritten, REWRITTEN_HIT,
                                    jnp.where(is_promoted,
                                              DYN_HIT_PROMOTED,
                                              jnp.where(dyn_hit,
                                                        DYN_HIT_DYNAMIC,
                                                        MISS))))
                          ).astype(jnp.int8)
            correct = jnp.where(l1hit, l1_ok_col, served_cls == qc)
            static_origin = jnp.where(
                l1hit, l1_so_col,
                jnp.logical_or(static_hit, is_promoted))
            if D > 0:
                stale = jnp.logical_and(
                    jnp.logical_and(vol, active), jnp.where(
                        l1hit, epoch(t) != epoch(l1_w_col),
                        jnp.where(static_hit, epoch(t) != 0,
                                  jnp.where(dyn_hit,
                                            epoch(t) != epoch(wa_j),
                                            False))))
            else:
                stale = jnp.zeros((K,), bool)

            # LRU touch, then write-back on miss (with the query's
            # staleness-risk TTL when the subsystem is on)
            key = jnp.where(jnp.logical_and(dyn_hit[:, None],
                                            iota_c == j_dyn[:, None]),
                            t, key)
            if use_ttl:
                tau_q = jnp.where(vol, ttl_v, ttl_s)
                key_eff = jnp.where(
                    jnp.logical_and(key < T.BIG, ~live), -T.BIG, key)
            else:
                tau_q = jnp.zeros((K,), jnp.int32)
                key_eff = key
            islot = jnp.argmin(key_eff, axis=1).astype(jnp.int32)
            i_hot = jnp.logical_and(miss[:, None],
                                    iota_c == islot[:, None])
            key = jnp.where(i_hot, t, key)
            dqi = jnp.where(i_hot, s_idx, dqi)
            if use_ttl:
                exp_i = jnp.where(tau_q > 0, t + tau_q, 0)
                expw = jnp.where(i_hot, exp_i[:, None], expw)

            # ---- 2b. L1 write-back on every semantic serve ----
            if use_l1:
                do_l1w = jnp.logical_and(
                    jnp.logical_and(l1f, active),
                    jnp.logical_and(~byp, ~l1hit))
                content_t = jnp.where(static_hit, 0,
                                      jnp.where(dyn_hit, wa_j, t))
                exp_l1 = jnp.where(tau_q > 0, t + tau_q, _L1_NEVER)
                l1e = l1e.at[:, kid].set(
                    jnp.where(do_l1w, exp_l1, l1e[:, kid]))
                l1w = l1w.at[:, kid].set(
                    jnp.where(do_l1w, content_t, l1_w_col))
                l1ok = l1ok.at[:, kid].set(
                    jnp.where(do_l1w, correct, l1_ok_col))
                l1so = l1so.at[:, kid].set(
                    jnp.where(do_l1w, static_origin, l1_so_col))
            byc = byc + byp.astype(jnp.int32)

            # ---- 3. grey-zone trigger ----
            grey = jnp.logical_and(ss >= sigma, ss < tau_s)
            want = jnp.logical_and(jnp.logical_and(grey, kr), active)
            want = jnp.logical_and(want, ~front)
            # dedup: skip if a promoted pointer already serves this query
            want = jnp.logical_and(
                want, ~jnp.logical_and(
                    dd, jnp.logical_and(is_promoted, s_dyn >= tau_d)))
            new_budget = jnp.minimum(budget + rate, 1e9)
            can = jnp.logical_and(want, new_budget >= 1.0)
            new_budget = jnp.where(can, new_budget - 1.0, new_budget)
            budget = jnp.where(active, new_budget, budget)
            ring = ring.at[:, jnp.mod(t, R)].set(can)
            drop = drop + jnp.logical_and(want, ~can).astype(jnp.int32)

            return ((key, dqi, expw, ring, budget, jc, ja, pr, drop,
                     tev, byc, l1e, l1w, l1ok, l1so, rbud, rwc, rwd),
                    (served_by, correct, static_origin, stale))

        carry0 = (key0, jnp.full((K, C), -1, jnp.int32), exp0,
                  st.ring, st.budget, st.judge_calls, st.judge_approved,
                  st.promotions, st.enq_dropped, st.ttl_evicted,
                  st.bypassed, st.l1_exp, st.l1_w, st.l1_ok, st.l1_so,
                  st.rbud, st.rewrites, st.rewrite_dropped)
        sxs = (jnp.arange(B, dtype=jnp.int32), qcb, ssb, hcb, volb, kidb,
               snap[:B], snap[B:], qq[:B], qq[B:],
               p_qc, p_hc, p_hr, p_fl, p_vl, p_rw)
        ((key, dqi, expw, ring, budget, jc, ja, pr, drop, tev, byc,
          l1e, l1w, l1ok, l1so, rbud, rwc, rwd),
         ys) = jax.lax.scan(step, carry0, sxs)

        # materialize this window's row writes into the tier
        mask = dqi >= 0
        w = jnp.mod(dqi, B)
        emb = jnp.where(mask[:, :, None], qstack[qrow(dqi)],
                        dyn.emb)
        cls_win_a = jnp.where(dqi < 2 * B, p_hc[w], p_qc[w]) \
            if use_rw else p_hc[w]
        cls_a = jnp.where(mask, jnp.where(dqi < B, qcb[jnp.clip(w, 0)],
                                          cls_win_a), cls0)
        ref_win_a = jnp.where(dqi < 2 * B, p_hr[w], -2) \
            if use_rw else p_hr[w]
        ref_a = jnp.where(mask, jnp.where(dqi < B, -1, ref_win_a),
                          dyn.answer_ref)
        so_a = jnp.where(mask, dqi >= B, so0)
        # promotion rows record their enqueue time (apply - lat0), miss
        # rows their write step — mirrors wa_of above
        wa_a = jnp.where(mask,
                         jnp.where(dqi < B, t0 + w, t0 + w - lat0), wa0)
        valid_a = jnp.logical_or(dyn.valid, mask)
        # the expiry carry already reflects every write this window
        exp_a = expw if use_ttl else dyn.expires_at
        # rows neither touched nor written kept their old clock; key holds
        # the new clock for everything else (sentinels mark untouched
        # invalid rows and rows beyond this config's capacity)
        lu_a = jnp.where(jnp.logical_and(key > -T.BIG, key < T.BIG),
                         key, dyn.last_used)
        new_dyn = T.DynamicTier(emb=emb, cls=cls_a, answer_ref=ref_a,
                                static_origin=so_a, valid=valid_a,
                                last_used=lu_a, written_at=wa_a,
                                expires_at=exp_a)
        new_state = SimState(dyn=new_dyn, ring=ring, budget=budget,
                             t=t0 + B, judge_calls=jc, judge_approved=ja,
                             promotions=pr, enq_dropped=drop,
                             l1_exp=l1e, l1_w=l1w, l1_ok=l1ok,
                             l1_so=l1so, ttl_evicted=tev, bypassed=byc,
                             rbud=rbud, rewrites=rwc,
                             rewrite_dropped=rwd)
        return new_state, ys

    xs = tuple(a.reshape((NB // B, B) + a.shape[1:])
               for a in (q_emb_p, q_cls_p, ss_p, h_cls_p, vol_p, kid_p))
    final, (served_by, correct, static_origin, stale) = jax.lax.scan(
        block, state, xs)
    # (nb, B, K) -> (K, N)
    unblock = lambda a: a.reshape(NB, K)[:N].T
    return SimResult(unblock(served_by), unblock(correct),
                     unblock(static_origin), unblock(stale),
                     final.judge_calls, final.judge_approved,
                     final.promotions, final.enq_dropped,
                     final.ttl_evicted, final.bypassed,
                     final.rewrites, final.rewrite_dropped)


@functools.partial(jax.jit,
                   static_argnames=("C", "R", "uniform_lat", "D", "nk",
                                    "use_l1", "use_ttl", "use_rw"))
def _run_sweep(static_emb, static_cls, q_emb, q_cls, judge_flip,
               volatile, key_id, rewritable, sweep: SweepConfig, C: int,
               R: int, uniform_lat: bool, D: int, nk: int, use_l1: bool,
               use_ttl: bool, use_rw: bool) -> SimResult:
    # the hoisted static lookup is config-independent: computed once,
    # shared across every swept config
    s_static, h_idx = _static_sims(static_emb, q_emb)
    core = _scan_core_blocked if uniform_lat else _scan_core
    return core(s_static, static_cls[h_idx], h_idx, q_emb, q_cls,
                judge_flip, volatile, key_id, rewritable,
                sweep.tau_static, sweep.tau_dynamic,
                sweep.sigma_min, sweep.judge_rate, sweep.capacity,
                sweep.judge_latency, sweep.krites, sweep.dedup,
                sweep.l1, sweep.volatile_bypass, sweep.ttl_volatile,
                sweep.ttl_stable, sweep.dup_threshold,
                sweep.rewrite, sweep.rewrite_rate,
                C=C, R=R, D=D, nk=nk, use_l1=use_l1, use_ttl=use_ttl,
                use_rw=use_rw)


def simulate(static_emb, static_cls, q_emb, q_cls, cfg: T.CacheConfig,
             krites: bool, capacity: int | None = None,
             judge_flip=None, volatile=None, key_id=None,
             drift_every: int = 0, rewritable=None) -> SimResult:
    """Run the policy over a request stream.

    static_emb (S, d) [normalized], static_cls (S,);
    q_emb (N, d) [normalized], q_cls (N,).
    judge_flip (N,) bool (optional): requests whose VerifyAndPromote is
    *falsely approved* regardless of class (noisy-verifier study, §5).
    volatile (N,) bool (optional): time-sensitive requests — drives the
    staleness accounting, the bypass, and the TTL class (§16).
    key_id (N,) i32 (required when ``cfg.l1``): exact-duplicate key of
    each request (equal ids = canonically identical prompts).
    drift_every: ground-truth rotation period for volatile queries; a
    hit serving content from an earlier epoch counts as stale.
    rewritable (N,) bool (optional, consulted only when ``cfg.rewrite``):
    would-reject grey-zone requests the rewriter can tailor — the
    judge's REWRITE verdicts in trace form (§18).

    Config scalars are traced, so re-invoking with different thresholds
    (e.g. a tuning loop) reuses the compiled program; only shapes
    (trace length, capacity, ring size) and the freshness feature gates
    retrigger compilation.
    """
    import dataclasses
    C = capacity or cfg.capacity
    if capacity is not None:
        cfg = dataclasses.replace(cfg, capacity=capacity)
    res = simulate_sweep(static_emb, static_cls, q_emb, q_cls,
                         sweep_from_configs([cfg], krites),
                         judge_flip=judge_flip, max_capacity=C,
                         volatile=volatile, key_id=key_id,
                         drift_every=drift_every, rewritable=rewritable)
    return slice_config(res, 0)


def simulate_sweep(static_emb, static_cls, q_emb, q_cls,
                   sweep: SweepConfig, judge_flip=None,
                   max_capacity: int | None = None,
                   ring: int | None = None, volatile=None, key_id=None,
                   drift_every: int = 0, rewritable=None) -> SimResult:
    """Evaluate K configs over one request stream in a single dispatch.

    Returns a :class:`SimResult` whose every field carries a leading
    (K,) config axis. Per config, results are bit-identical to a
    sequential :func:`simulate` call with the matching
    :class:`tiers.CacheConfig` (the equivalence contract of DESIGN.md
    §10, enforced by ``tests/test_sweep.py``).

    The dynamic tier is allocated once at ``max_capacity`` (default:
    the largest swept capacity) with per-config capacity masks, and the
    pending ring at ``ring`` slots (default: the largest swept latency).
    The L1 front allocates one column per distinct ``key_id`` — the sim
    models an uncapped L1 (the live tier's LRU cap is a documented
    batch-path relaxation; differential tests size it amply).
    """
    N, d = q_emb.shape
    if judge_flip is None:
        judge_flip = jnp.zeros((N,), bool)
    caps = np.asarray(sweep.capacity)
    lats = np.clip(np.asarray(sweep.judge_latency), 1, None)
    C = int(max_capacity or caps.max())
    R = int(ring or lats.max())
    if caps.max() > C:
        raise ValueError(f"swept capacity {caps.max()} > tier rows {C}")
    if lats.max() > R:
        raise ValueError(f"swept judge_latency {lats.max()} > ring {R}")
    use_l1 = bool(np.asarray(sweep.l1).any())
    if use_l1 and key_id is None:
        raise ValueError("cfg.l1 requires the trace's key_id array "
                         "(exact-duplicate key per request)")
    use_ttl = bool(np.asarray(sweep.ttl_volatile).max(initial=0) > 0
                   or np.asarray(sweep.ttl_stable).max(initial=0) > 0)
    use_rw = bool(np.asarray(sweep.rewrite).any())
    if volatile is None:
        volatile = np.zeros((N,), bool)
    if key_id is None:
        key_id = np.zeros((N,), np.int32)
    if rewritable is None:
        rewritable = np.zeros((N,), bool)
    key_id = np.asarray(key_id, np.int32)
    nk = int(key_id.max(initial=0)) + 1 if use_l1 else 1
    return _run_sweep(jnp.asarray(static_emb),
                      jnp.asarray(static_cls, jnp.int32),
                      jnp.asarray(q_emb),
                      jnp.asarray(q_cls, jnp.int32), judge_flip,
                      jnp.asarray(volatile, bool),
                      jnp.asarray(key_id),
                      jnp.asarray(rewritable, bool),
                      sweep, C=C, R=R,
                      uniform_lat=bool((lats == lats[0]).all()),
                      D=int(drift_every), nk=nk, use_l1=use_l1,
                      use_ttl=use_ttl, use_rw=use_rw)


# ---------------------------------------------------------------------------
# derived metrics
# ---------------------------------------------------------------------------

def summarize(res: SimResult) -> dict:
    n = res.served_by.shape[0]
    sb = res.served_by
    hit = sb != MISS
    # a hit is an error if the served answer is in the wrong equivalence
    # class OR stale (right class, earlier drift epoch) — identical to
    # the pre-§16 definition whenever no request is volatile
    bad = jnp.logical_and(hit, jnp.logical_or(~res.correct, res.stale))
    out = {
        "requests": n,
        "static_hit_rate": float(jnp.mean(sb == STATIC_HIT)),
        "dyn_hit_rate": float(jnp.mean((sb == DYN_HIT_DYNAMIC)
                                       | (sb == DYN_HIT_PROMOTED)
                                       | (sb == REWRITTEN_HIT))),
        "promoted_hit_rate": float(jnp.mean(sb == DYN_HIT_PROMOTED)),
        "rewritten_hit_rate": float(jnp.mean(sb == REWRITTEN_HIT)),
        "l1_hit_rate": float(jnp.mean(sb == L1_HIT)),
        "total_hit_rate": float(jnp.mean(hit)),
        "static_origin_rate": float(jnp.mean(res.static_origin)),
        "error_rate": float(jnp.mean(bad)),
        "stale_serve_rate": float(jnp.mean(res.stale)),
        "judge_calls": int(res.judge_calls),
        "judge_approved": int(res.judge_approved),
        "promotions": int(res.promotions),
        "enq_dropped": int(res.enq_dropped),
        "ttl_evictions": int(res.ttl_evicted),
        "bypassed_volatile": int(res.bypassed),
        "rewrites": int(res.rewrites),
        "rewrite_dropped": int(res.rewrite_dropped),
    }
    return out


def slice_config(res: SimResult, k: int) -> SimResult:
    """Extract config k's single-config SimResult from a sweep result."""
    return jax.tree.map(lambda a: a[k], res)


def summarize_sweep(res: SimResult) -> list[dict]:
    """Per-config :func:`summarize` rows for a ``simulate_sweep`` result."""
    host = jax.tree.map(np.asarray, res)   # one device->host transfer
    return [summarize(slice_config(host, k))
            for k in range(host.served_by.shape[0])]


def coverage_curve(res: SimResult, n_points: int = 100):
    """Cumulative static-origin served fraction vs requests (Figure 2)."""
    so = res.static_origin.astype(jnp.float32)
    cum = jnp.cumsum(so) / (jnp.arange(so.shape[0]) + 1)
    pts = jnp.linspace(0, so.shape[0] - 1, n_points).astype(jnp.int32)
    return pts, cum[pts]
