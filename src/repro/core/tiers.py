"""Tiered semantic cache state: read-only static tier + functional dynamic
tier (fixed-capacity struct-of-arrays with LRU eviction and upsert).

The dynamic tier is deliberately *functional JAX state* (arrays, not
pointers): every mutation returns a new pytree, so the tier can live inside
``lax.scan`` (trace simulation), be donated across steps (live serving), be
sharded (large deployments), and be checkpointed like any other state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.index.flat import l2_normalize

BIG = jnp.int32(2**30)


class StaticTier(NamedTuple):
    """Read-only curated tier. emb rows are L2-normalized."""
    emb: jax.Array        # (S, d) fp32
    cls: jax.Array        # (S,) int32 — equivalence class of the answer
    answer_ref: jax.Array  # (S,) int32 — opaque handle to the curated answer


class DynamicTier(NamedTuple):
    """Mutable tier: fixed capacity C, LRU clocks, provenance bits."""
    emb: jax.Array            # (C, d) fp32, normalized
    cls: jax.Array            # (C,) int32 answer class
    answer_ref: jax.Array     # (C,) int32
    static_origin: jax.Array  # (C,) bool — True if auxiliary-overwrite entry
    valid: jax.Array          # (C,) bool
    last_used: jax.Array      # (C,) int32 LRU clock
    written_at: jax.Array     # (C,) int32 timestamp (LWW guard)
    expires_at: jax.Array     # (C,) int32 per-entry expiry; 0 = never.
    # An entry is live while ``now <= expires_at`` (or expires_at == 0);
    # it is a third clock, distinct from written_at (LWW) and last_used
    # (LRU): expiry is assigned at write time (judge TTL verdict /
    # freshness class) and never refreshed by hits.


def make_static_tier(emb: jax.Array, cls: jax.Array,
                     answer_ref: jax.Array | None = None) -> StaticTier:
    if answer_ref is None:
        answer_ref = jnp.arange(emb.shape[0], dtype=jnp.int32)
    return StaticTier(l2_normalize(emb.astype(jnp.float32)),
                      cls.astype(jnp.int32), answer_ref.astype(jnp.int32))


def make_dynamic_tier(capacity: int, d: int) -> DynamicTier:
    return DynamicTier(
        emb=jnp.zeros((capacity, d), jnp.float32),
        cls=jnp.zeros((capacity,), jnp.int32),
        answer_ref=jnp.full((capacity,), -1, jnp.int32),
        static_origin=jnp.zeros((capacity,), bool),
        valid=jnp.zeros((capacity,), bool),
        last_used=jnp.zeros((capacity,), jnp.int32),
        written_at=jnp.zeros((capacity,), jnp.int32),
        expires_at=jnp.zeros((capacity,), jnp.int32),
    )


def live_mask(tier: DynamicTier, now=None) -> jax.Array:
    """(C,) bool: valid AND not past the per-entry expiry.

    ``now=None`` skips the expiry test (clockless callers — the legacy
    behaviour). The liveness rule is ``expires_at == 0 or
    now <= expires_at``: an entry is servable *through* its expiry tick
    and dead strictly after it, which keeps the legacy global-ttl
    wrapper (``expires_at = written_at + ttl``; expired iff
    ``now - written_at > ttl``) bit-compatible.
    """
    if now is None:
        return tier.valid
    alive = jnp.logical_or(tier.expires_at == 0,
                           jnp.asarray(now, jnp.int32) <= tier.expires_at)
    return jnp.logical_and(tier.valid, alive)


# ---------------------------------------------------------------------------
# lookups
# ---------------------------------------------------------------------------

def static_lookup(tier: StaticTier, q: jax.Array):
    """q (d,) normalized -> (best similarity, best index)."""
    sims = tier.emb @ q
    idx = jnp.argmax(sims)
    return sims[idx], idx.astype(jnp.int32)


def dynamic_lookup(tier: DynamicTier, q: jax.Array, index=None, now=None):
    """q (d,) normalized -> (best similarity, best index) over live rows.

    An injected ``index`` (``SegmentedIndex``, DESIGN.md §12) takes over
    the scan: candidates from its tail/segments are exact-reranked
    against ``tier.emb``, so the served (score, slot) pair equals this
    flat masked scan whenever the true best live slot survives into the
    candidate set. ``now`` additionally masks rows past their per-entry
    ``expires_at`` (DESIGN.md §16); the policies invalidate eagerly
    before lookup instead, so they leave it ``None``. The indexed path
    relies on the same eager invalidation (``index.invalidate``
    tombstones) and does not take a clock.
    """
    if index is not None:
        vals, idx = index.topk(q[None], tier.emb, k=1)
        return vals[0, 0], idx[0, 0].astype(jnp.int32)
    sims = tier.emb @ q
    sims = jnp.where(live_mask(tier, now), sims, -jnp.inf)
    idx = jnp.argmax(sims)
    return sims[idx], idx.astype(jnp.int32)


def static_lookup_batch(tier: StaticTier, q: jax.Array, index=None,
                        mesh=None, shard_axis: str = "model"):
    """Batched twin of :func:`static_lookup` for the serving hot path.

    q (B, d) normalized -> (best sims (B,), best idx (B,)). With
    ``index=None`` this is one fused exact top-1 pass over the whole
    micro-batch via ``kernels/simsearch`` (Pallas kernel on TPU, jnp
    reference elsewhere — see DESIGN.md §7). An injected ``index``
    (``FlatIndex``/``IVFIndex``, DESIGN.md §11, or ``ShardedIVFIndex``,
    §13) takes over the lookup; its exact rerank keeps the served
    (score, index) pairs equal to flat search whenever recall@C holds,
    so threshold semantics are unchanged. With ``mesh`` (and no index)
    the exact lookup runs row-sharded over ``shard_axis`` — per-shard
    fused scan + tiny k-candidate merge (``index/sharded.py``, §13);
    ``tier.emb`` must be a shard multiple (``pad_rows``) and decisions
    are identical to the single-device pass.
    """
    if index is not None:
        vals, idx = index.topk(q, 1)
        return vals[:, 0], idx[:, 0].astype(jnp.int32)
    if mesh is not None:
        from repro.index.sharded import sharded_cosine_topk
        vals, idx = sharded_cosine_topk(q, tier.emb, mesh, k=1,
                                        axis=shard_axis)
        return vals[:, 0], idx[:, 0].astype(jnp.int32)
    from repro.kernels.simsearch.ops import cosine_topk
    vals, idx = cosine_topk(q, tier.emb, k=1)
    return vals[:, 0], idx[:, 0].astype(jnp.int32)


def dynamic_lookup_batch(tier: DynamicTier, q: jax.Array, index=None,
                         mesh=None, shard_axis: str = "model"):
    """Batched twin of :func:`dynamic_lookup`: one masked matmul for the
    whole micro-batch. q (B, d) *L2-normalized* -> (best sims (B,),
    best idx (B,)). ``index`` mirrors :func:`dynamic_lookup`
    (sub-linear segmented scan + exact rerank instead of the full
    masked matmul). With ``mesh`` the masked scan runs row-sharded over
    ``shard_axis`` with a global slot merge (``sharded_masked_topk``,
    DESIGN.md §13), mirroring ``masked_cosine_topk(
    corpus_normalized=True)`` — the policies' single-device hot path —
    bit for bit, same lowest-slot tie rule. Note that path (and hence
    the mesh branch) renormalizes q while this inline flat matmul
    trusts the caller's normalization; with the documented normalized
    q the difference is float-rounding-level only."""
    if index is not None:
        vals, idx = index.topk(q, tier.emb, k=1)
        return vals[:, 0], idx[:, 0].astype(jnp.int32)
    if mesh is not None:
        from repro.index.sharded import sharded_masked_topk
        vals, idx = sharded_masked_topk(q, tier.emb, tier.valid, mesh,
                                        k=1, axis=shard_axis)
        return vals[:, 0], idx[:, 0].astype(jnp.int32)
    sims = q @ tier.emb.T
    sims = jnp.where(tier.valid[None, :], sims, -jnp.inf)
    idx = jnp.argmax(sims, axis=1)
    return (jnp.take_along_axis(sims, idx[:, None], 1)[:, 0],
            idx.astype(jnp.int32))


def serve_lookup_batch(static_tier: StaticTier, dyn_tier: DynamicTier,
                       q: jax.Array, fused):
    """Both tier lookups in ONE dispatch (DESIGN.md §15).

    ``fused`` is a ``kernels.fused_serve.FusedServe`` — the static IVF
    probe and the masked dynamic top-1 run in a single fused pass with
    the micro-batch resident in VMEM, int8/bf16 until a final exact
    fp32 rerank. q (B, d) L2-normalized. Returns
    ``(static sims (B,), static idx (B,), dyn sims (B,), dyn idx (B,))``
    — the concatenation of :func:`static_lookup_batch` and
    :func:`dynamic_lookup_batch` whenever recall@C / recall@Cd holds
    (the rerank recomputes the very same fp32 dots, so only *which*
    rows got scored can differ, never the served score). The static
    tier's packed IVF layout lives inside ``fused``; ``static_tier``
    rides along for interface symmetry and future exact fallbacks.
    """
    del static_tier   # the packed layout in `fused` covers the corpus
    ss, hi, sd, j = fused.lookup(q, dyn_tier)
    return ss, hi.astype(jnp.int32), sd, j.astype(jnp.int32)


# ---------------------------------------------------------------------------
# mutations (all functional)
# ---------------------------------------------------------------------------

def _lru_slot(tier: DynamicTier, cap=None, now=None) -> jax.Array:
    """Insertion slot: first non-live row, else least-recently-used.

    ``cap`` (optional, traceable int) restricts the choice to rows
    ``[0, cap)`` — the capacity-sweep path runs one max-capacity tier and
    masks the tail per config (DESIGN.md §10). Rows at or beyond ``cap``
    are never written, hence never valid, so lookups need no mask.
    ``now`` (optional) treats TTL-expired rows as free, same as invalid.
    """
    key = jnp.where(live_mask(tier, now), tier.last_used, -BIG)
    if cap is not None:
        key = jnp.where(jnp.arange(key.shape[0]) < cap, key, BIG)
    return jnp.argmin(key).astype(jnp.int32)


def _write(tier: DynamicTier, slot, q, cls, answer_ref, static_origin,
           now, last_used=None, expires=0) -> DynamicTier:
    """Write one row. ``now`` stamps ``written_at`` (the LWW guard's
    clock — for async promotions this is the *enqueue* time). The LRU
    clock defaults to the same value, but callers applying a delayed
    write (a slow judge's promotion) pass the live clock as
    ``last_used`` so the entry lands LRU-warm instead of inheriting an
    enqueue-time coldness that the very next insert would evict.
    ``expires`` stamps the per-entry expiry clock (0 = never)."""
    return DynamicTier(
        emb=tier.emb.at[slot].set(q),
        cls=tier.cls.at[slot].set(cls.astype(jnp.int32)),
        answer_ref=tier.answer_ref.at[slot].set(
            answer_ref.astype(jnp.int32)),
        static_origin=tier.static_origin.at[slot].set(static_origin),
        valid=tier.valid.at[slot].set(True),
        last_used=tier.last_used.at[slot].set(
            now if last_used is None else last_used),
        written_at=tier.written_at.at[slot].set(now),
        expires_at=tier.expires_at.at[slot].set(
            jnp.asarray(expires, jnp.int32)),
    )


def insert(tier: DynamicTier, q, cls, answer_ref, now,
           static_origin=False, cap=None, expires=0) -> DynamicTier:
    """Baseline write-back (Alg. 1 line 11): plain LRU insert."""
    so = jnp.asarray(static_origin)
    return _write(tier, _lru_slot(tier, cap, now), q, jnp.asarray(cls),
                  jnp.asarray(answer_ref), so, now, expires=expires)


def upsert(tier: DynamicTier, q, cls, answer_ref, now,
           static_origin=True, dedup_sim: float = 0.9999,
           lww: bool = True, cap=None, last_used=None,
           expires=0) -> DynamicTier:
    """Auxiliary overwrite (Alg. 2 line 21): idempotent, LWW-guarded.

    If a near-identical key exists (sim >= dedup_sim), overwrite that slot
    (idempotent re-promotion); otherwise take the LRU slot. With
    ``lww=True`` an existing *newer* entry (written after this task was
    enqueued, i.e. written_at > now) is left alone.

    ``now`` is the *enqueue* time of the promotion (it stamps
    ``written_at``, the LWW clock). ``last_used`` is the live clock at
    apply time and stamps the LRU clock; it defaults to ``now`` for
    synchronous callers, but async callers must pass it — a delayed
    promotion stamped LRU-cold at its enqueue time would be the
    eviction victim of the very next insert.
    """
    s, j = dynamic_lookup(tier, q, now=last_used)
    dup = s >= dedup_sim
    slot = jnp.where(dup, j, _lru_slot(tier, cap, now=last_used))
    skip = jnp.logical_and(dup, tier.written_at[j] > now) if lww \
        else jnp.asarray(False)
    new = _write(tier, slot, q, jnp.asarray(cls), jnp.asarray(answer_ref),
                 jnp.asarray(static_origin), now, last_used=last_used,
                 expires=expires)
    return jax.tree.map(lambda a, b: jnp.where(skip, a, b), tier, new)


def touch(tier: DynamicTier, slot, now) -> DynamicTier:
    """LRU touch on hit."""
    return tier._replace(last_used=tier.last_used.at[slot].set(now))


def touch_many(tier: DynamicTier, slots, nows) -> DynamicTier:
    """Batched LRU touch: one scatter for a whole micro-batch of hits.

    Callers must deduplicate ``slots`` (keep the latest ``now`` per slot)
    — XLA scatter order is unspecified for duplicate indices.
    """
    return tier._replace(
        last_used=tier.last_used.at[jnp.asarray(slots, jnp.int32)].set(
            jnp.asarray(nows, jnp.int32)))


def evict_expired(tier: DynamicTier, now, ttl: int | None = None,
                  index=None) -> DynamicTier:
    """TTL sweep: invalidate entries past their per-entry ``expires_at``.

    With ``ttl=None`` (the per-entry path, DESIGN.md §16) an entry is
    expired iff ``expires_at > 0 and now > expires_at`` — exactly the
    complement of :func:`live_mask`. The legacy global-``ttl`` signature
    is kept as a wrapper computing ``expires_at = written_at + ttl`` on
    the fly (expired iff ``now - written_at > ttl``, bit-identical to
    the old behaviour); ``ttl=0`` means TTL is disabled
    (``CacheConfig.ttl``'s documented contract) and the sweep is a
    no-op — NOT "everything is expired", which is what the naive
    ``age <= 0`` test would make of it.

    Callers serving through an injected dynamic index (DESIGN.md §12)
    must pass it here: eviction without a rewrite is the one mutation
    the index cannot observe through ``record_write``, and a stale
    live entry would let an indexed lookup serve an expired slot the
    flat masked scan rejects.
    """
    if ttl is not None:
        if ttl == 0:
            return tier
        alive = now - tier.written_at <= ttl   # == now <= written_at+ttl
    else:
        alive = jnp.logical_or(tier.expires_at == 0,
                               jnp.asarray(now, jnp.int32)
                               <= tier.expires_at)
    if index is not None:
        import numpy as np
        expired = np.nonzero(
            np.asarray(jnp.logical_and(tier.valid, ~alive)))[0]
        for slot in expired:
            index.invalidate(int(slot))
    return tier._replace(valid=jnp.logical_and(tier.valid, alive))


@dataclass(frozen=True)
class CacheConfig:
    """Thresholds + capacities for the tiered cache."""
    tau_static: float
    tau_dynamic: float
    sigma_min: float = 0.0      # grey-zone lower cutoff (paper: 0)
    capacity: int = 4096
    judge_latency: int = 64     # async completion lag, in requests
    ttl: int = 0                # 0 = disabled
    dedup: bool = True          # skip judging when a promoted pointer hits
    # Token-bucket judge budget refill per request (1 = one judge call
    # per request). One knob for both runtimes: the trace simulator
    # (core/simulate.py, tests/ref_policy.py) refills per simulated
    # request, and the live KritesPolicy threads it into the
    # VerifyAndPromote pool as its per-submission refill unless an
    # explicit wall-clock ``judge_rate_per_s`` override is given.
    judge_rate: float = 1.0
    # Freshness subsystem (DESIGN.md §16). All defaults keep the
    # classic behaviour bit-identical: no L1 front tier, no volatile
    # bypass, no per-class expiry stamps.
    l1: bool = False            # exact-match L1 front tier (simulator)
    volatile_bypass: bool = False  # volatile queries skip all caching
    ttl_volatile: int = 0       # expiry assigned to volatile writes
    ttl_stable: int = 0         # expiry assigned to non-volatile writes
    # Near-duplicate gate for promotion upserts: a promotion whose best
    # live neighbor scores >= dup_threshold overwrites that row in
    # place (idempotent re-promotion) instead of taking an LRU slot.
    # Must sit at or above tau_dynamic — below it, a key the tier
    # already *serves* for would still spawn a second row, and the LWW
    # staleness guard (which only applies on the dedup path) would
    # never fire for it.
    dup_threshold: float = 0.9999
    # TweakLLM rewrite outcome (DESIGN.md §18). ``rewrite`` enables the
    # third verdict: a grey-zone pair the judge would reject but deems
    # rewritable gets a tailored answer promoted under the *query's*
    # key instead of nothing. ``rewrite_rate`` is the rewriter's own
    # token-bucket refill per request, budgeted like ``judge_rate`` —
    # an exhausted bucket downgrades the verdict to REJECT. Defaults
    # keep every pre-rewrite program bit-identical.
    rewrite: bool = False
    rewrite_rate: float = 1.0

    def __post_init__(self):
        if not (0.0 < self.dup_threshold <= 1.0):
            raise ValueError(
                f"dup_threshold={self.dup_threshold} outside (0, 1]")
        if self.rewrite_rate < 0.0:
            raise ValueError(f"rewrite_rate={self.rewrite_rate} < 0")
        # tau_dynamic > 1 is the "dynamic tier unreachable" sentinel
        # (no cosine ever clears it), so the duplicate-row hazard this
        # guard exists for cannot arise there
        if self.dup_threshold < self.tau_dynamic <= 1.0:
            raise ValueError(
                f"dup_threshold={self.dup_threshold} < "
                f"tau_dynamic={self.tau_dynamic}: promotions for keys "
                "the tier already serves would duplicate rows")
