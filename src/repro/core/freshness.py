"""Staleness-risk classifier + TTL assignment (DESIGN.md §16).

Freshness-sensitive traffic ("what is the price of X *now*") is the
one scenario axis where serving a semantically-correct cached answer
is still wrong: the ground truth rotates under the cache. This module
is the serve-path half of the freshness subsystem:

- :func:`classify` buckets a prompt into VOLATILE / STABLE / UNKNOWN
  by keyword classes over its canonical token stream (the
  ``semantic-llm-cache`` exemplar's heuristic — cheap enough for the
  critical path, no model call).
- :class:`FreshnessPolicy` maps the class to a cache-life decision:
  volatile queries either bypass caching entirely
  (``volatile_bypass``) or get a short per-entry TTL; stable/unknown
  queries get their own (usually 0 = unbounded) TTLs. The same policy
  object backs the judge's TTL verdict on the async promotion path
  (``OracleJudge.assign_ttl``), so L1 entries, write-back inserts and
  verified promotions all expire on one rule.
- Drift accounting: with a ``drift_every`` epoch clock, a served hit
  is *stale* when the query is volatile and the answer's content
  timestamp falls in an earlier epoch than the serve tick
  (``content_t // drift_every != now // drift_every``). This is a
  property of the two clocks only — no ground truth needed live — and
  matches the simulator's ``stale_serve`` outcome bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.exact_tier import canonicalize

VOLATILE = "volatile"
STABLE = "stable"
UNKNOWN = "unknown"

# Single-token triggers over the canonical (casefolded) token stream.
VOLATILE_KEYWORDS = frozenset({
    "now", "today", "tonight", "latest", "current", "currently",
    "price", "prices", "stock", "stocks", "weather", "forecast",
    "news", "score", "scores", "live", "breaking", "recent",
    "yesterday", "tomorrow", "schedule", "open", "hours", "rate",
    "rates", "trending", "update", "updates",
})
STABLE_KEYWORDS = frozenset({
    "definition", "define", "meaning", "history", "formula",
    "theorem", "capital", "biography", "origin", "etymology",
    "boiling", "synonym", "antonym", "spelled", "spelling",
})


def classify(text: str) -> str:
    """Keyword staleness-risk class of a prompt: VOLATILE if any
    volatile trigger appears, else STABLE on a stable trigger, else
    UNKNOWN. Operates on canonical tokens, so case/whitespace/unicode
    phrasing does not change the class."""
    toks = set(canonicalize(text).split())
    if toks & VOLATILE_KEYWORDS:
        return VOLATILE
    if toks & STABLE_KEYWORDS:
        return STABLE
    return UNKNOWN


@dataclass(frozen=True)
class FreshnessPolicy:
    """Class -> cache-life mapping, in request ticks.

    ``ttl_* = 0`` means unbounded (never expires), mirroring
    ``CacheConfig.ttl``'s contract. ``volatile_bypass=True`` takes
    volatile queries out of the cache entirely (no L1 read/write, no
    semantic lookups, no write-back, no grey-zone submission — the
    answer goes straight to the backend), trading latency for a
    guaranteed zero stale serves on that class. ``drift_every`` is the
    epoch clock used only for stale *accounting* of volatile hits; it
    does not change serving decisions.
    """
    volatile_bypass: bool = True
    ttl_volatile: int = 64
    ttl_stable: int = 0
    ttl_unknown: int = 0
    drift_every: int = 0
    keywords_volatile: frozenset = field(default=VOLATILE_KEYWORDS)
    keywords_stable: frozenset = field(default=STABLE_KEYWORDS)

    def classify(self, text: str) -> str:
        toks = set(canonicalize(text).split())
        if toks & self.keywords_volatile:
            return VOLATILE
        if toks & self.keywords_stable:
            return STABLE
        return UNKNOWN

    def is_volatile(self, text: str) -> bool:
        return self.classify(text) == VOLATILE

    def ttl_for(self, fclass: str) -> int:
        if fclass == VOLATILE:
            return int(self.ttl_volatile)
        if fclass == STABLE:
            return int(self.ttl_stable)
        return int(self.ttl_unknown)

    def ttl_for_text(self, text: str) -> int:
        return self.ttl_for(self.classify(text))

    def expires_at(self, text: str, now: int) -> int:
        """Per-entry expiry stamp for a write at tick ``now`` (0 =
        never)."""
        ttl = self.ttl_for_text(text)
        return int(now) + ttl if ttl > 0 else 0

    def is_stale(self, text_volatile: bool, content_t: int,
                 now: int) -> bool:
        """Drift-clock staleness of a hit served at ``now`` whose
        answer content dates from ``content_t``."""
        d = int(self.drift_every)
        if d <= 0 or not text_volatile:
            return False
        return (int(content_t) // d) != (int(now) // d)
