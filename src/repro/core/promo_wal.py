"""Write-ahead promotion journal (DESIGN.md §14).

The async VerifyAndPromote pipeline pays for a judge call and then
mutates only process memory: a crash between the verdict and the
promotion upsert silently discards verified work, and a crash right
after it loses the promotion entirely unless a full snapshot happens to
follow. The WAL closes that window. ``KritesPolicy`` (``wal=``) appends
each *approved* verdict to the journal **before** applying the upsert;
on restart the journal is replayed through the very same
``_promote`` path, so recovery rides the existing idempotence + LWW
(``written_at``) contract of ``tiers.upsert`` instead of a parallel
code path:

- **replay is idempotent** — re-promoting a journaled record finds its
  own near-duplicate key (sim >= 0.9999) and rewrites the identical
  fields (``written_at`` equals the record's ``enq_t``; ``last_used``
  is the policy's live clock, constant across back-to-back replays),
  so N replays produce the state of one;
- **replay is LWW-safe** — a journaled promotion whose key already
  holds a *newer* entry (``written_at > enq_t``) is skipped exactly
  like a live slow-judge straggler would be;
- **any prefix is a valid journal** — records are length+CRC framed,
  the reader stops at the first torn or corrupt frame (a crash mid-
  append), and replaying a prefix simply recovers fewer promotions.

Snapshots (``serving/persist.py``) record the journal's sequence number
(``wal_seq``) at capture time; recovery replays only the suffix, so a
promotion journaled before the snapshot can never clobber the LRU
clocks the snapshot already captured.

Durability is fsync-batched (``fsync_every`` appends or
``fsync_interval_s``, whichever first): the default trades a bounded
tail of the newest verdicts for not paying an fsync per promotion;
``fsync_every=1`` gives strict append-before-apply durability (the
fault-injection tests run there).

File format (little-endian)::

    header   b"PWAL" + u32 version (1)
    record   u32 payload_len | u32 crc32(payload) | payload
    payload  JSON: {seq, h_idx, enq_t, ttl, v(base64 fp32 bytes),
                    q_text, h_text, outcome, rewritten, q_cls}

The embedding travels as raw float32 bytes (base64) so replayed keys
are bit-identical to the promoted ones — the dedup test is an exact
similarity threshold, and a decimal round-trip could move a key across
it. ``q_text``/``h_text`` ride along for auditability (what was
verified), not for replay.
"""
from __future__ import annotations

import base64
import json
import os
import struct
import threading
import time
import zlib
from pathlib import Path

import numpy as np

MAGIC = b"PWAL"
VERSION = 1
_HEADER = struct.Struct("<4sI")
_FRAME = struct.Struct("<II")


def encode_record(v: np.ndarray, h_idx: int, enq_t: int, *, ttl: int = 0,
                  q_text: str = "", h_text: str = "", seq: int = 0,
                  outcome: str = "approve", rewritten: str = "",
                  q_cls: int = -1) -> dict:
    """Journal record for one promoting verdict (see module docstring).

    ``outcome``/``rewritten``/``q_cls`` (DESIGN.md §18) carry REWRITE
    provenance: replay must reconstruct the tailored answer text and
    the query-class key, neither of which is derivable from the static
    tier. Absent fields (journals written before the verdict refactor)
    default to a plain approval — old journals replay unchanged."""
    v = np.ascontiguousarray(v, np.float32)
    return {
        "seq": int(seq),
        "h_idx": int(h_idx),
        "enq_t": int(enq_t),          # == the promotion's written_at
        "ttl": int(ttl),
        "v": base64.b64encode(v.tobytes()).decode("ascii"),
        "q_text": q_text,
        "h_text": h_text,
        "outcome": str(outcome),
        "rewritten": str(rewritten),
        "q_cls": int(q_cls),
    }


def decode_vector(record: dict) -> np.ndarray:
    """Bit-exact fp32 embedding back out of a journal record."""
    return np.frombuffer(base64.b64decode(record["v"]), np.float32).copy()


class PromotionWAL:
    """Append-only, CRC-framed promotion journal with batched fsync.

    Thread-safe: appends arrive from judge-pool workers (inside
    ``KritesPolicy._promote`` under ``dyn_lock``) and from shutdown
    hooks. Opening an existing file scans it, adopts the valid prefix
    (continuing ``seq`` from it) and truncates any torn tail left by a
    crash mid-append, so the next append never corrupts the frame
    stream.
    """

    def __init__(self, path: str | Path, *, fsync_every: int = 8,
                 fsync_interval_s: float = 0.05):
        self.path = Path(path)
        self.fsync_every = max(1, int(fsync_every))
        self.fsync_interval_s = fsync_interval_s
        self._lock = threading.Lock()
        self._pending = 0             # appends since the last fsync
        self._last_sync = time.monotonic()
        self._appended = 0            # this process's appends (telemetry)
        self._synced_seq = 0          # records known durable
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self.path.exists():
            records, _, valid_bytes = scan_wal(self.path)
            # continue from the highest stamped seq — after a compact()
            # the file holds fewer records than history positions
            self._seq = max([int(r.get("seq", 0)) for r in records]
                            + [len(records)])
            self._synced_seq = self._seq
            self._f = open(self.path, "r+b")
            if valid_bytes < _HEADER.size:      # empty or foreign file
                self._f.truncate(0)
                self._f.seek(0)
                self._f.write(_HEADER.pack(MAGIC, VERSION))
                self._f.flush()
                os.fsync(self._f.fileno())
            else:
                self._f.truncate(valid_bytes)   # drop any torn tail
                self._f.seek(valid_bytes)
        else:
            self._seq = 0
            self._f = open(self.path, "w+b")
            self._f.write(_HEADER.pack(MAGIC, VERSION))
            self._f.flush()
            os.fsync(self._f.fileno())

    # -- producer ----------------------------------------------------------

    @property
    def seq(self) -> int:
        """Records in the journal (preexisting + appended)."""
        with self._lock:
            return self._seq

    def append(self, record: dict) -> int:
        """Frame + append one record; returns its 1-based seq. The
        record's ``seq`` field is stamped here (append order is the
        replay order)."""
        with self._lock:
            self._seq += 1
            record = dict(record, seq=self._seq)
            payload = json.dumps(record, separators=(",", ":"),
                                 sort_keys=True).encode()
            self._f.write(_FRAME.pack(len(payload), zlib.crc32(payload)))
            self._f.write(payload)
            self._appended += 1
            self._pending += 1
            now = time.monotonic()
            if self._pending >= self.fsync_every \
                    or now - self._last_sync >= self.fsync_interval_s:
                self._sync_locked()
            return self._seq

    def sync(self) -> None:
        """Force-flush + fsync everything appended so far."""
        with self._lock:
            self._sync_locked()

    def _sync_locked(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())
        self._pending = 0
        self._synced_seq = self._seq
        self._last_sync = time.monotonic()

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._sync_locked()
                self._f.close()

    def stats(self) -> dict:
        with self._lock:
            return {"seq": self._seq, "appended": self._appended,
                    "synced_seq": self._synced_seq,
                    "pending_fsync": self._pending}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# reader / replay
# ---------------------------------------------------------------------------

def scan_wal(path: str | Path) -> tuple[list[dict], bool, int]:
    """Read a journal tolerantly.

    Returns ``(records, clean, valid_bytes)``: every record of the
    longest valid prefix, whether the file ended exactly at a frame
    boundary with no damage (``clean``), and the byte offset that
    prefix ends at. A torn final frame (crash mid-append), a CRC
    mismatch, or undecodable JSON stops the scan — never raises — so
    any crash leaves a journal whose readable prefix is still a valid
    journal (prefix-crash safety, test-pinned).
    """
    path = Path(path)
    records: list[dict] = []
    data = path.read_bytes()
    if len(data) < _HEADER.size:
        return records, False, 0
    magic, version = _HEADER.unpack_from(data, 0)
    if magic != MAGIC or version != VERSION:
        return records, False, 0
    off = _HEADER.size
    clean = True
    while off < len(data):
        if off + _FRAME.size > len(data):
            clean = False
            break
        length, crc = _FRAME.unpack_from(data, off)
        start = off + _FRAME.size
        end = start + length
        if end > len(data) or zlib.crc32(data[start:end]) != crc:
            clean = False
            break
        try:
            rec = json.loads(data[start:end])
        except ValueError:
            clean = False
            break
        records.append(rec)
        off = end
    return records, clean, off if not clean else len(data)


def read_wal(path: str | Path) -> tuple[list[dict], bool]:
    """(records of the longest valid prefix, file-was-clean)."""
    records, clean, _ = scan_wal(path)
    return records, clean


def replay_into(policy, path: str | Path, *, skip: int = 0) -> dict:
    """Replay a journal through ``policy._promote`` (journal=False so
    replay never re-appends). ``skip`` drops records with
    ``seq <= skip`` — the ``wal_seq`` a snapshot captured, whose
    effects (and any later LRU touches on them) the snapshot already
    holds. Matching on the stamped ``seq`` (not file position) keeps a
    snapshot's cursor valid across :func:`compact`. Safe to call any
    number of times: replay rides the upsert idempotence/LWW contract
    (module docstring). Returns counters for telemetry/tests."""
    records, clean = read_wal(path)
    replayed = skipped = 0
    for i, rec in enumerate(records):
        if int(rec.get("seq", i + 1)) <= skip:
            skipped += 1
            continue
        # the record's TTL verdict (0 = unbounded) reconstructs the same
        # expires_at on replay: expiry anchors at enq_t, which is here.
        # Outcome/rewritten/q_cls default to a plain approval so
        # pre-verdict journals replay bit-identically.
        policy._promote({"v": decode_vector(rec),
                         "h_idx": int(rec["h_idx"]),
                         "enq_t": int(rec["enq_t"]),
                         "ttl": int(rec.get("ttl", 0)),
                         "outcome": rec.get("outcome", "approve"),
                         "rewritten": rec.get("rewritten", ""),
                         "judge_args": {"q_cls": int(rec.get("q_cls", -1))},
                         }, journal=False)
        replayed += 1
    return {"records": len(records), "skipped": skipped,
            "replayed": replayed, "clean": clean}


def compact(path: str | Path, *, keep_from_seq: int) -> int:
    """Rewrite the journal dropping records with seq <= keep_from_seq
    (all subsumed by a snapshot that captured ``wal_seq ==
    keep_from_seq``). Kept records keep their original ``seq`` — seq is
    a position in the journal's history, not in the file — so a
    snapshot's ``wal_seq`` stays a valid replay cursor across
    compactions. Atomic (tmp + rename). Returns records kept.

    Callers must quiesce appends (close or lock the live WAL) first;
    the launcher compacts right after its snapshot, inside the same
    shutdown/checkpoint section.
    """
    path = Path(path)
    records, _, _ = scan_wal(path)
    kept = [r for r in records if int(r.get("seq", 0)) > keep_from_seq]
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as f:
        f.write(_HEADER.pack(MAGIC, VERSION))
        for rec in kept:
            payload = json.dumps(rec, separators=(",", ":"),
                                 sort_keys=True).encode()
            f.write(_FRAME.pack(len(payload), zlib.crc32(payload)))
            f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(kept)
