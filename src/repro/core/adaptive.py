"""Online per-segment threshold adaptation via shadow sweeps
(DESIGN.md §17).

Thresholds are tuned offline (``scripts/calibrate.py``) and pinned for
the lifetime of the service — exactly the operating-point rigidity the
follow-up papers (PAPERS.md: "From Offline Learning to Online
Adaptation", "Continuous Semantic Caching") show costs hit rate the
moment the traffic distribution moves. This module closes the loop:

- **Segments.** Traffic is keyed by the freshness classifier's
  canonical-token machinery (``core/freshness.classify`` over
  ``canonicalize`` token streams): UNKNOWN / VOLATILE / STABLE each get
  their own live ``(tau_static, tau_dynamic)`` operating point. The
  policies read these per request — one source of truth under
  ``dyn_lock`` across the scalar, batched, fused and mesh serve paths.

- **Window.** A bounded ring buffer records every semantically-served
  request (embedding, outcome label, segment). Labels start as the
  request's class id (``meta['cls']``, falling back to the static
  neighbor's class) and are *rewritten by evidence*: an async judge
  verdict stamps the neighbor class on approve or a unique reject
  sentinel on reject, and operator error feedback
  (``CacheRouter.feedback``) does the same — so the shadow evaluator
  scores candidate thresholds against what the service has actually
  learned about its traffic, not just the prior labels.

- **Shadow sweep.** Every ``adapt_every`` recorded requests (once the
  window is full), the controller re-scores a candidate threshold grid
  centered on each active segment's live point against the whole
  window in ONE ``simulate_sweep`` dispatch (the batched-K evaluator of
  DESIGN.md §10; all segments' grids ride the same dispatch and
  per-segment metrics are masked out of the shared (K, N) decision
  streams). Selection walks the measured Pareto frontier: the
  feasible-set rule of ``tune_threshold`` (max hits subject to the
  error budget) plus epsilon-greedy exploration over the feasible set,
  a bounded step size, and hysteresis so the critical path never flaps.

- **Determinism.** No wall clock, no entropy: exploration comes from a
  seeded 64-bit LCG advanced once per adaptation, and all metric
  arithmetic is integer counts + python-float threshold math, so the
  pure-numpy reference twin (``tests/ref_policy.ref_adaptive``) pins
  every adaptive decision field-identically.

The controller itself is policy-agnostic: it never imports the policy
and takes the lock + static tier handles as arguments, so the live
``BaselinePolicy``/``KritesPolicy`` and the test harnesses share it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.freshness import STABLE, VOLATILE, classify

SEGMENT_NAMES = ("unknown", "volatile", "stable")
N_SEGMENTS = 3

_LCG_MUL = 6364136223846793005
_LCG_INC = 1442695040888963407
_LCG_MASK = (1 << 64) - 1


def segment_of(text: str) -> int:
    """Traffic segment of a prompt: the freshness classifier's
    staleness-risk class over canonical tokens (0=unknown, 1=volatile,
    2=stable). Pure — safe to call outside any lock."""
    c = classify(text)
    if c == VOLATILE:
        return 1
    if c == STABLE:
        return 2
    return 0


def lcg_next(state: int) -> int:
    """One step of the 64-bit LCG driving epsilon-greedy exploration.
    Deterministic and shared with the numpy reference twin."""
    return (state * _LCG_MUL + _LCG_INC) & _LCG_MASK


@dataclass(frozen=True)
class AdaptiveParams:
    """Controller knobs. Defaults are conservative: small grids, small
    bounded steps, explore off — enable ``epsilon`` to trade a little
    window-local optimality for drift robustness."""
    window: int = 1024        # request-window ring size (W)
    adapt_every: int = 256    # recorded requests between shadow sweeps
    grid_points: int = 3      # candidates per threshold axis (odd:
    #                           the live point sits at the grid center)
    grid_radius: float = 0.04  # candidate spread around the live point
    max_step: float = 0.02    # bounded nudge per adaptation
    hysteresis: float = 0.005  # min hit-rate gain (fraction of the
    #                            segment window) required to move
    error_budget: float = 0.02  # shadow error ceiling (tune_threshold's)
    epsilon: float = 0.0      # explore probability over the feasible set
    tau_lo: float = 0.55      # hard floor for any live threshold
    tau_hi: float = 0.99      # hard ceiling (< dup_threshold by design)
    min_segment: int = 64     # window rows a segment needs to adapt
    shadow_capacity: int = 256  # dynamic-tier rows in the shadow sim
    seed: int = 0x9E3779B9    # LCG init for epsilon-greedy

    def __post_init__(self):
        if self.grid_points < 1 or self.grid_points % 2 == 0:
            raise ValueError("grid_points must be odd (live point at "
                             f"the grid center), got {self.grid_points}")
        if not (0.0 < self.tau_lo < self.tau_hi <= 1.0):
            raise ValueError(f"bad bounds [{self.tau_lo}, {self.tau_hi}]")


def candidate_grid(center_s: float, center_d: float,
                   p: AdaptiveParams) -> Tuple[list, int]:
    """The candidate (tau_static, tau_dynamic) grid around one live
    operating point: the cross product of ``grid_points`` evenly spaced
    values per axis, clipped to [tau_lo, tau_hi]. Returns the candidate
    list and the index of the live point (always present: odd
    ``grid_points`` puts it at both axis centers)."""
    g = p.grid_points
    half = g // 2
    step = p.grid_radius / max(half, 1)

    def axis(center):
        vals = []
        for k in range(g):
            v = center + (k - half) * step
            vals.append(min(max(v, p.tau_lo), p.tau_hi))
        vals[half] = center        # clipping must never move the center
        return vals

    ts_vals, td_vals = axis(center_s), axis(center_d)
    cands = [(ts, td) for ts in ts_vals for td in td_vals]
    return cands, half * g + half


def choose_candidate(hits: Sequence[int], errs: Sequence[int],
                     n_seg: int, center: int, p: AdaptiveParams,
                     explore_pick: Optional[int]) -> Tuple[int, str]:
    """Pareto-frontier selection over one segment's candidate grid.

    Pure integer/float arithmetic shared with the numpy reference twin:
    feasible = within the error budget; greedy = max hits (ties: fewer
    errors, then lowest index — i.e. closest to the frontier in grid
    order); hysteresis holds the live point unless the greedy winner
    beats it by ``hysteresis * n_seg`` hits (or the live point itself
    is infeasible); ``explore_pick`` (a pre-drawn LCG value, None = no
    exploration this round) indexes uniformly into the feasible set.

    Returns ``(chosen index, reason)`` with reason one of
    'hold' | 'greedy' | 'repair' | 'explore'.
    """
    K = len(hits)
    feasible = [k for k in range(K)
                if errs[k] <= p.error_budget * n_seg]
    if explore_pick is not None and feasible:
        return feasible[explore_pick % len(feasible)], "explore"
    if not feasible:
        # nothing within budget: repair toward minimum error
        best = min(range(K), key=lambda k: (errs[k], -hits[k], k))
        return (best, "repair") if best != center else (center, "hold")
    best = min(feasible, key=lambda k: (-hits[k], errs[k], k))
    if center in feasible:
        if hits[best] <= hits[center] + p.hysteresis * n_seg:
            return center, "hold"
    return (best, "greedy") if best != center else (center, "hold")


def _default_shadow_eval(static_emb, static_cls, q_emb, q_cls, cfgs):
    """One ``simulate_sweep`` dispatch over all candidate configs;
    returns host (K, N) decision streams. Baseline (krites=False)
    semantics: the shadow scores *serving thresholds* against the
    window — the async promotion pipeline's effect on the frontier is
    second-order at window scale and would cost a judge model the
    shadow does not have."""
    import jax
    import jax.numpy as jnp

    from repro.core.simulate import simulate_sweep, sweep_from_configs

    res = simulate_sweep(jnp.asarray(static_emb, jnp.float32),
                         jnp.asarray(static_cls, jnp.int32),
                         jnp.asarray(q_emb, jnp.float32),
                         jnp.asarray(q_cls, jnp.int32),
                         sweep_from_configs(cfgs, krites=False))
    served_by, correct = jax.device_get((res.served_by, res.correct))
    return np.asarray(served_by), np.asarray(correct)


class AdaptiveController:
    """Live per-segment threshold state + the shadow-sweep adaptation
    loop. All mutable state is guarded by the *policy's* ``dyn_lock``
    (the controller never takes it itself except in
    :meth:`maybe_adapt`, which is documented lock-free on entry), so
    threshold reads, window records and verdict rewrites are consistent
    with the tier mutations they ride along with."""

    def __init__(self, cfg, d: int,
                 params: Optional[AdaptiveParams] = None,
                 shadow_eval: Optional[Callable] = None,
                 frozen: bool = False):
        p = self.params = params or AdaptiveParams()
        self.d = int(d)
        self.cfg = cfg
        self.frozen = bool(frozen)
        self.shadow_eval = shadow_eval or _default_shadow_eval
        # live operating points, one per segment, seeded at the pinned
        # config — adaptive-off (frozen) serving is bit-identical to a
        # pinned policy because these never move
        self.tau_static: List[float] = \
            [float(cfg.tau_static)] * N_SEGMENTS
        self.tau_dynamic: List[float] = \
            [float(cfg.tau_dynamic)] * N_SEGMENTS
        # bounded request window (ring): embedding, evidence label,
        # segment. seq is 1-based and monotonic; row seq s lives at
        # (s - 1) % window until overwritten W records later.
        self._w_emb = np.zeros((p.window, self.d), np.float32)
        self._w_label = np.zeros(p.window, np.int32)
        self._w_seg = np.zeros(p.window, np.int8)
        self._count = 0           # total records ever (== last seq)
        self._since = 0           # records since the last adaptation
        # regret-style counters (per segment): shadow hits the live
        # point left on the table vs the measured frontier, summed over
        # sweeps; plus controller activity counters
        self.regret: List[int] = [0] * N_SEGMENTS
        self.seen: List[int] = [0] * N_SEGMENTS
        self.adaptations = 0
        self.moves = 0
        self.explores = 0
        self.verdicts = 0
        self.feedbacks = 0
        self._rng = lcg_next(p.seed & _LCG_MASK)
        self._last: dict = {}     # most recent sweep, for stats

    # -- critical-path reads (caller holds dyn_lock) ----------------------

    def thresholds(self, seg: int) -> Tuple[float, float]:
        return self.tau_static[seg], self.tau_dynamic[seg]

    # -- window recording (caller holds dyn_lock) -------------------------

    def record(self, emb: np.ndarray, label: int, seg: int) -> int:
        """Append one served request to the window; returns its seq
        (stamped into ``ServeResult.meta['adapt_seq']`` so judge
        verdicts and operator feedback can find the row again)."""
        i = self._count % self.params.window
        self._w_emb[i] = emb
        self._w_label[i] = label
        self._w_seg[i] = seg
        self._count += 1
        self._since += 1
        self.seen[seg] += 1
        return self._count

    def _row_of(self, seq: int) -> Optional[int]:
        """Ring row still holding ``seq``, or None if overwritten."""
        if seq is None or seq <= 0 or seq > self._count \
                or seq <= self._count - self.params.window:
            return None
        return (seq - 1) % self.params.window

    def record_verdict(self, seq: int, approved: bool,
                       h_cls: int) -> None:
        """Judge-verdict evidence: the async judge decided whether this
        window row's query really belongs to its static neighbor's
        class. Approve stamps the neighbor class; reject stamps a
        unique negative sentinel (−2−seq) so the shadow counts any
        static/neighbor serve of that row as an error without aliasing
        two rejected rows onto each other."""
        i = self._row_of(seq)
        if i is None:
            return
        self.verdicts += 1
        self._w_label[i] = int(h_cls) if approved else -2 - int(seq)

    def record_feedback(self, seq: int, ok: bool) -> None:
        """Operator error feedback on a served answer (router-level):
        a report of a wrong answer poisons the row's label with the
        same unique reject sentinel the judge path uses."""
        i = self._row_of(seq)
        if i is None:
            return
        self.feedbacks += 1
        if not ok:
            self._w_label[i] = -2 - int(seq)

    # -- adaptation -------------------------------------------------------

    def should_adapt(self) -> bool:
        """Caller holds dyn_lock. Adapts only on a *full* window (fixed
        shadow trace length keeps the sweep's compiled program stable
        across the service lifetime) and at the configured cadence."""
        return (not self.frozen
                and self._count >= self.params.window
                and self._since >= self.params.adapt_every)

    def window_snapshot(self):
        """Window in insertion order, oldest first (caller holds
        dyn_lock). Only valid once the ring is full."""
        W = self.params.window
        pos = self._count % W
        order = np.concatenate([np.arange(pos, W), np.arange(0, pos)])
        return (self._w_emb[order].copy(), self._w_label[order].copy(),
                self._w_seg[order].copy())

    def maybe_adapt(self, lock, static_emb, static_cls) -> bool:
        """The adaptation step: snapshot the window under ``lock``, run
        the shadow sweep *outside* it (device work must not stall the
        serve path), then install the nudged operating points back
        under ``lock``. Returns True when a sweep ran."""
        with lock:
            if not self.should_adapt():
                return False
            self._since = 0
            emb, label, seg = self.window_snapshot()
            centers = [(self.tau_static[s], self.tau_dynamic[s])
                       for s in range(N_SEGMENTS)]
            rng = self._rng = lcg_next(self._rng)
        plan, last = self._plan(emb, label, seg, centers, rng,
                                static_emb, static_cls)
        with lock:
            self.adaptations += 1
            self._last = last
            for s, (ts, td, reason, gap) in plan.items():
                self.regret[s] += gap
                if reason == "explore":
                    self.explores += 1
                if (ts, td) != (self.tau_static[s], self.tau_dynamic[s]):
                    self.moves += 1
                    self.tau_static[s], self.tau_dynamic[s] = ts, td
        return True

    def _plan(self, emb, label, seg, centers, rng, static_emb,
              static_cls):
        """One shadow sweep over the window -> per-segment nudges.
        Pure w.r.t. controller state (everything it needs came in as
        arguments), so the numpy reference twin can replay it."""
        p = self.params
        active = [s for s in range(N_SEGMENTS)
                  if int((seg == s).sum()) >= p.min_segment]
        if not active:
            return {}, {"active": []}

        cfgs, spans = [], {}     # seg -> (start, cands, center_idx)
        for s in active:
            cands, ci = candidate_grid(*centers[s], p)
            spans[s] = (len(cfgs), cands, ci)
            cfgs.extend(self._shadow_cfg(ts, td) for ts, td in cands)

        served_by, correct = self.shadow_eval(
            static_emb, static_cls, emb, label, cfgs)
        hit = np.asarray(served_by) != 0          # MISS == 0
        bad = hit & ~np.asarray(correct)

        # epsilon-greedy: one explore decision per sweep, applied to
        # every active segment, each with its own derived pick
        explore = (rng >> 17) % 1_000_000 < int(p.epsilon * 1_000_000)

        plan, last = {}, {"active": active, "segments": {}}
        for s in active:
            start, cands, ci = spans[s]
            mask = seg == s
            n_seg = int(mask.sum())
            hits = [int((hit[start + k] & mask).sum())
                    for k in range(len(cands))]
            errs = [int((bad[start + k] & mask).sum())
                    for k in range(len(cands))]
            pick = (lcg_next(rng + s) >> 11) if explore else None
            k, reason = choose_candidate(hits, errs, n_seg, ci, p, pick)
            # regret vs the measured frontier (greedy winner), even
            # when exploring or holding
            g, _ = choose_candidate(hits, errs, n_seg, ci, p, None)
            gap = max(0, hits[g] - hits[ci])
            cs, cd = centers[s]
            ts = cs + min(max(cands[k][0] - cs, -p.max_step), p.max_step)
            td = cd + min(max(cands[k][1] - cd, -p.max_step), p.max_step)
            ts = min(max(ts, p.tau_lo), p.tau_hi)
            td = min(max(td, p.tau_lo), p.tau_hi)
            plan[s] = (ts, td, reason, gap)
            last["segments"][SEGMENT_NAMES[s]] = {
                "n": n_seg, "chosen": k, "reason": reason,
                "center_hits": hits[ci], "center_errs": errs[ci],
                "best_hits": hits[g], "best_errs": errs[g],
                "tau_static": ts, "tau_dynamic": td,
            }
        return plan, last

    def _shadow_cfg(self, ts: float, td: float):
        """A candidate CacheConfig for the shadow sweep: the live
        serving thresholds under test, the shadow tier capacity, and
        dup_threshold pinned to 1.0 (the shadow is baseline-only — no
        promotions — and 1.0 satisfies the >= tau_dynamic validation
        for any candidate)."""
        from repro.core.tiers import CacheConfig
        return CacheConfig(tau_static=ts, tau_dynamic=td,
                           sigma_min=0.0,
                           capacity=self.params.shadow_capacity,
                           judge_latency=1, dup_threshold=1.0)

    # -- telemetry / persistence ------------------------------------------

    def stats(self) -> dict:
        """Live operating points + regret counters for router/stats
        windows. Caller need not hold the lock for a monitoring read —
        python float/int reads are atomic and monotonic-ish staleness
        is fine for dashboards."""
        out = {
            "adaptive_frozen": self.frozen,
            "adaptive_window_fill": min(self._count, self.params.window),
            "adaptive_adaptations": self.adaptations,
            "adaptive_moves": self.moves,
            "adaptive_explores": self.explores,
            "adaptive_verdicts": self.verdicts,
            "adaptive_feedbacks": self.feedbacks,
        }
        for s, name in enumerate(SEGMENT_NAMES):
            out[f"tau_static_{name}"] = self.tau_static[s]
            out[f"tau_dynamic_{name}"] = self.tau_dynamic[s]
            out[f"adaptive_regret_{name}"] = self.regret[s]
            out[f"adaptive_seen_{name}"] = self.seen[s]
        return out

    def to_state(self) -> Tuple[dict, dict]:
        """(arrays, scalars) for snapshot persistence (DESIGN.md §14:
        arrays ride the hashed leaf tree, scalars the JSON manifest).
        Caller holds dyn_lock."""
        arrays = {
            "emb": self._w_emb.copy(),
            "label": self._w_label.copy(),
            "seg": self._w_seg.copy(),
            "tau_static": np.asarray(self.tau_static, np.float64),
            "tau_dynamic": np.asarray(self.tau_dynamic, np.float64),
        }
        scalars = {
            "window": int(self.params.window),
            "count": int(self._count), "since": int(self._since),
            "adaptations": int(self.adaptations),
            "moves": int(self.moves), "explores": int(self.explores),
            "verdicts": int(self.verdicts),
            "feedbacks": int(self.feedbacks),
            "regret": [int(r) for r in self.regret],
            "seen": [int(s) for s in self.seen],
            "rng": int(self._rng), "frozen": bool(self.frozen),
        }
        return arrays, scalars

    def load_state(self, arrays: dict, scalars: dict) -> None:
        """Restore a snapshot's controller state (caller holds
        dyn_lock). The window geometry must match — a resized window
        cannot meaningfully inherit ring contents."""
        if int(scalars["window"]) != self.params.window:
            raise ValueError(
                f"snapshot window {scalars['window']} != controller "
                f"window {self.params.window}")
        self._w_emb[:] = arrays["emb"]
        self._w_label[:] = arrays["label"]
        self._w_seg[:] = arrays["seg"]
        self.tau_static = [float(x) for x in arrays["tau_static"]]
        self.tau_dynamic = [float(x) for x in arrays["tau_dynamic"]]
        self._count = int(scalars["count"])
        self._since = int(scalars["since"])
        self.adaptations = int(scalars["adaptations"])
        self.moves = int(scalars["moves"])
        self.explores = int(scalars["explores"])
        self.verdicts = int(scalars.get("verdicts", 0))
        self.feedbacks = int(scalars.get("feedbacks", 0))
        self.regret = [int(r) for r in scalars["regret"]]
        self.seen = [int(s) for s in scalars["seen"]]
        self._rng = int(scalars["rng"])
        self.frozen = bool(scalars["frozen"])
