"""Asynchronous VerifyAndPromote worker pool (live serving path).

Implements the operational pipeline of §3.1: bounded queue, deduplication
of (query, static-neighbor) pairs, token-bucket rate limiting, retry with
exponential backoff, and straggler mitigation (a task past its deadline is
re-dispatched to another worker; first completion wins, idempotent upsert
makes the duplicate harmless).

The judge emits a structured ``Verdict`` (plain bools are auto-wrapped)
and the pool dispatches per outcome through an extensible action
registry: APPROVE and REWRITE both run the promote action by default
(the payload carries the outcome tag and the rewritten text, so the
policy's upsert knows which variant it is landing), REJECT runs none.
Retry/backoff and first-completion-wins apply identically to every
outcome — the action, not the verdict, is what retries.

Everything is off the serving path: ``submit`` never blocks and serving
never waits on this pool. Queue depth only delays promotions (§3.1).
"""
from __future__ import annotations

import heapq
import itertools
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.core.judge import APPROVE, REJECT, REWRITE, as_verdict


@dataclass
class VerifyTask:
    key: tuple                  # dedup key: (q_fingerprint, h_idx)
    payload: dict
    attempts: int = 0
    enqueued_at: float = field(default_factory=time.monotonic)


@dataclass
class PoolStats:
    submitted: int = 0
    deduped: int = 0
    rate_limited: int = 0
    dropped_full: int = 0
    judged: int = 0
    approved: int = 0
    retried: int = 0
    redispatched: int = 0
    duplicate_completions: int = 0
    failed: int = 0
    # per-outcome counters (winning completions only — the same
    # accounting discipline `approved` always had)
    rejected: int = 0
    rewritten: int = 0
    # rewrite-path degradations: the judge said REWRITE but no tailored
    # text landed (rewriter missing/failed/empty -> rewrite_failed;
    # rewrite token bucket empty -> rewrite_rate_limited). Both
    # downgrade the verdict to REJECT and are also counted there.
    rewrite_failed: int = 0
    rewrite_rate_limited: int = 0


class VerifyAndPromotePool:
    """Background pool running judge -> verdict -> per-outcome actions."""

    def __init__(self,
                 judge_fn: Callable[[dict], object],
                 promote_fn: Callable[[dict], None],
                 n_workers: int = 2,
                 max_depth: int = 1024,
                 rate_per_s: float = float("inf"),
                 rate_per_req: float = 0.0,
                 max_attempts: int = 3,
                 backoff_s: float = 0.05,
                 straggler_deadline_s: float = 5.0,
                 actions: Optional[Dict[str, Callable]] = None):
        """``rate_per_s`` refills the token bucket by wall-clock time;
        ``rate_per_req`` additionally refills it per submission attempt
        — the live analogue of the simulator's per-request
        ``CacheConfig.judge_rate`` budget (core/simulate.py), which
        ``KritesPolicy`` threads through here by default.

        ``judge_fn`` may return a ``Verdict`` or a plain bool (wrapped
        via ``as_verdict``). ``actions`` maps verdict outcomes to the
        callable run for winning completions of that outcome; the
        default registry promotes APPROVE and REWRITE payloads (the
        promote callback reads the payload's outcome tag) and does
        nothing on REJECT. Extra outcomes just need a registry entry."""
        self.judge_fn = judge_fn
        self.promote_fn = promote_fn
        self.actions: Dict[str, Optional[Callable]] = {
            APPROVE: promote_fn,
            REWRITE: promote_fn,
            REJECT: None,
        }
        if actions:
            self.actions.update(actions)
        self.q: "queue.Queue[VerifyTask]" = queue.Queue(max_depth)
        self.stats = PoolStats()
        self._inflight: dict = {}
        # retry backoff is deadline-based, not sleep-based: a retrying
        # task parks here as (ready_at, seq, task) and is re-enqueued by
        # whichever worker/reaper loop next observes ready_at passed —
        # no worker slot blocks for the backoff duration
        self._delayed: list = []
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._rate = rate_per_s
        self._rate_req = rate_per_req
        self._tokens = float(min(rate_per_s, 1e9))
        self._last_refill = time.monotonic()
        self._max_attempts = max_attempts
        self._backoff = backoff_s
        self._deadline = straggler_deadline_s
        self._workers = [
            threading.Thread(target=self._run, daemon=True,
                             name=f"krites-judge-{i}")
            for i in range(n_workers)]
        for w in self._workers:
            w.start()
        self._reaper = threading.Thread(target=self._reap_stragglers,
                                        daemon=True)
        self._reaper.start()

    # -- producer side (called from the serving path; never blocks) -------
    def submit(self, key: tuple, payload: dict) -> bool:
        task = VerifyTask(key, payload)
        with self._lock:
            self.stats.submitted += 1
            if key in self._inflight:
                self.stats.deduped += 1
                return False
            if not self._take_token():
                self.stats.rate_limited += 1
                return False
            # [dispatch time, task, outstanding copies]: the reaper
            # re-dispatches a stuck task to another worker and bumps
            # the copy count; the key leaves the set when a copy wins
            # or every copy has terminally failed
            self._inflight[key] = [time.monotonic(), task, 1]
        try:
            self.q.put_nowait(task)
            return True
        except queue.Full:
            with self._lock:
                self.stats.dropped_full += 1
                self._inflight.pop(key, None)
            return False

    def submit_many(self, items) -> int:
        """Bulk submit for the batched serving path: one lock acquisition
        for a whole micro-batch of grey-zone triggers. ``items`` is an
        iterable of (key, payload); returns the number enqueued. Same
        dedup / token-bucket / drop-on-full semantics as :meth:`submit`,
        applied per item in order."""
        accepted = []
        with self._lock:
            for key, payload in items:
                self.stats.submitted += 1
                if key in self._inflight:
                    self.stats.deduped += 1
                    continue
                if not self._take_token():
                    self.stats.rate_limited += 1
                    continue
                task = VerifyTask(key, payload)
                self._inflight[key] = [time.monotonic(), task, 1]
                accepted.append(task)
        n = 0
        for task in accepted:
            try:
                self.q.put_nowait(task)
                n += 1
            except queue.Full:
                with self._lock:
                    self.stats.dropped_full += 1
                    self._inflight.pop(task.key, None)
        return n

    def _take_token(self) -> bool:
        now = time.monotonic()
        if self._rate == float("inf"):
            self._tokens = 1e9
        else:
            self._tokens = min(
                self._tokens + (now - self._last_refill) * self._rate
                + self._rate_req,
                max(self._rate, self._rate_req, 1.0))
        self._last_refill = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return True
        return False

    # -- worker side -------------------------------------------------------
    def _flush_delayed(self) -> None:
        """Re-enqueue every parked retry whose backoff deadline passed.
        Called from the worker loops (<=0.1 s latency via the queue-get
        timeout) and the reaper sweep."""
        while True:
            with self._lock:
                if not self._delayed \
                        or self._delayed[0][0] > time.monotonic():
                    return
                _, _, task = heapq.heappop(self._delayed)
            try:
                self.q.put_nowait(task)
            except queue.Full:
                self._abandon_copy(task.key)

    def _run(self):
        while not self._stop.is_set():
            self._flush_delayed()
            try:
                task = self.q.get(timeout=0.1)
            except queue.Empty:
                continue
            try:
                verdict = as_verdict(self.judge_fn(task.payload))
                action = self.actions.get(verdict.outcome)
                with self._lock:
                    self.stats.judged += 1
                    # first completion wins: a re-dispatched duplicate
                    # arriving after the winner popped the key skips
                    # the action (which is idempotent anyway)
                    live = task.key in self._inflight
                if live and action is not None:
                    # idempotent upsert — safe under duplicate dispatch.
                    # The key stays inflight until the action lands,
                    # so a transient failure hits the retry path below
                    # instead of being dropped, and drain() keeps
                    # waiting through the backoff.
                    action(task.payload)
                with self._lock:
                    won = live and self._inflight.pop(task.key,
                                                      None) is not None
                    if not won:  # another copy won first
                        self.stats.duplicate_completions += 1
                    elif verdict.outcome == APPROVE:
                        self.stats.approved += 1
                    elif verdict.outcome == REWRITE:
                        self.stats.rewritten += 1
                    else:
                        self.stats.rejected += 1
                        # rewrite-path degradation flags stamped by the
                        # judge wrapper (policy._judge_payload)
                        if task.payload.get("rewrite_failed"):
                            self.stats.rewrite_failed += 1
                        if task.payload.get("rewrite_rate_limited"):
                            self.stats.rewrite_rate_limited += 1
            except Exception:  # noqa: BLE001 — transient failure: retry
                task.attempts += 1
                if task.attempts < self._max_attempts:
                    # deadline-based requeue: park the task until its
                    # backoff expires (no worker sleeps) and push the
                    # inflight dispatch clock to that deadline, so the
                    # straggler reaper — which fires on `now - e[0] >
                    # deadline` — cannot re-dispatch a task that is
                    # merely backing off (duplicate judge calls,
                    # inflated copy counts)
                    ready_at = time.monotonic() \
                        + self._backoff * (2 ** task.attempts)
                    with self._lock:
                        self.stats.retried += 1
                        entry = self._inflight.get(task.key)
                        if entry is not None:
                            entry[0] = ready_at
                        heapq.heappush(self._delayed,
                                       (ready_at, next(self._seq), task))
                else:
                    self._abandon_copy(task.key)

    def _abandon_copy(self, key: tuple) -> None:
        """One copy of an inflight task failed terminally. The key only
        leaves the set when no copy remains, so a failed re-dispatched
        duplicate cannot orphan a straggler that later completes."""
        with self._lock:
            entry = self._inflight.get(key)
            if entry is None:
                return            # another copy already completed it
            self.stats.failed += 1
            entry[2] -= 1
            if entry[2] <= 0:
                self._inflight.pop(key, None)

    def _reap_stragglers(self):
        """Re-dispatch tasks stuck past the deadline to another worker
        (straggler mitigation, §3.1): a duplicate of the stuck task is
        re-enqueued; whichever copy completes first pops the inflight
        key and wins, the loser sees the key gone and skips the
        (idempotent) promote."""
        while not self._stop.is_set():
            self._stop.wait(self._deadline / 2)
            self._flush_delayed()
            now = time.monotonic()
            with self._lock:
                stuck = [(k, e) for k, e in self._inflight.items()
                         if now - e[0] > self._deadline]
                for _, e in stuck:
                    e[0] = now
            for k, e in stuck:
                dup = VerifyTask(k, e[1].payload, attempts=e[1].attempts)
                try:
                    self.q.put_nowait(dup)
                    with self._lock:
                        self.stats.redispatched += 1
                        entry = self._inflight.get(k)
                        if entry is not None:
                            entry[2] += 1
                except queue.Full:
                    pass   # still tracked; next sweep retries

    def depth(self) -> dict:
        """Live queue-depth telemetry (the load harness plots this over
        time — queue depth only delays promotions, §3.1): tasks waiting
        in the queue and keys dispatched but not yet completed."""
        with self._lock:
            return {"queued": self.q.qsize(),
                    "inflight": len(self._inflight),
                    "backing_off": len(self._delayed)}

    def drain(self, timeout_s: float = 30.0):
        """Block until the queue is empty (tests / shutdown only)."""
        t0 = time.monotonic()
        while (not self.q.empty() or self._inflight) \
                and time.monotonic() - t0 < timeout_s:
            time.sleep(0.01)

    def stop(self):
        self._stop.set()
