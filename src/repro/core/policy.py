"""Live (host-level) tiered semantic cache policies.

``BaselinePolicy`` = Algorithm 1 (GPTCache-style static thresholds).
``KritesPolicy``   = Algorithm 2: identical serving path + grey-zone
                     trigger feeding the async VerifyAndPromote pool.

These wrap the functional JAX tiers for production serving (the trace
simulator in core/simulate.py is the batched twin used for evaluation).
The backend, embedder and judge are injected callables, so the same policy
fronts an LLM engine, a GNN, or a recsys scorer (DESIGN.md §5). The
static-tier lookup is likewise injectable: pass ``index=`` (a
``FlatIndex`` or — for million-entry tiers — an ``IVFIndex``, DESIGN.md
§11) and both serving entry points route their static top-1 through it;
the default (None) stays the exact flat/simsearch path.

Two serving entry points share one decision procedure:

- ``serve(prompt)``        — scalar path, one request at a time;
- ``serve_batch(prompts)`` — the batched hot path (DESIGN.md §7): embeds
  the whole micro-batch at once, does ONE fused static-tier lookup via
  ``kernels/simsearch`` (Pallas on TPU, jnp reference elsewhere) and ONE
  masked dynamic-tier lookup against the tier snapshot, then resolves rows
  in request order so results are identical to calling ``serve`` per row.
  Misses go to the backend as a single batch (amortized prefill),
  grey-zone triggers are bulk-enqueued to the VerifyAndPromote pool, and
  all tier mutations land as one fused scatter at the end of the batch.

The policy keeps small host-side mirrors of the dynamic tier's decision
metadata (valid / last_used / static_origin / written_at) so per-row
bookkeeping (LRU slot choice, provenance reads, the promotion LWW
guard) never costs a device round-trip; the functional JAX tier stays
the source of truth for state that is looked up, checkpointed, or
sharded. Every mutation path (scalar serve, batch serve, async promote)
updates both under ``dyn_lock``.

**Multi-device serving (DESIGN.md §13).** Pass ``mesh=`` and the whole
serving path becomes mesh-aware: the static top-1 runs row-sharded
through ``sharded_cosine_topk`` (or inject a ``ShardedIVFIndex`` via
``index=`` for the ANN twin), the dynamic lookup through the
row-sharded masked top-1 with global-slot merge, and every tier write —
scalar insert, batched ``_bulk_insert``, LRU touches, async promotion —
lands on the owning shard as a shard-local scatter without ever
gathering the tier. Serving decisions are identical to the
single-device path on any shard count (test-enforced): scores are
bit-equal and the shard merge keeps the lowest-index tie rule.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive as A
from repro.core import tiers as T
from repro.core.async_queue import VerifyAndPromotePool
from repro.core.exact_tier import ExactTier, canonicalize
from repro.core.judge import APPROVE, REJECT, REWRITE, Verdict, as_verdict
from repro.index.flat import l2_normalize, masked_cosine_topk

_BIG = np.int64(2**30)   # host twin of tiers.BIG (LRU key for invalid rows)


@jax.jit
def _masked_dyn_topk(emb, valid, q):
    """Dynamic-tier top-1 through the public masked index path. Tier
    rows are L2-normalized on insert, so ``corpus_normalized=True``
    skips the per-lookup corpus renormalization (a full (C, d) pass
    the old path paid on every call). Shared across policies: one
    compile per (capacity, batch) shape."""
    vals, idx = masked_cosine_topk(q, emb, valid, k=1,
                                   corpus_normalized=True)
    return vals[:, 0], idx[:, 0]


@jax.jit
def _bulk_insert(dyn: T.DynamicTier, V, slots, rows, ts, cls, exps=None
                 ) -> T.DynamicTier:
    """Scatter a batch's inserts into the tier in one fused update.
    Callers pad ``slots``/``rows``/``ts``/``cls``/``exps`` to a fixed
    length by repeating their first entry (identical values, so the
    duplicate scatter is benign) — keeping shapes static across
    batches. ``exps=None`` means no per-entry expiry (0), matching the
    ``sharded_bulk_insert`` twin."""
    if exps is None:
        exps = jnp.zeros_like(jnp.asarray(ts, jnp.int32))
    return dyn._replace(
        emb=dyn.emb.at[slots].set(V[rows]),
        cls=dyn.cls.at[slots].set(cls),
        answer_ref=dyn.answer_ref.at[slots].set(jnp.int32(-1)),
        static_origin=dyn.static_origin.at[slots].set(False),
        valid=dyn.valid.at[slots].set(True),
        written_at=dyn.written_at.at[slots].set(ts),
        expires_at=dyn.expires_at.at[slots].set(exps))


def _pad_to(arr: np.ndarray, n: int) -> np.ndarray:
    if len(arr) == n:
        return arr
    return np.concatenate([arr, np.repeat(arr[:1], n - len(arr), axis=0)])


def _usable_rows(V_np: np.ndarray) -> np.ndarray:
    """Which rows of an already-normalized (B, d) block are servable
    cache keys. ``l2_normalize`` maps a zero embedding to zero (its
    cosine against everything is 0, so argmax picks an arbitrary row)
    and passes NaN/inf through — and a non-finite key *inserted* into
    the tier poisons every later argmax over it. A good normalized row
    has unit norm, so ``> 0.5`` cleanly separates degenerate rows
    without chasing float error."""
    return np.isfinite(V_np).all(axis=-1) \
        & (np.linalg.norm(V_np, axis=-1) > 0.5)


@dataclass
class ServeResult:
    answer: object
    served_by: str   # 'l1' | 'static' | 'dynamic' | 'rewritten' | 'backend'
    static_origin: bool
    similarity: float
    latency_s: float
    # meta flags the freshness layer sets (DESIGN.md §16):
    #   "stale": True   — volatile hit whose content predates the
    #                     current drift epoch
    #   "bypass": "volatile" — served backend-only, cache skipped
    meta: dict = field(default_factory=dict)


class BaselinePolicy:
    """Algorithm 1. The dynamic tier is guarded by a lock so async
    promotions (Krites subclass) can't race the serving loop."""

    def __init__(self, cfg: T.CacheConfig, static_tier: T.StaticTier,
                 static_answers, embed_fn: Callable,
                 backend_fn: Callable, d: int, *,
                 embed_batch_fn: Optional[Callable] = None,
                 backend_batch_fn: Optional[Callable] = None,
                 index=None, dyn_index=None, static_texts=None,
                 mesh=None, shard_axis: str = "model", fused=None,
                 l1=None, freshness=None, adaptive=None):
        self.cfg = cfg
        self.static = static_tier
        # online threshold controller (core/adaptive.py, DESIGN.md §17):
        # when set, every serving path reads its live per-segment
        # (tau_static, tau_dynamic) under dyn_lock instead of the pinned
        # cfg values, and served requests are recorded into its bounded
        # window. None (or a frozen controller) keeps serving
        # bit-identical to the pinned-threshold policy.
        self.adaptive = adaptive
        # L1 exact-match front tier (DESIGN.md §16): an ExactTier, an
        # int capacity, or None (off). Probed on the canonical prompt
        # BEFORE the embedder — an L1 hit skips embed + both semantic
        # lookups entirely. Composable with every lookup config below
        # (index/dyn_index/mesh/fused): it sits strictly in front.
        self.l1 = ExactTier(capacity=l1) if isinstance(l1, int) else l1
        # staleness-risk layer (core/freshness.py): volatile-query
        # bypass, per-class TTLs for L1 + write-back entries, and the
        # drift clock for stale accounting. None = classic behaviour.
        self.freshness = freshness
        self._l1_hits = 0
        self._l1_bypass = 0
        self._stale_serves = 0
        self._ttl_evictions = 0
        # flips True at the first write that stamps a finite expiry; the
        # eager expiry sweep is a no-op until then, so TTL-free serving
        # pays nothing
        self._ttl_active = False
        # injectable static-tier index (FlatIndex/IVFIndex/
        # ShardedIVFIndex, DESIGN.md §11/§13); None = exact flat lookup
        self.index = index
        # injectable fused serve path (kernels/fused_serve, DESIGN.md
        # §15): ONE dispatch for the static IVF probe + the masked
        # dynamic top-1. Flag-gated and exclusive — it replaces both
        # lookups, so composing it with another index/mesh config would
        # silently shadow that config's lookup semantics.
        if fused is not None and (index is not None
                                  or dyn_index is not None
                                  or mesh is not None):
            raise ValueError(
                "fused= replaces both tier lookups; it cannot be "
                "combined with index=, dyn_index= or mesh=")
        self.fused = fused
        # injectable dynamic-tier index (SegmentedIndex, DESIGN.md §12);
        # None = exact flat masked scan. "segmented" builds the default.
        if dyn_index == "segmented":
            from repro.index.segmented import SegmentedIndex
            dyn_index = SegmentedIndex(cfg.capacity, d)
        self.dyn_index = dyn_index
        self.static_answers = static_answers
        # prompt texts of the curated entries, aligned with the tier
        # rows: the judge verifies on the (q_text, h_text, answer)
        # triple, so grey-zone payloads need the neighbor's real text
        self.static_texts = list(static_texts) if static_texts is not None \
            else None
        self.embed_fn = embed_fn
        self.backend_fn = backend_fn
        self.embed_batch_fn = embed_batch_fn
        self.backend_batch_fn = backend_batch_fn
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.dyn = T.make_dynamic_tier(cfg.capacity, d)
        self.dyn_answers: list = [None] * cfg.capacity
        self.dyn_lock = threading.Lock()
        self.t = 0
        self.events: list = []
        # host-side copies of the (immutable) static-tier metadata: the
        # serving loop indexes these per request, which must not cost a
        # device round-trip each time
        self._static_ref_np = np.asarray(static_tier.answer_ref)
        self._static_cls_np = np.asarray(static_tier.cls)
        # host mirrors of the dynamic tier's decision metadata
        self._valid_np = np.zeros(cfg.capacity, bool)
        self._last_used_np = np.zeros(cfg.capacity, np.int64)
        self._static_origin_np = np.zeros(cfg.capacity, bool)
        self._written_at_np = np.zeros(cfg.capacity, np.int64)
        self._expires_np = np.zeros(cfg.capacity, np.int64)
        # rewrite provenance (DESIGN.md §18): True for entries whose
        # answer is a REWRITE-verdict tailored variant, not the curated
        # static text. Device twin: ``answer_ref == -2`` sentinel — that
        # column is what snapshots/restores derive this mirror from.
        self._rewritten_np = np.zeros(cfg.capacity, bool)
        if mesh is None:
            self._touch_many = jax.jit(T.touch_many)
            self._bulk_insert_fn = _bulk_insert
            self._write_fn = T._write
        else:
            self._init_mesh(d)

    def _init_mesh(self, d: int) -> None:
        """Mesh mode (DESIGN.md §13): place the tiers row-sharded and
        swap every lookup/scatter primitive for its shard-routed twin
        from ``index/sharded.py``. The host mirrors and all decision
        logic are unchanged — only the device primitives differ — which
        is what keeps sharded serving decision-identical."""
        from repro.index import sharded as Sh
        mesh, axis = self.mesh, self.shard_axis
        n_shards = mesh.shape[axis]
        if self.dyn_index is not None:
            raise ValueError(
                "dyn_index + mesh is not supported yet: the segmented "
                "index reranks against a host-managed layout; the "
                "sharded dynamic path uses the exact row-sharded "
                "masked scan (DESIGN.md §13)")
        assert self.cfg.capacity % n_shards == 0, \
            (self.cfg.capacity, n_shards)
        # static corpus: pad to a shard multiple with copies of row 0
        # (never returned — stable merge prefers the real row) and keep
        # it device-resident row-sharded; host metadata mirrors keep
        # their original (unpadded) length. An injected index (e.g.
        # ShardedIVFIndex) owns the static lookup instead, so skip the
        # duplicate device-resident corpus copy then.
        if self.index is None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            self._static_mesh_tier = self.static._replace(
                emb=jax.device_put(
                    Sh.pad_rows(self.static.emb, n_shards),
                    NamedSharding(mesh, P(axis, None))))
            self._sh_static_fn = jax.jit(functools.partial(
                T.static_lookup_batch, mesh=mesh, shard_axis=axis))
        self.dyn = Sh.shard_dynamic_tier(self.dyn, mesh, axis)
        self._sh_dyn_fn = jax.jit(functools.partial(
            T.dynamic_lookup_batch, mesh=mesh, shard_axis=axis))
        self._touch_many = jax.jit(functools.partial(
            Sh.sharded_touch_many, mesh=mesh, axis=axis))
        self._bulk_insert_fn = jax.jit(functools.partial(
            Sh.sharded_bulk_insert, mesh=mesh, axis=axis))
        self._write_fn = jax.jit(functools.partial(
            Sh.sharded_dyn_write, mesh=mesh, axis=axis))

    def _serve_static(self, idx: int):
        return self.static_answers[int(self._static_ref_np[idx])]

    def _static_topk_batch(self, V: jax.Array):
        """Static-tier top-1 for a (B, d) block through whichever path
        is configured: injected index, sharded exact scan, or the fused
        single-device kernel."""
        if self.index is not None:
            return T.static_lookup_batch(self.static, V, index=self.index)
        if self.mesh is not None:
            return self._sh_static_fn(self._static_mesh_tier, V)
        return T.static_lookup_batch(self.static, V)

    def _dyn_topk(self, dyn: T.DynamicTier, q: jax.Array):
        """Dynamic-tier top-1 for a (B, d) query block: exact masked
        matmul, its row-sharded twin (DESIGN.md §13), or the injected
        segmented index (DESIGN.md §12)."""
        if self.dyn_index is not None:
            vals, idx = self.dyn_index.topk(q, dyn.emb, k=1)
            return vals[:, 0], idx[:, 0]
        if self.mesh is not None:
            return self._sh_dyn_fn(dyn, q)
        return _masked_dyn_topk(dyn.emb, dyn.valid, q)

    def _host_lru_slot(self) -> int:
        """Host twin of tiers._lru_slot over the mirrored metadata."""
        key = np.where(self._valid_np, self._last_used_np, -_BIG)
        return int(key.argmin())

    # ------------------------------------------------------------------
    # adaptive thresholds (core/adaptive.py, DESIGN.md §17)
    # ------------------------------------------------------------------

    def _live_taus(self, prompt: str, *, locked: bool = False):
        """The (tau_static, tau_dynamic, segment) this request serves
        under: the controller's live per-segment operating point, or
        the pinned cfg values (segment −1) without a controller.
        Segment classification is pure text work and runs outside any
        lock; the threshold pair is read under ``dyn_lock`` — the one
        source of truth every serving path (scalar, batch, fused, mesh)
        shares with the controller's adaptation writes."""
        if self.adaptive is None:
            return self.cfg.tau_static, self.cfg.tau_dynamic, -1
        seg = A.segment_of(prompt)
        if locked:
            return (self.adaptive.tau_static[seg],
                    self.adaptive.tau_dynamic[seg], seg)
        with self.dyn_lock:
            return (self.adaptive.tau_static[seg],
                    self.adaptive.tau_dynamic[seg], seg)

    def _adapt_record(self, v_np, meta, h_idx, seg, res,
                      *, locked: bool = False) -> None:
        """Append a served semantic request to the controller window.
        The outcome label starts as the caller-declared class
        (``meta['cls']``), falling back to the static neighbor's class;
        judge verdicts / error feedback rewrite it later via the seq
        stamped into ``res.meta['adapt_seq']``."""
        if self.adaptive is None or seg < 0:
            return
        label = int((meta or {}).get("cls", -1))
        if label < 0:
            label = int(self._static_cls_np[h_idx])
        if locked:
            seq = self.adaptive.record(v_np, label, seg)
        else:
            with self.dyn_lock:
                seq = self.adaptive.record(v_np, label, seg)
        res.meta["adapt_seq"] = seq
        res.meta["segment"] = seg

    def _maybe_adapt(self) -> None:
        """Serve-call-boundary adaptation check. Must be called with
        ``dyn_lock`` released — the controller snapshots and installs
        under the lock itself and runs the shadow sweep outside it. The
        scalar path checks after every request (the reference twin's
        cadence); the batched path checks once per batch, so a batch
        may overshoot ``adapt_every`` by up to B−1 records — the same
        deliberate batching relaxation as the L1 write-back order."""
        if self.adaptive is not None:
            self.adaptive.maybe_adapt(self.dyn_lock, self.static.emb,
                                      self.static.cls)

    # -- hooks for Krites (no-ops in the baseline) -------------------------
    def _after_static_miss(self, prompt, v, h_idx, s_static, res, meta,
                           tau_s=None):
        return

    def _after_static_miss_batch(self, rows) -> None:
        return

    def serve(self, prompt: str, meta: Optional[dict] = None) -> ServeResult:
        """Scalar serving entry. With the freshness subsystem wired
        (DESIGN.md §16) the decision procedure gains two stages strictly
        in FRONT of the classic semantic path:

        1. volatile bypass — a volatile-classified query (with
           ``volatile_bypass``) goes straight to the backend: no L1
           read/write, no embed, no tier lookup, no write-back, no
           grey-zone trigger;
        2. L1 probe — an exact-match hit on the canonical prompt serves
           in O(1), skipping the embedder and BOTH semantic lookups.

        Every non-bypassed serve outcome is written back to L1 with its
        freshness-class expiry, so byte-identical repeats short-circuit
        next time. Semantic decisions for L1 misses are unchanged.
        """
        t0 = time.monotonic()
        self.t += 1
        volatile = self._is_volatile(prompt)
        if volatile and self.freshness.volatile_bypass:
            self._l1_bypass += 1
            answer = self.backend_fn(prompt)
            res = ServeResult(answer, "backend", False, 0.0,
                              time.monotonic() - t0,
                              meta={"bypass": "volatile"})
            self.events.append((res.served_by, res.static_origin))
            self._maybe_adapt()
            return res
        key = None
        if self.l1 is not None:
            key = canonicalize(prompt)
            e = self.l1.get(key, self.t)
            if e is not None:
                self._l1_hits += 1
                res = ServeResult(e.answer, "l1", e.static_origin, 1.0,
                                  time.monotonic() - t0)
                self._mark_stale(res, volatile, e.content_t, self.t)
                self.events.append((res.served_by, res.static_origin))
                self._maybe_adapt()
                return res
        res, content_t = self._serve_semantic(prompt, meta, t0)
        self._mark_stale(res, volatile, content_t, self.t)
        if self.l1 is not None:
            self.l1.put(key, res.answer,
                        static_origin=res.static_origin,
                        content_t=content_t,
                        expires_at=self._entry_expiry(prompt, self.t),
                        now=self.t)
        self._maybe_adapt()
        return res

    def _serve_semantic(self, prompt: str, meta: Optional[dict],
                        t0: float):
        """The classic (Alg. 1) decision procedure for one request at
        tick ``self.t`` (already advanced by the caller). Returns
        ``(ServeResult, content_t)`` — the content clock is what the
        served answer's generation time is for drift accounting: 0 for
        curated static answers, the entry's ``written_at`` for dynamic
        hits, the current tick for fresh backend answers."""
        v = l2_normalize(jnp.asarray(self.embed_fn(prompt), jnp.float32))
        if not _usable_rows(np.asarray(v)[None])[0]:
            # degenerate embedding (zero / non-finite): serve via the
            # backend without caching — inserting it would poison the
            # tier's argmax for every later request — and without a
            # grey trigger (a promotion would insert the same key)
            answer = self.backend_fn(prompt)
            res = ServeResult(answer, "backend", False, 0.0,
                              time.monotonic() - t0)
            self.events.append((res.served_by, res.static_origin))
            return res, self.t
        tau_s, tau_d, seg = self._live_taus(prompt)
        content_t = self.t        # backend answers are generated now
        if self.fused is not None:
            # fused fast path (DESIGN.md §15): BOTH tier lookups in one
            # dispatch, under the lock so the touch below lands on the
            # very tier snapshot the lookup scanned
            with self.dyn_lock:
                self._sweep_expired_locked(self.t)
                ssb, hib, sdb, jdb = jax.device_get(
                    T.serve_lookup_batch(self.static, self.dyn, v[None],
                                         self.fused))
                s_s, h_idx = float(ssb[0]), int(hib[0])
                s_d, j = float(sdb[0]), int(jdb[0])
                res = None
                if s_s < tau_s and s_d >= tau_d:
                    self.dyn = T.touch(self.dyn, j, self.t)
                    self._last_used_np[j] = self.t
                    content_t = int(self._written_at_np[j])
                    by = "rewritten" if self._rewritten_np[j] \
                        else "dynamic"
                    res = ServeResult(self.dyn_answers[j], by,
                                      bool(self._static_origin_np[j]),
                                      s_d, time.monotonic() - t0)
            if s_s >= tau_s:
                res = ServeResult(self._serve_static(h_idx), "static",
                                  True, s_s, time.monotonic() - t0)
                self._adapt_record(np.asarray(v), meta, h_idx, seg, res)
                self.events.append((res.served_by, res.static_origin))
                return res, 0
        else:
            if self.index is not None:
                sv, si = self.index.topk(v[None], 1)
                s_s, h_idx = sv[0, 0], si[0, 0]
            elif self.mesh is not None:
                sv, si = self._sh_static_fn(self._static_mesh_tier,
                                            v[None])
                s_s, h_idx = sv[0], si[0]
            else:
                s_s, h_idx = T.static_lookup(self.static, v)
            s_s, h_idx = float(s_s), int(h_idx)
            if s_s >= tau_s:
                res = ServeResult(self._serve_static(h_idx), "static",
                                  True, s_s, time.monotonic() - t0)
                self._adapt_record(np.asarray(v), meta, h_idx, seg, res)
                self.events.append((res.served_by, res.static_origin))
                return res, 0

            with self.dyn_lock:
                self._sweep_expired_locked(self.t)
                sd, jd = self._dyn_topk(self.dyn, v[None])
                s_d, j = float(sd[0]), int(jd[0])
                if s_d >= tau_d:
                    if self.mesh is None:
                        self.dyn = T.touch(self.dyn, j, self.t)
                    else:   # owner-local scatter, batch-shaped
                        self.dyn = self._touch_many(
                            self.dyn, np.asarray([j]),
                            np.asarray([self.t]))
                    self._last_used_np[j] = self.t
                    content_t = int(self._written_at_np[j])
                    by = "rewritten" if self._rewritten_np[j] \
                        else "dynamic"
                    res = ServeResult(self.dyn_answers[j], by,
                                      bool(self._static_origin_np[j]),
                                      s_d, time.monotonic() - t0)
                else:
                    res = None

        if res is None:
            answer = self.backend_fn(prompt)   # outside the lock
            exp = self._entry_expiry(prompt, self.t)
            with self.dyn_lock:
                slot = self._host_lru_slot()
                self.dyn = self._write_fn(
                    self.dyn, slot, v,
                    jnp.int32((meta or {}).get("cls", -1)),
                    jnp.int32(-1), jnp.asarray(False), self.t,
                    expires=exp)
                self._mirror_write(slot, self.t, static_origin=False,
                                   expires=exp)
                if self.dyn_index is not None:
                    self.dyn_index.record_write(slot, np.asarray(v))
                self.dyn_answers[slot] = answer
            content_t = self.t
            res = ServeResult(answer, "backend", False, s_d,
                              time.monotonic() - t0)

        self._adapt_record(np.asarray(v), meta, h_idx, seg, res)
        self.events.append((res.served_by, res.static_origin))
        # Alg. 2 line 13: grey-zone test on EVERY static miss (dyn hit or
        # backend call alike); non-blocking, off the critical path.
        # The gate uses the SAME live tau_static that made this serving
        # decision — a concurrent adaptation must not widen/narrow the
        # grey zone out from under a decision already taken.
        self._after_static_miss(prompt, v, h_idx, s_s, res, meta, tau_s)
        return res, content_t

    def _mirror_write(self, slot: int, now: int, static_origin: bool,
                      written_at: Optional[int] = None,
                      expires: int = 0, rewritten: bool = False):
        """Host twin of a tier row write. ``now`` is the LRU clock;
        ``written_at`` (the LWW clock) defaults to it, but async
        promotions pass their enqueue time — same split as
        ``tiers._write``. ``expires`` stamps the per-entry expiry
        mirror (0 = never); ``rewritten`` marks a REWRITE-verdict
        tailored variant (DESIGN.md §18)."""
        self._valid_np[slot] = True
        self._last_used_np[slot] = now
        self._static_origin_np[slot] = static_origin
        self._written_at_np[slot] = now if written_at is None \
            else written_at
        self._expires_np[slot] = expires
        self._rewritten_np[slot] = rewritten
        if expires > 0:
            self._ttl_active = True

    # ------------------------------------------------------------------
    # freshness layer (DESIGN.md §16)
    # ------------------------------------------------------------------

    def _sweep_expired_locked(self, now: int) -> int:
        """Eagerly invalidate dynamic-tier entries past their
        ``expires_at`` (expired iff ``now > expires_at > 0``). Called
        under ``dyn_lock`` at the head of every serve/promote critical
        section, so lookups never see an expired row — the host twin of
        ``tiers.evict_expired(tier, now)``. Tombstones any injected
        dynamic index (the one mutation it can't observe through
        ``record_write``). Returns how many entries died."""
        if not self._ttl_active:
            return 0
        dead = np.nonzero(self._valid_np & (self._expires_np > 0)
                          & (self._expires_np < now))[0]
        if len(dead) == 0:
            return 0
        self._valid_np[dead] = False
        self._expires_np[dead] = 0
        self._rewritten_np[dead] = False
        idx = jnp.asarray(dead)
        self.dyn = self.dyn._replace(
            valid=self.dyn.valid.at[idx].set(False),
            expires_at=self.dyn.expires_at.at[idx].set(0))
        for s in dead:
            if self.dyn_index is not None:
                self.dyn_index.invalidate(int(s))
            self.dyn_answers[int(s)] = None
        self._ttl_evictions += len(dead)
        return len(dead)

    def _is_volatile(self, prompt: str) -> bool:
        return self.freshness is not None \
            and self.freshness.is_volatile(prompt)

    def _entry_expiry(self, prompt: str, now: int) -> int:
        """Per-entry expiry stamp for a cache write at tick ``now``:
        the freshness policy's class TTL, else the legacy global
        ``cfg.ttl`` (0 = never)."""
        if self.freshness is not None:
            return self.freshness.expires_at(prompt, now)
        return now + self.cfg.ttl if self.cfg.ttl > 0 else 0

    def _mark_stale(self, res: ServeResult, volatile: bool,
                    content_t: int, now: int) -> None:
        """Drift-clock stale accounting for a served hit (never for
        backend answers — those are fresh by construction)."""
        if self.freshness is None or res.served_by == "backend":
            return
        if self.freshness.is_stale(volatile, content_t, now):
            res.meta["stale"] = True
            self._stale_serves += 1

    # ------------------------------------------------------------------
    # batched serving path
    # ------------------------------------------------------------------

    def _embed_batch(self, prompts: Sequence[str]) -> jax.Array:
        if self.embed_batch_fn is not None:
            emb = self.embed_batch_fn(prompts)
        else:
            batch = getattr(self.embed_fn, "batch", None)
            emb = batch(list(prompts)) if batch is not None else \
                np.stack([np.asarray(self.embed_fn(p)) for p in prompts])
        return l2_normalize(jnp.asarray(emb, jnp.float32))

    def _backend_batch(self, prompts: List[str]) -> List[object]:
        if self.backend_batch_fn is not None:
            return list(self.backend_batch_fn(prompts))
        return [self.backend_fn(p) for p in prompts]

    def _snap_best_excluding(self, snap: T.DynamicTier, v, exclude):
        """Masked top-1 over the batch-start snapshot with ``exclude``d
        slots removed — the rare repair when an intra-batch insert evicts
        the snapshot argmax of a later row."""
        excl = np.zeros(self.cfg.capacity, bool)
        excl[list(exclude)] = True
        sims = jnp.where(jnp.logical_and(snap.valid,
                                         jnp.asarray(~excl)),
                         snap.emb @ v, -jnp.inf)
        j = int(jnp.argmax(sims))
        return float(sims[j]), j

    def serve_batch(self, prompts: Sequence[str],
                    metas: Optional[Sequence[Optional[dict]]] = None
                    ) -> List[ServeResult]:
        """Serve a micro-batch. Equivalent, request for request, to
        calling :meth:`serve` on each prompt in order (same answers,
        served_by, static_origin and promotions); the fast primitives are
        batched instead of per-row.

        The dynamic-tier lock is held for the whole batch (backend call
        included), so concurrent promotions land between batches — they
        are asynchronous anyway, and this keeps the in-batch decision
        sequence deterministic.

        If the batched backend call raises, the batch's inserts are
        rolled back (no answerless cache entries) and the exception
        propagates; hits decided before the failure keep their LRU
        touches, mirroring the scalar path's failure behavior.

        Freshness front (DESIGN.md §16): volatile-bypass rows and L1
        exact-match hits are resolved BEFORE the embedder runs — only
        the remaining rows are embedded and looked up, so a pure-repeat
        batch costs zero embed calls and zero tier dispatches. Ticks
        are assigned to every row (front-resolved or not) in request
        order, so decisions equal the scalar path's. One deliberate
        relaxation: L1 write-backs land at the end of the batch, so
        under L1 *capacity pressure within a single batch* the LRU
        eviction order can differ from scalar serving (the semantic
        decisions never do).
        """
        if not prompts:
            return []
        t0 = time.monotonic()
        B = len(prompts)
        metas = list(metas) if metas is not None else [None] * B
        fresh = self.freshness

        # --- freshness front: resolve bypass + L1 rows pre-embedding ---
        front: dict = {}     # row -> ("bypass",)|("hit", entry)|("dup", p)
        keys: List[Optional[str]] = [None] * B
        vol = [False] * B
        exp_of = [0] * B     # L1 expiry stamp for producer rows
        if fresh is not None or self.l1 is not None:
            pend: dict = {}  # canon key -> (producer row, expires_at)
            for i in range(B):
                ti = self.t + i + 1
                volatile = fresh is not None \
                    and fresh.is_volatile(prompts[i])
                vol[i] = volatile
                if volatile and fresh.volatile_bypass:
                    front[i] = ("bypass",)
                    continue
                if self.l1 is None:
                    continue
                k = canonicalize(prompts[i])
                keys[i] = k
                e = self.l1.get(k, ti)
                if e is not None:
                    front[i] = ("hit", e)
                elif k in pend and (pend[k][1] == 0
                                    or ti <= pend[k][1]):
                    front[i] = ("dup", pend[k][0])
                else:
                    exp_of[i] = self._entry_expiry(prompts[i], ti)
                    pend[k] = (i, exp_of[i])
        sem = [i for i in range(B) if i not in front]
        pos_of = {i: p for p, i in enumerate(sem)}

        # pad the semantic sub-batch to a power-of-two bucket: device
        # shapes (and the compiled executables behind them) stay fixed
        # across the varying batch sizes a router produces
        V = V_np = ok = s_sb = h_idxb = None
        Bp = 1
        if sem:
            Bs = len(sem)
            Bp = 1 << (Bs - 1).bit_length()
            V = self._embed_batch([prompts[i] for i in sem])   # (Bs, d)
            if Bp != Bs:
                V = jnp.pad(V, ((0, Bp - Bs), (0, 0)))
            # degenerate-embedding guard (same contract as the scalar
            # path): zero out unusable rows so one NaN can't leak
            # through the fused lookups, and serve them backend-only
            # further down — never cached, never grey-triggered
            ok = _usable_rows(np.asarray(V)[:Bs])
            if not ok.all():
                V = jnp.where(
                    jnp.asarray(np.pad(ok, (0, Bp - Bs)))[:, None],
                    V, 0.0)
            V_np = np.asarray(V)[:Bs]
            if self.fused is None:
                s_sb, h_idxb = jax.device_get(
                    self._static_topk_batch(V))               # fused top-1
                s_sb, h_idxb = s_sb[:Bs], h_idxb[:Bs]

        results: List[Optional[ServeResult]] = [None] * B
        content_of = [0] * B    # per-row content clock (drift accounting)
        grey_rows = []          # static-miss rows, for the Krites hook
        l1_dup_fill = []        # (row, producer row) — answer arrives late
        ev0 = len(self.events)  # rollback point: a failed batch serves
        with self.dyn_lock:     # nobody, so it must record no events
            # one masked lookup against the dynamic-tier snapshot; the
            # tier object is immutable, so `snap` stays the batch-start
            # state while mutations accumulate on the host
            snap = self.dyn
            if sem:
                if self.fused is not None:
                    # fused fast path (DESIGN.md §15): static probe +
                    # masked dynamic top-1 in ONE dispatch over the batch
                    s_sb, h_idxb, s_db, j_db = jax.device_get(
                        T.serve_lookup_batch(self.static, snap, V,
                                             self.fused))
                    s_sb, h_idxb = s_sb[:len(sem)], h_idxb[:len(sem)]
                else:
                    s_db, j_db = jax.device_get(self._dyn_topk(snap, V))
                s_db, j_db = s_db[:len(sem)], j_db[:len(sem)]

            written: dict = {}   # slot -> (row, pos) of its last writer
            w_meta: dict = {}    # slot -> (pos, t, cls, exp) bulk write
            saved: dict = {}     # slot -> pre-write mirror state (rollback)
            touched: set = set()
            excl: set = set()    # snapshot rows invalidated this batch
            dead: set = set()    # slots TTL-expired mid-batch
            backend_rows: List[int] = []
            backend_slots: List[int] = []
            deferred = []        # (row, producer row)

            for i in range(B):
                self.t += 1
                ti = self.t
                f = front.get(i)
                if f is not None:
                    if f[0] == "bypass":
                        self._l1_bypass += 1
                        backend_rows.append(i)
                        backend_slots.append(-1)
                        results[i] = ServeResult(
                            None, "backend", False, 0.0, 0.0,
                            meta={"bypass": "volatile"})
                        self.events.append(("backend", False))
                    elif f[0] == "hit":
                        e = f[1]
                        self._l1_hits += 1
                        results[i] = ServeResult(e.answer, "l1",
                                                 e.static_origin, 1.0,
                                                 0.0)
                        content_of[i] = e.content_t
                        self._mark_stale(results[i], vol[i],
                                         e.content_t, ti)
                        self.events.append(("l1", e.static_origin))
                    else:       # in-batch duplicate of a producer row
                        p = f[1]
                        self._l1_hits += 1
                        results[i] = ServeResult(
                            results[p].answer, "l1",
                            results[p].static_origin, 1.0, 0.0)
                        content_of[i] = content_of[p]
                        self._mark_stale(results[i], vol[i],
                                         content_of[p], ti)
                        self.events.append(("l1",
                                            results[p].static_origin))
                        if results[p].answer is None:
                            l1_dup_fill.append((i, p))
                    continue
                pos = pos_of[i]
                if not ok[pos]:
                    # backend-only: slot sentinel -1 skips the cache
                    # write when the batched answers come back
                    backend_rows.append(i)
                    backend_slots.append(-1)
                    results[i] = ServeResult(None, "backend", False,
                                             0.0, 0.0)
                    content_of[i] = ti
                    self.events.append(("backend", False))
                    continue
                ss_i, h_i = float(s_sb[pos]), int(h_idxb[pos])
                tau_si, tau_di, seg_i = self._live_taus(prompts[i],
                                                        locked=True)
                if ss_i >= tau_si:
                    results[i] = ServeResult(self._serve_static(h_i),
                                             "static", True, ss_i, 0.0)
                    content_of[i] = 0
                    self._adapt_record(V_np[pos], metas[i], h_i, seg_i,
                                       results[i], locked=True)
                    self._mark_stale(results[i], vol[i], 0, ti)
                    self.events.append(("static", True))
                    continue

                # eager TTL expiry at this row's tick (the batched twin
                # of the scalar path's pre-lookup sweep): mirrors flip
                # now; the device scatter is deferred to batch end
                if self._ttl_active:
                    newly = np.nonzero(
                        self._valid_np & (self._expires_np > 0)
                        & (self._expires_np < ti))[0]
                    for s in newly:
                        s = int(s)
                        self._valid_np[s] = False
                        self._expires_np[s] = 0
                        self._rewritten_np[s] = False
                        if self.dyn_index is not None:
                            self.dyn_index.invalidate(s)
                        self.dyn_answers[s] = None
                        written.pop(s, None)
                        dead.add(s)
                        excl.add(s)
                    self._ttl_evictions += len(newly)

                # dynamic candidate = snapshot best, repaired for slots
                # overwritten/expired this batch, merged with intra-batch
                # inserts
                s_d, j = float(s_db[pos]), int(j_db[pos])
                if j in excl:
                    s_d, j = self._snap_best_excluding(snap, V[pos],
                                                       excl)
                for slot, (wrow, wpos) in written.items():
                    sw = float(V_np[pos] @ V_np[wpos])
                    if sw > s_d or (sw == s_d and slot < j):
                        s_d, j = sw, slot

                if s_d >= tau_di:
                    self._last_used_np[j] = ti
                    touched.add(j)
                    if j in written:  # answer arrives with the batch call
                        origin, by = False, "dynamic"
                        results[i] = ServeResult(None, "dynamic", False,
                                                 s_d, 0.0)
                        deferred.append((i, written[j][0]))
                    else:
                        origin = bool(self._static_origin_np[j])
                        by = "rewritten" if self._rewritten_np[j] \
                            else "dynamic"
                        results[i] = ServeResult(self.dyn_answers[j],
                                                 by, origin, s_d, 0.0)
                    content_of[i] = int(self._written_at_np[j])
                    self._mark_stale(results[i], vol[i], content_of[i],
                                     ti)
                    self.events.append((by, origin))
                else:
                    slot = self._host_lru_slot()
                    if slot not in saved:
                        saved[slot] = (bool(self._valid_np[slot]),
                                       int(self._last_used_np[slot]),
                                       bool(self._static_origin_np[slot]),
                                       int(self._written_at_np[slot]),
                                       int(self._expires_np[slot]),
                                       bool(self._rewritten_np[slot]),
                                       self.dyn_answers[slot])
                    exp = self._entry_expiry(prompts[i], ti)
                    self._mirror_write(slot, ti, static_origin=False,
                                       expires=exp)
                    self.dyn_answers[slot] = None
                    written[slot] = (i, pos)
                    excl.add(slot)
                    dead.discard(slot)
                    w_meta[slot] = (pos, ti,
                                    (metas[i] or {}).get("cls", -1), exp)
                    backend_rows.append(i)
                    backend_slots.append(slot)
                    results[i] = ServeResult(None, "backend", False, s_d,
                                             0.0)
                    content_of[i] = ti
                    self.events.append(("backend", False))
                self._adapt_record(V_np[pos], metas[i], h_i, seg_i,
                                   results[i], locked=True)
                grey_rows.append((prompts[i], V_np[pos], h_i, ss_i,
                                  results[i], metas[i], ti, tau_si))

            # backend first: a failed batch must not commit its inserts
            # (the scalar path likewise only inserts after the backend
            # returns), so a backend outage can't poison the cache with
            # answerless entries
            answers: List[object] = []
            if backend_rows:
                try:
                    # one batched backend call amortizes prefill
                    answers = self._backend_batch(
                        [prompts[i] for i in backend_rows])
                except Exception:
                    for slot, st in saved.items():
                        (self._valid_np[slot], self._last_used_np[slot],
                         self._static_origin_np[slot],
                         self._written_at_np[slot],
                         self._expires_np[slot],
                         self._rewritten_np[slot],
                         self.dyn_answers[slot]) = st
                    del self.events[ev0:]
                    self._apply_batch_writes(V, {}, touched, Bp,
                                             dead=dead)
                    raise
            self._apply_batch_writes(V, w_meta, touched, Bp, dead=dead)
            if backend_rows:
                for slot, i, ans in zip(backend_slots, backend_rows,
                                        answers):
                    # -1 = degenerate/bypass row, never cached; a slot
                    # whose entry TTL-expired mid-batch (or was rewritten
                    # by a later row) must not get this answer either
                    if slot >= 0 and self._valid_np[slot] \
                            and written.get(slot, (None,))[0] == i:
                        self.dyn_answers[slot] = ans
                    results[i].answer = ans
                for i, producer in deferred:
                    results[i].answer = results[producer].answer
                for i, producer in l1_dup_fill:
                    results[i].answer = results[producer].answer

        # L1 write-back: every semantic row's outcome becomes an exact-
        # match entry (in row order, after the batch's answers landed)
        if self.l1 is not None:
            for i in sem:
                self.l1.put(keys[i], results[i].answer,
                            static_origin=results[i].static_origin,
                            content_t=content_of[i],
                            expires_at=exp_of[i],
                            now=self.t - B + i + 1)

        lat = time.monotonic() - t0
        for r in results:
            r.latency_s = lat
        self._after_static_miss_batch(grey_rows)
        self._maybe_adapt()
        return results  # type: ignore[return-value]

    def _apply_batch_writes(self, V: jax.Array, w_meta: dict,
                            touched: set, B: int, dead=()) -> None:
        """Push a batch's accumulated inserts + LRU touches to the JAX
        tier as one fused scatter per field (vs one dispatch per row).
        Index arrays are padded to the batch's power-of-two bucket so
        shapes — and hence compiled executables — stay fixed even when a
        router produces ragged batch sizes. ``dead`` slots (TTL-expired
        mid-batch, mirror-invalid) get their valid bit cleared first;
        inserts into slots the mirrors since invalidated are dropped —
        the mirrors are the source of decision truth within the batch."""
        dyn = self.dyn
        dead = [s for s in dead if not self._valid_np[s]]
        if dead:
            idx = jnp.asarray(sorted(dead))
            dyn = dyn._replace(
                valid=dyn.valid.at[idx].set(False),
                expires_at=dyn.expires_at.at[idx].set(0))
        w_meta = {s: m for s, m in w_meta.items() if self._valid_np[s]}
        if w_meta:
            slots = np.fromiter(w_meta.keys(), np.int64, len(w_meta))
            rows = np.asarray([w_meta[s][0] for s in slots])
            ts = np.asarray([w_meta[s][1] for s in slots], np.int32)
            cls = np.asarray([w_meta[s][2] for s in slots], np.int32)
            exps = np.asarray([w_meta[s][3] for s in slots], np.int32)
            dyn = self._bulk_insert_fn(dyn, V, _pad_to(slots, B),
                                       _pad_to(rows, B), _pad_to(ts, B),
                                       _pad_to(cls, B),
                                       exps=_pad_to(exps, B))
            if self.dyn_index is not None:
                V_np = np.asarray(V)
                for s, r in zip(slots, rows):
                    self.dyn_index.record_write(int(s), V_np[r])
        upd = set(w_meta) | touched
        if upd:
            sl = np.fromiter(upd, np.int64, len(upd))
            dyn = self._touch_many(dyn, _pad_to(sl, B),
                                   _pad_to(self._last_used_np[sl], B))
        self.dyn = dyn

    def describe_index(self) -> str:
        """Telemetry string for the static-tier index in use (router
        stats surface this — serving/router.py)."""
        if self.fused is not None:
            return self.fused.describe()
        if self.index is None:
            S = len(self._static_ref_np)
            if self.mesh is not None:
                return (f"sharded-flat(S={S}, "
                        f"shards={self.mesh.shape[self.shard_axis]})")
            return f"flat-exact(S={S})"
        describe = getattr(self.index, "describe", None)
        return describe() if describe else type(self.index).__name__

    def describe_dyn_index(self) -> str:
        """Telemetry string for the dynamic-tier lookup path."""
        if self.dyn_index is None:
            if self.mesh is not None:
                return (f"sharded-masked(C={self.cfg.capacity}, "
                        f"shards={self.mesh.shape[self.shard_axis]})")
            return f"flat-masked(C={self.cfg.capacity})"
        describe = getattr(self.dyn_index, "describe", None)
        return describe() if describe else type(self.dyn_index).__name__

    def shard_stats(self) -> Optional[dict]:
        """Mesh-serving telemetry (DESIGN.md §13): shard count and the
        per-shard occupancy of the row-sharded dynamic tier, computed
        from the host mirrors (no device round-trip). None when serving
        single-device."""
        if self.mesh is None:
            return None
        n_shards = self.mesh.shape[self.shard_axis]
        occ = self._valid_np.reshape(n_shards, -1).sum(axis=1)
        return {"shards": n_shards,
                "shard_occupancy": [int(x) for x in occ]}

    def dyn_index_stats(self) -> Optional[dict]:
        """Segment/tail occupancy + compaction counters of the injected
        dynamic index (None on the flat path) — surfaced by the router."""
        if self.dyn_index is None:
            return None
        stats = getattr(self.dyn_index, "stats", None)
        return stats() if stats else None

    def stats(self) -> dict:
        n = max(len(self.events), 1)
        by = [e[0] for e in self.events]
        # tier-internal counters first: the policy-level keys below
        # (notably l1_hits, which also counts in-batch exact dups the
        # tier never probes) stay authoritative on key collisions
        out = dict(self.l1.stats()) if self.l1 is not None else {}
        out.update({
            "requests": len(self.events),
            "static_hit_rate": by.count("static") / n,
            "dynamic_hit_rate": by.count("dynamic") / n,
            # TweakLLM rewrite variants served from the dynamic tier
            # (DESIGN.md §18) — a distinct hit source so coverage
            # dashboards can attribute the rewrite frontier
            "rewritten_hit_rate": by.count("rewritten") / n,
            "backend_rate": by.count("backend") / n,
            "l1_hit_rate": by.count("l1") / n,
            "static_origin_rate":
                sum(1 for e in self.events if e[1]) / n,
            # freshness subsystem counters (DESIGN.md §16) — always
            # present so dashboards don't branch on configuration
            "l1_hits": self._l1_hits,
            "l1_bypass_volatile": self._l1_bypass,
            "stale_serves": self._stale_serves,
            "ttl_evictions": self._ttl_evictions,
        })
        if self.adaptive is not None:
            out.update(self.adaptive.stats())
        return out

    def feedback(self, seq: int, ok: bool) -> bool:
        """Operator error feedback on a served answer: ``seq`` is the
        ``adapt_seq`` stamped into the ServeResult meta. A wrong-answer
        report poisons the controller window row's label so the next
        shadow sweep counts serving that query as an error. Returns
        False when no controller is attached or the row has already
        rotated out of the window."""
        if self.adaptive is None:
            return False
        with self.dyn_lock:
            before = self.adaptive.feedbacks
            self.adaptive.record_feedback(seq, ok)
            return self.adaptive.feedbacks > before


class KritesPolicy(BaselinePolicy):
    """Algorithm 2: baseline serving + async grey-zone verification."""

    def __init__(self, cfg: T.CacheConfig, static_tier: T.StaticTier,
                 static_answers, embed_fn, backend_fn, judge_fn, d: int,
                 n_workers: int = 2,
                 judge_rate_per_s: Optional[float] = None, *,
                 embed_batch_fn: Optional[Callable] = None,
                 backend_batch_fn: Optional[Callable] = None,
                 index=None, dyn_index=None, static_texts=None,
                 mesh=None, shard_axis: str = "model", wal=None,
                 fused=None, l1=None, freshness=None, adaptive=None,
                 rewriter=None):
        super().__init__(cfg, static_tier, static_answers, embed_fn,
                         backend_fn, d, embed_batch_fn=embed_batch_fn,
                         backend_batch_fn=backend_batch_fn, index=index,
                         dyn_index=dyn_index, static_texts=static_texts,
                         mesh=mesh, shard_axis=shard_axis, fused=fused,
                         l1=l1, freshness=freshness, adaptive=adaptive)
        # write-ahead promotion journal (core/promo_wal.py, DESIGN.md
        # §14): each approved verdict is appended — inside dyn_lock, so
        # journal order equals apply order — before its upsert, and
        # replayed idempotently on restart via the same LWW contract
        self.wal = wal
        # one judge-budget knob: cfg.judge_rate (per request, shared
        # with the trace simulator) is the default; judge_rate_per_s is
        # an explicit wall-clock override for live deployments
        if judge_rate_per_s is None:
            rate_kw = dict(rate_per_s=0.0, rate_per_req=cfg.judge_rate)
        else:
            rate_kw = dict(rate_per_s=judge_rate_per_s)
        self._judge_fn = judge_fn
        # TweakLLM rewriter (DESIGN.md §18): a ``RewriterFn`` producing
        # the tailored answer for REWRITE verdicts, run on the pool
        # worker threads — strictly off the serving path. Budgeted like
        # the judge: ``cfg.rewrite_rate`` tokens accrue per judged
        # task (the live twin of the simulator's per-step refill);
        # an empty bucket downgrades the verdict to REJECT.
        self._rewriter = rewriter
        self._rw_rate = float(cfg.rewrite_rate)
        self._rw_budget = 0.0
        self._rw_lock = threading.Lock()
        self.pool = VerifyAndPromotePool(
            judge_fn=self._judge_payload,
            promote_fn=self._promote,
            n_workers=n_workers, **rate_kw)

    def _judge_payload(self, payload: dict) -> Verdict:
        """Pool adapter: run the judge over the payload's verification
        triple and, for promoting outcomes, stamp the TTL verdict onto
        the payload — it rides the same object into ``_promote`` (and
        the WAL), so the entry's lifetime is decided at verification
        time. A REWRITE verdict additionally runs the rewriter here
        (worker thread, never the serving path); its tailored text and
        outcome tag ride the payload too. Legacy ``bool``-returning
        judges are auto-wrapped via ``as_verdict``."""
        ja = payload["judge_args"]
        # the rewrite token bucket refills per judged task whether or
        # not this verdict rewrites — same discipline as the simulator's
        # per-step refill at the completion-processing point
        if self._rewriter is not None:
            with self._rw_lock:
                self._rw_budget = min(self._rw_budget + self._rw_rate,
                                      1e9)
        verdict = as_verdict(self._judge_fn(**ja))
        if verdict.outcome == REWRITE:
            verdict = self._try_rewrite(verdict, payload, ja)
        if verdict.outcome != REJECT:
            payload["ttl"] = int(verdict.ttl) if verdict.ttl is not None \
                else self._assign_ttl(ja)
        payload["outcome"] = verdict.outcome
        # verdict evidence for the threshold controller (DESIGN.md §17):
        # rewrite the window row's outcome label so shadow sweeps score
        # candidate thresholds against what the judge actually decided.
        # REWRITE counts as not-approved: the judge ruled the static
        # neighbor NOT equivalent, so serving it as-is would be an error
        # — exactly what the window's static-serve scoring models.
        seq = payload.get("adapt_seq", 0)
        if self.adaptive is not None and seq:
            with self.dyn_lock:
                self.adaptive.record_verdict(seq, verdict.approved,
                                             ja["h_cls"])
        return verdict

    def _try_rewrite(self, verdict: Verdict, payload: dict,
                     ja: dict) -> Verdict:
        """Resolve a REWRITE verdict into a promotable tailored answer,
        or degrade it to REJECT: no rewriter / rewriter raised / empty
        text -> ``rewrite_failed``; token bucket empty ->
        ``rewrite_rate_limited``. The flags ride the payload so the
        pool's per-outcome stats attribute the degradation."""
        if self._rewriter is None:
            payload["rewrite_failed"] = True
            return Verdict(REJECT, confidence=verdict.confidence)
        with self._rw_lock:
            if self._rw_budget < 1.0:
                payload["rewrite_rate_limited"] = True
                return Verdict(REJECT, confidence=verdict.confidence)
            self._rw_budget -= 1.0
        text = verdict.text
        if not text:
            try:
                text = self._rewriter(ja.get("q_text", ""),
                                      ja.get("h_text", ""),
                                      ja.get("answer", ""))
            except Exception:  # noqa: BLE001 — degrade, don't retry:
                text = ""      # a broken rewriter must stay deterministic
        if not text:
            payload["rewrite_failed"] = True
            return Verdict(REJECT, confidence=verdict.confidence)
        payload["rewritten"] = str(text)
        return Verdict(REWRITE, text=str(text), ttl=verdict.ttl,
                       confidence=verdict.confidence)

    def _assign_ttl(self, ja: dict) -> int:
        """TTL verdict precedence (DESIGN.md §16): a freshness-aware
        judge is authoritative (it saw the texts); else the policy's
        own classifier; else the config-wide ttl (0 = unbounded)."""
        judge = self._judge_fn
        if getattr(judge, "freshness", None) is not None:
            return int(judge.assign_ttl(ja.get("q_text", ""),
                                        ja.get("h_text", ""),
                                        ja.get("answer", "")))
        if self.freshness is not None:
            return int(self.freshness.ttl_for_text(
                ja.get("q_text", "") or ja.get("h_text", "")))
        return int(self.cfg.ttl)

    def _grey_submission(self, prompt, v, h_idx, s_static, res, meta,
                         enq_t, tau_s=None):
        """Alg. 2 grey-zone gate -> (key, payload) for the pool, or None.

        The payload's ``judge_args`` carry the full verification triple
        the paper's judge is defined over: the query text, the static
        neighbor's prompt text (``static_texts``; the curated answer
        text is the fallback proxy when none were provided) and the
        curated answer itself — class ids alone are only the oracle
        shortcut.

        ``tau_s`` is the live tau_static the serving decision used
        (adaptive thresholds, DESIGN.md §17); the grey zone's upper
        edge must be that same value, not whatever the controller has
        moved it to since."""
        if tau_s is None:
            tau_s = self.cfg.tau_static
        if not (self.cfg.sigma_min <= s_static < tau_s):
            return None
        if self.cfg.dedup and res.served_by in ("dynamic", "rewritten") \
                and res.static_origin:
            return None  # a promoted pointer already serves this query
        va = np.asarray(v)
        fp = hash(va.tobytes())
        answer = self._serve_static(h_idx)
        h_text = self.static_texts[h_idx] \
            if self.static_texts is not None else str(answer)
        return ((fp, h_idx), {
            "v": va,
            "h_idx": h_idx,
            "enq_t": enq_t,
            "adapt_seq": res.meta.get("adapt_seq", 0),
            "judge_args": {
                "q_cls": (meta or {}).get("cls", -1),
                "h_cls": int(self._static_cls_np[h_idx]),
                "q_text": prompt or "",
                "h_text": h_text,
                "answer": "" if answer is None else str(answer),
            },
        })

    def _after_static_miss(self, prompt, v, h_idx, s_static, res, meta,
                           tau_s=None):
        sub = self._grey_submission(prompt, v, h_idx, s_static, res, meta,
                                    self.t, tau_s)
        if sub is not None:
            self.pool.submit(*sub)

    def _after_static_miss_batch(self, rows) -> None:
        items = []
        for prompt, v, h_idx, s_static, res, meta, enq_t, tau_s in rows:
            sub = self._grey_submission(prompt, v, h_idx, s_static, res,
                                        meta, enq_t, tau_s)
            if sub is not None:
                items.append(sub)
        if items:
            self.pool.submit_many(items)

    def _promote(self, payload: dict, journal: bool = True):
        """Auxiliary overwrite: upsert the curated static answer under
        the new key — idempotent, near-duplicate keys overwrite in
        place, and last-writer-wins guarded exactly as
        ``tiers.upsert(lww=True)`` documents: a near-duplicate entry
        *written after this task was enqueued* (``written_at > enq_t``)
        is newer state a slow judge must not clobber, so the stale
        promotion is skipped and neither the device tier nor the host
        mirrors are touched.

        With a ``wal`` the verdict is journaled before the upsert
        (write-ahead: a crash after the append replays the promotion on
        restart; a crash before it re-judges at the next grey trigger).
        ``journal=False`` is the replay path — journaled records must
        not re-append.

        Clock split: ``written_at`` gets ``enq_t`` (the LWW guard must
        compare against the enqueue time), but ``last_used`` gets the
        *live* clock — a promotion applied after a slow judge is fresh
        state; stamping its LRU clock with the stale ``enq_t`` would
        make it the coldest entry in the tier and the eviction victim
        of the very next insert under churn."""
        h_idx = payload["h_idx"]
        v = jnp.asarray(payload["v"])
        enq_t = payload["enq_t"]
        ja = payload.get("judge_args", {})
        # TTL verdict stamped by _judge_payload (or carried by a WAL
        # record on replay). Expiry anchors at enq_t — it is in the WAL
        # record, so replay reconstructs the same expires_at even though
        # apply_t differs across restarts.
        ttl = int(payload.get("ttl", self.cfg.ttl))
        exp = enq_t + ttl if ttl > 0 else 0
        # outcome tag stamped by _judge_payload (or replayed from the
        # WAL): REWRITE lands the tailored text keyed to the NEW
        # prompt's embedding and class, with the answer_ref=-2 sentinel
        # marking provenance; APPROVE lands the curated static pointer.
        rewrite = payload.get("outcome", APPROVE) == REWRITE
        if rewrite:
            answer = payload.get("rewritten", "")
            if not answer:
                return   # defensive: a REWRITE without text is a no-op
            cls, ref = int(ja.get("q_cls", -1)), -2
        else:
            answer = self._serve_static(h_idx)
            cls = int(self._static_cls_np[h_idx])
            ref = int(self._static_ref_np[h_idx])
        with self.dyn_lock:
            apply_t = self.t      # live LRU clock, read under the lock
            self._sweep_expired_locked(apply_t)
            if exp and exp < apply_t:
                return  # verdict outlived its own TTL; nothing to apply
            # the async promotion path rides the same index: dedup
            # lookup through the segmented tail/segments (§12) or the
            # row-sharded masked scan (§13), fresh write into the tier
            if self.mesh is not None:
                sd, jd = self._sh_dyn_fn(self.dyn, v[None])
                s_d, j = float(sd[0]), int(jd[0])
            else:
                s_d, j = T.dynamic_lookup(self.dyn, v,
                                          index=self.dyn_index)
                s_d, j = float(s_d), int(j)
            dup = s_d >= self.cfg.dup_threshold
            if dup and self._written_at_np[j] > enq_t:
                return       # LWW: a newer write owns this key
            # journal only promotions that will actually apply — the
            # append still precedes the upsert (write-ahead contract),
            # but a stale promotion the LWW guard skips must not land
            # in the WAL, or replay/compaction re-applies a write the
            # live tier rightly refused, forever
            if journal and self.wal is not None:
                from repro.core.promo_wal import encode_record
                self.wal.append(encode_record(
                    payload["v"], h_idx, enq_t, ttl=ttl,
                    q_text=ja.get("q_text", ""),
                    h_text=ja.get("h_text", ""),
                    outcome=REWRITE if rewrite else APPROVE,
                    rewritten=str(answer) if rewrite else "",
                    q_cls=int(ja.get("q_cls", -1))))
            slot = j if dup else self._host_lru_slot()
            self.dyn = self._write_fn(
                self.dyn, slot, v,
                jnp.int32(cls), jnp.int32(ref),
                jnp.asarray(True), enq_t, last_used=apply_t,
                expires=exp)
            self._mirror_write(slot, apply_t, static_origin=True,
                               written_at=enq_t, expires=exp,
                               rewritten=rewrite)
            if self.dyn_index is not None:
                self.dyn_index.record_write(slot, payload["v"])
            self.dyn_answers[slot] = answer

    def stats(self) -> dict:
        out = super().stats()
        ps = self.pool.stats
        out.update({"judge_submitted": ps.submitted,
                    "judge_deduped": ps.deduped,
                    "judge_rate_limited": ps.rate_limited,
                    "judged": ps.judged, "approved": ps.approved,
                    "rejected": ps.rejected,
                    "rewritten": ps.rewritten,
                    "rewrite_failed": ps.rewrite_failed,
                    "rewrite_rate_limited": ps.rewrite_rate_limited,
                    "redispatched": ps.redispatched})
        if self.wal is not None:
            ws = self.wal.stats()
            out["wal_seq"] = ws["seq"]
            out["wal_synced_seq"] = ws["synced_seq"]
        return out
