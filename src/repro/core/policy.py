"""Live (host-level) tiered semantic cache policies.

``BaselinePolicy`` = Algorithm 1 (GPTCache-style static thresholds).
``KritesPolicy``   = Algorithm 2: identical serving path + grey-zone
                     trigger feeding the async VerifyAndPromote pool.

These wrap the functional JAX tiers for production serving (the trace
simulator in core/simulate.py is the batched twin used for evaluation).
The backend, embedder and judge are injected callables, so the same policy
fronts an LLM engine, a GNN, or a recsys scorer (DESIGN.md §5).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import tiers as T
from repro.core.async_queue import VerifyAndPromotePool
from repro.index.flat import l2_normalize


@dataclass
class ServeResult:
    answer: object
    served_by: str              # 'static' | 'dynamic' | 'backend'
    static_origin: bool
    similarity: float
    latency_s: float
    meta: dict = field(default_factory=dict)


class BaselinePolicy:
    """Algorithm 1. The dynamic tier is guarded by a lock so async
    promotions (Krites subclass) can't race the serving loop."""

    def __init__(self, cfg: T.CacheConfig, static_tier: T.StaticTier,
                 static_answers, embed_fn: Callable,
                 backend_fn: Callable, d: int):
        self.cfg = cfg
        self.static = static_tier
        self.static_answers = static_answers
        self.embed_fn = embed_fn
        self.backend_fn = backend_fn
        self.dyn = T.make_dynamic_tier(cfg.capacity, d)
        self.dyn_answers: list = [None] * cfg.capacity
        self.dyn_lock = threading.Lock()
        self.t = 0
        self.events: list = []

    def _serve_static(self, idx: int):
        return self.static_answers[int(self.static.answer_ref[idx])]

    # -- hook for Krites (no-op in the baseline) ---------------------------
    def _after_static_miss(self, prompt, v, h_idx, s_static, res, meta):
        return

    def serve(self, prompt: str, meta: Optional[dict] = None) -> ServeResult:
        t0 = time.monotonic()
        self.t += 1
        v = l2_normalize(jnp.asarray(self.embed_fn(prompt), jnp.float32))
        s_s, h_idx = T.static_lookup(self.static, v)
        s_s, h_idx = float(s_s), int(h_idx)
        if s_s >= self.cfg.tau_static:
            res = ServeResult(self._serve_static(h_idx), "static", True,
                              s_s, time.monotonic() - t0)
            self.events.append((res.served_by, res.static_origin))
            return res

        with self.dyn_lock:
            s_d, j = T.dynamic_lookup(self.dyn, v)
            s_d, j = float(s_d), int(j)
            if s_d >= self.cfg.tau_dynamic:
                self.dyn = T.touch(self.dyn, j, self.t)
                res = ServeResult(self.dyn_answers[j], "dynamic",
                                  bool(self.dyn.static_origin[j]), s_d,
                                  time.monotonic() - t0)
            else:
                res = None

        if res is None:
            answer = self.backend_fn(prompt)   # outside the lock
            with self.dyn_lock:
                slot = int(T._lru_slot(self.dyn))
                self.dyn = T.insert(
                    self.dyn, v, (meta or {}).get("cls", -1), -1, self.t)
                self.dyn_answers[slot] = answer
            res = ServeResult(answer, "backend", False, s_d,
                              time.monotonic() - t0)

        self.events.append((res.served_by, res.static_origin))
        # Alg. 2 line 13: grey-zone test on EVERY static miss (dyn hit or
        # backend call alike); non-blocking, off the critical path.
        self._after_static_miss(prompt, v, h_idx, s_s, res, meta)
        return res

    def stats(self) -> dict:
        n = max(len(self.events), 1)
        by = [e[0] for e in self.events]
        return {
            "requests": len(self.events),
            "static_hit_rate": by.count("static") / n,
            "dynamic_hit_rate": by.count("dynamic") / n,
            "backend_rate": by.count("backend") / n,
            "static_origin_rate":
                sum(1 for e in self.events if e[1]) / n,
        }


class KritesPolicy(BaselinePolicy):
    """Algorithm 2: baseline serving + async grey-zone verification."""

    def __init__(self, cfg: T.CacheConfig, static_tier: T.StaticTier,
                 static_answers, embed_fn, backend_fn, judge_fn, d: int,
                 n_workers: int = 2,
                 judge_rate_per_s: float = float("inf")):
        super().__init__(cfg, static_tier, static_answers, embed_fn,
                         backend_fn, d)
        self.pool = VerifyAndPromotePool(
            judge_fn=lambda payload: judge_fn(**payload["judge_args"]),
            promote_fn=self._promote,
            n_workers=n_workers,
            rate_per_s=judge_rate_per_s)

    def _after_static_miss(self, prompt, v, h_idx, s_static, res, meta):
        if not (self.cfg.sigma_min <= s_static < self.cfg.tau_static):
            return
        if self.cfg.dedup and res.served_by == "dynamic" \
                and res.static_origin:
            return  # a promoted pointer already serves this query
        fp = hash(np.asarray(v).tobytes())
        self.pool.submit(
            key=(fp, h_idx),
            payload={
                "v": np.asarray(v),
                "h_idx": h_idx,
                "enq_t": self.t,
                "judge_args": {
                    "q_cls": (meta or {}).get("cls", -1),
                    "h_cls": int(self.static.cls[h_idx]),
                    "q_text": prompt or "",
                    "h_text": "", "answer": "",
                },
            })

    def _promote(self, payload: dict):
        """Auxiliary overwrite: upsert the curated static answer under the
        new key (idempotent; near-duplicate keys overwrite in place)."""
        h_idx = payload["h_idx"]
        v = jnp.asarray(payload["v"])
        answer = self._serve_static(h_idx)
        with self.dyn_lock:
            s_d, j = T.dynamic_lookup(self.dyn, v)
            dup = float(s_d) >= 0.9999
            slot = int(j) if dup else int(T._lru_slot(self.dyn))
            self.dyn = T._write(
                self.dyn, slot, v,
                jnp.int32(int(self.static.cls[h_idx])),
                jnp.int32(int(self.static.answer_ref[h_idx])),
                jnp.asarray(True), payload["enq_t"])
            self.dyn_answers[slot] = answer

    def stats(self) -> dict:
        out = super().stats()
        ps = self.pool.stats
        out.update({"judge_submitted": ps.submitted,
                    "judge_deduped": ps.deduped,
                    "judged": ps.judged, "approved": ps.approved,
                    "redispatched": ps.redispatched})
        return out
