"""L1 exact-match front tier: canonicalized query -> answer (DESIGN.md §16).

The cheapest large win at production repeat rates: a byte-identical (up
to canonicalization) repeat should not pay the embedder or either
semantic lookup. This tier fronts ``BaselinePolicy``/``KritesPolicy``
on both serve paths with an O(1) dict probe keyed by the *canonical
form* of the prompt:

    NFC unicode normalization -> casefold -> whitespace collapse

Equal canonical forms always alias (one entry); distinct canonical
forms never collide — the dict's hash buckets are resolved by full-key
equality, so a hash collision degrades to a probe, never to a wrong
answer. Entries are LRU-capped (``OrderedDict`` move-to-end on hit)
and carry a per-entry ``expires_at`` in the policy's request-tick
clock (0 = never): an entry is servable while ``now <= expires_at``
and dead strictly after — the same liveness rule as the dynamic tier's
``expires_at`` column.

The tier caches *whatever the policy served* (static hit, dynamic hit,
or backend answer) together with its provenance (``static_origin``)
and the serve-time content clock (``content_t`` — when the cached
answer was generated; 0 for curated static answers), which the
freshness layer (``core/freshness.py``) uses for drift/staleness
accounting. Thread-safe: the router's micro-batcher and scalar callers
may probe concurrently.
"""
from __future__ import annotations

import threading
import unicodedata
from collections import OrderedDict
from dataclasses import dataclass


def canonicalize(text: str) -> str:
    """Canonical form: NFC -> casefold -> whitespace collapse.

    ``casefold`` (not ``lower``) so e.g. ``ß``/``ss`` alias; NFC so
    composed and decomposed accents alias; ``split()`` collapses every
    unicode whitespace run (tabs, NBSP after NFC, newlines) to a single
    space and strips the ends.
    """
    return " ".join(unicodedata.normalize("NFC", str(text))
                    .casefold().split())


@dataclass
class L1Entry:
    """One cached serve outcome, keyed by canonical prompt."""
    answer: object
    static_origin: bool = False
    content_t: int = 0      # request tick the answer content dates from
    expires_at: int = 0     # 0 = never; live while now <= expires_at
    written_at: int = 0     # tick the entry was inserted


class ExactTier:
    """LRU-capped exact-match cache with per-entry expiry."""

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._od: "OrderedDict[str, L1Entry]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.ttl_evictions = 0
        self.lru_evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._od)

    def get(self, key: str, now: int) -> L1Entry | None:
        """O(1) probe. A hit moves the entry to the LRU head; an
        expired entry (``now > expires_at > 0``) is dropped on touch
        and counts as a TTL eviction + miss."""
        with self._lock:
            e = self._od.get(key)
            if e is None:
                self.misses += 1
                return None
            if 0 < e.expires_at < now:
                del self._od[key]
                self.ttl_evictions += 1
                self.misses += 1
                return None
            self._od.move_to_end(key)
            self.hits += 1
            return e

    def put(self, key: str, answer, *, static_origin: bool = False,
            content_t: int = 0, expires_at: int = 0,
            now: int = 0) -> None:
        """Insert/overwrite; evicts the LRU tail past capacity."""
        with self._lock:
            self._od[key] = L1Entry(answer, bool(static_origin),
                                    int(content_t), int(expires_at),
                                    int(now))
            self._od.move_to_end(key)
            self.puts += 1
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
                self.lru_evictions += 1

    def sweep(self, now: int) -> int:
        """Drop every expired entry; returns how many died."""
        with self._lock:
            dead = [k for k, e in self._od.items()
                    if 0 < e.expires_at < now]
            for k in dead:
                del self._od[k]
            self.ttl_evictions += len(dead)
            return len(dead)

    def stats(self) -> dict:
        with self._lock:
            return {"l1_entries": len(self._od),
                    "l1_capacity": self.capacity,
                    "l1_hits": self.hits, "l1_misses": self.misses,
                    "l1_puts": self.puts,
                    "l1_ttl_evictions": self.ttl_evictions,
                    "l1_lru_evictions": self.lru_evictions}

    # -- persistence (serving/persist.py snapshots) ---------------------

    def to_state(self) -> list:
        """JSON-serializable dump in LRU order (oldest first)."""
        with self._lock:
            return [[k, e.answer if isinstance(e.answer, str)
                     else str(e.answer), bool(e.static_origin),
                     int(e.content_t), int(e.expires_at),
                     int(e.written_at)]
                    for k, e in self._od.items()]

    def load_state(self, state: list, *, now: int = 0) -> int:
        """Rebuild from :meth:`to_state`, dropping entries already past
        their expiry at restore time — expired entries must not
        resurrect on warm restore (DESIGN.md §16). Returns the live
        count installed."""
        with self._lock:
            self._od.clear()
            for k, ans, so, ct, exp, wr in state:
                if 0 < exp < now:
                    continue
                self._od[k] = L1Entry(ans, bool(so), int(ct), int(exp),
                                      int(wr))
            while len(self._od) > self.capacity:
                self._od.popitem(last=False)
            return len(self._od)
