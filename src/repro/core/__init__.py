"""Krites: asynchronous verified semantic caching (the paper's core).

tiers      — static (read-only, curated) + dynamic (functional LRU) tiers
policy     — Algorithms 1 & 2 on the live serving path
async_queue— off-path VerifyAndPromote worker pool (dedup/rate/retry)
judge      — oracle / noisy-oracle / LLM judges
simulate   — jittable lax.scan trace simulator (the paper's evaluation)
"""
from repro.core.tiers import (CacheConfig, DynamicTier, StaticTier,
                              make_dynamic_tier, make_static_tier)
from repro.core.simulate import simulate, summarize, coverage_curve
from repro.core.judge import LLMJudge, NoisyOracleJudge, OracleJudge
from repro.core.policy import BaselinePolicy, KritesPolicy, ServeResult

__all__ = [
    "CacheConfig", "DynamicTier", "StaticTier", "make_dynamic_tier",
    "make_static_tier", "simulate", "summarize", "coverage_curve",
    "LLMJudge", "NoisyOracleJudge", "OracleJudge",
    "BaselinePolicy", "KritesPolicy", "ServeResult",
]
