"""Parse collective-communication bytes out of compiled HLO text.

``compiled.cost_analysis()`` has no collective accounting, so we walk the
optimized HLO: build a name->shape map from instruction definitions, then
for every collective op sum its *operand* sizes (bytes entering the
collective on each device's program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# "%name = f32[1,2,3]{...} op-name(" — also matches tuple-less simple defs
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\([^=]*\)|[\w]+\[[^\]]*\][^\s]*)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Returns {collective_kind: summed operand bytes} + {'total': ...}.

    Bytes are per-device-program (HLO under SPMD is the per-device view).
    """
    shapes: Dict[str, int] = {}
    defs = []
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        shapes[name] = _shape_bytes(type_str)
        defs.append((name, op, line))

    out: Dict[str, int] = defaultdict(int)
    for name, op, line in defs:
        kind = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "."):
                kind = c
                break
        if kind is None:
            continue
        # operands: names inside the call parens
        paren = line[line.index("(", line.index(op)) + 1:]
        depth, args = 1, ""
        for ch in paren:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        operand_bytes = 0
        for om in _OPERAND_RE.finditer(args):
            operand_bytes += shapes.get(om.group(1), 0)
        if operand_bytes == 0:
            # fallback: result size
            operand_bytes = shapes.get(name, 0)
        out[kind] += operand_bytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_collectives(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for c in COLLECTIVES:
        counts[c] = len(re.findall(rf"\b{c}(?:-start)?(?:\.\d+)?\(",
                                   hlo_text))
    return dict(counts)
