"""Three-term roofline model from compiled dry-run artifacts.

TPU v5e-class constants (per chip):
    197 TFLOP/s bf16 | 819 GB/s HBM | ~50 GB/s/link ICI

Terms (seconds, per step, per chip — HLO under SPMD is the per-device
program, so cost_analysis numbers are already per-chip):
    compute    = HLO_FLOPs / peak_flops
    memory     = HLO_bytes / hbm_bw
    collective = collective_operand_bytes / ici_bw
"""
from __future__ import annotations

from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclass
class Roofline:
    name: str
    chips: int
    hlo_flops: float          # per-chip program flops
    hlo_bytes: float          # per-chip bytes accessed
    coll_bytes: float         # per-chip collective operand bytes
    model_flops: float        # 6ND-style useful flops (GLOBAL)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bound: str = ""
    useful_ratio: float = 0.0  # model_flops / (hlo_flops * chips)
    step_s: float = 0.0        # max of the three terms
    roofline_frac: float = 0.0  # useful compute time / bound term

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bound = max(terms, key=terms.get)
        self.step_s = terms[self.bound]
        total_hlo = self.hlo_flops * self.chips
        self.useful_ratio = (self.model_flops / total_hlo) if total_hlo \
            else 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        self.roofline_frac = ideal / self.step_s if self.step_s else 0.0
        return self

    def to_dict(self) -> Dict:
        return asdict(self)


def from_compiled(name: str, compiled, mesh, model_flops: float,
                  hlo_text: Optional[str] = None) -> Roofline:
    from repro.analysis.hlo_parse import collective_bytes
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    return Roofline(
        name=name, chips=int(mesh.devices.size), hlo_flops=flops,
        hlo_bytes=byt, coll_bytes=float(coll.get("total", 0)),
        model_flops=model_flops).finalize()


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = float(v)
    args = out.get("argument_size_in_bytes", 0.0)
    alias = out.get("alias_size_in_bytes", 0.0)
    temp = out.get("temp_size_in_bytes", 0.0)
    outb = out.get("output_size_in_bytes", 0.0)
    # peak live bytes per device ~ args + temps + (outputs not aliased)
    out["peak_bytes_est"] = args + temp + max(outb - alias, 0.0)
    return out
