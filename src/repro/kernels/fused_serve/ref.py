"""Pure-jnp oracle for the fused serve-pipeline kernel.

The static half reuses ``ivf_scan_ref`` verbatim (same probed clusters,
same dequantized int8 scoring, same (score desc, global id asc)
ordering). The dynamic half mirrors the kernel's precision exactly:
tier rows round-trip through bf16 (the streamed tile dtype) before the
fp32 dot against the normalized query, invalid slots are masked to NEG
with id -1, and the top-``Cd`` candidates come out in the same
(score desc, slot asc) order with padding flushed as (NEG, -1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan.ref import (  # noqa: F401 — shared contract
    BIG_IDX, NEG, _normalize, ivf_scan_ref, select_clusters)


def dyn_scan_ref(queries: jax.Array, dyn_emb: jax.Array,
                 dyn_valid: jax.Array, n_dyn_candidates: int):
    """Reference dynamic-tier candidate scan.

    queries (B, d); dyn_emb (C, d) fp32 (valid rows L2-normalized);
    dyn_valid (C,) bool. Returns (approx scores (B, Cd) fp32, tier
    slots (B, Cd) int32); absent candidates have score NEG and id -1.
    """
    C = dyn_emb.shape[0]
    Cd = min(n_dyn_candidates, C)
    q = _normalize(queries)
    e = dyn_emb.astype(jnp.bfloat16).astype(jnp.float32)   # tile dtype
    sims = q @ e.T                                         # (B, C)
    ids = jnp.where(dyn_valid, jnp.arange(C, dtype=jnp.int32), -1)
    sims = jnp.where(ids[None, :] < 0, NEG, sims)
    flat_i = jnp.broadcast_to(ids[None, :], sims.shape)
    order = jnp.lexsort((flat_i, -sims))[:, :Cd]
    vals = jnp.take_along_axis(sims, order, axis=1)
    cand = jnp.take_along_axis(flat_i, order, axis=1)
    return vals, jnp.where(vals == NEG, -1, cand).astype(jnp.int32)


def fused_serve_ref(queries: jax.Array, centroids: jax.Array,
                    codes: jax.Array, scales: jax.Array,
                    row_ids: jax.Array, dyn_emb: jax.Array,
                    dyn_valid: jax.Array, nprobe: int,
                    n_candidates: int, n_dyn_candidates: int):
    """Reference fused probe: static IVF scan + dynamic masked scan.

    Returns (static scores (B, C), static global ids (B, C),
             dyn scores (B, Cd), dyn tier slots (B, Cd)) under the
    kernel's clamps (C <= nprobe*cap, Cd <= capacity).
    """
    K, cap, _ = codes.shape
    nprobe = min(nprobe, K)
    n_candidates = min(n_candidates, nprobe * cap)
    sv, si = ivf_scan_ref(queries, centroids, codes, scales, row_ids,
                          nprobe, n_candidates)
    dv, di = dyn_scan_ref(queries, dyn_emb, dyn_valid, n_dyn_candidates)
    return sv, si, dv, di
