from repro.kernels.fused_serve.ops import (FusedServe, dyn_rerank_exact,
                                           fused_serve,
                                           fused_serve_probe,
                                           pack_dyn_tiles)

__all__ = ["FusedServe", "dyn_rerank_exact", "fused_serve",
           "fused_serve_probe", "pack_dyn_tiles"]
