"""Jitted public wrappers for the fused serve pipeline (DESIGN.md §15).

``fused_serve_probe`` — backend-dispatched candidate generation: one
                        pass emits the static IVF candidates *and* the
                        dynamic-tier candidates (Pallas kernel on TPU,
                        jnp twin elsewhere).
``fused_serve``       — probe + exact fp32 rerank of both candidate
                        lists inside the same jitted computation,
                        emitting ``(s_static, h_idx, s_dyn, j)`` per
                        row in one host round trip. The static pair
                        equals ``ivf_search(k=1)`` and the dynamic pair
                        equals the policies' masked argmax whenever the
                        true best row/slot survives into the candidate
                        set (recall@C / recall@Cd) — ANN only changes
                        which rows get scored, never the served score.
``FusedServe``        — the injectable serve-path object consumed by
                        ``core.tiers.serve_lookup_batch`` and
                        ``core.policy`` (flag-gated fast path).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.kernels.fused_serve import kernel as _kernel
from repro.kernels.fused_serve.ref import NEG, _normalize, select_clusters
from repro.kernels.ivf_scan.ops import _scan_jnp, rerank_exact


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pack_dyn_tiles(dyn_emb: jax.Array, dyn_valid: jax.Array,
                   tile: int):
    """Tile the dynamic tier for streaming: (C, d) fp32 ->
    ((T, tile, d) bf16 tiles, (T, tile) int32 slot ids, -1 where the
    slot is invalid or padding). Capacity is padded up to a tile
    multiple with id -1 rows, which the kernel masks to NEG exactly
    like invalid slots."""
    C, d = dyn_emb.shape
    ids = jnp.where(dyn_valid, jnp.arange(C, dtype=jnp.int32), -1)
    pad = (-C) % tile
    emb = jnp.pad(dyn_emb, ((0, pad), (0, 0))).astype(jnp.bfloat16)
    ids = jnp.pad(ids, (0, pad), constant_values=-1)
    T = (C + pad) // tile
    return emb.reshape(T, tile, d), ids.reshape(T, tile)


def _dyn_scan_jnp(queries, dyn_emb, dyn_valid, n_dyn_candidates):
    """CPU/GPU fast path for the dynamic half: bf16-precision masked
    matmul + ``lax.top_k``, survivors re-ordered to the oracle's
    (score desc, slot asc) contract (the ``_scan_jnp`` idiom)."""
    C = dyn_emb.shape[0]
    q = _normalize(queries)
    e = dyn_emb.astype(jnp.bfloat16).astype(jnp.float32)
    sims = q @ e.T
    ids = jnp.where(dyn_valid, jnp.arange(C, dtype=jnp.int32), -1)
    sims = jnp.where(ids[None, :] < 0, NEG, sims)
    flat_i = jnp.broadcast_to(ids[None, :], sims.shape)
    vals, pos = jax.lax.top_k(sims, n_dyn_candidates)
    cand = jnp.take_along_axis(flat_i, pos, axis=1)
    order = jnp.lexsort((cand, -vals))
    vals = jnp.take_along_axis(vals, order, axis=1)
    cand = jnp.take_along_axis(cand, order, axis=1)
    return vals, jnp.where(vals == NEG, -1, cand).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "n_candidates",
                                    "n_dyn_candidates", "dyn_tile",
                                    "force"))
def fused_serve_probe(queries: jax.Array, centroids: jax.Array,
                      codes: jax.Array, scales: jax.Array,
                      row_ids: jax.Array, dyn_emb: jax.Array,
                      dyn_valid: jax.Array, nprobe: int = 8,
                      n_candidates: int = 32,
                      n_dyn_candidates: int = 16, dyn_tile: int = 512,
                      force: str | None = None):
    """Fused candidate generation for both tiers.

    queries (B, d); centroids (K, d); codes (K, cap, d) int8;
    scales (K, cap); row_ids (K, cap), -1 = padding; dyn_emb (C, d)
    fp32; dyn_valid (C,) bool.
    force: None (auto) | 'pallas' | 'interpret' | 'jnp'.
    Returns (static scores (B, C), static ids (B, C),
             dyn scores (B, Cd), dyn slots (B, Cd)); -1 = absent.
    """
    K, cap, _ = codes.shape
    B = queries.shape[0]
    C_dyn = dyn_emb.shape[0]
    nprobe = min(nprobe, K)
    n_candidates = min(n_candidates, nprobe * cap)
    n_dyn_candidates = min(n_dyn_candidates, C_dyn)
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp" or B == 0:    # a (0,) Pallas grid has no steps to
        sv, si = _scan_jnp(        # even flush outputs — jnp handles it
            queries, centroids, codes, scales, row_ids, nprobe,
            n_candidates)
        dv, di = _dyn_scan_jnp(queries, dyn_emb, dyn_valid,
                               n_dyn_candidates)
        return sv, si, dv, di
    _, cids = select_clusters(queries, centroids, nprobe)
    tiles, tile_ids = pack_dyn_tiles(dyn_emb, dyn_valid,
                                     min(dyn_tile, C_dyn))
    return _kernel.fused_serve_kernel(
        queries, cids, codes, scales, row_ids, tiles, tile_ids,
        n_candidates, n_dyn_candidates,
        interpret=(mode == "interpret"))


def dyn_rerank_exact(queries: jax.Array, dyn_emb: jax.Array,
                     cand_slots: jax.Array):
    """Exact fp32 top-1 over the dynamic candidates.

    queries (B, d) L2-normalized; dyn_emb (C, d) fp32; cand_slots
    (B, Cd) with -1 marking absent. Returns (score (B,), slot (B,))
    matching the policies' masked argmax contract: lowest slot on
    ties, and the all-invalid tier yields (-inf, 0) exactly like
    ``argmax`` over an all ``-inf`` row.
    """
    safe = jnp.clip(cand_slots, 0, dyn_emb.shape[0] - 1)
    rows = jnp.take(dyn_emb, safe, axis=0)                # (B, Cd, d)
    exact = jnp.einsum("bcd,bd->bc", rows.astype(jnp.float32), queries)
    exact = jnp.where(cand_slots < 0, -jnp.inf, exact)
    order = jnp.lexsort((cand_slots, -exact))[:, :1]
    s = jnp.take_along_axis(exact, order, axis=1)[:, 0]
    j = jnp.take_along_axis(cand_slots, order, axis=1)[:, 0]
    return s, jnp.where(jnp.isneginf(s), 0, j).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "n_candidates",
                                    "n_dyn_candidates", "dyn_tile",
                                    "force"))
def fused_serve(queries: jax.Array, corpus: jax.Array,
                centroids: jax.Array, codes: jax.Array,
                scales: jax.Array, row_ids: jax.Array,
                dyn_emb: jax.Array, dyn_valid: jax.Array,
                nprobe: int = 8, n_candidates: int = 32,
                n_dyn_candidates: int = 16, dyn_tile: int = 512,
                force: str | None = None):
    """Full fused serve lookup: probe + exact fp32 rerank, one round
    trip. Returns ``(s_static (B,), h_idx (B,), s_dyn (B,), j (B,))``.
    """
    sv, si, dv, di = fused_serve_probe(
        queries, centroids, codes, scales, row_ids, dyn_emb, dyn_valid,
        nprobe=nprobe, n_candidates=n_candidates,
        n_dyn_candidates=n_dyn_candidates, dyn_tile=dyn_tile,
        force=force)
    q = _normalize(queries)
    ss, hi = rerank_exact(queries, corpus, si, k=1)
    sd, j = dyn_rerank_exact(q, dyn_emb, di)
    return ss[:, 0], hi[:, 0], sd, j


@dataclass(frozen=True)
class FusedServe:
    """Injectable fused serve path: both tier lookups in one dispatch.

    ``ivf`` is the packed static-tier layout (``repro.index.ivf.IVF``).
    Consumed by ``core.tiers.serve_lookup_batch`` and gated into the
    policies via ``KritesPolicy(fused=...)`` / ``launch/serve.py
    --fused`` (default off; the flat/IVF/segmented/mesh paths are
    untouched when absent).
    """
    ivf: object
    nprobe: int = 8
    n_candidates: int = 32
    n_dyn_candidates: int = 16
    dyn_tile: int = 512
    force: str | None = None     # kernel dispatch override (see above)

    def lookup(self, queries: jax.Array, dyn):
        """queries (B, d) L2-normalized; ``dyn`` a ``DynamicTier``.
        Returns (s_static (B,), h_idx (B,), s_dyn (B,), j (B,))."""
        return fused_serve(queries, self.ivf.corpus, self.ivf.centroids,
                           self.ivf.codes, self.ivf.scales,
                           self.ivf.row_ids, dyn.emb, dyn.valid,
                           nprobe=self.nprobe,
                           n_candidates=self.n_candidates,
                           n_dyn_candidates=self.n_dyn_candidates,
                           dyn_tile=self.dyn_tile, force=self.force)

    def describe(self) -> str:
        K, cap, d = self.ivf.codes.shape
        return (f"fused-serve(N={self.ivf.corpus.shape[0]}, K={K}, "
                f"cap={cap}, d={d}, nprobe={self.nprobe}, "
                f"C={self.n_candidates}, Cd={self.n_dyn_candidates})")
