"""Fused single-pass serve-pipeline Pallas TPU kernel (DESIGN.md §15).

One dispatch runs *both* halves of a serve decision for a micro-batch:
the static-tier IVF probe (the ``kernels/ivf_scan`` band scan) and the
dynamic-tier masked scan, with the query row resident in VMEM the whole
time. The dispatched path pays two kernel launches and re-stages the
query block for each; here the probed int8 bands and the bf16 dynamic
tiles stream through VMEM scratch around a single resident query.

Grid: (B,) — one step per query row. Per step:

- the top-``nprobe`` cluster ids arrive as a scalar-prefetch argument
  (same contract as ``ivf_scan``), and the probed clusters' int8
  codes/scales/row_ids are *manually* DMA'd HBM->VMEM through a 2-slot
  double buffer: band ``p+1`` starts fetching while band ``p`` is
  scored, so the scan is DMA/compute overlapped instead of
  BlockSpec-serialized;
- the dynamic tier streams as bf16 ``(capd, d)`` tiles through its own
  2-slot double buffer. Its first tile's DMA is issued *before* the
  static band loop runs, so the two streams genuinely overlap: the
  dynamic fetch hides behind static compute;
- both scans carry running top-C candidate lists (the online-top-k
  idiom of ``kernels/simsearch``) and stay int8/bf16 end-to-end — the
  exact fp32 rerank happens outside the kernel (``ops.fused_serve``)
  inside the same jitted dispatch.

Outputs per row: static candidates ``(C,)`` (approx score, global row
id) and dynamic candidates ``(Cd,)`` (approx score, tier slot), both in
(score desc, id asc) order with padding flushed as (NEG, -1) — the same
contract the ``ref.py`` oracle pins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.simsearch.kernel import BIG_IDX, NEG, _merge_topk


def _kernel(cids_ref, q_ref, codes_hbm, scales_hbm, ids_hbm,
            dyn_hbm, dyn_ids_hbm,
            sv_ref, si_ref, dv_ref, di_ref,
            band_c, band_s, band_i, dtile_e, dtile_i, sem,
            *, nprobe, n_candidates, n_dyn_candidates, n_dyn_tiles):
    b = pl.program_id(0)

    def band_copies(slot, cluster):
        # the three arrays of one probed cluster's band share a slot;
        # each stream gets its own semaphore row so waits are exact
        return (pltpu.make_async_copy(codes_hbm.at[cluster],
                                      band_c.at[slot], sem.at[0, slot]),
                pltpu.make_async_copy(scales_hbm.at[cluster],
                                      band_s.at[slot], sem.at[1, slot]),
                pltpu.make_async_copy(ids_hbm.at[cluster],
                                      band_i.at[slot], sem.at[2, slot]))

    def dyn_copies(slot, t):
        return (pltpu.make_async_copy(dyn_hbm.at[t],
                                      dtile_e.at[slot], sem.at[3, slot]),
                pltpu.make_async_copy(dyn_ids_hbm.at[t],
                                      dtile_i.at[slot], sem.at[4, slot]))

    q = q_ref[...].astype(jnp.float32)                       # (1, d)
    q = q * jax.lax.rsqrt(
        jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))

    # warm-up: the dynamic stream's first tile starts fetching BEFORE
    # any static work — it lands while the static bands are scored —
    # then the static double buffer primes its own first band
    for c in dyn_copies(0, 0):
        c.start()
    for c in band_copies(0, cids_ref[b, 0]):
        c.start()

    def static_body(p, carry):
        rv, ri = carry
        slot = jax.lax.rem(p, 2)

        @pl.when(p + 1 < nprobe)
        def _start_next():
            for c in band_copies(jax.lax.rem(p + 1, 2),
                                 cids_ref[b, p + 1]):
                c.start()

        for c in band_copies(slot, cids_ref[b, p]):
            c.wait()
        codes = band_c[slot].astype(jnp.float32)             # (cap, d)
        sims = jax.lax.dot_general(
            q, codes, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (1, cap)
        sims = sims * band_s[slot][None, :]
        ids = band_i[slot][None, :]
        sims = jnp.where(ids < 0, NEG, sims)
        mids = jnp.where(ids < 0, BIG_IDX, ids)
        return _merge_topk(jnp.concatenate([rv, sims], axis=1),
                           jnp.concatenate([ri, mids], axis=1),
                           n_candidates)

    rv = jnp.full((1, n_candidates), NEG, jnp.float32)
    ri = jnp.full((1, n_candidates), BIG_IDX, jnp.int32)
    rv, ri = jax.lax.fori_loop(0, nprobe, static_body, (rv, ri))
    sv_ref[...] = rv
    si_ref[...] = jnp.where(rv == NEG, -1, ri)

    def dyn_body(t, carry):
        rv, ri = carry
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < n_dyn_tiles)
        def _start_next():
            for c in dyn_copies(jax.lax.rem(t + 1, 2), t + 1):
                c.start()

        for c in dyn_copies(slot, t):
            c.wait()
        tile = dtile_e[slot].astype(jnp.float32)             # (capd, d)
        sims = jax.lax.dot_general(
            q, tile, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (1, capd)
        ids = dtile_i[slot][None, :]
        sims = jnp.where(ids < 0, NEG, sims)
        mids = jnp.where(ids < 0, BIG_IDX, ids)
        return _merge_topk(jnp.concatenate([rv, sims], axis=1),
                           jnp.concatenate([ri, mids], axis=1),
                           n_dyn_candidates)

    dv = jnp.full((1, n_dyn_candidates), NEG, jnp.float32)
    di = jnp.full((1, n_dyn_candidates), BIG_IDX, jnp.int32)
    dv, di = jax.lax.fori_loop(0, n_dyn_tiles, dyn_body, (dv, di))
    dv_ref[...] = dv
    di_ref[...] = jnp.where(dv == NEG, -1, di)


@functools.partial(jax.jit, static_argnames=("n_candidates",
                                             "n_dyn_candidates",
                                             "interpret"))
def fused_serve_kernel(queries: jax.Array, cids: jax.Array,
                       codes: jax.Array, scales: jax.Array,
                       row_ids: jax.Array, dyn_tiles: jax.Array,
                       dyn_tile_ids: jax.Array, n_candidates: int = 32,
                       n_dyn_candidates: int = 16,
                       interpret: bool = False):
    """Fused static + dynamic candidate generation.

    queries (B, d); cids (B, nprobe) int32; codes (K, cap, d) int8;
    scales (K, cap); row_ids (K, cap); dyn_tiles (T, capd, d) bf16;
    dyn_tile_ids (T, capd) int32 (-1 = invalid/padding slot).

    Returns (static scores (B, C), static row ids (B, C),
             dyn scores (B, Cd), dyn tier slots (B, Cd)).
    """
    B, d = queries.shape
    _, nprobe = cids.shape
    K, cap, _ = codes.shape
    n_dyn_tiles, capd, _ = dyn_tiles.shape
    C, Cd = n_candidates, n_dyn_candidates

    kern = functools.partial(_kernel, nprobe=nprobe, n_candidates=C,
                             n_dyn_candidates=Cd,
                             n_dyn_tiles=n_dyn_tiles)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, cids: (b, 0)),
            # manually-DMA'd operands stay in HBM; the kernel pulls
            # exactly the probed bands / dyn tiles through scratch
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda b, cids: (b, 0)),
            pl.BlockSpec((1, C), lambda b, cids: (b, 0)),
            pl.BlockSpec((1, Cd), lambda b, cids: (b, 0)),
            pl.BlockSpec((1, Cd), lambda b, cids: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, cap, d), jnp.int8),       # static band x2
            pltpu.VMEM((2, cap), jnp.float32),
            pltpu.VMEM((2, cap), jnp.int32),
            pltpu.VMEM((2, capd, d), jnp.bfloat16),  # dyn tile x2
            pltpu.VMEM((2, capd), jnp.int32),
            pltpu.SemaphoreType.DMA((5, 2)),         # stream x slot
        ],
    )
    sv, si, dv, di = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.int32),
            jax.ShapeDtypeStruct((B, Cd), jnp.float32),
            jax.ShapeDtypeStruct((B, Cd), jnp.int32),
        ],
        interpret=interpret,
    )(cids.astype(jnp.int32), queries, codes, scales, row_ids,
      dyn_tiles.astype(jnp.bfloat16), dyn_tile_ids)
    return sv, si, dv, di
