"""Dispatching wrapper: Pallas embedding-bag on TPU, take+reduce off."""
from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag import kernel as _kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("force",))
def embedding_bag(table, ids, weights, force: str | None = None):
    """table (V, d); ids (B, m); weights (B, m) -> (B, d) fp32."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp":
        return embedding_bag_ref(table, ids, weights)
    return _kernel.embedding_bag(table, ids, weights,
                                 interpret=(mode == "interpret"))
