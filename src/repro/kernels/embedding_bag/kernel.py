"""EmbeddingBag Pallas TPU kernel via scalar-prefetch row DMA.

JAX/TPU has no native EmbeddingBag; this kernel implements the gather +
weighted reduce with *data-dependent DMA*: the bag ids arrive as scalar
prefetch, and each grid step's BlockSpec index_map picks the table row to
stream HBM->VMEM. The (B, m, d) gathered intermediate of the jnp path is
never materialized.

Grid: (B, m) — bag-major, so the output block (1, d) stays resident in
VMEM across the m accumulation steps of one bag.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, row_ref, o_ref, *, m):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[b, j]
    o_ref[...] += w * row_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jax.Array, ids: jax.Array, weights: jax.Array,
                  interpret: bool = False) -> jax.Array:
    """table (V, d); ids (B, m) int32; weights (B, m) fp32 -> (B, d)."""
    V, d = table.shape
    B, m = ids.shape

    kern = functools.partial(_kernel, m=m)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # ids, weights
        grid=(B, m),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, j, ids, w: (ids[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j, ids, w: (b, 0)),
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, d), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), weights.astype(jnp.float32), table)
    return out
