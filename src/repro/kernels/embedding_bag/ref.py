"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array) -> jax.Array:
    """Weighted bag reduce: table (V, d), ids (B, m), weights (B, m).

    Returns (B, d) fp32 = sum_j weights[b, j] * table[ids[b, j]].
    (mean mode = weights 1/count; masked entries = weight 0).
    """
    rows = jnp.take(table, ids, axis=0).astype(jnp.float32)  # (B, m, d)
    return jnp.einsum("bmd,bm->bd", rows, weights.astype(jnp.float32))
