"""Jitted public wrapper for simsearch: pads, dispatches kernel vs jnp."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.simsearch import kernel as _kernel
from repro.kernels.simsearch.ref import simsearch_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "force"))
def cosine_topk(queries: jax.Array, corpus: jax.Array, k: int = 1,
                tile_n: int = 512, force: str | None = None):
    """Cosine top-k with automatic backend dispatch.

    force: None (auto) | 'pallas' | 'interpret' | 'jnp'.
    Pads the corpus to a tile multiple; padded rows are masked out by
    scoring them NEG (they can never enter the top-k).
    """
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp":
        return simsearch_ref(queries, corpus, k)

    N, d = corpus.shape
    pad = (-N) % tile_n
    if pad:
        # Padded rows are all-zero; give them a strongly negative first
        # component so normalization keeps them, but real queries never
        # select them: score of a zero row is 0/eps -> 0; instead we mask
        # by index after the kernel.
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
    vals, idx = _kernel.simsearch(queries, corpus, k=k, tile_n=tile_n,
                                  interpret=(mode == "interpret"))
    if pad:
        bad = idx >= N
        vals = jnp.where(bad, -jnp.inf, vals)
        idx = jnp.where(bad, 0, idx)
        # re-sort so masked entries sink to the tail
        order = jnp.argsort(-vals, axis=1)
        vals = jnp.take_along_axis(vals, order, axis=1)
        idx = jnp.take_along_axis(idx, order, axis=1)
    return vals, idx
