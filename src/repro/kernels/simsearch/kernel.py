"""Fused cosine-similarity top-k Pallas TPU kernel.

The cache-lookup hot path: normalize queries once, stream corpus tiles
HBM->VMEM, score on the MXU, and carry a running top-k in VMEM scratch
across tiles (online top-k — the selection analogue of online softmax).
The (B, N) similarity matrix is never materialized in HBM.

Grid: (N // tile_n,) — one step per corpus tile.
Blocks: queries (B, d) resident; corpus tile (tile_n, d) streamed.
Scratch: running values (B, k_pad) fp32 + indices (B, k_pad) int32.

Top-k merge uses max-reduce + min-index tie-breaking (no gather/sort inside
the kernel — TPU-friendly elementwise/reduce ops only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -2.0                      # below any cosine similarity
BIG_IDX = 2**30


def _merge_topk(vals, idxs, k):
    """Select top-k (max value, min index on ties) from (B, M) candidates.

    Returns ((B, k) values, (B, k) indices). Pure elementwise/reduce ops.
    """
    out_v, out_i = [], []
    for _ in range(k):
        m = jnp.max(vals, axis=1, keepdims=True)                 # (B, 1)
        sel = vals >= m                                          # ties incl.
        pick = jnp.min(jnp.where(sel, idxs, BIG_IDX), axis=1,
                       keepdims=True)                            # (B, 1)
        out_v.append(m)
        out_i.append(pick)
        vals = jnp.where(idxs == pick, NEG, vals)
    return jnp.concatenate(out_v, 1), jnp.concatenate(out_i, 1)


def _kernel(q_ref, c_ref, vals_ref, idx_ref, run_v, run_i, *, k, tile_n,
            n_tiles, d):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG)
        run_i[...] = jnp.full_like(run_i, BIG_IDX)

    q = q_ref[...].astype(jnp.float32)                           # (B, d)
    c = c_ref[...].astype(jnp.float32)                           # (tile, d)
    qn = q * jax.lax.rsqrt(
        jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
    cn = c * jax.lax.rsqrt(
        jnp.maximum(jnp.sum(c * c, -1, keepdims=True), 1e-18))
    sims = jax.lax.dot_general(
        qn, cn, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                      # (B, tile)

    gidx = t * tile_n + jax.lax.broadcasted_iota(
        jnp.int32, sims.shape, 1)
    cand_v = jnp.concatenate([run_v[...], sims], axis=1)
    cand_i = jnp.concatenate([run_i[...], gidx], axis=1)
    new_v, new_i = _merge_topk(cand_v, cand_i, k)
    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(t == n_tiles - 1)
    def _done():
        vals_ref[...] = run_v[...]
        idx_ref[...] = run_i[...]


@functools.partial(jax.jit, static_argnames=("k", "tile_n", "interpret"))
def simsearch(queries: jax.Array, corpus: jax.Array, k: int = 1,
              tile_n: int = 512, interpret: bool = False):
    """Fused cosine top-k. queries (B, d), corpus (N, d).

    N must be a multiple of tile_n (callers pad with zero rows; zero rows
    score 0.0 > NEG but are excluded by callers via masking — see ops.py).
    """
    B, d = queries.shape
    N, _ = corpus.shape
    assert N % tile_n == 0, (N, tile_n)
    n_tiles = N // tile_n

    kern = functools.partial(_kernel, k=k, tile_n=tile_n, n_tiles=n_tiles,
                             d=d)
    vals, idx = pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((B, d), lambda t: (0, 0)),
            pl.BlockSpec((tile_n, d), lambda t: (t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda t: (0, 0)),
            pl.BlockSpec((B, k), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, k), jnp.float32),
            pltpu.VMEM((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, corpus)
    return vals, idx
