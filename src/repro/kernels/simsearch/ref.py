"""Pure-jnp oracle for the fused simsearch kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def simsearch_ref(queries: jax.Array, corpus: jax.Array, k: int):
    """Cosine-similarity top-k.

    queries (B, d), corpus (N, d) — neither pre-normalized.
    Returns (scores (B, k) fp32, idx (B, k) int32); ties broken by lowest
    index (matching the kernel's min-index tie rule).
    """
    q = queries.astype(jnp.float32)
    c = corpus.astype(jnp.float32)
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-9)
    c = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-9)
    sims = q @ c.T
    vals, idx = jax.lax.top_k(sims, k)
    return vals, idx.astype(jnp.int32)
