"""Dispatching wrapper: Pallas flash attention on TPU, jnp blockwise off."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import kernel as _kernel
from repro.models.attention import causal_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bq", "bk", "force"))
def attention(q, k, v, bq: int = 512, bk: int = 512,
              force: str | None = None):
    """Causal GQA attention. q (B,S,H,D); k,v (B,S,K,D) -> (B,S,H,D)."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp":
        return causal_attention(q, k, v, chunk=bq)
    return _kernel.flash_attention(q, k, v, bq=bq, bk=bk,
                                   interpret=(mode == "interpret"))
