"""Causal GQA flash-attention Pallas TPU kernel (prefill/training fwd).

Grid: (B*H, n_q, n_kv) with the kv axis innermost. Online-softmax running
stats (m, l, acc) live in VMEM scratch and persist across kv steps; fully
masked kv blocks (block start beyond the causal frontier) skip all compute
via ``pl.when``. KV blocks for GQA are selected by index_map arithmetic
(kv head = q head // group), so kv tiles are DMA'd once per group.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, bq, bk, n_kv,
            scale):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # causal frontier: kv block needed iff kj*bk <= qi*bq + bq - 1
    @pl.when(kj * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, 1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, 1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _done():
        o_ref[0] = (acc_s[...] / l_s[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B, S, H, D); k, v (B, S, K, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    n_q, n_kv = S // bq, S // bk

    # fold batch*head; kv folded to batch*kv_head
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)

    def kv_index(bh, qi, kj):
        b, h = bh // H, bh % H
        return (b * K + h // G, kj, 0)

    kern = functools.partial(_kernel, bq=bq, bk=bk, n_kv=n_kv,
                             scale=D ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
