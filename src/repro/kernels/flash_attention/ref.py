"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array):
    """Naive causal GQA attention.

    q (B, S, H, D); k, v (B, S, K, D); returns (B, S, H, D) fp32.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.astype(jnp.float32).reshape(B, S, K, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    s = s * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D)
