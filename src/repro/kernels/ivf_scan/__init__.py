from repro.kernels.ivf_scan.ops import ivf_scan, ivf_search, rerank_exact

__all__ = ["ivf_scan", "ivf_search", "rerank_exact"]
