"""Pure-jnp oracle for the fused IVF scan kernel.

Scores the probed clusters' int8 codes exactly as the kernel does
(dequantized dot against the normalized query) and selects the top-C
candidates with the same ordering contract: descending approximate
score, ties broken by lowest *global row id* (not position), padding
slots (row id -1, score NEG) sinking to the tail.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# shared contract constants: ops.py masks padding with NEG and the
# Pallas kernel flushes run_v == NEG back as id -1, so the sentinel
# must be the single definition the whole kernel family uses
from repro.kernels.simsearch.kernel import BIG_IDX, NEG  # noqa: F401


def _normalize(q: jax.Array) -> jax.Array:
    # index.flat.l2_normalize, inlined: importing repro.index here
    # would cycle back through index/__init__ -> ivf -> this package
    q = q.astype(jnp.float32)
    return q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True),
                           1e-9)


def select_clusters(queries: jax.Array, centroids: jax.Array,
                    nprobe: int):
    """Centroid scoring: (B, d) x (K, d) -> top-``nprobe`` cluster ids.

    Returns (centroid scores (B, nprobe), cluster ids (B, nprobe)).
    Shared by the oracle and the kernel dispatcher so both scan the
    same clusters.
    """
    q = _normalize(queries)
    cs = q @ centroids.astype(jnp.float32).T
    return jax.lax.top_k(cs, nprobe)


def ivf_scan_ref(queries: jax.Array, centroids: jax.Array,
                 codes: jax.Array, scales: jax.Array, row_ids: jax.Array,
                 nprobe: int, n_candidates: int):
    """Reference IVF scan.

    queries (B, d); centroids (K, d) normalized; codes (K, cap, d) int8;
    scales (K, cap) fp32; row_ids (K, cap) int32 (-1 = padding slot).
    Returns (approx scores (B, C) fp32, candidate row ids (B, C) int32);
    absent candidates have score NEG and id -1.
    """
    q = _normalize(queries)
    _, cids = select_clusters(queries, centroids, nprobe)    # (B, P)

    g_codes = codes[cids].astype(jnp.float32)                # (B,P,cap,d)
    g_scales = scales[cids]                                  # (B, P, cap)
    g_ids = row_ids[cids]                                    # (B, P, cap)
    sims = jnp.einsum("bpcd,bd->bpc", g_codes, q) * g_scales
    sims = jnp.where(g_ids < 0, NEG, sims)

    B = q.shape[0]
    flat = g_ids.shape[1] * g_ids.shape[2]  # explicit: B may be 0,
    flat_v = sims.reshape(B, flat)          # which breaks -1 inference
    flat_i = g_ids.reshape(B, flat)
    # descending score, ties -> lowest global row id; pads (NEG) sink
    # to the tail because no real cosine can reach NEG
    order = jnp.lexsort((flat_i, -flat_v))[:, :n_candidates]
    return (jnp.take_along_axis(flat_v, order, axis=1),
            jnp.take_along_axis(flat_i, order, axis=1).astype(jnp.int32))
