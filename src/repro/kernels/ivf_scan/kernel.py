"""Fused IVF cluster-scan Pallas TPU kernel.

The ANN static-tier lookup hot path (DESIGN.md §11): queries have
already been scored against the K cluster centroids and the top-nprobe
cluster ids per query are handed in as a *scalar-prefetch* argument, so
the BlockSpec index maps can DMA exactly the probed clusters'
quantized codes HBM->VMEM — nothing else of the corpus is touched.

Grid: (B, nprobe) — one step per (query, probed cluster); the probe
axis is innermost. Per step the kernel dequantizes one cluster's int8
codes ((cap, d) block), scores them against the resident query row on
the MXU, and folds the cluster's rows into a running top-C candidate
list carried in VMEM scratch (the online-top-k idiom shared with
``kernels/simsearch``). Candidate ids are *global row ids* (from the
packed layout's ``row_ids``), so the merge's min-index tie-break makes
the output ordering identical to the ``ref.py`` oracle's
(score desc, global id asc); padding slots (row id -1) are masked to
NEG and flushed back as id -1.

A (1, d) query block underuses the MXU's sublane dimension; batching
queries that probe the same cluster (cluster-grouped dispatch) is the
known follow-up — the layout and scalar-prefetch machinery here
already support it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.simsearch.kernel import BIG_IDX, NEG, _merge_topk


def _kernel(cids_ref, q_ref, codes_ref, scales_ref, ids_ref,
            vals_ref, idx_ref, run_v, run_i, *, n_candidates, nprobe):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        run_v[...] = jnp.full_like(run_v, NEG)
        run_i[...] = jnp.full_like(run_i, BIG_IDX)

    q = q_ref[...].astype(jnp.float32)                       # (1, d)
    q = q * jax.lax.rsqrt(
        jnp.maximum(jnp.sum(q * q, -1, keepdims=True), 1e-18))
    c = codes_ref[0].astype(jnp.float32)                     # (cap, d)
    sims = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (1, cap)
    sims = sims * scales_ref[...]
    ids = ids_ref[...]                                       # (1, cap)
    sims = jnp.where(ids < 0, NEG, sims)
    mids = jnp.where(ids < 0, BIG_IDX, ids)

    cand_v = jnp.concatenate([run_v[...], sims], axis=1)
    cand_i = jnp.concatenate([run_i[...], mids], axis=1)
    new_v, new_i = _merge_topk(cand_v, cand_i, n_candidates)
    run_v[...] = new_v
    run_i[...] = new_i

    @pl.when(p == nprobe - 1)
    def _done():
        vals_ref[...] = run_v[...]
        # absent candidates (still NEG) flush as id -1, like the oracle;
        # no real cosine can reach NEG so the test is unambiguous
        idx_ref[...] = jnp.where(run_v[...] == NEG, -1, run_i[...])


@functools.partial(jax.jit,
                   static_argnames=("n_candidates", "interpret"))
def ivf_scan_kernel(queries: jax.Array, cids: jax.Array,
                    codes: jax.Array, scales: jax.Array,
                    row_ids: jax.Array, n_candidates: int = 32,
                    interpret: bool = False):
    """Scan the prefetched clusters. queries (B, d); cids (B, nprobe)
    int32; codes (K, cap, d) int8; scales (K, cap); row_ids (K, cap).

    Returns (approx scores (B, C) fp32, global row ids (B, C) int32).
    """
    B, d = queries.shape
    _, nprobe = cids.shape
    K, cap, _ = codes.shape
    C = n_candidates

    kern = functools.partial(_kernel, n_candidates=C, nprobe=nprobe)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nprobe),
        in_specs=[
            pl.BlockSpec((1, d), lambda b, p, cids: (b, 0)),
            pl.BlockSpec((1, cap, d),
                         lambda b, p, cids: (cids[b, p], 0, 0)),
            pl.BlockSpec((1, cap), lambda b, p, cids: (cids[b, p], 0)),
            pl.BlockSpec((1, cap), lambda b, p, cids: (cids[b, p], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C), lambda b, p, cids: (b, 0)),
            pl.BlockSpec((1, C), lambda b, p, cids: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, C), jnp.float32),
            pltpu.VMEM((1, C), jnp.int32),
        ],
    )
    vals, idx = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, C), jnp.float32),
            jax.ShapeDtypeStruct((B, C), jnp.int32),
        ],
        interpret=interpret,
    )(cids.astype(jnp.int32), queries, codes, scales, row_ids)
    return vals, idx
