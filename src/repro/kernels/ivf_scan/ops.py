"""Jitted public wrappers for the IVF scan: backend dispatch + rerank.

``ivf_scan``   — centroid selection + probed-cluster int8 scan, emitting
                 top-C (approx score, global row id) candidates.
``ivf_search`` — scan + exact fp32 rerank of the C candidates against
                 the original corpus rows, emitting (score, id) pairs in
                 the same format as ``kernels.simsearch.ops.cosine_topk``.
                 Whenever the true best row is among the candidates
                 (recall@C holds) the served pair equals flat search:
                 the rerank recomputes the very same normalized-fp32 dot
                 the flat path computes, and ties break by lowest global
                 row id in both (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ivf_scan import kernel as _kernel
from repro.kernels.ivf_scan.ref import NEG, _normalize, select_clusters


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _scan_jnp(queries, centroids, codes, scales, row_ids, nprobe,
              n_candidates):
    """CPU/GPU fast path: gathered int8 band scan + ``lax.top_k``
    selection (a full (score, id) lexsort over every scanned slot
    doubles the scan's wall time). The C survivors are then re-ordered
    to the oracle's (score desc, global id asc) contract, so output
    ordering matches ``ivf_scan_ref`` except when an exact
    approx-score tie straddles the C boundary — the exact rerank makes
    that distinction unobservable in served results."""
    qn = _normalize(queries)
    _, cids = select_clusters(queries, centroids, nprobe)
    g = codes[cids].astype(jnp.float32)                  # (B,P,cap,d)
    sims = jnp.einsum("bpcd,bd->bpc", g, qn) * scales[cids]
    ids = row_ids[cids]
    B = queries.shape[0]
    flat = ids.shape[1] * ids.shape[2]   # explicit: B may be 0, which
    fv = jnp.where(ids < 0, NEG, sims).reshape(B, flat)  # breaks -1
    fi = ids.reshape(B, flat)
    vals, pos = jax.lax.top_k(fv, n_candidates)
    cand = jnp.take_along_axis(fi, pos, axis=1)
    order = jnp.lexsort((cand, -vals))
    return (jnp.take_along_axis(vals, order, axis=1),
            jnp.take_along_axis(cand, order, axis=1).astype(jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("nprobe", "n_candidates", "force"))
def ivf_scan(queries: jax.Array, centroids: jax.Array, codes: jax.Array,
             scales: jax.Array, row_ids: jax.Array, nprobe: int = 8,
             n_candidates: int = 32, force: str | None = None):
    """Approximate candidate generation over the packed IVF layout.

    queries (B, d); centroids (K, d); codes (K, cap, d) int8;
    scales (K, cap); row_ids (K, cap), -1 = padding.
    force: None (auto) | 'pallas' | 'interpret' | 'jnp'.
    Returns (approx scores (B, C), global row ids (B, C), -1 = absent).
    """
    K, cap, _ = codes.shape
    nprobe = min(nprobe, K)
    n_candidates = min(n_candidates, nprobe * cap)
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp":
        return _scan_jnp(queries, centroids, codes, scales, row_ids,
                         nprobe, n_candidates)
    _, cids = select_clusters(queries, centroids, nprobe)
    return _kernel.ivf_scan_kernel(queries, cids, codes, scales, row_ids,
                                   n_candidates,
                                   interpret=(mode == "interpret"))


def rerank_exact(queries: jax.Array, corpus: jax.Array,
                 cand_ids: jax.Array, k: int):
    """Exact fp32 rerank of scan candidates.

    queries (B, d); corpus (N, d) L2-normalized fp32; cand_ids (B, C)
    with -1 marking absent slots. Returns (scores (B, k), ids (B, k)) —
    bit-equal to flat search on the candidate rows (same normalized
    dot, same lowest-global-id tie-break).
    """
    assert k <= cand_ids.shape[1], \
        f"rerank k={k} exceeds candidate count {cand_ids.shape[1]}"
    q = _normalize(queries)
    safe = jnp.clip(cand_ids, 0, corpus.shape[0] - 1)
    rows = jnp.take(corpus, safe, axis=0)                 # (B, C, d)
    exact = jnp.einsum("bcd,bd->bc", rows.astype(jnp.float32), q)
    exact = jnp.where(cand_ids < 0, -jnp.inf, exact)
    order = jnp.lexsort((cand_ids, -exact))[:, :k]
    return (jnp.take_along_axis(exact, order, axis=1),
            jnp.take_along_axis(cand_ids, order, axis=1).astype(
                jnp.int32))


@functools.partial(jax.jit,
                   static_argnames=("k", "nprobe", "n_candidates",
                                    "force"))
def ivf_search(queries: jax.Array, corpus: jax.Array,
               centroids: jax.Array, codes: jax.Array, scales: jax.Array,
               row_ids: jax.Array, k: int = 1, nprobe: int = 8,
               n_candidates: int = 32, force: str | None = None):
    """IVF scan + exact rerank; drop-in (B, k) twin of ``cosine_topk``.

    Requires ``k`` <= the effective candidate count (``n_candidates``
    after the scan's nprobe*cap clamp) — asserted, since silently
    returning fewer than k columns would break fixed-shape consumers
    like the sharded k-candidate merge.
    """
    K, cap, _ = codes.shape
    effective_c = min(n_candidates, min(nprobe, K) * cap)
    assert k <= effective_c, \
        f"k={k} exceeds candidate budget {effective_c} " \
        f"(n_candidates={n_candidates}, nprobe={nprobe}, cap={cap})"
    _, cand = ivf_scan(queries, centroids, codes, scales, row_ids,
                       nprobe=nprobe, n_candidates=n_candidates,
                       force=force)
    return rerank_exact(queries, corpus, cand, k)
