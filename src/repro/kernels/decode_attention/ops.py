"""Dispatching wrapper: Pallas decode attention on TPU, jnp split-K off."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention import kernel as _kernel
from repro.models import attention as attn_lib


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bs", "force"))
def decode_attention(q, k_cache, v_cache, lengths, bs: int = 512,
                     force: str | None = None):
    """q (B, H, D); caches (B, S, K, D); lengths (B,) -> (B, H, D)."""
    mode = force or ("pallas" if _on_tpu() else "jnp")
    if mode == "jnp":
        out = attn_lib.decode_attention(q[:, None], k_cache, v_cache,
                                        lengths)
        return out[:, 0].astype(jax.numpy.float32)
    return _kernel.decode_attention(q, k_cache, v_cache, lengths, bs=bs,
                                    interpret=(mode == "interpret"))
