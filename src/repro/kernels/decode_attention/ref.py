"""Pure-jnp oracle for GQA decode attention with a length-masked KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, lengths: jax.Array):
    """q (B, H, D); caches (B, S, K, D); lengths (B,) valid positions.

    Returns (B, H, D) fp32.
    """
    B, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.astype(jnp.float32).reshape(B, K, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg,
                   k_cache.astype(jnp.float32)) * D ** -0.5
    pos = jnp.arange(k_cache.shape[1])
    valid = pos[None] < lengths[:, None]                 # (B, S)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D)
