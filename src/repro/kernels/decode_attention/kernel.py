"""GQA decode-attention Pallas TPU kernel (flash-decoding style).

One new token per sequence attends over a long KV cache. The cache is
streamed through VMEM in sequence tiles (split-K); per-(batch, kv-head)
online-softmax stats live in scratch. The group of G query heads sharing a
kv head rides in the sublane dimension, so the MXU sees (G, D) x (D, bs)
matmuls per tile.

Grid: (B*K, n_s) with the sequence axis innermost. Per-sequence valid
``lengths`` arrive via scalar prefetch and gate both the compute (whole
tile beyond length is skipped) and the in-tile mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *, bs,
            n_s, K, scale):
    bh = pl.program_id(0)
    sj = pl.program_id(1)
    b = bh // K
    length = len_ref[b]

    @pl.when(sj == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    @pl.when(sj * bs < length)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (G, D)
        k = k_ref[0].astype(jnp.float32)                 # (bs, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bs)
        kpos = sj * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, 1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, 1, keepdims=True)
        acc_s[...] = acc_s[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(sj == n_s - 1)
    def _done():
        o_ref[0] = (acc_s[...] / l_s[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, bs: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q (B, H, D); caches (B, S, K, D); lengths (B,) -> (B, H, D)."""
    B, H, D = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    bs = min(bs, S)
    assert S % bs == 0
    n_s = S // bs

    qf = q.reshape(B * K, G, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, D)

    kern = functools.partial(_kernel, bs=bs, n_s=n_s, K=K, scale=D ** -0.5)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * K, n_s),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda bh, sj, lens: (bh, 0, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, sj, lens: (bh, sj, 0)),
            pl.BlockSpec((1, bs, D), lambda bh, sj, lens: (bh, sj, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda bh, sj, lens: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * K, G, D), jnp.float32),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, kf, vf)
    return out.reshape(B, H, D)
