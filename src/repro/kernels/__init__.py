"""Pallas TPU kernels for the framework's compute hot spots.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted dispatch wrapper with a jnp fallback off-TPU), and ref.py (the
pure-jnp oracle used by the interpret-mode test sweeps).

  simsearch        fused cosine top-k (cache lookup / retrieval_cand)
  flash_attention  causal GQA prefill attention
  decode_attention flash-decoding over long KV caches
  embedding_bag    scalar-prefetch gather + weighted bag reduce
"""
