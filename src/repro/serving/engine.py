"""Batched LLM serving engine: prefill + decode with KV cache, plus a
continuous-batching-lite request queue.

The engine is the backend ``B`` that Krites fronts: every cache hit is a
skipped ``generate`` call. Works with any LMConfig (the 5 assigned archs
at full scale on TPU; smoke configs on CPU for the examples/tests).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.data.tokenizer import ByteTokenizer, EOS, PAD
from repro.models import transformer as tr


@dataclass
class EngineStats:
    prefills: int = 0
    decode_steps: int = 0
    generated_tokens: int = 0
    batches: int = 0
    wall_prefill_s: float = 0.0
    wall_decode_s: float = 0.0


class LLMEngine:
    """Synchronous batched generate; thread-safe via internal lock."""

    def __init__(self, cfg: LMConfig, params=None, seed: int = 0,
                 max_len: int = 256, temperature: float = 0.0):
        self.cfg = cfg
        self.tok = ByteTokenizer()
        assert cfg.vocab_size >= self.tok.vocab_size
        self.params = params if params is not None else tr.init_params(
            cfg, jax.random.PRNGKey(seed))
        self.max_len = max_len
        self.temperature = temperature
        self.stats = EngineStats()
        self._lock = threading.Lock()

        self._prefill = jax.jit(
            lambda p, t: tr.prefill(cfg, p, t, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t: tr.decode_step(cfg, p, c, t))

    def generate_batch(self, prompts: List[str],
                       max_new_tokens: int = 32) -> List[str]:
        with self._lock:
            return self._generate(prompts, max_new_tokens)

    def _generate(self, prompts: List[str], max_new: int) -> List[str]:
        B = len(prompts)
        in_len = max(8, max(len(p.encode()) + 2 for p in prompts))
        in_len = min(in_len, self.max_len - max_new)
        toks = np.stack([self.tok.encode(p, max_len=in_len)
                         for p in prompts])
        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        self.stats.prefills += B
        self.stats.wall_prefill_s += time.monotonic() - t0

        out = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        tok = self._sample(logits)
        t0 = time.monotonic()
        for _ in range(max_new):
            for b in range(B):
                if not done[b]:
                    out[b].append(int(tok[b]))
                    done[b] |= int(tok[b]) == EOS
            if done.all():
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tok))
            self.stats.decode_steps += 1
            tok = self._sample(logits)
        self.stats.wall_decode_s += time.monotonic() - t0
        self.stats.generated_tokens += sum(len(o) for o in out)
        self.stats.batches += 1
        return [self.tok.decode(o) for o in out]

    def _sample(self, logits) -> np.ndarray:
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1), np.int32)
        g = np.random.gumbel(size=logits.shape)
        return np.asarray(
            jnp.argmax(logits / self.temperature + g, -1), np.int32)

    def generate(self, prompt: str, max_new_tokens: int = 32) -> str:
        return self.generate_batch([prompt], max_new_tokens)[0]


class BatchingFrontend:
    """Continuous-batching-lite: coalesce concurrent requests into
    engine batches (max_batch or max_wait_ms, whichever first). The
    queue/collector machinery is the shared ``_MicroBatcher`` — the same
    one ``CacheRouter`` uses over ``Policy.serve_batch``."""

    def __init__(self, engine: LLMEngine, max_batch: int = 8,
                 max_wait_ms: float = 5.0, max_new_tokens: int = 32):
        from repro.serving.router import _MicroBatcher
        self.engine = engine
        self.max_new = max_new_tokens
        self._mb = _MicroBatcher(self._serve, max_batch, max_wait_ms / 1e3,
                                 name="batching-frontend")

    def submit(self, prompt: str, timeout_s: float = 60.0) -> str:
        p = self._mb.submit(prompt)
        p.done.wait(timeout_s)
        return p.result if p.result is not None else ""

    def submit_many(self, prompts: List[str],
                    timeout_s: float = 60.0) -> List[str]:
        """Enqueue a pre-formed group and block until every answer is
        in. Usable directly as a policy's ``backend_batch_fn``: the
        group reaches the collector at once, so a cache micro-batch's
        misses become one engine prefill instead of ``len(prompts)``
        serialized ``submit`` calls."""
        pending = [self._mb.submit(p) for p in prompts]
        for p in pending:
            p.done.wait(timeout_s)
        return [p.result if p.result is not None else "" for p in pending]

    def _serve(self, batch):
        results = self.engine.generate_batch(
            [p.prompt for p in batch], self.max_new)
        for p, r in zip(batch, results):
            p.result = r

    def stop(self):
        self._mb.stop()
