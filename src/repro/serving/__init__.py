from repro.serving.engine import BatchingFrontend, LLMEngine

__all__ = ["BatchingFrontend", "LLMEngine"]
