from repro.serving.engine import BatchingFrontend, LLMEngine
from repro.serving.router import CacheRouter

__all__ = ["BatchingFrontend", "CacheRouter", "LLMEngine"]
