"""Snapshot/restore of the serving state: both tiers, their ANN
indexes, and the policy's host mirrors (DESIGN.md §14).

A million-entry static tier takes ~1 min to IVF-build; the dynamic tier
holds every verified promotion the async pipeline has paid judge calls
for. Neither should start cold on every process restart. This module
persists the whole serving state through the atomic-write conventions
of ``distributed/checkpoint.py`` (tmp dir + ``os.replace`` publish,
per-leaf blake2s hashes verified on load) and restores it into a
freshly constructed policy:

- **dynamic tier** — all eight device arrays (``expires_at``
  included), the six host decision mirrors (rewrite provenance
  included, DESIGN.md §18), the answer list and the
  logical clock ``t``, restored field-identically (sharded onto the
  policy's mesh when serving multi-device); entries already past their
  expiry at the captured clock are swept on restore — expired state
  never resurrects;
- **L1 front tier** — the exact-match cache rides in the manifest
  (``extra["l1"]``, LRU order preserved) and is reinstalled through
  ``ExactTier.load_state``, which drops entries expired at the
  restored clock;
- **static ANN index** — the packed IVF layout (centroids, int8 codes,
  scales, row ids) is saved *without* its corpus (the corpus IS the
  static tier embedding matrix, stored once) and re-wired to the live
  tier on load. The manifest records the corpus hash the index was
  built from: restore installs it only when that hash matches the
  policy's static tier (warm restore); a stale or absent index triggers
  a rebuild instead — inline or on a background thread that atomically
  swaps ``policy.index`` when done, serving exact (flat or existing-
  index) lookups meanwhile;
- **segmented dynamic index** — rebuilt from the restored live set via
  ``SegmentedIndex.bulk_load`` (one merged segment — the steady state a
  long deployment reaches after compaction): tombstoned slots are not
  in the live set, so they stay unreachable, and lookups are decision-
  identical by the exact-rerank contract (§12);
- **WAL cursor** — the manifest records the promotion journal's
  ``wal_seq`` at capture time (captured under ``dyn_lock``, so it is
  consistent with the tier arrays); recovery replays only journal
  records after it (``promo_wal.replay_into(skip=...)``).

The snapshot manifest is versioned (``format``); loaders refuse
snapshots they do not understand instead of misreading them.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Optional

import numpy as np

from repro.distributed import checkpoint as ckpt

SNAP_FORMAT = 4            # 4: + rewrite provenance mirror (DESIGN.md §18)
SNAP_FORMATS = (1, 2, 3, 4)   # formats the loader understands
SNAP_KIND = "krites-snapshot"


def state_hash(arr) -> str:
    """Content hash used to tie an index to the corpus it was built
    from (and snapshots to their static tier)."""
    return ckpt._hash(np.ascontiguousarray(np.asarray(arr)))


def _jsonable(x: Any) -> Any:
    """Answers are strings in every shipped backend; anything exotic is
    coerced so a snapshot never fails mid-write."""
    return x if isinstance(x, (str, int, float, bool)) or x is None \
        else str(x)


@dataclass
class Snapshot:
    """A loaded snapshot: raw arrays (nested dict) + manifest extras."""
    step: int
    tree: dict
    extra: dict
    path: Path


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def save_snapshot(snap_dir: str | Path, policy, *, step: Optional[int] = None,
                  include_static: bool = True) -> Path:
    """Capture the policy's full serving state and publish it atomically.

    The capture (device->host gather of the dynamic tier, mirror
    copies, ``wal_seq``) happens under ``dyn_lock`` so it is a
    consistent cut w.r.t. concurrent promotions; the disk write happens
    after the lock is released, on the captured copies. The WAL is
    fsynced inside the cut, so ``wal_seq`` counts only durable records.
    """
    snap_dir = Path(snap_dir)
    if step is None:
        last = latest_snapshot(snap_dir)
        step = 0 if last is None else last + 1

    with policy.dyn_lock:
        wal = getattr(policy, "wal", None)
        if wal is not None:
            wal.sync()
        wal_seq = wal.seq if wal is not None else 0
        dyn = {f: np.asarray(jax_get(v))
               for f, v in zip(policy.dyn._fields, policy.dyn)}
        mirrors = {
            "valid": policy._valid_np.copy(),
            "last_used": policy._last_used_np.copy(),
            "static_origin": policy._static_origin_np.copy(),
            "written_at": policy._written_at_np.copy(),
            "expires_at": policy._expires_np.copy(),
            "rewritten": policy._rewritten_np.copy(),
        }
        t = policy.t
        dyn_answers = [_jsonable(a) for a in policy.dyn_answers]
        l1 = getattr(policy, "l1", None)
        l1_state = l1.to_state() if l1 is not None else None
        # adaptive threshold controller (DESIGN.md §17): window arrays
        # ride the hashed leaf tree, counters/rng/taus the manifest —
        # captured in the same consistent cut as the tier they tuned
        adaptive = getattr(policy, "adaptive", None)
        adaptive_arrays = adaptive_scalars = None
        if adaptive is not None:
            adaptive_arrays, adaptive_scalars = adaptive.to_state()

    tree: dict = {"dyn": dyn, "mirrors": mirrors}
    if adaptive_arrays is not None:
        tree["adaptive"] = adaptive_arrays
    extra: dict = {
        "format": SNAP_FORMAT,
        "kind": SNAP_KIND,
        "saved_unix": time.time(),
        "t": int(t),
        "wal_seq": int(wal_seq),
        "capacity": int(policy.cfg.capacity),
        "d": int(dyn["emb"].shape[1]),
        "dyn_answers": dyn_answers,
        "l1": l1_state,
        "dyn_index": policy.describe_dyn_index()
        if policy.dyn_index is not None else None,
        "adaptive": adaptive_scalars,
        "ivf": None,
        "static_hash": None,
    }

    static_emb = np.asarray(jax_get(policy.static.emb))
    extra["static_hash"] = state_hash(static_emb)
    if include_static:
        tree["static"] = {
            "emb": static_emb,
            "cls": np.asarray(jax_get(policy.static.cls)),
            "answer_ref": np.asarray(jax_get(policy.static.answer_ref)),
        }
        extra["static_answers"] = [_jsonable(a)
                                   for a in policy.static_answers]
        extra["static_texts"] = list(policy.static_texts) \
            if policy.static_texts is not None else None

    ivf_index = _plain_ivf_index(policy.index)
    if ivf_index is not None:
        ivf = ivf_index.ivf
        tree["ivf"] = {
            "centroids": np.asarray(jax_get(ivf.centroids)),
            "codes": np.asarray(jax_get(ivf.codes)),
            "scales": np.asarray(jax_get(ivf.scales)),
            "row_ids": np.asarray(jax_get(ivf.row_ids)),
        }
        extra["ivf"] = {
            "nprobe": int(ivf_index.nprobe),
            "n_candidates": int(ivf_index.n_candidates),
            # the corpus is not duplicated on disk: it is the static
            # tier embedding matrix, re-wired on load — this hash is
            # what makes staleness detectable
            "corpus_hash": state_hash(np.asarray(jax_get(ivf.corpus))),
        }

    return ckpt.save(snap_dir, step, tree, extra=extra)


def jax_get(x):
    """`jax.device_get` without importing jax at module import time
    (the loader side is useful in plain-numpy tooling too)."""
    import jax
    return jax.device_get(x)


def _plain_ivf_index(index) -> Optional[object]:
    """The single-device IVFIndex if that is what the policy serves
    through; sharded/flat/None indexes are not snapshot-persisted (a
    sharded layout is mesh-shaped — it is rebuilt from the corpus on
    restore; flat has nothing to persist)."""
    from repro.index.ivf import IVFIndex
    return index if isinstance(index, IVFIndex) else None


# ---------------------------------------------------------------------------
# load
# ---------------------------------------------------------------------------

def latest_snapshot(snap_dir: str | Path) -> Optional[int]:
    """Newest published snapshot step, ignoring torn tmp dirs (the
    atomic-rename convention: a crash mid-save leaves only ``.tmp_*``,
    which is never listed)."""
    return ckpt.latest_step(snap_dir)


def load_snapshot(snap_dir: str | Path, step: Optional[int] = None,
                  verify: bool = True) -> Snapshot:
    """Read a snapshot back into host arrays, hash-verifying each leaf.

    Raises ``FileNotFoundError`` when no snapshot exists, ``IOError``
    on corruption, ``ValueError`` on an unknown manifest format.
    """
    snap_dir = Path(snap_dir)
    if step is None:
        step = latest_snapshot(snap_dir)
        if step is None:
            raise FileNotFoundError(f"no snapshot under {snap_dir}")
    src = snap_dir / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    extra = manifest.get("extra", {})
    if extra.get("format") not in SNAP_FORMATS \
            or extra.get("kind") != SNAP_KIND:
        raise ValueError(
            f"{src}: not a format-{SNAP_FORMATS} {SNAP_KIND} manifest "
            f"(got format={extra.get('format')!r} "
            f"kind={extra.get('kind')!r})")

    tree: dict = {}
    for name, meta in manifest["leaves"].items():
        arr = np.load(src / meta["file"])
        if verify and ckpt._hash(arr) != meta["hash"]:
            raise IOError(f"snapshot corruption in leaf {name}")
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return Snapshot(step=step, tree=tree, extra=extra, path=src)


def load_static_index(snap: "Snapshot | str | Path", corpus, *,
                      nprobe: Optional[int] = None,
                      n_candidates: Optional[int] = None,
                      force: Optional[str] = None):
    """Warm-restore the static IVF index against ``corpus`` (the live
    static tier embedding matrix). Returns an ``IVFIndex`` ready to
    inject, or ``None`` when the snapshot carries no index or carries
    one built from a different corpus (stale — the caller rebuilds).
    ``nprobe``/``n_candidates`` override the snapshotted operating
    point (they are serving knobs, not layout)."""
    import jax.numpy as jnp

    from repro.index.ivf import IVF, IVFIndex

    if not isinstance(snap, Snapshot):
        try:
            snap = load_snapshot(snap)
        except FileNotFoundError:
            return None
    meta = snap.extra.get("ivf")
    if meta is None or "ivf" not in snap.tree:
        return None
    if meta["corpus_hash"] != state_hash(corpus):
        return None                      # stale: corpus changed
    leaves = snap.tree["ivf"]
    ivf = IVF(centroids=jnp.asarray(leaves["centroids"]),
              codes=jnp.asarray(leaves["codes"]),
              scales=jnp.asarray(leaves["scales"]),
              row_ids=jnp.asarray(leaves["row_ids"]),
              corpus=jnp.asarray(corpus, jnp.float32))
    return IVFIndex(ivf,
                    nprobe=meta["nprobe"] if nprobe is None else nprobe,
                    n_candidates=meta["n_candidates"]
                    if n_candidates is None else n_candidates,
                    force=force)


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def restore_policy(policy, snap: "Snapshot | str | Path", *,
                   step: Optional[int] = None,
                   rebuild: str = "background") -> dict:
    """Install a snapshot's serving state into a freshly constructed
    policy (same ``capacity``/``d``/mesh topology as the saver; the
    dynamic tier and any injected ``dyn_index`` must be empty — restore
    replaces state, it does not merge).

    Static-index handling (``rebuild``):

    - the snapshot's IVF layout is installed directly when its corpus
      hash matches the policy's static tier (**warm restore** — the
      launcher can also do this up front via :func:`load_static_index`
      and skip the cold build entirely);
    - otherwise (stale or absent index, and only when the deployment
      uses one: the policy already carries an ``IVFIndex`` or the
      snapshot recorded one): ``"inline"`` rebuilds before returning,
      ``"background"`` returns immediately and atomically swaps
      ``policy.index`` when the build finishes (serving the existing
      exact path meanwhile), ``"never"`` leaves the index alone.

    Returns a report: restored step/t/wal_seq, live-entry count, what
    happened to the index, and the rebuild thread (if any) so callers
    can join it.
    """
    import jax.numpy as jnp

    from repro.core import tiers as T

    if rebuild not in ("background", "inline", "never"):
        raise ValueError(f"rebuild={rebuild!r}")
    if not isinstance(snap, Snapshot):
        snap = load_snapshot(snap, step=step)

    dyn_np = snap.tree["dyn"]
    cap, d = dyn_np["emb"].shape
    if cap != policy.cfg.capacity:
        raise ValueError(f"snapshot capacity {cap} != policy "
                         f"capacity {policy.cfg.capacity}")
    if int(snap.extra["t"]) < 0:
        raise ValueError("negative clock in snapshot")

    # format-1 snapshots predate per-entry expiry: default to "never"
    if "expires_at" not in dyn_np:
        dyn_np = dict(dyn_np,
                      expires_at=np.zeros(cap, np.int32))
    dyn = T.DynamicTier(**{f: jnp.asarray(dyn_np[f])
                           for f in T.DynamicTier._fields})
    with policy.dyn_lock:
        if policy.mesh is not None:
            from repro.index.sharded import shard_dynamic_tier
            dyn = shard_dynamic_tier(dyn, policy.mesh, policy.shard_axis)
        policy.dyn = dyn
        m = snap.tree["mirrors"]
        policy._valid_np[:] = m["valid"]
        policy._last_used_np[:] = m["last_used"]
        policy._static_origin_np[:] = m["static_origin"]
        policy._written_at_np[:] = m["written_at"]
        policy._expires_np[:] = m.get("expires_at",
                                      np.zeros(cap, np.int64))
        # rewrite provenance (format 4, DESIGN.md §18). Older snapshots
        # carry it implicitly: the answer_ref == -2 sentinel is in the
        # saved device arrays, so the mirror is derivable either way.
        rw = m.get("rewritten")
        if rw is None:
            rw = (np.asarray(dyn_np["answer_ref"]) == -2) & m["valid"]
        policy._rewritten_np[:] = rw
        policy._ttl_active = bool((policy._expires_np > 0).any())
        policy.t = int(snap.extra["t"])
        answers = snap.extra.get("dyn_answers") or [None] * cap
        policy.dyn_answers = list(answers)
        if policy.dyn_index is not None:
            if policy.dyn_index.stats().get("writes", 0):
                raise ValueError(
                    "restore_policy needs a fresh dyn_index: the "
                    "segmented index is rebuilt from the restored "
                    "live set, not merged into existing state")
            live = np.nonzero(m["valid"])[0]
            if len(live):
                policy.dyn_index.bulk_load(live.astype(np.int32),
                                           dyn_np["emb"][live])
        # entries already past their expiry at the captured clock must
        # not resurrect (DESIGN.md §16) — the policy's own eager sweep
        # kills them in the tier, the mirrors, and the dynamic index
        ttl_dropped = policy._sweep_expired_locked(policy.t)

    l1_restored = 0
    l1_state = snap.extra.get("l1")
    if getattr(policy, "l1", None) is not None and l1_state:
        l1_restored = policy.l1.load_state(l1_state, now=policy.t)

    # adaptive controller state (DESIGN.md §17): live per-segment
    # thresholds, the evidence window, and the regret counters pick up
    # exactly where the crashed process left them — a restart must not
    # reset the operating point back to the pinned config
    adaptive_restored = False
    ad_scalars = snap.extra.get("adaptive")
    if getattr(policy, "adaptive", None) is not None \
            and ad_scalars and "adaptive" in snap.tree:
        with policy.dyn_lock:
            policy.adaptive.load_state(snap.tree["adaptive"], ad_scalars)
        adaptive_restored = True

    report = {
        "step": snap.step, "t": policy.t,
        "adaptive_restored": adaptive_restored,
        "wal_seq": int(snap.extra.get("wal_seq", 0)),
        "dyn_live": int(policy._valid_np.sum()),
        "ttl_dropped": int(ttl_dropped),
        "l1_restored": int(l1_restored),
        "index": "none", "rebuild_thread": None,
    }

    # -- static index: warm restore, else rebuild-and-swap ----------------
    wants_index = _plain_ivf_index(policy.index) is not None \
        or (policy.index is None and policy.mesh is None
            and snap.extra.get("ivf") is not None)
    if not wants_index or rebuild == "never" and policy.index is not None:
        report["index"] = "kept" if policy.index is not None else "none"
        return report

    warm = load_static_index(snap, policy.static.emb)
    if warm is not None:
        cur = _plain_ivf_index(policy.index)
        if cur is not None:   # keep the operator's live serving knobs
            warm = load_static_index(snap, policy.static.emb,
                                     nprobe=cur.nprobe,
                                     n_candidates=cur.n_candidates,
                                     force=cur.force)
        policy.index = warm
        report["index"] = "warm"
        return report
    if rebuild == "never":
        report["index"] = "kept" if policy.index is not None else "none"
        return report

    report["index"] = f"rebuild-{rebuild}"
    ivf_meta = snap.extra.get("ivf") or {}
    cur = _plain_ivf_index(policy.index)
    nprobe = cur.nprobe if cur is not None \
        else ivf_meta.get("nprobe", 8)
    n_candidates = cur.n_candidates if cur is not None \
        else ivf_meta.get("n_candidates", 32)

    def _build_and_swap():
        from repro.index.ivf import IVFIndex, build_ivf
        ivf = build_ivf(policy.static.emb, corpus_normalized=True)
        # atomic swap: attribute assignment is atomic under the GIL,
        # and every serve reads `policy.index` exactly once per call
        policy.index = IVFIndex(ivf, nprobe=nprobe,
                                n_candidates=n_candidates)

    if rebuild == "inline":
        _build_and_swap()
    else:
        th = threading.Thread(target=_build_and_swap, daemon=True,
                              name="persist-index-rebuild")
        th.start()
        report["rebuild_thread"] = th
    return report
