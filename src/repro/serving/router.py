"""Micro-batching request router in front of a cache policy.

``CacheRouter`` is the serving front door (DESIGN.md §7): concurrent
callers ``submit()`` prompts; a collector thread coalesces them into
micro-batches (``max_batch`` requests or ``max_wait_ms``, whichever first)
and drives ``policy.serve_batch`` — so the embed, the fused static-tier
top-k, the masked dynamic lookup and the backend prefill are all amortized
across in-flight requests, while per-request semantics stay identical to
the scalar ``policy.serve`` path.

The router also owns the serving telemetry: per-tier hit counters, batch
occupancy, error counts, and end-to-end (enqueue -> answer) latency
percentiles.

The queue + collector machinery lives in ``_MicroBatcher`` and is shared
with :class:`repro.serving.engine.BatchingFrontend`, which batches raw
engine requests the same way.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence


@dataclass
class _PendingRequest:
    prompt: str
    meta: Optional[dict] = None
    enq_t: float = field(default_factory=time.monotonic)
    done: threading.Event = field(default_factory=threading.Event)
    result: object = None
    error: Optional[BaseException] = None
    latency_s: float = 0.0


class _MicroBatcher:
    """Queue + collector thread coalescing submissions into batches.

    ``serve_fn(batch)`` receives a list of :class:`_PendingRequest` and
    fills each ``result``; if it raises, every request in the batch gets
    the exception on ``error`` instead. Completion events are always set,
    so callers never hang on a failed batch.
    """

    def __init__(self, serve_fn: Callable[[List[_PendingRequest]], None],
                 max_batch: int, max_wait_s: float,
                 name: str = "micro-batcher"):
        self.serve_fn = serve_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_s
        self.q: "queue.Queue[_PendingRequest]" = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._worker.start()

    def submit(self, prompt: str,
               meta: Optional[dict] = None) -> _PendingRequest:
        p = _PendingRequest(prompt, meta)
        self.q.put(p)
        return p

    def _run(self):
        while not self._stop.is_set():
            try:
                first = self.q.get(timeout=0.1)
            except queue.Empty:
                continue
            batch = [first]
            t0 = time.monotonic()
            while len(batch) < self.max_batch \
                    and time.monotonic() - t0 < self.max_wait:
                try:
                    batch.append(self.q.get_nowait())
                except queue.Empty:
                    time.sleep(0.0005)
            try:
                self.serve_fn(batch)
            except Exception as e:  # noqa: BLE001 — surface, don't strand
                for p in batch:
                    p.error = e
            finally:
                now = time.monotonic()
                for p in batch:
                    p.latency_s = now - p.enq_t
                    p.done.set()

    def stop(self):
        self._stop.set()
        self._worker.join(timeout=2.0)


class CacheRouter:
    """Request queue + micro-batcher over ``policy.serve_batch``."""

    def __init__(self, policy, max_batch: int = 32,
                 max_wait_ms: float = 2.0, latency_window: int = 100_000):
        self.policy = policy
        self._lock = threading.Lock()
        self._tier_counts = {"l1": 0, "static": 0, "dynamic": 0,
                             "rewritten": 0, "backend": 0}
        self._static_origin = 0
        self._promoted = 0          # dynamic hits serving promoted content
        self._stale = 0             # hits flagged stale by the drift clock
        self._bypassed = 0          # volatile requests routed cache-free
        self._requests = 0
        # latency percentiles come from a bounded window so a long-lived
        # router neither leaks memory nor sorts its whole history
        self._latencies: deque = deque(maxlen=latency_window)
        self._batches = 0
        self._batched_requests = 0
        self._errors = 0
        self._last_error = ""
        self._mb = _MicroBatcher(self._serve, max_batch,
                                 max_wait_ms / 1e3, name="cache-router")

    # -- client side -------------------------------------------------------
    def submit(self, prompt: str, meta: Optional[dict] = None,
               timeout_s: float = 60.0):
        """Enqueue one request and block until its ServeResult is ready.
        Returns None if the batch failed (see ``stats()['errors']``) or
        the timeout elapsed."""
        p = self._mb.submit(prompt, meta)
        p.done.wait(timeout_s)
        return p.result

    def submit_many(self, prompts: Sequence[str],
                    metas: Optional[Sequence[Optional[dict]]] = None,
                    timeout_s: float = 60.0):
        """Enqueue a pre-formed group; blocks until every result is in.

        Unlike :meth:`submit` from N threads, this hands the collector the
        whole group at once, so it batches without waiting ``max_wait``.
        """
        metas = list(metas) if metas is not None else [None] * len(prompts)
        pending = [self._mb.submit(p, m) for p, m in zip(prompts, metas)]
        for p in pending:
            p.done.wait(timeout_s)
        return [p.result for p in pending]

    def feedback(self, result, ok: bool) -> bool:
        """Operator error feedback on a served answer (DESIGN.md §17):
        pass the ``ServeResult`` (or its ``meta['adapt_seq']`` int) and
        whether the answer was right. A wrong-answer report rewrites
        the threshold controller's window-row label, so the next shadow
        sweep scores serving that query as an error. No-op (False)
        without an adaptive controller or once the row has rotated out
        of the bounded window."""
        fb = getattr(self.policy, "feedback", None)
        if fb is None:
            return False
        seq = result if isinstance(result, int) \
            else (getattr(result, "meta", None) or {}).get("adapt_seq", 0)
        if not seq:
            return False
        return bool(fb(seq, ok))

    # -- collector callback ------------------------------------------------
    def _serve(self, batch: List[_PendingRequest]):
        try:
            results = self.policy.serve_batch(
                [p.prompt for p in batch], [p.meta for p in batch])
        except Exception as e:  # noqa: BLE001 — count, then fail the batch
            with self._lock:
                self._errors += len(batch)
                self._last_error = repr(e)
            raise
        now = time.monotonic()
        with self._lock:
            self._batches += 1
            self._batched_requests += len(batch)
            self._requests += len(batch)
            for p, r in zip(batch, results):
                p.result = r
                self._latencies.append(now - p.enq_t)
                self._tier_counts[r.served_by] = \
                    self._tier_counts.get(r.served_by, 0) + 1
                self._static_origin += bool(r.static_origin)
                # rewritten serves are promoted content too (§18): the
                # tailored variant entered the tier via a verdict
                self._promoted += (r.served_by in ("dynamic", "rewritten")
                                   and bool(r.static_origin))
                self._stale += bool(r.meta.get("stale"))
                self._bypassed += r.meta.get("bypass") == "volatile"

    # -- telemetry ---------------------------------------------------------
    def stats(self) -> dict:
        import numpy as np
        with self._lock:
            lat = np.asarray(self._latencies, np.float64)
            n = max(self._requests, 1)
            describe = getattr(self.policy, "describe_index", None)
            dyn_describe = getattr(self.policy, "describe_dyn_index",
                                   None)
            out = {
                "requests": self._requests,
                "batches": self._batches,
                # which static-tier index serves the lookups (flat exact
                # vs injected ANN — DESIGN.md §11)
                "static_index": describe() if describe else "unknown",
                # dynamic-tier lookup path (flat masked scan vs the
                # segmented incremental index — DESIGN.md §12)
                "dynamic_index": dyn_describe() if dyn_describe
                else "unknown",
                "mean_batch_size": round(
                    self._batched_requests / max(self._batches, 1), 2),
                # hit-source mix (DESIGN.md §16): the L1 exact front,
                # the two semantic tiers (dynamic split by content
                # origin), and the backend — plus the freshness flags
                "l1_hit_rate": self._tier_counts["l1"] / n,
                "static_hit_rate": self._tier_counts["static"] / n,
                "dynamic_hit_rate": self._tier_counts["dynamic"] / n,
                "rewritten_hit_rate": self._tier_counts["rewritten"] / n,
                "promoted_hit_rate": self._promoted / n,
                "backend_rate": self._tier_counts["backend"] / n,
                "static_origin_rate": self._static_origin / n,
                "stale_serve_rate": self._stale / n,
                "bypassed_volatile": self._bypassed,
                "errors": self._errors,
            }
            # freshness-layer counters owned by the policy (L1 probes,
            # volatile bypasses, TTL deaths) — surfaced when present
            for name, attr in (("l1_hits", "_l1_hits"),
                               ("l1_bypass_volatile", "_l1_bypass"),
                               ("stale_serves", "_stale_serves"),
                               ("ttl_evictions", "_ttl_evictions")):
                v = getattr(self.policy, attr, None)
                if v is not None:
                    out[name] = int(v)
            l1 = getattr(self.policy, "l1", None)
            if l1 is not None:
                out["l1_entries"] = l1.stats()["l1_entries"]
            shard_stats = getattr(self.policy, "shard_stats", None)
            shard_stats = shard_stats() if shard_stats else None
            if shard_stats is not None:
                # mesh-serving layout (DESIGN.md §13): how many shards
                # the tiers are row-partitioned over, and how the live
                # dynamic entries spread across them
                out["shards"] = shard_stats["shards"]
                out["shard_occupancy"] = shard_stats["shard_occupancy"]
            dyn_stats = getattr(self.policy, "dyn_index_stats", None)
            dyn_stats = dyn_stats() if dyn_stats else None
            if dyn_stats is not None:
                # segment/tail occupancy + compaction counters
                # (SegmentedIndex.stats, DESIGN.md §12)
                out["dyn_tail_live"] = dyn_stats["tail_live"]
                out["dyn_segments"] = dyn_stats["segments"]
                out["dyn_segment_live"] = dyn_stats["segment_live"]
                out["dyn_seals"] = dyn_stats["seals"]
                out["dyn_merges"] = dyn_stats["merges"]
                out["dyn_tombstones"] = dyn_stats["tombstones"]
            pool = getattr(self.policy, "pool", None)
            if pool is not None and hasattr(pool, "depth"):
                # async VerifyAndPromote backlog (DESIGN.md §4/§14):
                # the load harness tracks this over time — depth only
                # delays promotions, never serving
                depth = pool.depth()
                out["judge_queued"] = depth["queued"]
                out["judge_inflight"] = depth["inflight"]
                # per-outcome verdict counters (§18): how the judged
                # grey-zone tasks resolved, plus the rewrite-path
                # degradation counts
                ps = getattr(pool, "stats", None)
                if ps is not None:
                    for name in ("approved", "rejected", "rewritten",
                                 "rewrite_failed",
                                 "rewrite_rate_limited"):
                        v = getattr(ps, name, None)
                        if v is not None:
                            out[f"judge_{name}"] = int(v)
            wal = getattr(self.policy, "wal", None)
            if wal is not None:
                out["wal_seq"] = wal.stats()["seq"]
            adaptive = getattr(self.policy, "adaptive", None)
            if adaptive is not None:
                # online threshold controller (DESIGN.md §17): live
                # per-segment operating points, window fill, and the
                # regret-style counters (shadow hits the pinned point
                # left on the table vs the measured frontier)
                out.update(adaptive.stats())
            if self._last_error:
                out["last_error"] = self._last_error
            if lat.size:
                out["p50_latency_ms"] = round(
                    1e3 * float(np.percentile(lat, 50)), 3)
                out["p99_latency_ms"] = round(
                    1e3 * float(np.percentile(lat, 99)), 3)
        return out

    def stop(self):
        self._mb.stop()
