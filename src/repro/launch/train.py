"""Multi-device training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --steps 50 --batch 8 --seq 128 [--devices 8] [--ckpt DIR]

On a real TPU pod slice this runs under the production mesh; on CPU pass
--devices N to force host devices (set before jax init).
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing)")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch, smoke_config
    from repro.data.lm_data import synthetic_lm_batches
    from repro.distributed import sharding as shd
    from repro.distributed.act_sharding import use_dp_axes
    from repro.launch.mesh import make_smoke_mesh, dp_axes
    from repro.models import transformer as tr
    from repro.training import optimizer as opt
    from repro.training.train_loop import TrainConfig, lr_schedule

    cfg = smoke_config(args.arch) if args.smoke else get_arch(args.arch)
    mesh = make_smoke_mesh()
    dp = dp_axes(mesh)
    print(f"mesh {dict(mesh.shape)} | arch {cfg.name}")

    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shd.lm_param_specs(cfg),
                           is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(params, p_shard)
    opt_state = opt.init(params, opt.AdamWConfig())

    step0 = opt.make_train_step(
        lambda p, b: tr.train_loss(cfg, p, b,
                                   vocab_chunk_seq=min(args.seq, 512)),
        opt.AdamWConfig())

    def step(p, o, b):
        with use_dp_axes(dp):
            return step0(p, o, b)

    jstep = jax.jit(step, donate_argnums=(0, 1))
    data = synthetic_lm_batches(cfg.vocab_size, args.batch, args.seq)
    bshard = NamedSharding(mesh, P(dp, None))

    from repro.distributed import checkpoint as ck
    with mesh:
        for i in range(args.steps):
            b = next(data)
            b = {k: jax.device_put(jnp.asarray(v), bshard)
                 for k, v in b.items()}
            params, opt_state, m = jstep(params, opt_state, b)
            if (i + 1) % 5 == 0 or i == 0:
                print(f"step {i+1:4d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.2f}")
            if args.ckpt and (i + 1) % 20 == 0:
                ck.save(args.ckpt, i + 1,
                        {"params": params, "opt": opt_state})
    print("done")


if __name__ == "__main__":
    main()
