import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402

from repro.analysis import roofline as rl               # noqa: E402
from repro.analysis.hlo_parse import (collective_bytes,  # noqa: E402
                                      count_collectives)
from repro.configs import ARCHS, all_cells, get_arch, shapes_for  # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.workloads import build_workload       # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile_costs(wl, mesh) -> dict:
    """Lower+compile one workload; return cost/collective/memory numbers."""
    jitted = jax.jit(wl.fn, in_shardings=wl.in_shardings,
                     out_shardings=wl.out_shardings,
                     donate_argnums=wl.donate_argnums)
    lowered = jitted.lower(*wl.args)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    mem = rl.memory_summary(compiled)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
        "coll_counts": count_collectives(hlo),
        "mem": mem,
        "hlo": hlo,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             save_hlo: bool = False) -> dict:
    """Compile the full cell (proves the 512-chip sharding) and, for LM
    archs, two unrolled analysis variants (1- and 2-layer) to correct
    XLA's while-loop cost undercount: cost(L) = fixed + L*per_layer.
    (GNN/recsys workloads are loop-free, so cost_analysis is exact.)
    """
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape}__{mesh_name}"
    out_path = out_dir / f"{cell}.json"
    t0 = time.time()
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        wl = build_workload(arch, shape, mesh)
        cfg = get_arch(arch)
        is_lm = hasattr(cfg, "n_layers") and hasattr(cfg, "vocab_size")
        with mesh:
            full = _compile_costs(wl, mesh)
            t_compile = time.time() - t0
            mem = dict(full["mem"])
            args_b = mem.get("argument_size_in_bytes", 0.0)
            out_b = mem.get("output_size_in_bytes", 0.0)
            alias_b = mem.get("alias_size_in_bytes", 0.0)
            temp_b = mem.get("temp_size_in_bytes", 0.0)
            residuals = wl.residual_bytes_per_layer * wl.n_loop_layers
            flops = full["flops"]
            coll_total = float(full["coll"].get("total", 0))
            coll_kinds = dict(full["coll"])
            corrected = False
            if is_lm:
                # XLA cost analysis counts while-loop bodies ONCE; lower
                # loop-free 1- and 2-layer variants and extrapolate
                # cost(L) = fixed + L*per_layer (verified experimentally,
                # see EXPERIMENTS.md §Dry-run methodology).
                wl1 = build_workload(arch, shape, mesh,
                                     n_layers_override=1, unroll=True)
                wl2 = build_workload(arch, shape, mesh,
                                     n_layers_override=2, unroll=True)
                c1 = _compile_costs(wl1, mesh)
                c2 = _compile_costs(wl2, mesh)
                L = cfg.n_layers

                def extrap(a, b):
                    per_layer = max(b - a, 0.0)
                    fixed = max(a - per_layer, 0.0)
                    return fixed + L * per_layer
                flops = extrap(c1["flops"], c2["flops"])
                coll_total = extrap(
                    float(c1["coll"].get("total", 0)),
                    float(c2["coll"].get("total", 0)))
                coll_kinds = {
                    k: extrap(float(c1["coll"].get(k, 0)),
                              float(c2["coll"].get(k, 0)))
                    for k in set(c1["coll"]) | set(c2["coll"])}
                # per-layer transient footprint (upper bound: CPU buffer
                # assignment does not reuse across layers)
                t1 = c1["mem"].get("temp_size_in_bytes", 0.0)
                t2 = c2["mem"].get("temp_size_in_bytes", 0.0)
                transient_layer = max(t2 - t1, 0.0)
                mem["transient_per_layer_est"] = transient_layer
                mem["residual_bytes"] = residuals
                mem["peak_bytes_est"] = (args_b + residuals
                                         + transient_layer
                                         + max(out_b - alias_b, 0.0))
                # HBM traffic model: read args + write outputs + residual
                # save/restore. Transients stay in VMEM on TPU (the jnp
                # attention/MoE paths are written flash-style).
                byts = args_b + out_b + 2.0 * residuals
                corrected = True
            else:
                # loop-free: cost_analysis flops are exact; HBM traffic =
                # buffers (temps here are real HBM-resident gathers etc.)
                byts = args_b + out_b + temp_b
                mem["peak_bytes_est"] = (args_b + temp_b
                                         + max(out_b - alias_b, 0.0))

            roof = rl.Roofline(
                name=wl.name, chips=int(mesh.devices.size),
                hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll_total,
                model_flops=wl.model_flops).finalize()
            rec.update({
                "ok": True,
                "compile_s": round(t_compile, 1),
                "corrected_by_unrolled_variants": corrected,
                "raw_cost_analysis": {"flops": full["flops"],
                                      "bytes": full["bytes"]},
                "memory": mem,
                "bytes_per_device": mem.get("peak_bytes_est"),
                "collectives": full["coll_counts"],
                "collective_bytes": coll_kinds,
                "roofline": roof.to_dict(),
            })
            print(f"[OK] {cell}: compile={t_compile:.0f}s "
                  f"bound={roof.bound} step={roof.step_s*1e3:.2f}ms "
                  f"frac={roof.roofline_frac:.3f} "
                  f"mem/dev={mem.get('peak_bytes_est', 0)/2**30:.2f}GiB")
            if save_hlo:
                (out_dir / f"{cell}.hlo.txt").write_text(full["hlo"])
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {cell}: {rec['error'].splitlines()[0][:200]}")
    rec["wall_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None,
                    help="arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all for the arch)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells whose JSON already reports ok")
    args = ap.parse_args()

    out_dir = Path(args.out)
    cells = []
    for a, s in all_cells():
        if args.arch and a != args.arch:
            continue
        if args.shape and s != args.shape:
            continue
        cells.append((a, s))

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    n_ok = n_fail = n_skip = 0
    for a, s in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            jpath = out_dir / f"{a}__{s}__{mesh_name}.json"
            if args.skip_done and jpath.exists():
                try:
                    if json.loads(jpath.read_text()).get("ok"):
                        n_skip += 1
                        continue
                except Exception:
                    pass
            rec = run_cell(a, s, mp, out_dir, save_hlo=args.save_hlo)
            n_ok += bool(rec.get("ok"))
            n_fail += not rec.get("ok")
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped "
          f"-> {out_dir}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
