"""Build (step_fn, abstract args, shardings) for every dry-run cell.

A *workload* is the jit-able function + ShapeDtypeStruct stand-ins for all
of its inputs (params, optimizer state, batch / cache) + matching
NamedShardings, for one (architecture x input-shape x mesh) combination.
Nothing here allocates device memory — everything is abstract until
``.lower().compile()`` in dryrun.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, get_shape
from repro.configs.base import GNNConfig, LMConfig, RecSysConfig, ShapeSpec
from repro.distributed import sharding as shd
from repro.distributed.act_sharding import use_dp_axes
from repro.launch.mesh import dp_axes
from repro.models import gnn, recsys, transformer as tr
from repro.training import optimizer as opt

ADAMW = opt.AdamWConfig()


@dataclass
class Workload:
    name: str
    fn: Callable          # positional args
    args: Tuple[Any, ...]  # ShapeDtypeStructs (pytrees)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    # model-level useful flops (6ND etc.) for the roofline analysis
    model_flops: float
    arch: str
    shape: str
    # buffers consumed by the step (train: params+opt; decode: KV cache) —
    # donation makes updates in-place, halving state traffic
    donate_argnums: Tuple[int, ...] = ()
    # per-device bytes saved for the backward pass per layer (remat carry);
    # 0 for inference / loop-free workloads
    residual_bytes_per_layer: float = 0.0
    n_loop_layers: int = 0


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _shard_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _params_abstract(init_fn):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(init_fn, key)


def _opt_abstract(params_abs):
    return jax.eval_shape(functools.partial(opt.init, cfg=ADAMW),
                          params_abs)


def _opt_specs(param_specs):
    return {"mu": param_specs, "nu": param_specs, "master": param_specs,
            "step": P()}


# ---------------------------------------------------------------------------
# LM workloads
# ---------------------------------------------------------------------------

def _lm_flops(cfg: LMConfig, shape: ShapeSpec) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + KV read has no flops
    return 2.0 * n_active * shape.global_batch


def build_lm(cfg: LMConfig, shape: ShapeSpec, mesh) -> Workload:
    import os
    if cfg.is_moe and os.environ.get("REPRO_MOE_DISPATCH"):
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, dispatch=os.environ["REPRO_MOE_DISPATCH"]))
    dp = dp_axes(mesh)
    params_abs = _params_abstract(lambda k: tr.init_params(cfg, k))
    p_specs = shd.lm_param_specs(cfg)
    p_shard = _shard_tree(mesh, p_specs)
    name = f"{cfg.name}:{shape.name}"
    mflops = _lm_flops(cfg, shape)

    if shape.kind == "train":
        B, S = shape.global_batch, shape.seq_len
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        # remat(nothing_saveable) saves only the layer input per layer;
        # under sequence-parallel residuals it is sharded over 'model' too
        carry = (B // n_dp) * S * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
        if cfg.seq_parallel and S % 16 == 0:
            carry //= mesh.shape.get("model", 1)
        batch = {"tokens": _sds((B, S), "int32"),
                 "labels": _sds((B, S), "int32")}
        b_shard = _shard_tree(mesh, {"tokens": P(dp, None),
                                     "labels": P(dp, None)})
        opt_abs = _opt_abstract(params_abs)
        o_shard = _shard_tree(mesh, _opt_specs(p_specs))
        step0 = opt.make_train_step(
            lambda p, b: tr.train_loss(cfg, p, b), ADAMW)

        def step(params, opt_state, b):
            with use_dp_axes(dp, mesh=mesh):
                return step0(params, opt_state, b)
        metrics_shard = _shard_tree(mesh, {"loss": P(),
                                           "grad_norm": P()})
        return Workload(name, step, (params_abs, opt_abs, batch),
                        (p_shard, o_shard, b_shard),
                        (p_shard, o_shard, metrics_shard),
                        mflops, cfg.name, shape.name,
                        donate_argnums=(0, 1),
                        residual_bytes_per_layer=float(carry),
                        n_loop_layers=cfg.n_layers)

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len
        tokens = _sds((B, S), "int32")
        t_shard = _shard_tree(mesh, P(dp, None))
        out_shard = (_shard_tree(mesh, P(dp, "model")),
                     _shard_tree(mesh, shd.lm_cache_spec(mesh)))
        fn = functools.partial(tr.prefill, cfg)

        def prefill_fn(params, toks):
            with use_dp_axes(dp, mesh=mesh):
                return fn(params, toks)
        return Workload(name, prefill_fn, (params_abs, tokens),
                        (p_shard, t_shard), out_shard,
                        mflops, cfg.name, shape.name)

    if shape.kind == "decode":
        B, S = shape.global_batch, shape.seq_len
        cache = {
            "k": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim),
                      cfg.dtype),
            "v": _sds((cfg.n_layers, B, S, cfg.n_kv_heads, cfg.head_dim),
                      cfg.dtype),
            "length": _sds((B,), "int32"),
        }
        c_shard = _shard_tree(mesh, shd.lm_cache_spec(mesh))
        token = _sds((B,), "int32")
        tk_shard = _shard_tree(mesh, P(dp))
        out_shard = (_shard_tree(mesh, P(dp, "model")), c_shard)

        def decode_fn(params, cache, token):
            with use_dp_axes(dp):
                return tr.decode_step(cfg, params, cache, token)
        return Workload(name, decode_fn, (params_abs, cache, token),
                        (p_shard, c_shard, tk_shard), out_shard,
                        mflops, cfg.name, shape.name,
                        donate_argnums=(1,))
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# GNN workloads
# ---------------------------------------------------------------------------

def _pad_to(n: int, mult: int) -> int:
    return -(-n // mult) * mult


def build_gnn(cfg: GNNConfig, shape: ShapeSpec, mesh) -> Workload:
    dp = dp_axes(mesh)
    n_dev = mesh.devices.size
    name = f"{cfg.name}:{shape.name}"

    if shape.kind in ("full_graph", "batched_graphs"):
        d_feat = shape.d_feat
    else:
        d_feat = shape.d_feat or cfg.d_feat
    params_abs = _params_abstract(
        lambda k: gnn.init_params(cfg, k, d_feat=d_feat))
    p_shard = _shard_tree(mesh, jax.tree.map(lambda _: P(), params_abs))
    opt_abs = _opt_abstract(params_abs)
    o_shard = _shard_tree(mesh, jax.tree.map(lambda _: P(), opt_abs))
    metrics_shard = _shard_tree(mesh, {"loss": P(), "grad_norm": P()})

    if shape.kind == "full_graph":
        N, E = shape.n_nodes, _pad_to(shape.n_edges, n_dev)
        batch = {"feats": _sds((N, d_feat), "float32"),
                 "edges": _sds((E, 2), "int32"),
                 "edge_mask": _sds((E,), "bool"),
                 "labels": _sds((N,), "int32"),
                 "label_mask": _sds((N,), "bool")}
        b_spec = {"feats": P(None, None),
                  "edges": P(tuple(mesh.axis_names), None),
                  "edge_mask": P(tuple(mesh.axis_names)),
                  "labels": P(None), "label_mask": P(None)}
        loss = functools.partial(gnn.full_graph_loss, cfg)
        # gradient flops ~ 3x fwd; fwd ~ 2*E*d_in (gather+scatter has no
        # flops) + matmuls N*(d_in*d + d*d) per layer
        fwd = 2 * N * (d_feat * cfg.d_hidden * 2) \
            + 2 * N * (cfg.d_hidden * cfg.d_hidden * 2) * (cfg.n_layers - 1)
        mflops = 3.0 * fwd
    elif shape.kind == "minibatch":
        B = shape.batch_nodes
        f1, f2 = shape.fanout
        batch = {"feat_l0": _sds((B, d_feat), "float32"),
                 "feat_l1": _sds((B, f1, d_feat), "float32"),
                 "feat_l2": _sds((B, f1, f2, d_feat), "float32"),
                 "labels": _sds((B,), "int32")}
        b_spec = {"feat_l0": P(dp, None), "feat_l1": P(dp, None, None),
                  "feat_l2": P(dp, None, None, None), "labels": P(dp)}
        loss = functools.partial(gnn.minibatch_loss, cfg)
        n_vec = B * (1 + f1 + f1 * f2)
        mflops = 3.0 * 2 * n_vec * d_feat * cfg.d_hidden * 2
    else:  # batched_graphs
        G, Ng, Eg = shape.global_batch, shape.n_nodes, shape.n_edges
        batch = {"feats": _sds((G, Ng, d_feat), "float32"),
                 "edges": _sds((G, Eg, 2), "int32"),
                 "edge_mask": _sds((G, Eg), "bool"),
                 "labels": _sds((G,), "int32")}
        b_spec = {"feats": P(dp, None, None), "edges": P(dp, None, None),
                  "edge_mask": P(dp, None), "labels": P(dp)}
        loss = functools.partial(gnn.batched_graphs_loss, cfg)
        mflops = 3.0 * 2 * G * Ng * (
            d_feat * cfg.d_hidden * 2
            + cfg.d_hidden * cfg.d_hidden * 2 * (cfg.n_layers - 1))

    b_shard = _shard_tree(mesh, b_spec)
    step = opt.make_train_step(loss, ADAMW)
    return Workload(name, step, (params_abs, opt_abs, batch),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, metrics_shard),
                    mflops, cfg.name, shape.name,
                    donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# RecSys workloads
# ---------------------------------------------------------------------------

SERVE_SLATE = {"sasrec": 100, "mind": 100, "bst": 1, "wide_deep": 1}


def _recsys_batch(cfg: RecSysConfig, kind: str, B: int, n_cands: int):
    mh = cfg.multi_hot
    if cfg.kind == "wide_deep":
        b = {"sparse_ids": _sds((B, cfg.n_sparse, mh), "int32"),
             "sparse_mask": _sds((B, cfg.n_sparse, mh), "bool")}
    else:
        b = {"seq": _sds((B, cfg.seq_len), "int32")}
    if kind == "train":
        if cfg.kind == "sasrec":
            b.update({"pos": _sds((B, cfg.seq_len), "int32"),
                      "neg": _sds((B, cfg.seq_len), "int32")})
        elif cfg.kind == "mind":
            b.update({"pos": _sds((B,), "int32"),
                      "neg": _sds((B, 16), "int32")})
        elif cfg.kind == "bst":
            b.update({"target": _sds((B,), "int32"),
                      "label": _sds((B,), "float32")})
        else:
            b["label"] = _sds((B,), "float32")
    elif kind == "serve":
        slate = SERVE_SLATE[cfg.kind]
        if cfg.kind != "wide_deep":
            b["cands"] = _sds((B, slate), "int32")
    else:  # retrieval
        b["cand_ids"] = _sds((n_cands,), "int32")
    return b


def _recsys_flops(cfg: RecSysConfig, shape: ShapeSpec) -> float:
    d = cfg.embed_dim
    if cfg.kind in ("sasrec", "mind", "bst"):
        S = cfg.seq_len + (1 if cfg.kind == "bst" else 0)
        blocks = max(cfg.n_blocks, 1)
        per_ex = blocks * (8 * S * d * d + 4 * S * S * d) \
            + sum(a * b * 2 for a, b in zip(
                ((cfg.seq_len + 1) * d,) + tuple(cfg.mlp_dims),
                tuple(cfg.mlp_dims) + (1,))) * (cfg.kind == "bst")
        if cfg.kind == "mind":
            per_ex = cfg.capsule_iters * 4 * S * cfg.n_interests * d \
                + 2 * S * d * d
    else:
        dims = (cfg.n_sparse * d,) + tuple(cfg.mlp_dims) + (1,)
        per_ex = sum(a * b * 2 for a, b in zip(dims[:-1], dims[1:]))
    if shape.kind == "train":
        return 3.0 * per_ex * shape.global_batch
    if shape.kind == "serve":
        slate = SERVE_SLATE[cfg.kind]
        mult = slate if cfg.kind == "bst" else 1
        return per_ex * shape.global_batch * mult
    # retrieval: encode once + dot against all candidates
    return per_ex + 2.0 * shape.n_candidates * cfg.embed_dim


def build_recsys(cfg: RecSysConfig, shape: ShapeSpec, mesh) -> Workload:
    name = f"{cfg.name}:{shape.name}"
    params_abs = _params_abstract(lambda k: recsys.init_params(cfg, k))
    p_specs = shd.recsys_param_specs(cfg, params_abs)
    p_shard = _shard_tree(mesh, p_specs)
    mflops = _recsys_flops(cfg, shape)

    batch = _recsys_batch(cfg, shape.kind, shape.global_batch,
                          shape.n_candidates)
    b_spec = shd.recsys_batch_spec(mesh, cfg, shape.kind)
    b_spec = {k: b_spec[k] for k in batch}  # align key sets
    b_shard = _shard_tree(mesh, b_spec)

    if shape.kind == "train":
        opt_abs = _opt_abstract(params_abs)
        o_shard = _shard_tree(mesh, _opt_specs(p_specs))
        step = opt.make_train_step(
            lambda p, b: recsys.train_loss(cfg, p, b), ADAMW)
        metrics_shard = _shard_tree(mesh, {"loss": P(), "grad_norm": P()})
        return Workload(name, step, (params_abs, opt_abs, batch),
                        (p_shard, o_shard, b_shard),
                        (p_shard, o_shard, metrics_shard),
                        mflops, cfg.name, shape.name,
                        donate_argnums=(0, 1))

    if shape.kind == "serve":
        dp = dp_axes(mesh)

        def serve_fn(params, b):
            return recsys.serve_scores(cfg, params, b)
        out_shard = _shard_tree(mesh, P(dp, None))
        return Workload(name, serve_fn, (params_abs, batch),
                        (p_shard, b_shard), out_shard,
                        mflops, cfg.name, shape.name)

    # retrieval — shard_map per-shard top-k + merge by default; set
    # REPRO_SHARDED_RETRIEVAL=0 for the auto-GSPMD baseline (§Perf A/B)
    import os
    use_sharded = os.environ.get("REPRO_SHARDED_RETRIEVAL", "1") == "1" \
        and "model" in mesh.axis_names \
        and shape.n_candidates % mesh.shape["model"] == 0

    def retr_fn(params, b):
        if use_sharded:
            return recsys.retrieval_sharded(cfg, params, b, mesh, k=100)
        return recsys.retrieval(cfg, params, b, k=100)
    out_shard = _shard_tree(mesh, (P(None, None), P(None, None)))
    return Workload(name, retr_fn, (params_abs, batch),
                    (p_shard, b_shard), out_shard,
                    mflops, cfg.name, shape.name)


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def build_workload(arch_id: str, shape_name: str, mesh,
                   n_layers_override: int | None = None,
                   unroll: bool = False) -> Workload:
    """``n_layers_override``/``unroll`` build the loop-free analysis
    variants used to correct XLA's while-loop cost undercount (the
    two-point extrapolation in dryrun.py / analysis.roofline)."""
    import dataclasses
    cfg = get_arch(arch_id)
    shape = get_shape(cfg, shape_name)
    if isinstance(cfg, LMConfig):
        if n_layers_override is not None or unroll:
            # larger attention chunks in the unrolled variants: identical
            # FLOPs/bytes math, ~4x fewer blocks -> tractable compiles
            chunk = max(cfg.attn_chunk,
                        shape.seq_len // 16 if shape.seq_len else 0)
            cfg = dataclasses.replace(
                cfg, n_layers=n_layers_override or cfg.n_layers,
                scan_layers=not unroll, attn_chunk=chunk)
        return build_lm(cfg, shape, mesh)
    if isinstance(cfg, GNNConfig):
        return build_gnn(cfg, shape, mesh)
    if isinstance(cfg, RecSysConfig):
        return build_recsys(cfg, shape, mesh)
    raise TypeError(type(cfg))
