"""The paper's own serving-path compute as a dry-run workload: batched
Krites cache lookup against a production-sized static tier.

Workload: B concurrent requests x (embed-dim d) queries against a static
tier of S curated entries sharded over 'model' — per-shard fused
simsearch (normalize · GEMM · online top-k) + k-candidate merge. This is
the simsearch kernel's production shape; run it through dryrun-style
lowering with:

    PYTHONPATH=src python -m repro.launch.cache_workload
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json                      # noqa: E402
import time                      # noqa: E402
from pathlib import Path         # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402

from repro.analysis import roofline as rl                  # noqa: E402
from repro.analysis.hlo_parse import collective_bytes      # noqa: E402
from repro.index.sharded import sharded_cosine_topk        # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run(B: int = 4096, S: int = 4_194_304, d: int = 64, k: int = 4,
        multi_pod: bool = False) -> dict:
    """4096 in-flight requests against a 4M-entry curated tier."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    q = jax.ShapeDtypeStruct((B, d), jnp.float32)
    corpus = jax.ShapeDtypeStruct((S, d), jnp.float32)

    with mesh:
        c = jax.jit(
            lambda q, c: sharded_cosine_topk(q, c, mesh, k=k)
        ).lower(q, corpus).compile()
    hlo = c.as_text()
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    mem = rl.memory_summary(c)
    args_b = mem.get("argument_size_in_bytes", 0.0)
    out_b = mem.get("output_size_in_bytes", 0.0)
    roof = rl.Roofline(
        name=f"krites-cache-lookup:B{B}xS{S}", chips=mesh.devices.size,
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=args_b + out_b + mem.get("temp_size_in_bytes", 0.0),
        coll_bytes=float(collective_bytes(hlo).get("total", 0)),
        model_flops=2.0 * B * S * d).finalize()
    rec = {"arch": "krites-cache-lookup", "shape": f"B{B}xS{S}xd{d}",
           "mesh": mesh_name, "ok": True, "memory": mem,
           "collective_bytes": collective_bytes(hlo),
           "roofline": roof.to_dict()}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"krites-cache-lookup__B{B}xS{S}__{mesh_name}.json"
     ).write_text(json.dumps(rec, indent=1))
    print(f"[OK] cache-lookup {mesh_name}: bound={roof.bound} "
          f"step={roof.step_s*1e6:.1f}us compute={roof.compute_s*1e6:.1f}us "
          f"mem={roof.memory_s*1e6:.1f}us coll={roof.collective_s*1e6:.1f}us "
          f"frac={roof.roofline_frac:.2f}")
    return rec


if __name__ == "__main__":
    run(multi_pod=False)
    run(multi_pod=True)
