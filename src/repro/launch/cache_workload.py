"""The paper's own serving-path compute as a dry-run workload: batched
Krites cache lookup against a production-sized static tier.

Workload: B concurrent requests x (embed-dim d) queries against a static
tier of S curated entries sharded over 'model' — per-shard fused
simsearch (normalize · GEMM · online top-k) + k-candidate merge. This is
the simsearch kernel's production shape; run it through dryrun-style
lowering with:

    PYTHONPATH=src python -m repro.launch.cache_workload

``--live`` instead runs the same serving path end to end on local
devices: concurrent clients -> CacheRouter micro-batcher ->
KritesPolicy.serve_batch (fused static top-k + masked dynamic lookup +
bulk grey-zone verification) -> batched backend (DESIGN.md §7):

    PYTHONPATH=src python -m repro.launch.cache_workload --live
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json                      # noqa: E402
import time                      # noqa: E402
from pathlib import Path         # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402

from repro.analysis import roofline as rl                  # noqa: E402
from repro.analysis.hlo_parse import collective_bytes      # noqa: E402
from repro.index.sharded import sharded_cosine_topk        # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run(B: int = 4096, S: int = 4_194_304, d: int = 64, k: int = 4,
        multi_pod: bool = False) -> dict:
    """4096 in-flight requests against a 4M-entry curated tier."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    q = jax.ShapeDtypeStruct((B, d), jnp.float32)
    corpus = jax.ShapeDtypeStruct((S, d), jnp.float32)

    with mesh:
        c = jax.jit(
            lambda q, c: sharded_cosine_topk(q, c, mesh, k=k)
        ).lower(q, corpus).compile()
    hlo = c.as_text()
    ca = c.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    mem = rl.memory_summary(c)
    args_b = mem.get("argument_size_in_bytes", 0.0)
    out_b = mem.get("output_size_in_bytes", 0.0)
    roof = rl.Roofline(
        name=f"krites-cache-lookup:B{B}xS{S}", chips=mesh.devices.size,
        hlo_flops=float(ca.get("flops", 0.0)),
        hlo_bytes=args_b + out_b + mem.get("temp_size_in_bytes", 0.0),
        coll_bytes=float(collective_bytes(hlo).get("total", 0)),
        model_flops=2.0 * B * S * d).finalize()
    rec = {"arch": "krites-cache-lookup", "shape": f"B{B}xS{S}xd{d}",
           "mesh": mesh_name, "ok": True, "memory": mem,
           "collective_bytes": collective_bytes(hlo),
           "roofline": roof.to_dict()}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"krites-cache-lookup__B{B}xS{S}__{mesh_name}.json"
     ).write_text(json.dumps(rec, indent=1))
    print(f"[OK] cache-lookup {mesh_name}: bound={roof.bound} "
          f"step={roof.step_s*1e6:.1f}us compute={roof.compute_s*1e6:.1f}us "
          f"mem={roof.memory_s*1e6:.1f}us coll={roof.collective_s*1e6:.1f}us "
          f"frac={roof.roofline_frac:.2f}")
    return rec


def run_live(n_requests: int = 800, n_clients: int = 8,
             max_batch: int = 32, max_wait_ms: float = 2.0,
             tau: float = 0.92, index: str = "flat",
             static_rows: int = 0, nprobe: int = 8,
             dyn_index: str = "flat", seg_rows: int = 4096,
             compact_every: int = 4, shards: int = 1,
             l1_capacity: int = 0, volatile_bypass: bool = False,
             ttl_volatile: int = 0, ttl_stable: int = 0,
             adaptive: bool = False, adapt_every: int = 256,
             adapt_window: int = 1024, rewrite: bool = False,
             rewrite_rate: float = 1.0) -> dict:
    """Live router-fronted serving demo: the batched serving path under
    concurrent client load, with per-tier hit and latency telemetry.
    ``index='ivf'`` swaps the static lookup for the quantized ANN index
    (padding the tier to ``static_rows`` synthetic entries first);
    ``dyn_index='segmented'`` serves dynamic-tier lookups through the
    incremental tail+segments index (DESIGN.md §12); ``shards > 1``
    serves both tiers row-sharded over a 'model' mesh of that many
    (forced host) devices with shard-routed writes (DESIGN.md §13) —
    decisions identical to single-device."""
    import threading

    import numpy as np

    from repro.core.judge import OracleJudge, template_rewriter
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig
    from repro.embedding.embedder import Embedder
    from repro.launch.mesh import make_shard_mesh
    from repro.launch.serve import build_demo_tier, build_dyn_index
    from repro.serving.router import CacheRouter

    mesh = make_shard_mesh(shards) if shards > 1 else None
    if mesh is not None and dyn_index == "segmented":
        print("note: dyn_index='segmented' is single-device only; "
              "shards>1 uses the row-sharded masked scan (DESIGN.md §13)")
        dyn_index = "flat"
    embed = Embedder(d_out=64)
    intents = [f"how do i {v} my {n}" for v in
               ("fix", "update", "reset", "clean", "sell", "charge")
               for n in ("bike", "laptop", "router", "garden", "phone")]
    tier, answers, texts, idx_obj = build_demo_tier(
        np.asarray(embed.batch(intents)),
        [f"[curated] {p}" for p in intents],
        static_rows=static_rows, index=index, nprobe=nprobe,
        mesh=mesh, texts=intents)

    freshness = None
    if volatile_bypass or ttl_volatile or ttl_stable:
        from repro.core.freshness import FreshnessPolicy
        freshness = FreshnessPolicy(volatile_bypass=volatile_bypass,
                                    ttl_volatile=ttl_volatile,
                                    ttl_stable=ttl_stable,
                                    ttl_unknown=ttl_stable)
    cfg = CacheConfig(tau, tau, sigma_min=0.3, capacity=1024,
                      l1=bool(l1_capacity),
                      volatile_bypass=volatile_bypass,
                      ttl_volatile=ttl_volatile, ttl_stable=ttl_stable,
                      rewrite=rewrite, rewrite_rate=rewrite_rate)
    adaptive_ctl = None
    if adaptive:
        from repro.core.adaptive import (AdaptiveController,
                                         AdaptiveParams)
        adaptive_ctl = AdaptiveController(
            cfg, d=64, params=AdaptiveParams(window=adapt_window,
                                             adapt_every=adapt_every))
    policy = KritesPolicy(
        cfg, tier, answers,
        embed, backend_fn=lambda p: f"generated({p})",
        judge_fn=OracleJudge(
            freshness=freshness,
            rewritable=(lambda qc, hc, qt, ht: True)
            if rewrite else None),
        d=64,
        backend_batch_fn=lambda ps: [f"generated({p})" for p in ps],
        index=idx_obj, static_texts=texts, mesh=mesh,
        rewriter=template_rewriter if rewrite else None,
        l1=l1_capacity or None, freshness=freshness,
        adaptive=adaptive_ctl,
        dyn_index=build_dyn_index(dyn_index, cfg.capacity, 64,
                                  seg_rows=seg_rows,
                                  compact_every=compact_every))
    router = CacheRouter(policy, max_batch=max_batch,
                         max_wait_ms=max_wait_ms)

    prefixes = ["", "hey ", "um, ", "please, ", "quick q: ", "so, "]
    rng = np.random.default_rng(0)
    reqs = [(prefixes[int(rng.integers(len(prefixes)))] + intents[c], c)
            for c in rng.integers(0, len(intents), n_requests)]

    t0 = time.time()

    def client(k):
        for p, c in reqs[k::n_clients]:
            router.submit(p, meta={"cls": int(c)})

    threads = [threading.Thread(target=client, args=(k,))
               for k in range(n_clients)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wall = time.time() - t0     # serving throughput only — the async
    policy.pool.drain()         # verification drain is off-path

    s = router.stats()
    s["requests_per_s"] = round(n_requests / wall, 1)
    print(f"[OK] live router: {n_requests} reqs from {n_clients} clients "
          f"in {wall:.2f}s ({s['requests_per_s']} req/s)")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    router.stop()
    policy.pool.stop()
    return s


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--live", action="store_true",
                    help="run the router-fronted live serving demo "
                         "instead of the dry-run lowering")
    ap.add_argument("--requests", type=int, default=800)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--index", choices=["flat", "ivf"], default="flat",
                    help="static-tier lookup strategy for --live "
                         "(DESIGN.md §11)")
    ap.add_argument("--static-rows", type=int, default=0,
                    help="pad the live demo's curated tier to this many "
                         "rows before building the index")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--dyn-index", choices=["flat", "segmented"],
                    default="flat",
                    help="dynamic-tier lookup strategy for --live "
                         "(DESIGN.md §12)")
    ap.add_argument("--seg-rows", type=int, default=4096,
                    help="segmented dynamic index tail capacity")
    ap.add_argument("--compact-every", type=int, default=4,
                    help="merge sealed segments whenever this many "
                         "have accumulated")
    ap.add_argument("--shards", type=int, default=1,
                    help="serve --live through the row-sharded mesh "
                         "path over this many host devices "
                         "(DESIGN.md §13); 1 = single-device")
    ap.add_argument("--l1-capacity", type=int, default=0,
                    help="L1 exact-match front tier size for --live "
                         "(DESIGN.md §16); 0 = off")
    ap.add_argument("--volatile-bypass", action="store_true",
                    help="serve freshness-volatile prompts cache-free "
                         "in --live (DESIGN.md §16)")
    ap.add_argument("--ttl-volatile", type=int, default=0,
                    help="per-entry cache lifetime for volatile "
                         "content in --live (ticks; 0 = never)")
    ap.add_argument("--ttl-stable", type=int, default=0,
                    help="per-entry cache lifetime for stable/unknown "
                         "content in --live (ticks; 0 = never)")
    ap.add_argument("--adaptive", action="store_true",
                    help="attach the online threshold controller to "
                         "--live serving (DESIGN.md §17)")
    ap.add_argument("--adapt-every", type=int, default=256,
                    help="recorded requests between shadow sweeps")
    ap.add_argument("--adapt-window", type=int, default=1024,
                    help="controller request-window ring size")
    ap.add_argument("--rewrite", action="store_true",
                    help="three-outcome judge pipeline in --live "
                         "(DESIGN.md §18): would-reject grey-zone "
                         "pairs are rewritten and promoted keyed to "
                         "the new prompt")
    ap.add_argument("--rewrite-rate", type=float, default=1.0,
                    help="rewrite token-bucket refill per judged task")
    a = ap.parse_args()
    if a.live:
        run_live(n_requests=a.requests, n_clients=a.clients,
                 max_batch=a.max_batch, index=a.index,
                 static_rows=a.static_rows, nprobe=a.nprobe,
                 dyn_index=a.dyn_index, seg_rows=a.seg_rows,
                 compact_every=a.compact_every, shards=a.shards,
                 l1_capacity=a.l1_capacity,
                 volatile_bypass=a.volatile_bypass,
                 ttl_volatile=a.ttl_volatile, ttl_stable=a.ttl_stable,
                 adaptive=a.adaptive, adapt_every=a.adapt_every,
                 adapt_window=a.adapt_window, rewrite=a.rewrite,
                 rewrite_rate=a.rewrite_rate)
    else:
        run(multi_pod=False)
        run(multi_pod=True)
