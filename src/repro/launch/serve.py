"""Serving launcher: Krites-fronted LLM engine with request batching.

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Wires the full production topology on local devices: embedder -> tiered
cache (KritesPolicy, async judge pool) -> batching frontend -> LLM engine
(prefill + KV decode). ``--index ivf`` (with ``--static-rows N`` to pad
the curated tier to a realistic size) swaps the static lookup for the
IVF quantized ANN index (DESIGN.md §11):

    PYTHONPATH=src python -m repro.launch.serve --requests 200 \
        --index ivf --static-rows 100000

``--shards N`` serves through the mesh-aware path (DESIGN.md §13): both
tiers row-sharded over an N-device 'model' mesh, per-shard fused scans
with a tiny candidate merge, writes scattered to the owning shard. On a
CPU host it forces ``XLA_FLAGS=--xla_force_host_platform_device_count``
so N host devices exist; decisions are identical to ``--shards 1``:

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --shards 4

``--fused`` serves both tier decisions in ONE dispatch (DESIGN.md
§15): the static IVF probe and the masked dynamic top-1 run as a
single fused pass (``kernels/fused_serve``) with exact fp32 reranks,
so served scores match the dispatched paths. It replaces both lookups
and is mutually exclusive with ``--index ivf``, ``--dyn-index
segmented`` and ``--shards``:

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --fused

``--snapshot-dir DIR`` makes the service crash-safe (DESIGN.md §14):
on start it restores the newest snapshot (dynamic tier + mirrors + warm
ANN index) and replays the promotion WAL tail past the snapshot's
``wal_seq`` cursor; every approved promotion is journaled
(append-before-upsert) so a SIGKILL at any point loses no verified
promotion. ``--snapshot-every N`` saves periodically; a final snapshot
+ WAL compaction happens on clean shutdown:

    PYTHONPATH=src python -m repro.launch.serve --requests 200 \
        --snapshot-dir /tmp/krites-snaps

``--serve-stdio`` runs the process as a long-lived JSON-lines service
on stdin/stdout (one request or control op per line; consecutive serve
ops are coalesced into one batched call) — the protocol the live load
harness (``benchmarks/load_service.py``) and the crash-recovery tests
drive:

    {"op": "serve", "id": 0, "prompt": "how do i fix my bike", "cls": 0}
    {"op": "stats"} | {"op": "snapshot"} | {"op": "drain"}
    {"op": "shutdown"}
"""
import argparse
import os
import sys
import time


def build_demo_tier(emb_rows, answers, static_rows: int = 0,
                    index: str = "flat", nprobe: int = 8, mesh=None,
                    texts=None):
    """Shared demo-topology helper (also used by
    ``launch/cache_workload.py --live``): optionally pad the curated
    tier with synthetic entries to ``static_rows`` rows, then build the
    requested static-index object (DESIGN.md §11) — the sharded variant
    (§13) when a ``mesh`` is given. ``texts`` are the curated entries'
    prompt texts (row-aligned; judge payloads carry them).

    Returns (StaticTier, answers, texts, index object or None for
    exact flat).
    """
    import numpy as np

    from repro.core.tiers import make_static_tier

    emb_rows = np.asarray(emb_rows, np.float32)
    answers = list(answers)
    texts = list(texts) if texts is not None else [str(a) for a in answers]
    if static_rows > len(answers):
        # synthetic curated entries: random directions far from the
        # intent cluster, each its own answer class
        pad = np.random.default_rng(7).normal(
            size=(static_rows - len(answers),
                  emb_rows.shape[1])).astype(np.float32)
        emb_rows = np.concatenate([emb_rows, pad])
        answers += [f"[curated] synthetic-{i}" for i in range(len(pad))]
        texts += [f"synthetic prompt {i}" for i in range(len(pad))]
    tier = make_static_tier(emb_rows, np.arange(len(answers)))

    idx_obj = None
    if index == "ivf":
        if mesh is not None:
            from repro.index.sharded import ShardedIVFIndex
            idx_obj = ShardedIVFIndex(tier.emb, mesh, nprobe=nprobe)
        else:
            from repro.index.ivf import IVFIndex, build_ivf
            idx_obj = IVFIndex(build_ivf(tier.emb,
                                         corpus_normalized=True),
                               nprobe=nprobe)
        print(f"static index: {idx_obj.describe()}")
    return tier, answers, texts, idx_obj


def build_dyn_index(dyn_index: str, capacity: int, d: int,
                    seg_rows: int = 4096, compact_every: int = 4):
    """Dynamic-tier lookup strategy for the launchers (DESIGN.md §12):
    'flat' -> None (exact masked scan), 'segmented' -> a SegmentedIndex
    with a ``seg_rows`` tail sealing into int8 segments and a compactor
    merging every ``compact_every`` of them."""
    if dyn_index != "segmented":
        return None
    from repro.index.segmented import SegmentedIndex
    idx = SegmentedIndex(capacity, d, tail_rows=seg_rows,
                         compact_every=compact_every)
    print(f"dynamic index: {idx.describe()}")
    return idx


DEMO_INTENTS = [f"how do i {v} my {n}" for v in
                ("fix", "update", "reset", "clean", "sell")
                for n in ("bike", "laptop", "router", "garden")]
DEMO_PREFIXES = ["", "hey ", "um, ", "please, ", "quick q: "]


def _serve_stdio(policy, snap_dir, wal) -> None:
    """JSON-lines service loop (DESIGN.md §14): one message per stdin
    line, one JSON reply per line on stdout. Messages are processed in
    arrival order; consecutive ``serve`` ops already queued are
    coalesced into a single ``serve_batch`` call (the stdio twin of the
    router's micro-batcher). Control ops: ``stats``, ``snapshot``,
    ``drain``, ``shutdown``."""
    import json
    import queue as _q
    import threading

    from repro.distributed import checkpoint as ckpt
    from repro.serving import persist

    inq: "_q.Queue[object]" = _q.Queue()

    def _reader():
        for line in sys.stdin:
            line = line.strip()
            if line:
                inq.put(line)
        inq.put(None)

    threading.Thread(target=_reader, daemon=True,
                     name="stdio-reader").start()

    def emit(obj: dict) -> None:
        sys.stdout.write(json.dumps(obj) + "\n")
        sys.stdout.flush()

    def _serve_run(msgs: list) -> None:
        results = policy.serve_batch(
            [m.get("prompt", "") for m in msgs],
            [{"cls": m["cls"]} if "cls" in m else None for m in msgs])
        for m, r in zip(msgs, results):
            emit({"ok": True, "id": m.get("id"),
                  "served_by": r.served_by,
                  "static_origin": bool(r.static_origin),
                  "similarity": float(r.similarity),
                  "stale": bool(r.meta.get("stale", False)),
                  "bypass": r.meta.get("bypass"),
                  "answer": None if r.answer is None else str(r.answer)})

    emit({"ok": True, "ready": True, "pid": os.getpid(),
          "t": policy.t, "wal_seq":
          wal.seq if wal is not None else None})
    eof = False
    while not eof:
        first = inq.get()
        if first is None:
            break
        batch = [first]
        while True:          # coalesce whatever has already arrived
            try:
                nxt = inq.get_nowait()
            except _q.Empty:
                break
            if nxt is None:
                eof = True
                break
            batch.append(nxt)

        msgs = []
        for ln in batch:
            try:
                msgs.append(json.loads(ln))
            except ValueError:
                emit({"ok": False, "error": f"bad json: {ln[:80]!r}"})
        i = 0
        while i < len(msgs):
            msg = msgs[i]
            op = msg.get("op", "serve")
            if op == "serve":
                j = i
                while j < len(msgs) and \
                        msgs[j].get("op", "serve") == "serve":
                    j += 1
                _serve_run(msgs[i:j])
                i = j
                continue
            if op == "stats":
                s = policy.stats()
                s["t"] = policy.t
                depth = policy.pool.depth()
                s["judge_queued"] = depth["queued"]
                s["judge_inflight"] = depth["inflight"]
                emit({"ok": True, "id": msg.get("id"), "stats": s})
            elif op == "snapshot":
                if snap_dir is None:
                    emit({"ok": False, "id": msg.get("id"),
                          "error": "no --snapshot-dir"})
                else:
                    path = persist.save_snapshot(snap_dir, policy)
                    ckpt.prune(snap_dir, keep=3)
                    emit({"ok": True, "id": msg.get("id"),
                          "snapshot": str(path), "t": policy.t,
                          "wal_seq":
                          wal.seq if wal is not None else None})
            elif op == "drain":
                policy.pool.drain(float(msg.get("timeout_s", 30.0)))
                emit({"ok": True, "id": msg.get("id"),
                      "depth": policy.pool.depth()})
            elif op == "shutdown":
                emit({"ok": True, "id": msg.get("id"), "bye": True})
                eof = True
                break
            else:
                emit({"ok": False, "id": msg.get("id"),
                      "error": f"unknown op {op!r}"})
            i += 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--tau", type=float, default=0.92)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve both tiers row-sharded over this many "
                         "devices (DESIGN.md §13); on CPU forces a "
                         "host-device mesh of that size. 1 = the "
                         "single-device path")
    ap.add_argument("--index", choices=["flat", "ivf"], default="flat",
                    help="static-tier lookup strategy (DESIGN.md §11); "
                         "'ivf' builds the quantized ANN index over the "
                         "tier and injects it into the policy")
    ap.add_argument("--static-rows", type=int, default=0,
                    help="pad the curated tier to this many rows with "
                         "synthetic entries (exercises the ANN path at "
                         "realistic tier sizes)")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--fused", action="store_true",
                    help="serve through the fused single-pass pipeline "
                         "(DESIGN.md §15): static IVF probe + masked "
                         "dynamic top-1 in ONE kernel dispatch. "
                         "Replaces both tier lookups; incompatible "
                         "with --index ivf, --dyn-index segmented and "
                         "--shards > 1")
    ap.add_argument("--dyn-index", choices=["flat", "segmented"],
                    default="flat",
                    help="dynamic-tier lookup strategy (DESIGN.md §12); "
                         "'segmented' serves dynamic lookups through the "
                         "incremental tail+segments index")
    ap.add_argument("--seg-rows", type=int, default=4096,
                    help="segmented dynamic index: tail capacity, i.e. "
                         "rows absorbed before sealing an int8 segment")
    ap.add_argument("--compact-every", type=int, default=4,
                    help="segmented dynamic index: merge sealed "
                         "segments whenever this many have accumulated")
    ap.add_argument("--capacity", type=int, default=512,
                    help="dynamic-tier capacity")
    ap.add_argument("--l1-capacity", type=int, default=0,
                    help="L1 exact-match front tier size (DESIGN.md "
                         "§16): canonically identical repeat prompts "
                         "are answered from a hashed lookup with no "
                         "embed and no semantic search. 0 = off")
    ap.add_argument("--volatile-bypass", action="store_true",
                    help="route freshness-volatile prompts (keyword "
                         "classifier, DESIGN.md §16) straight to the "
                         "backend with no cache read or write — "
                         "guarantees zero stale serves on that class")
    ap.add_argument("--ttl-volatile", type=int, default=0,
                    help="cache-entry lifetime (request ticks) the "
                         "judge assigns to volatile-class content; "
                         "0 = never expires")
    ap.add_argument("--ttl-stable", type=int, default=0,
                    help="cache-entry lifetime for stable/unknown-"
                         "class content; 0 = never expires")
    ap.add_argument("--rewrite", action="store_true",
                    help="multi-outcome judge pipeline (DESIGN.md §18): "
                         "grey-zone pairs the judge would reject get a "
                         "REWRITE verdict instead; the template "
                         "rewriter tailors the cached answer and the "
                         "variant is promoted keyed to the NEW "
                         "prompt's embedding — served only to later "
                         "repeats, never the triggering request")
    ap.add_argument("--rewrite-rate", type=float, default=1.0,
                    help="rewrite token-bucket refill per judged task "
                         "(bounds rewriter invocations; empty bucket "
                         "degrades the verdict to REJECT)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="crash-safe persistence (DESIGN.md §14): "
                         "restore the newest snapshot on start, replay "
                         "the promotion WAL tail, snapshot on shutdown")
    ap.add_argument("--wal", default=None,
                    help="promotion write-ahead journal path (default: "
                         "<snapshot-dir>/promo.wal when --snapshot-dir "
                         "is set)")
    ap.add_argument("--wal-fsync-every", type=int, default=1,
                    help="fsync the WAL every N appends (1 = every "
                         "approved promotion is durable before its "
                         "upsert)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="save a snapshot every N served requests "
                         "(0 = only at shutdown / on the stdio "
                         "'snapshot' op)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online threshold controller (DESIGN.md §17): "
                         "per-segment tau_static/tau_dynamic operating "
                         "points tuned live by shadow sweeps over the "
                         "recent request window")
    ap.add_argument("--adapt-every", type=int, default=256,
                    help="recorded requests between shadow sweeps")
    ap.add_argument("--adapt-window", type=int, default=1024,
                    help="request-window ring size the shadow sweep "
                         "re-scores (the first sweep waits for a full "
                         "window)")
    ap.add_argument("--adapt-frozen", action="store_true",
                    help="attach the controller (stats, window, "
                         "persistence) but never move thresholds — "
                         "serving stays bit-identical to pinned")
    ap.add_argument("--serve-stdio", action="store_true",
                    help="run as a long-lived JSON-lines service on "
                         "stdin/stdout instead of the demo loop (the "
                         "load harness and recovery tests drive this)")
    args = ap.parse_args()

    # the host-device count must be forced before the first jax import
    # (all repro imports below touch jax), so do it off the parsed flag:
    # keep any pre-existing XLA_FLAGS but replace a conflicting
    # device-count setting with ours — a smaller inherited count would
    # otherwise make the mesh build fail
    if args.shards > 1:
        import re
        cur = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                     os.environ.get("XLA_FLAGS", ""))
        os.environ["XLA_FLAGS"] = (
            f"{cur} --xla_force_host_platform_device_count="
            f"{args.shards}").strip()

    import numpy as np
    from repro.configs import smoke_config
    from repro.core.judge import OracleJudge, template_rewriter
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig
    from repro.embedding.embedder import Embedder
    from repro.launch.mesh import make_shard_mesh
    from repro.serving.engine import BatchingFrontend, LLMEngine

    from repro.serving import persist

    mesh = make_shard_mesh(args.shards) if args.shards > 1 else None
    embed = Embedder(d_out=64)
    engine = LLMEngine(smoke_config(args.arch), max_len=96)
    frontend = BatchingFrontend(engine, max_batch=8, max_new_tokens=8)

    snap = None
    if args.snapshot_dir and \
            persist.latest_snapshot(args.snapshot_dir) is not None:
        snap = persist.load_snapshot(args.snapshot_dir)
        print(f"snapshot: step {snap.step} (t={snap.extra['t']}, "
              f"wal_seq={snap.extra['wal_seq']})")

    intents = DEMO_INTENTS
    canon = intents
    # with a snapshot on disk, defer the IVF build: the snapshot's
    # packed index warm-restores in milliseconds when its corpus hash
    # matches the rebuilt tier (persist.load_static_index); the cold
    # build only runs when the snapshot is stale or absent
    warm_ivf = snap is not None and args.index == "ivf" and mesh is None
    tier, answers, texts, index = build_demo_tier(
        np.asarray(embed.batch(canon)), [f"[curated] {p}" for p in canon],
        static_rows=args.static_rows,
        index="flat" if warm_ivf else args.index,
        nprobe=args.nprobe, mesh=mesh, texts=canon)
    if warm_ivf:
        index = persist.load_static_index(snap, tier.emb,
                                          nprobe=args.nprobe)
        if index is not None:
            print(f"static index: warm-restored {index.describe()}")
        else:
            from repro.index.ivf import IVFIndex, build_ivf
            index = IVFIndex(build_ivf(tier.emb, corpus_normalized=True),
                             nprobe=args.nprobe)
            print(f"static index: {index.describe()} "
                  "(snapshot index stale/absent — cold rebuild)")

    fused = None
    if args.fused:
        if args.index != "flat" or args.dyn_index != "flat" \
                or args.shards > 1:
            ap.error("--fused replaces both tier lookups; drop "
                     "--index ivf / --dyn-index segmented / --shards")
        from repro.index.ivf import build_ivf
        from repro.kernels.fused_serve import FusedServe
        fused = FusedServe(build_ivf(tier.emb, corpus_normalized=True),
                           nprobe=args.nprobe)
        print(f"serve path: {fused.describe()}")

    dyn_index = args.dyn_index
    if mesh is not None and dyn_index == "segmented":
        print("note: --dyn-index segmented is single-device only; "
              "--shards serves the dynamic tier through the "
              "row-sharded masked scan instead (DESIGN.md §13)")
        dyn_index = "flat"
    wal = None
    wal_path = args.wal or (os.path.join(args.snapshot_dir, "promo.wal")
                            if args.snapshot_dir else None)
    if wal_path:
        from repro.core.promo_wal import PromotionWAL
        wal = PromotionWAL(wal_path, fsync_every=args.wal_fsync_every)

    # freshness subsystem (DESIGN.md §16): keyword staleness-risk
    # classifier feeding the bypass, the judge's TTL verdicts, and the
    # baseline write-back expiry
    freshness = None
    if args.volatile_bypass or args.ttl_volatile or args.ttl_stable:
        from repro.core.freshness import FreshnessPolicy
        freshness = FreshnessPolicy(volatile_bypass=args.volatile_bypass,
                                    ttl_volatile=args.ttl_volatile,
                                    ttl_stable=args.ttl_stable,
                                    ttl_unknown=args.ttl_stable)
        print(f"freshness: bypass={args.volatile_bypass} "
              f"ttl_volatile={args.ttl_volatile} "
              f"ttl_stable={args.ttl_stable}")
    if args.l1_capacity:
        print(f"l1 front tier: {args.l1_capacity} entries")

    cfg = CacheConfig(args.tau, args.tau, sigma_min=0.3,
                      capacity=args.capacity,
                      l1=bool(args.l1_capacity),
                      volatile_bypass=args.volatile_bypass,
                      ttl_volatile=args.ttl_volatile,
                      ttl_stable=args.ttl_stable,
                      rewrite=args.rewrite,
                      rewrite_rate=args.rewrite_rate)
    if args.rewrite:
        print(f"rewrite verdicts: on (rate={args.rewrite_rate}/judged)")
    adaptive = None
    if args.adaptive:
        from repro.core.adaptive import (AdaptiveController,
                                         AdaptiveParams)
        adaptive = AdaptiveController(
            cfg, d=64,
            params=AdaptiveParams(window=args.adapt_window,
                                  adapt_every=args.adapt_every),
            frozen=args.adapt_frozen)
        print(f"adaptive thresholds: window={args.adapt_window} "
              f"every={args.adapt_every} frozen={args.adapt_frozen}")
    # the demo's oracle rewrite model: every would-reject grey-zone
    # pair is tailorable (the rewriter is the deterministic template)
    judge = OracleJudge(freshness=freshness,
                        rewritable=(lambda qc, hc, qt, ht: True)
                        if args.rewrite else None)
    policy = KritesPolicy(cfg, tier, answers, embed,
                          backend_fn=frontend.submit,
                          judge_fn=judge,
                          d=64,
                          backend_batch_fn=frontend.submit_many,
                          index=index, static_texts=texts,
                          mesh=mesh, wal=wal, fused=fused,
                          rewriter=template_rewriter
                          if args.rewrite else None,
                          l1=args.l1_capacity or None,
                          freshness=freshness, adaptive=adaptive,
                          dyn_index=build_dyn_index(
                              dyn_index, cfg.capacity, 64,
                              seg_rows=args.seg_rows,
                              compact_every=args.compact_every))

    # crash recovery (DESIGN.md §14): newest snapshot first, then the
    # journal tail past its wal_seq cursor — promotions journaled after
    # the capture replay idempotently through the same LWW guard
    if snap is not None:
        rep = persist.restore_policy(policy, snap, rebuild="background")
        print(f"restored: t={rep['t']} dyn_live={rep['dyn_live']} "
              f"index={rep['index']} l1={rep['l1_restored']} "
              f"ttl_dropped={rep['ttl_dropped']}")
    if wal_path and os.path.exists(wal_path):
        from repro.core.promo_wal import replay_into
        r = replay_into(policy, wal_path,
                        skip=snap.extra["wal_seq"] if snap else 0)
        if r["replayed"] or not r["clean"]:
            print(f"wal replay: {r['replayed']} promotions "
                  f"(skipped {r['skipped']}, clean={r['clean']})")

    if args.serve_stdio:
        _serve_stdio(policy, args.snapshot_dir, wal)
        if args.snapshot_dir:
            persist.save_snapshot(args.snapshot_dir, policy)
        policy.pool.stop()
        frontend.stop()
        if wal is not None:
            wal.close()
        return

    rng = np.random.default_rng(0)
    prefixes = DEMO_PREFIXES
    t0 = time.time()
    for i in range(args.requests):
        c = int(rng.integers(0, len(intents)))
        p = prefixes[int(rng.integers(0, len(prefixes)))] + intents[c]
        policy.serve(p, meta={"cls": c})
        if (i + 1) % 50 == 0:
            s = policy.stats()
            print(f"{i+1:5d} reqs | static-origin "
                  f"{s['static_origin_rate']:.3f} | backend "
                  f"{s['backend_rate']:.3f} | judged {s['judged']}")
        if args.snapshot_dir and args.snapshot_every \
                and (i + 1) % args.snapshot_every == 0:
            path = persist.save_snapshot(args.snapshot_dir, policy)
            from repro.distributed.checkpoint import prune
            prune(args.snapshot_dir, keep=3)
            print(f"snapshot -> {path.name}")
    policy.pool.drain()
    s = policy.stats()
    print(f"\nfinal ({time.time()-t0:.1f}s):")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    if policy.dyn_index is not None:
        print(f"  {'dyn_index':22s} {policy.describe_dyn_index()}")
    sh = policy.shard_stats()
    if sh is not None:
        print(f"  {'shards':22s} {sh['shards']}")
        print(f"  {'shard_occupancy':22s} {sh['shard_occupancy']}")
    if args.snapshot_dir:
        # final snapshot, then drop the journal prefix it covers — the
        # classic checkpoint+truncate cycle (safe only with the WAL
        # closed: compaction rewrites the file under a new inode)
        path = persist.save_snapshot(args.snapshot_dir, policy)
        print(f"  {'snapshot':22s} {path}")
        if wal is not None:
            seq = wal.seq
            wal.close()
            wal = None
            from repro.core.promo_wal import compact
            kept = compact(wal_path, keep_from_seq=seq)
            print(f"  {'wal_compacted':22s} kept {kept} records")
    policy.pool.stop()
    frontend.stop()
    if wal is not None:
        wal.close()


if __name__ == "__main__":
    main()
