"""Serving launcher: Krites-fronted LLM engine with request batching.

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Wires the full production topology on local devices: embedder -> tiered
cache (KritesPolicy, async judge pool) -> batching frontend -> LLM engine
(prefill + KV decode). ``--index ivf`` (with ``--static-rows N`` to pad
the curated tier to a realistic size) swaps the static lookup for the
IVF quantized ANN index (DESIGN.md §11):

    PYTHONPATH=src python -m repro.launch.serve --requests 200 \
        --index ivf --static-rows 100000

``--shards N`` serves through the mesh-aware path (DESIGN.md §13): both
tiers row-sharded over an N-device 'model' mesh, per-shard fused scans
with a tiny candidate merge, writes scattered to the owning shard. On a
CPU host it forces ``XLA_FLAGS=--xla_force_host_platform_device_count``
so N host devices exist; decisions are identical to ``--shards 1``:

    PYTHONPATH=src python -m repro.launch.serve --requests 200 --shards 4
"""
import argparse
import os
import time


def build_demo_tier(emb_rows, answers, static_rows: int = 0,
                    index: str = "flat", nprobe: int = 8, mesh=None,
                    texts=None):
    """Shared demo-topology helper (also used by
    ``launch/cache_workload.py --live``): optionally pad the curated
    tier with synthetic entries to ``static_rows`` rows, then build the
    requested static-index object (DESIGN.md §11) — the sharded variant
    (§13) when a ``mesh`` is given. ``texts`` are the curated entries'
    prompt texts (row-aligned; judge payloads carry them).

    Returns (StaticTier, answers, texts, index object or None for
    exact flat).
    """
    import numpy as np

    from repro.core.tiers import make_static_tier

    emb_rows = np.asarray(emb_rows, np.float32)
    answers = list(answers)
    texts = list(texts) if texts is not None else [str(a) for a in answers]
    if static_rows > len(answers):
        # synthetic curated entries: random directions far from the
        # intent cluster, each its own answer class
        pad = np.random.default_rng(7).normal(
            size=(static_rows - len(answers),
                  emb_rows.shape[1])).astype(np.float32)
        emb_rows = np.concatenate([emb_rows, pad])
        answers += [f"[curated] synthetic-{i}" for i in range(len(pad))]
        texts += [f"synthetic prompt {i}" for i in range(len(pad))]
    tier = make_static_tier(emb_rows, np.arange(len(answers)))

    idx_obj = None
    if index == "ivf":
        if mesh is not None:
            from repro.index.sharded import ShardedIVFIndex
            idx_obj = ShardedIVFIndex(tier.emb, mesh, nprobe=nprobe)
        else:
            from repro.index.ivf import IVFIndex, build_ivf
            idx_obj = IVFIndex(build_ivf(tier.emb,
                                         corpus_normalized=True),
                               nprobe=nprobe)
        print(f"static index: {idx_obj.describe()}")
    return tier, answers, texts, idx_obj


def build_dyn_index(dyn_index: str, capacity: int, d: int,
                    seg_rows: int = 4096, compact_every: int = 4):
    """Dynamic-tier lookup strategy for the launchers (DESIGN.md §12):
    'flat' -> None (exact masked scan), 'segmented' -> a SegmentedIndex
    with a ``seg_rows`` tail sealing into int8 segments and a compactor
    merging every ``compact_every`` of them."""
    if dyn_index != "segmented":
        return None
    from repro.index.segmented import SegmentedIndex
    idx = SegmentedIndex(capacity, d, tail_rows=seg_rows,
                         compact_every=compact_every)
    print(f"dynamic index: {idx.describe()}")
    return idx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--tau", type=float, default=0.92)
    ap.add_argument("--shards", type=int, default=1,
                    help="serve both tiers row-sharded over this many "
                         "devices (DESIGN.md §13); on CPU forces a "
                         "host-device mesh of that size. 1 = the "
                         "single-device path")
    ap.add_argument("--index", choices=["flat", "ivf"], default="flat",
                    help="static-tier lookup strategy (DESIGN.md §11); "
                         "'ivf' builds the quantized ANN index over the "
                         "tier and injects it into the policy")
    ap.add_argument("--static-rows", type=int, default=0,
                    help="pad the curated tier to this many rows with "
                         "synthetic entries (exercises the ANN path at "
                         "realistic tier sizes)")
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--dyn-index", choices=["flat", "segmented"],
                    default="flat",
                    help="dynamic-tier lookup strategy (DESIGN.md §12); "
                         "'segmented' serves dynamic lookups through the "
                         "incremental tail+segments index")
    ap.add_argument("--seg-rows", type=int, default=4096,
                    help="segmented dynamic index: tail capacity, i.e. "
                         "rows absorbed before sealing an int8 segment")
    ap.add_argument("--compact-every", type=int, default=4,
                    help="segmented dynamic index: merge sealed "
                         "segments whenever this many have accumulated")
    args = ap.parse_args()

    # the host-device count must be forced before the first jax import
    # (all repro imports below touch jax), so do it off the parsed flag:
    # keep any pre-existing XLA_FLAGS but replace a conflicting
    # device-count setting with ours — a smaller inherited count would
    # otherwise make the mesh build fail
    if args.shards > 1:
        import re
        cur = re.sub(r"--xla_force_host_platform_device_count=\S+", "",
                     os.environ.get("XLA_FLAGS", ""))
        os.environ["XLA_FLAGS"] = (
            f"{cur} --xla_force_host_platform_device_count="
            f"{args.shards}").strip()

    import numpy as np
    from repro.configs import smoke_config
    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig
    from repro.embedding.embedder import Embedder
    from repro.launch.mesh import make_shard_mesh
    from repro.serving.engine import BatchingFrontend, LLMEngine

    mesh = make_shard_mesh(args.shards) if args.shards > 1 else None
    embed = Embedder(d_out=64)
    engine = LLMEngine(smoke_config(args.arch), max_len=96)
    frontend = BatchingFrontend(engine, max_batch=8, max_new_tokens=8)

    intents = [f"how do i {v} my {n}" for v in
               ("fix", "update", "reset", "clean", "sell")
               for n in ("bike", "laptop", "router", "garden")]
    canon = intents
    tier, answers, texts, index = build_demo_tier(
        np.asarray(embed.batch(canon)), [f"[curated] {p}" for p in canon],
        static_rows=args.static_rows, index=args.index,
        nprobe=args.nprobe, mesh=mesh, texts=canon)

    dyn_index = args.dyn_index
    if mesh is not None and dyn_index == "segmented":
        print("note: --dyn-index segmented is single-device only; "
              "--shards serves the dynamic tier through the "
              "row-sharded masked scan instead (DESIGN.md §13)")
        dyn_index = "flat"
    cfg = CacheConfig(args.tau, args.tau, sigma_min=0.3, capacity=512)
    policy = KritesPolicy(cfg, tier, answers, embed,
                          backend_fn=frontend.submit,
                          judge_fn=OracleJudge(), d=64,
                          backend_batch_fn=frontend.submit_many,
                          index=index, static_texts=texts,
                          mesh=mesh,
                          dyn_index=build_dyn_index(
                              dyn_index, cfg.capacity, 64,
                              seg_rows=args.seg_rows,
                              compact_every=args.compact_every))

    rng = np.random.default_rng(0)
    prefixes = ["", "hey ", "um, ", "please, ", "quick q: "]
    t0 = time.time()
    for i in range(args.requests):
        c = int(rng.integers(0, len(intents)))
        p = prefixes[int(rng.integers(0, len(prefixes)))] + intents[c]
        policy.serve(p, meta={"cls": c})
        if (i + 1) % 50 == 0:
            s = policy.stats()
            print(f"{i+1:5d} reqs | static-origin "
                  f"{s['static_origin_rate']:.3f} | backend "
                  f"{s['backend_rate']:.3f} | judged {s['judged']}")
    policy.pool.drain()
    s = policy.stats()
    print(f"\nfinal ({time.time()-t0:.1f}s):")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    if policy.dyn_index is not None:
        print(f"  {'dyn_index':22s} {policy.describe_dyn_index()}")
    sh = policy.shard_stats()
    if sh is not None:
        print(f"  {'shards':22s} {sh['shards']}")
        print(f"  {'shard_occupancy':22s} {sh['shard_occupancy']}")
    policy.pool.stop()
    frontend.stop()


if __name__ == "__main__":
    main()
