"""Serving launcher: Krites-fronted LLM engine with request batching.

    PYTHONPATH=src python -m repro.launch.serve --requests 200

Wires the full production topology on local devices: embedder -> tiered
cache (KritesPolicy, async judge pool) -> batching frontend -> LLM engine
(prefill + KV decode).
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--tau", type=float, default=0.92)
    args = ap.parse_args()

    import numpy as np
    from repro.configs import smoke_config
    from repro.core.judge import OracleJudge
    from repro.core.policy import KritesPolicy
    from repro.core.tiers import CacheConfig, make_static_tier
    from repro.embedding.embedder import Embedder
    from repro.serving.engine import BatchingFrontend, LLMEngine

    embed = Embedder(d_out=64)
    engine = LLMEngine(smoke_config(args.arch), max_len=96)
    frontend = BatchingFrontend(engine, max_batch=8, max_new_tokens=8)

    intents = [f"how do i {v} my {n}" for v in
               ("fix", "update", "reset", "clean", "sell")
               for n in ("bike", "laptop", "router", "garden")]
    canon = intents
    tier = make_static_tier(np.asarray(embed.batch(canon)),
                            np.arange(len(canon)))
    answers = [f"[curated] {p}" for p in canon]
    cfg = CacheConfig(args.tau, args.tau, sigma_min=0.3, capacity=512)
    policy = KritesPolicy(cfg, tier, answers, embed,
                          backend_fn=frontend.submit,
                          judge_fn=OracleJudge(), d=64)

    rng = np.random.default_rng(0)
    prefixes = ["", "hey ", "um, ", "please, ", "quick q: "]
    t0 = time.time()
    for i in range(args.requests):
        c = int(rng.integers(0, len(intents)))
        p = prefixes[int(rng.integers(0, len(prefixes)))] + intents[c]
        policy.serve(p, meta={"cls": c})
        if (i + 1) % 50 == 0:
            s = policy.stats()
            print(f"{i+1:5d} reqs | static-origin "
                  f"{s['static_origin_rate']:.3f} | backend "
                  f"{s['backend_rate']:.3f} | judged {s['judged']}")
    policy.pool.drain()
    s = policy.stats()
    print(f"\nfinal ({time.time()-t0:.1f}s):")
    for k, v in s.items():
        print(f"  {k:22s} {v}")
    policy.pool.stop()
    frontend.stop()


if __name__ == "__main__":
    main()
