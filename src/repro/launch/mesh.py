"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axis semantics: 'pod' = pure data parallelism across DCN; 'data' =
    in-pod data parallel / FSDP shard axis; 'model' = tensor/expert/
    sequence parallel axis (ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_shard_mesh(n_shards: int):
    """1-D 'model' mesh for the sharded serving path (DESIGN.md §13):
    the tiers are row-partitioned over these devices and every policy
    lookup/write runs shard-local with a tiny candidate merge. On CPU
    pair with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (set before the first jax import) — the launchers' ``--shards N``
    flag does exactly that."""
    return jax.make_mesh((n_shards,), ("model",))


def make_smoke_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes used for batch/data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
