"""The five assigned LM transformer architectures (exact public configs)."""
from __future__ import annotations

from repro.configs.base import LMConfig, MoEConfig

# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 24L d_model=2048 16H (GQA kv=16) d_ff=1408
# vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.
QWEN2_MOE_A2_7B = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                  d_ff_expert=1408, dispatch="ep"),
    rope_theta=1_000_000.0,
)

# [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] 48L d_model=5120 40H
# (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1, early fusion.
LLAMA4_SCOUT_17B_A16E = LMConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=MoEConfig(n_experts=16, top_k=1, n_shared_experts=1,
                  d_ff_expert=8192, dispatch="ep"),
    rope_theta=500_000.0,
)

# [arXiv:2407.14679; hf] Minitron-8B (pruned Nemotron): 32L d_model=4096
# 32H (GQA kv=8) d_ff=16384 vocab=256000.
MINITRON_8B = LMConfig(
    name="minitron-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab_size=256000,
    head_dim=128,
)

# [hf:THUDM/glm-4-9b; hf] 40L d_model=4096 32H (GQA kv=2) d_ff=13696
# vocab=151552, RoPE.
GLM4_9B = LMConfig(
    name="glm4-9b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151552,
)

# [hf:Qwen/Qwen3-*; hf] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
# vocab=151936, qk_norm.
QWEN3_1_7B = LMConfig(
    name="qwen3-1.7b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
)

LM_ARCHS = {
    c.name: c for c in (
        QWEN2_MOE_A2_7B, LLAMA4_SCOUT_17B_A16E, MINITRON_8B, GLM4_9B,
        QWEN3_1_7B,
    )
}
