"""Assigned GNN + RecSys architecture configs (exact public dims)."""
from __future__ import annotations

from repro.configs.base import GNNConfig, RecSysConfig

# [arXiv:1706.02216; paper] GraphSAGE on Reddit: 2 layers, d_hidden=128,
# mean aggregator, neighbor sample sizes 25-10.
GRAPHSAGE_REDDIT = GNNConfig(
    name="graphsage-reddit",
    n_layers=2, d_hidden=128, d_feat=602, n_classes=41,
    aggregator="mean", sample_sizes=(25, 10),
)

# [arXiv:1808.09781; paper] SASRec: embed_dim=50, 2 blocks, 1 head, seq 50.
SASREC = RecSysConfig(
    name="sasrec", kind="sasrec",
    embed_dim=50, seq_len=50, n_blocks=2, n_heads=1,
    interaction="self-attn-seq",
)

# [arXiv:1904.08030; unverified] MIND: embed_dim=64, 4 interest capsules,
# 3 dynamic-routing iterations.
MIND = RecSysConfig(
    name="mind", kind="mind",
    embed_dim=64, seq_len=50, n_interests=4, capsule_iters=3,
    interaction="multi-interest",
)

# [arXiv:1905.06874; paper] BST (Alibaba): embed_dim=32, seq 20, 1 block,
# 8 heads, MLP 1024-512-256.
BST = RecSysConfig(
    name="bst", kind="bst",
    embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
    mlp_dims=(1024, 512, 256), interaction="transformer-seq",
)

# [arXiv:1606.07792; paper] Wide&Deep: 40 sparse fields, embed_dim=32,
# MLP 1024-512-256.
WIDE_DEEP = RecSysConfig(
    name="wide-deep", kind="wide_deep",
    embed_dim=32, n_sparse=40, mlp_dims=(1024, 512, 256),
    interaction="concat",
)

GNN_ARCHS = {GRAPHSAGE_REDDIT.name: GRAPHSAGE_REDDIT}
RECSYS_ARCHS = {c.name: c for c in (SASREC, MIND, BST, WIDE_DEEP)}
