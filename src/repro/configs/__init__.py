"""Architecture registry: ``get_arch(id)`` + per-arch smoke variants."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (
    GNNConfig, LMConfig, MoEConfig, RecSysConfig, ShapeSpec,
    LM_SHAPES, LM_SHAPES_SKIPPED, GNN_SHAPES, RECSYS_SHAPES, shapes_for,
)
from repro.configs.lm_archs import (
    LM_ARCHS, QWEN2_MOE_A2_7B, LLAMA4_SCOUT_17B_A16E, MINITRON_8B, GLM4_9B,
    QWEN3_1_7B,
)
from repro.configs.other_archs import (
    GNN_ARCHS, RECSYS_ARCHS, GRAPHSAGE_REDDIT, SASREC, MIND, BST, WIDE_DEEP,
)

ARCHS: Dict[str, object] = {}
ARCHS.update(LM_ARCHS)
ARCHS.update(GNN_ARCHS)
ARCHS.update(RECSYS_ARCHS)


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(cfg, shape_name: str) -> ShapeSpec:
    for s in shapes_for(cfg):
        if s.name == shape_name:
            return s
    raise KeyError(f"{cfg.name} has no shape {shape_name!r}; "
                   f"available: {[s.name for s in shapes_for(cfg)]}")


def all_cells():
    """Every runnable (arch, shape) pair — the dry-run matrix."""
    for arch_id, cfg in ARCHS.items():
        for s in shapes_for(cfg):
            yield arch_id, s.name


def smoke_config(arch_id: str):
    """A reduced same-family config that runs one step on a laptop CPU."""
    cfg = get_arch(arch_id)
    if isinstance(cfg, LMConfig):
        moe = cfg.moe
        if moe is not None:
            moe = dataclasses.replace(
                moe, n_experts=4, top_k=min(2, moe.top_k),
                n_shared_experts=min(1, moe.n_shared_experts), d_ff_expert=64)
        return dataclasses.replace(
            cfg, name=cfg.name + "-smoke", n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, 4 // (cfg.n_heads // cfg.n_kv_heads)),
            head_dim=16, d_ff=128, vocab_size=512, moe=moe, attn_chunk=32)
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(
            cfg, name=cfg.name + "-smoke", d_hidden=16, d_feat=8, n_classes=5)
    if isinstance(cfg, RecSysConfig):
        return dataclasses.replace(
            cfg, name=cfg.name + "-smoke",
            embed_dim=max(8, cfg.embed_dim // 8), n_items=128,
            sparse_vocab=64, seq_len=min(cfg.seq_len, 8) if cfg.seq_len else 0,
            mlp_dims=tuple(d // 16 for d in cfg.mlp_dims) if cfg.mlp_dims
            else ())
    raise TypeError(type(cfg))


__all__ = [
    "ARCHS", "get_arch", "get_shape", "all_cells", "smoke_config",
    "LMConfig", "MoEConfig", "GNNConfig", "RecSysConfig", "ShapeSpec",
    "LM_SHAPES", "LM_SHAPES_SKIPPED", "GNN_SHAPES", "RECSYS_SHAPES",
    "shapes_for",
    "QWEN2_MOE_A2_7B", "LLAMA4_SCOUT_17B_A16E", "MINITRON_8B", "GLM4_9B",
    "QWEN3_1_7B", "GRAPHSAGE_REDDIT", "SASREC", "MIND", "BST", "WIDE_DEEP",
]
