"""Config dataclasses for every architecture family in the framework.

Configs are plain frozen dataclasses so they hash, compare, and print cleanly
and can be closed over by jitted functions without tracer surprises.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (GShard/Switch style)."""
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert hidden width
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # dispatch strategy: 'sort' (argsort grouped, default — never builds the
    # (T,E,C) one-hot tensor) | 'einsum' (GShard one-hot; small-T only)
    dispatch: str = "sort"
    # tokens are split into n_groups capacity groups; groups align with the
    # data-parallel shards so the dispatch argsort is shard-local (no
    # cross-device sort). Must be a multiple of the data axis size.
    n_groups: int = 32


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer (dense or MoE)."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # attention chunk size for the blockwise online-softmax path
    attn_chunk: int = 1024
    remat: bool = True            # activation checkpointing per layer
    scan_layers: bool = True      # lax.scan over the layer stack
    # Megatron-style sequence-parallel residuals: the layer carry (and so
    # every remat-saved activation) is sharded over 'model' on the seq
    # axis -> 16x less residual memory, collective-neutral (§Perf)
    seq_parallel: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, (
            f"{self.name}: n_heads={self.n_heads} not divisible by "
            f"n_kv_heads={self.n_kv_heads}")

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def param_count(self) -> int:
        """Total parameter count (embeddings included)."""
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) \
            + (self.n_heads * h) * d
        if self.moe is not None:
            m = self.moe
            ffn = m.n_experts * 3 * d * m.d_ff_expert          # routed experts
            ffn += m.n_shared_experts * 3 * d * m.d_ff_expert  # shared experts
            ffn += d * m.n_experts                             # router
        else:
            ffn = 3 * d * self.d_ff                            # SwiGLU
        norms = 2 * d + (2 * h if self.qk_norm else 0)
        per_layer = attn + ffn + norms
        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d
        return self.n_layers * per_layer + embed + unembed + d  # final norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top_k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        routed_all = self.n_layers * m.n_experts * 3 * d * m.d_ff_expert
        routed_active = self.n_layers * m.top_k * 3 * d * m.d_ff_expert
        return self.param_count() - routed_all + routed_active


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_feat: int                   # input feature width (overridden per shape)
    n_classes: int = 41
    aggregator: str = "mean"      # mean | max | sum
    sample_sizes: Tuple[int, ...] = (25, 10)
    dtype: str = "float32"
    norm_eps: float = 1e-6


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                     # sasrec | mind | bst | wide_deep
    embed_dim: int
    n_items: int = 1_000_000      # item vocab (sparse table rows)
    # sequential models
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    # MIND
    n_interests: int = 0
    capsule_iters: int = 0
    # wide&deep / MLP heads
    n_sparse: int = 0             # number of categorical fields
    sparse_vocab: int = 100_000   # rows per categorical field table
    mlp_dims: Tuple[int, ...] = ()
    interaction: str = ""
    dtype: str = "float32"
    dropout: float = 0.0

    @property
    def multi_hot(self) -> int:
        """Avg multi-hot ids per sparse field (embedding-bag size)."""
        return 4


# ---------------------------------------------------------------------------
# Shapes: every (arch-family, workload) cell the dry-run exercises
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell.

    kind:
      train      -> lower train_step
      prefill    -> lower prefill (serving, full-sequence forward)
      decode     -> lower serve_step (1 new token against a KV cache)
      full_graph -> full-batch GNN training step
      minibatch  -> sampled-neighborhood GNN training step
      batched_graphs -> many small graphs, padded batch
      serve      -> recsys forward scoring
      retrieval  -> 1 query vs n_candidates scoring + top-k
    """
    name: str
    kind: str
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    # recsys
    n_candidates: int = 0


LM_SHAPES = (
    ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    # long_500k (seq_len=524288, gb=1, decode) is skipped for all 5 assigned
    # LM archs: they are pure full-attention (GQA) models. See DESIGN.md
    # §Arch-applicability.
)

LM_SHAPES_SKIPPED = (
    ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "full_graph",
              n_nodes=2708, n_edges=10556, d_feat=1433),
    ShapeSpec("minibatch_lg", "minibatch",
              n_nodes=232965, n_edges=114615892, batch_nodes=1024,
              fanout=(15, 10), d_feat=602),
    ShapeSpec("ogb_products", "full_graph",
              n_nodes=2449029, n_edges=61859140, d_feat=100),
    ShapeSpec("molecule", "batched_graphs",
              n_nodes=30, n_edges=64, global_batch=128, d_feat=32),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", global_batch=65536),
    ShapeSpec("serve_p99", "serve", global_batch=512),
    ShapeSpec("serve_bulk", "serve", global_batch=262144),
    ShapeSpec("retrieval_cand", "retrieval", global_batch=1,
              n_candidates=1_000_000),
)


def shapes_for(cfg) -> Tuple[ShapeSpec, ...]:
    if isinstance(cfg, LMConfig):
        return LM_SHAPES
    if isinstance(cfg, GNNConfig):
        return GNN_SHAPES
    if isinstance(cfg, RecSysConfig):
        return RECSYS_SHAPES
    raise TypeError(f"unknown config type {type(cfg)}")


def scaled_down(cfg, **overrides):
    """Return a reduced copy of a config for CPU smoke tests."""
    return dataclasses.replace(cfg, **overrides)
