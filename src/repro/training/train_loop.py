"""Fault-tolerant training loop: grad accumulation, LR schedule, sharded
AdamW, periodic checkpointing, restart-on-failure, optional cross-pod
gradient compression.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.distributed import checkpoint as ckpt_lib
from repro.distributed.overlap import accumulate_microbatches
from repro.training import optimizer as opt_lib


@dataclass
class TrainConfig:
    n_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = ""
    log_every: int = 10
    n_microbatches: int = 1
    warmup_steps: int = 10
    lr: float = 3e-4
    lr_min_ratio: float = 0.1


def lr_schedule(cfg: TrainConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.n_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def make_step(loss_fn: Callable, tcfg: TrainConfig,
              adamw: opt_lib.AdamWConfig):
    """Jit-able (params, opt_state, batch) -> (params, opt_state, metrics)
    with microbatched grad accumulation and scheduled LR."""
    if tcfg.n_microbatches > 1:
        grad_fn = accumulate_microbatches(loss_fn, tcfg.n_microbatches)
    else:
        def grad_fn(params, batch):
            return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt_state, batch):
        loss, grads = grad_fn(params, batch)
        lr = lr_schedule(tcfg, opt_state["step"])
        import dataclasses
        new_p, new_s, gnorm = opt_lib.update(
            grads, opt_state, params,
            dataclasses.replace(adamw, lr=1.0))
        # scale the applied update by the scheduled lr: recompute with
        # the schedule folded in (lr=1 trick avoids re-tracing per step)
        new_p = jax.tree.map(
            lambda old, new: old + (new - old) * lr, params, new_p)
        new_s["master"] = jax.tree.map(
            lambda old, new: old + (new - old) * lr,
            opt_state["master"], new_s["master"])
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm, "lr": lr}
    return step


def train(loss_fn: Callable,
          params: Any,
          data_iter: Iterator,
          tcfg: TrainConfig,
          adamw: Optional[opt_lib.AdamWConfig] = None,
          jit: bool = True) -> tuple:
    """Single-host driver (the multi-pod path adds shardings via
    launch/train.py). Returns (params, opt_state, history)."""
    adamw = adamw or opt_lib.AdamWConfig()
    opt_state = opt_lib.init(params, adamw)
    step_fn = make_step(loss_fn, tcfg, adamw)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    start = 0
    if tcfg.ckpt_dir:
        last = ckpt_lib.latest_step(tcfg.ckpt_dir)
        if last is not None:
            state = ckpt_lib.restore(
                tcfg.ckpt_dir, last,
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = last

    history = []
    t0 = time.time()
    for i in range(start, tcfg.n_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % tcfg.log_every == 0 or i == start:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=i + 1, wall_s=round(time.time() - t0, 2))
            history.append(m)
            print(f"step {i+1:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
        if tcfg.ckpt_dir and ((i + 1) % tcfg.ckpt_every == 0
                              or i + 1 == tcfg.n_steps):
            ckpt_lib.save(tcfg.ckpt_dir, i + 1,
                          {"params": params, "opt": opt_state})
            ckpt_lib.prune(tcfg.ckpt_dir)
    return params, opt_state, history
