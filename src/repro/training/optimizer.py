"""Hand-rolled sharded AdamW with fp32 master weights.

Optimizer state mirrors the parameter pytree (so it inherits the params'
2D FSDPxTP sharding — ZeRO-style without extra machinery):
    state = {"mu": fp32, "nu": fp32, "master": fp32, "step": i32}
Params may live in bf16; updates are computed against the fp32 master and
cast back.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # store moments in bf16 to halve optimizer memory (production trick;
    # master stays fp32)
    moments_dtype: str = "float32"


def init(params: Any, cfg: AdamWConfig) -> dict:
    mdt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        # copy=True: for fp32 params astype would ALIAS the param buffer,
        # breaking donation (same buffer donated twice)
        "master": jax.tree.map(
            lambda p: jnp.array(p, jnp.float32, copy=True), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(grads: Any, state: dict, params: Any, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / c1
        vhat = nu32 / c2
        new_master = master - cfg.lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps)
            + cfg.weight_decay * master)
        return (mu32.astype(mdt), nu32.astype(mdt), new_master,
                new_master.astype(p.dtype))

    out = jax.tree.map(upd, grads, state["mu"], state["nu"],
                       state["master"], params)
    # unzip the 4-tuples
    mu = jax.tree.map(lambda t: t[0], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda t: t[3], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"mu": mu, "nu": nu, "master": master, "step": step}, gnorm


def make_train_step(loss_fn, opt_cfg: AdamWConfig):
    """loss_fn(params, batch) -> scalar. Returns jit-able step fn."""
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_s, gnorm = update(grads, opt_state, params, opt_cfg)
        return new_p, new_s, {"loss": loss, "grad_norm": gnorm}
    return step
