from repro.index.flat import cosine_topk, topk_scores, l2_normalize

__all__ = ["cosine_topk", "topk_scores", "l2_normalize"]
