from repro.index.flat import (FlatIndex, cosine_topk, l2_normalize,
                              masked_cosine_topk, topk_scores)
from repro.index.ivf import IVF, IVFIndex, build_ivf, train_kmeans
from repro.index.segmented import SegmentedIndex

__all__ = ["cosine_topk", "topk_scores", "l2_normalize",
           "masked_cosine_topk", "FlatIndex",
           "IVF", "IVFIndex", "build_ivf", "train_kmeans",
           "SegmentedIndex"]
