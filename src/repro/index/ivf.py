"""IVF quantized ANN index for the static tier (DESIGN.md §11).

Scaling the static tier past ~100k rows makes the exact flat lookup
(`index/flat.py`, `kernels/simsearch`) the serving bottleneck: its cost
is linear in corpus size. This module provides the sub-linear path:

- **training** — jit-compatible spherical k-means (`train_kmeans`) over
  the L2-normalized corpus (cosine argmax assignment, renormalized
  centroid updates, empty clusters keep their previous centroid);
- **layout** (`build_ivf`) — a packed *cluster-major* corpus: every
  cluster owns a fixed-capacity band of slots holding int8
  scalar-quantized codes (symmetric per-row scale ``max|x|/127``), the
  fp32 dequant scales, and the member rows' global ids (-1 padding);
- **search** (`IVFIndex`) — centroid scoring -> top-``nprobe`` clusters
  -> int8 scan of only those bands (`kernels/ivf_scan`) -> exact fp32
  rerank of the top-``n_candidates`` against the original corpus rows.

The rerank makes the served (score, index) pairs equal to flat search
whenever the true nearest row lands in the candidate set (recall@C),
so the paper's threshold semantics are preserved — ANN only changes
*which rows get scored*, never the score of the served row.

``IVFIndex`` (and ``FlatIndex`` in `index/flat.py`) implement the
injectable index protocol consumed by ``core.policy`` /
``core.tiers.static_lookup_batch``: ``topk(queries, k)`` over
L2-normalized queries plus a ``describe()`` telemetry string.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.flat import l2_normalize
from repro.kernels.ivf_scan.ops import ivf_search


class IVF(NamedTuple):
    """Packed cluster-major IVF layout (all device arrays; a pytree)."""
    centroids: jax.Array   # (K, d) fp32, L2-normalized
    codes: jax.Array       # (K, cap, d) int8 scalar-quantized rows
    scales: jax.Array      # (K, cap) fp32 per-row dequant scale
    row_ids: jax.Array     # (K, cap) int32 global row id, -1 = padding
    corpus: jax.Array      # (N, d) fp32 normalized — exact rerank rows


@functools.partial(jax.jit, static_argnames=("n_clusters", "iters"))
def train_kmeans(corpus: jax.Array, n_clusters: int, iters: int = 6,
                 seed: int = 0) -> jax.Array:
    """Spherical k-means centroids over an L2-normalized corpus.

    Assignment is cosine argmax; updates renormalize the cluster means;
    a cluster that goes empty keeps its previous centroid. Pure JAX
    (init by random row choice, ``lax.scan`` over iterations), so it
    jits and shards like any other training step.
    """
    n = corpus.shape[0]
    x = corpus.astype(jnp.float32)
    init = jax.random.choice(jax.random.PRNGKey(seed), n,
                             (n_clusters,), replace=n < n_clusters)
    cent = x[init]

    def step(cent, _):
        assign = jnp.argmax(x @ cent.T, axis=1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign,
                                     num_segments=n_clusters)
        new = l2_normalize(sums / jnp.maximum(counts, 1.0)[:, None])
        return jnp.where(counts[:, None] > 0, new, cent), None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    return cent


def quantize_rows(rows: np.ndarray):
    """Symmetric per-row int8 scalar quantization.

    code = round(x / s), s = max|x| / 127; dequant error per component
    is bounded by s/2 (enforced by ``tests/test_ivf_index.py``).
    """
    rows = np.asarray(rows, np.float32)
    scale = np.abs(rows).max(axis=1) / 127.0
    safe = np.where(scale > 0, scale, 1.0)
    codes = np.clip(np.rint(rows / safe[:, None]), -127, 127)
    return codes.astype(np.int8), scale.astype(np.float32)


def default_n_clusters(n_rows: int) -> int:
    """4*sqrt(N) clusters (the classic IVF operating range): with
    capacity-bounded bands the centroid pass costs B*K*d while each
    probe scans ~N/K rows, so more, smaller clusters cut scan volume
    until the centroid pass catches up around K ~ sqrt(N*nprobe).
    Capped so clusters keep >= 64 rows — fragmenting a small corpus
    into tiny bands costs recall (spills land further from their
    centroid) without meaningful scan savings."""
    return max(8, min(int(round(4 * math.sqrt(n_rows))),
                      n_rows // 64 or 1))


def _topk_clusters_host(c: np.ndarray, cent: np.ndarray, nchoice: int,
                        chunk: int = 65536):
    """Per-row top-``nchoice`` cluster choices (ids + sims), descending,
    computed in device chunks to bound the (N, K) sims buffer."""
    ids, sims = [], []
    for lo in range(0, c.shape[0], chunk):
        s, i = jax.lax.top_k(
            jnp.asarray(c[lo:lo + chunk]) @ jnp.asarray(cent).T, nchoice)
        ids.append(np.asarray(i))
        sims.append(np.asarray(s))
    return np.concatenate(ids), np.concatenate(sims)


def _greedy_round(pending, want, sims, assign, load, cap):
    """One contended-assignment round: among ``pending`` rows, each
    wanting cluster ``want[i]`` with similarity ``sims[i]``,
    higher-similarity rows win the cluster's remaining slots. Mutates
    ``assign``/``load``; returns the still-unassigned rows."""
    K = len(load)
    by_sim = np.argsort(-sims, kind="stable")
    w = want[by_sim]
    order = np.argsort(w, kind="stable")
    w_sorted = w[order]
    starts = np.searchsorted(w_sorted, np.arange(K))
    rank = np.arange(len(w)) - starts[w_sorted]
    ok = rank < (cap - load)[w_sorted]
    rows = pending[by_sim[order[ok]]]
    assign[rows] = w_sorted[ok]
    load += np.bincount(w_sorted[ok], minlength=K)
    return pending[assign[pending] < 0]


def _balanced_assign(c: np.ndarray, cent: np.ndarray, cap: int,
                     nchoice: int = 8) -> np.ndarray:
    """Capacity-bounded cluster assignment: each row goes to its best
    centroid that still has a free slot (spilling to 2nd..n-th choice),
    higher-similarity rows winning contended slots. Bounded bands keep
    the packed layout's padding — and hence the per-probe scan volume —
    near ``N/K`` instead of the natural assignment's max cluster size
    (heavily skewed corpora otherwise pad every band several-fold).
    """
    n = c.shape[0]
    K = cent.shape[0]
    assert cap * K >= n, (cap, K, n)
    choice_ids, choice_sims = _topk_clusters_host(c, cent,
                                                  min(K, nchoice))
    assign = np.full(n, -1, np.int64)
    load = np.zeros(K, np.int64)
    pending = np.arange(n)
    for r in range(choice_ids.shape[1]):
        if not len(pending):
            break
        pending = _greedy_round(pending, choice_ids[pending, r],
                                choice_sims[pending, r], assign, load,
                                cap)
    while len(pending):
        # all listed choices full (rare): re-rank the leftovers against
        # the clusters that still have space and repeat the contended
        # greedy rounds — dumping them into arbitrary free bands would
        # park rows under unrelated centroids that no probe ever visits
        sims = np.array(jnp.asarray(c[pending]) @ jnp.asarray(cent).T)
        sims[:, load >= cap] = -np.inf
        want = sims.argmax(axis=1)
        best = sims[np.arange(len(pending)), want]
        pending = _greedy_round(pending, want, best, assign, load, cap)
    return assign


def build_ivf(corpus, n_clusters: int | None = None, *, iters: int = 6,
              seed: int = 0, corpus_normalized: bool = False,
              train_rows: int | None = 131072, cap: int | None = None,
              cap_multiple: int = 8,
              max_imbalance: float | None = 1.3) -> IVF:
    """Train + pack an IVF index over ``corpus`` (N, d).

    ``train_rows`` caps the k-means training set (a uniform subsample —
    the assignment pass still covers every row). ``max_imbalance``
    bounds the band capacity at ``ceil(N/K * max_imbalance)`` and
    spills overflow rows to their next-best centroid with space
    (:func:`_balanced_assign`): the probe scan reads whole padded
    bands, so skewed natural clusters would otherwise inflate every
    probe's volume by the skew factor. ``None`` keeps the natural
    argmax assignment (cap = observed max cluster size). ``cap``
    forces the capacity outright (the sharded builder uses it to keep
    shard layouts stackable).
    """
    c = np.asarray(corpus, np.float32)
    if not corpus_normalized:
        c = np.asarray(l2_normalize(jnp.asarray(c)))
    n, d = c.shape
    K = n_clusters or default_n_clusters(n)

    train = c
    if train_rows is not None and n > train_rows:
        sub = np.random.default_rng(seed).choice(n, train_rows,
                                                 replace=False)
        train = c[sub]
    cent = np.asarray(train_kmeans(jnp.asarray(train), K, iters=iters,
                                   seed=seed))

    if cap is None and max_imbalance is not None:
        want = int(math.ceil(n / K * max_imbalance))
        cap = -(-max(1, want) // cap_multiple) * cap_multiple
    if cap is not None:
        if cap * K < n:
            raise ValueError(f"cap={cap} x K={K} < corpus rows {n}")
        assign = _balanced_assign(c, cent, cap)
    else:
        assign = np.asarray(_assign(jnp.asarray(c), jnp.asarray(cent)))
        need = max(1, int(np.bincount(assign, minlength=K).max()))
        cap = -(-need // cap_multiple) * cap_multiple

    # cluster-major packing: stable sort by cluster, slot = rank within
    order = np.argsort(assign, kind="stable")
    sorted_assign = assign[order]
    starts = np.searchsorted(sorted_assign, np.arange(K))
    slot = np.arange(n) - starts[sorted_assign]

    all_codes, all_scales = quantize_rows(c)
    codes = np.zeros((K, cap, d), np.int8)
    scales = np.zeros((K, cap), np.float32)
    row_ids = np.full((K, cap), -1, np.int32)
    codes[sorted_assign, slot] = all_codes[order]
    scales[sorted_assign, slot] = all_scales[order]
    row_ids[sorted_assign, slot] = order

    return IVF(jnp.asarray(cent), jnp.asarray(codes), jnp.asarray(scales),
               jnp.asarray(row_ids), jnp.asarray(c))


@jax.jit
def _assign(c: jax.Array, cent: jax.Array) -> jax.Array:
    return jnp.argmax(c @ cent.T, axis=1).astype(jnp.int32)


@dataclass(frozen=True)
class IVFIndex:
    """Injectable ANN index: IVF scan + exact rerank behind ``topk``."""
    ivf: IVF
    nprobe: int = 8
    n_candidates: int = 32
    force: str | None = None     # kernel dispatch override (see ops.py)

    def topk(self, queries: jax.Array, k: int = 1):
        """queries (B, d) L2-normalized -> (scores (B, k), idx (B, k))."""
        return ivf_search(queries, self.ivf.corpus, self.ivf.centroids,
                          self.ivf.codes, self.ivf.scales,
                          self.ivf.row_ids, k=k, nprobe=self.nprobe,
                          n_candidates=self.n_candidates,
                          force=self.force)

    def describe(self) -> str:
        K, cap, d = self.ivf.codes.shape
        return (f"ivf(N={self.ivf.corpus.shape[0]}, K={K}, cap={cap}, "
                f"d={d}, nprobe={self.nprobe}, C={self.n_candidates})")
