"""Segmented incremental ANN index for the *dynamic* tier (DESIGN.md §12).

After PR 3 the read-only static tier scales past a million rows through
the IVF index, but every dynamic-tier lookup is still a flat masked scan
over the full capacity — linear cost on the one tier that *grows online*
as the judge approves promotions. This module closes that gap with an
LSM-style layout over the dynamic tier's slots:

- **tail** — a fixed-capacity mutable fp32 buffer absorbing every
  upsert/promotion at O(tail) cost (one scatter + host mirror write).
  Lookups scan it exactly (one small masked matmul).
- **sealed segments** — when the tail fills, it is sealed into an
  immutable int8 cluster-major block with the same packed layout the
  static IVF uses, scanned by the very same ``kernels/ivf_scan`` band
  scan; ``row_ids`` hold *dynamic-tier slot ids*, so candidates from
  every source speak the tier's native coordinate.
- **tombstones** — LRU eviction and LWW upserts overwrite slots; the
  stale copy (in the tail or in a sealed segment) is tombstoned
  (``row_id -> -1``), never rewritten in place, so each live slot
  appears in exactly one place and a lookup can never resurrect an
  overwritten entry. Tombstones are buffered host-side and flushed as
  one scatter per segment at the next lookup.
- **compactor** — a background (or inline) compactor merges accumulated
  segments into one, dropping tombstones and re-training the cluster
  layout off the serving path; serving results are unchanged by
  compaction timing because served scores come from the exact rerank.

Every lookup reranks the union of candidates (tail top-C + per-segment
band-scan top-C) against the **live tier embedding matrix** in exact
fp32 with the lowest-slot-id tie contract, so whenever the true best
live row survives into the candidate set the served (score, slot) pair
equals the flat masked scan — the same exactness contract as the static
IVF path (DESIGN.md §11), now under online mutation.
"""
from __future__ import annotations

import functools
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.ivf import build_ivf, default_n_clusters
from repro.kernels.ivf_scan.ops import ivf_scan, rerank_exact


@functools.partial(jax.jit, static_argnames=("c",))
def _tail_topc(tail_emb: jax.Array, tail_slots: jax.Array, q: jax.Array,
               c: int):
    """Top-``c`` tail candidates per query: one masked matmul over the
    fixed-shape tail buffer. Returns (B, c) slot ids (-1 = absent).
    Selection order is scale-invariant in ``q``; exact scoring and the
    tie contract are applied later by the shared rerank."""
    sims = q.astype(jnp.float32) @ tail_emb.T            # (B, T)
    sims = jnp.where(tail_slots[None, :] >= 0, sims, -jnp.inf)
    _, pos = jax.lax.top_k(sims, c)
    return jnp.take(tail_slots, pos)


class _Segment:
    """Sealed immutable int8 cluster-major block (ivf_scan layout) whose
    row ids are dynamic-tier slot ids. Mutation = tombstoning only."""

    __slots__ = ("centroids", "codes", "scales", "row_ids", "pos",
                 "live", "pending", "n_clusters", "cap")

    def __init__(self, rows: np.ndarray, slots: np.ndarray,
                 n_clusters: Optional[int] = None, iters: int = 4,
                 seed: int = 0):
        n = rows.shape[0]
        k = min(n_clusters or default_n_clusters(n), n)
        ivf = build_ivf(rows, n_clusters=k, iters=iters, seed=seed,
                        corpus_normalized=True)
        ids = np.asarray(ivf.row_ids)                    # (K, cap) -> row
        slot_ids = np.where(ids >= 0, slots[np.clip(ids, 0, None)],
                            -1).astype(np.int32)
        self.centroids = ivf.centroids
        self.codes = ivf.codes
        self.scales = ivf.scales
        self.row_ids = jnp.asarray(slot_ids)
        self.n_clusters, self.cap = slot_ids.shape
        kk, cc = np.nonzero(slot_ids >= 0)
        self.pos = {int(s): (int(a), int(b))
                    for s, a, b in zip(slot_ids[kk, cc], kk, cc)}
        self.live = len(self.pos)
        self.pending: list = []          # (k, c) tombstones awaiting flush

    def tombstone(self, slot: int) -> None:
        self.pending.append(self.pos.pop(slot))
        self.live -= 1

    def flush(self) -> None:
        if self.pending:
            kk = jnp.asarray([p[0] for p in self.pending], jnp.int32)
            cc = jnp.asarray([p[1] for p in self.pending], jnp.int32)
            self.row_ids = self.row_ids.at[kk, cc].set(-1)
            self.pending.clear()


class SegmentedIndex:
    """Incrementally updatable ANN over the dynamic tier's slots.

    Injectable into ``BaselinePolicy``/``KritesPolicy`` via ``dyn_index=``
    and into ``tiers.dynamic_lookup{,_batch}`` via ``index=``. Protocol:

    - ``topk(queries, emb, k=1)`` — queries (B, d) L2-normalized, ``emb``
      the live tier embedding matrix (the exact-rerank corpus); returns
      ((B, k) scores, (B, k) slot ids) matching the flat masked scan
      whenever the true best live slot survives into the candidate set
      (always, when ``nprobe=None`` full probe and the candidate budgets
      cover the live set — the test-enforced equivalence config);
    - ``record_write(slot, vec)`` — a tier write landed at ``slot``
      (LRU insert, batch insert, or promotion upsert): tombstone the
      slot's previous location, append to the tail;
    - ``invalidate(slot)`` — the slot became invalid without a rewrite
      (TTL eviction): tombstone only;
    - ``describe()`` / ``stats()`` — router telemetry.

    ``compact_every`` sealed segments are merged into one (tombstones
    dropped, clusters re-trained); with ``background=True`` the merge
    runs on a compactor thread off the serving path and is swapped in
    atomically, re-applying any tombstones that landed mid-build.
    """

    def __init__(self, capacity: int, d: int, *, tail_rows: int = 4096,
                 seg_clusters: Optional[int] = None,
                 nprobe: Optional[int] = 16, n_candidates: int = 64,
                 tail_candidates: int = 32, compact_every: int = 4,
                 kmeans_iters: int = 4, background: bool = False,
                 force: Optional[str] = None):
        self.capacity = capacity
        self.d = d
        self.tail_rows = tail_rows
        self.seg_clusters = seg_clusters
        self.nprobe = nprobe                 # None = full probe
        self.n_candidates = n_candidates
        self.tail_candidates = min(tail_candidates, tail_rows)
        self.compact_every = max(2, compact_every)
        self.kmeans_iters = kmeans_iters
        self.background = background
        self.force = force

        self._lock = threading.RLock()
        self._vec = np.zeros((capacity, d), np.float32)  # slot -> vector
        self._loc: dict = {}     # slot -> ("tail", pos) | (_Segment, None)
        self._tail_np = np.zeros((tail_rows, d), np.float32)
        self._tail_slots = np.full(tail_rows, -1, np.int32)
        self._tail_count = 0
        self._tail_live = 0
        self._tail_dev = None    # lazily refreshed (emb, slots) device pair
        self._segments: list[_Segment] = []
        self._seals = 0
        self._merges = 0
        self._writes = 0
        self._tombstones = 0
        self._compactor: Optional[threading.Thread] = None

    # -- mutation (called under the policy's dyn_lock) ---------------------

    def record_write(self, slot: int, vec) -> None:
        """A tier write landed at ``slot``: supersede any earlier copy."""
        vec = np.asarray(vec, np.float32).reshape(self.d)
        with self._lock:
            self._tombstone(slot)
            if self._tail_count == self.tail_rows:
                self._seal_tail()
            pos = self._tail_count
            self._tail_np[pos] = vec
            self._tail_slots[pos] = slot
            self._tail_count += 1
            self._tail_live += 1
            self._loc[slot] = ("tail", pos)
            self._vec[slot] = vec
            self._tail_dev = None
            self._writes += 1

    def bulk_load(self, slots, vectors) -> None:
        """Seed the index with a pre-existing live set in one build —
        the steady state a long-running deployment reaches after
        compaction (one merged segment), without replaying every write.
        ``slots`` (n,) distinct slot ids; ``vectors`` (n, d) normalized.
        """
        slots = np.asarray(slots, np.int32)
        vectors = np.asarray(vectors, np.float32)
        with self._lock:
            for s in slots:
                self._tombstone(int(s))
            seg = _Segment(vectors, slots, n_clusters=self.seg_clusters,
                           iters=self.kmeans_iters, seed=self._seals)
            for slot in seg.pos:
                self._loc[slot] = (seg, None)
            self._segments.append(seg)
            self._vec[slots] = vectors
            self._writes += len(slots)
            self._seals += 1

    def invalidate(self, slot: int) -> None:
        """Eviction without rewrite (e.g. TTL sweep): tombstone only."""
        with self._lock:
            self._tombstone(slot)

    def _tombstone(self, slot: int) -> None:
        loc = self._loc.pop(slot, None)
        if loc is None:
            return
        where, pos = loc
        if where == "tail":
            self._tail_slots[pos] = -1
            self._tail_live -= 1
            self._tail_dev = None
        else:
            where.tombstone(slot)
        self._tombstones += 1

    # -- sealing + compaction ----------------------------------------------

    def _seal_tail(self) -> None:
        """Freeze the full tail buffer into an int8 sealed segment.

        Dead tail rows (slot -1) are carried into the build and come out
        pre-tombstoned — sealing always sees the same (tail_rows, d)
        shape, so the k-means/packing path compiles once.
        """
        seg = _Segment(self._tail_np.copy(), self._tail_slots.copy(),
                       n_clusters=self.seg_clusters,
                       iters=self.kmeans_iters, seed=self._seals)
        for slot in seg.pos:
            self._loc[slot] = (seg, None)
        self._segments.append(seg)
        self._tail_np[:] = 0.0
        self._tail_slots[:] = -1
        self._tail_count = 0
        self._tail_live = 0
        self._tail_dev = None
        self._seals += 1
        if len(self._segments) >= self.compact_every:
            if self.background:
                self._spawn_compactor()
            else:
                self.compact()

    def compact(self) -> None:
        """Merge every sealed segment into one: gather live rows, drop
        tombstones, re-train the cluster layout. Serving results are
        unchanged (the exact rerank scores whatever candidates survive),
        so the merge can run inline or on the compactor thread."""
        with self._lock:
            src = list(self._segments)
        self._merge(src)

    def _spawn_compactor(self) -> None:
        if self._compactor is not None and self._compactor.is_alive():
            return
        src = list(self._segments)
        self._compactor = threading.Thread(
            target=self._merge, args=(src,), daemon=True,
            name="segidx-compactor")
        self._compactor.start()

    def wait_compaction(self, timeout_s: float = 60.0) -> None:
        t = self._compactor
        if t is not None:
            t.join(timeout_s)

    def _merge(self, src: list) -> None:
        if not src:
            return
        with self._lock:
            # snapshot the rows that are live *now*; writes racing the
            # build will tombstone in src and be re-checked at swap time
            slots = np.asarray(sorted(
                s for s, loc in self._loc.items() if loc[0] in src),
                np.int64)
            rows = self._vec[slots].copy() if len(slots) else None
        if rows is None:
            with self._lock:
                self._segments = [s for s in self._segments
                                  if s not in src]
            return
        merged = _Segment(rows, slots.astype(np.int32),
                          n_clusters=self.seg_clusters,
                          iters=self.kmeans_iters, seed=self._merges + 1)
        with self._lock:
            for slot in list(merged.pos):
                if self._loc.get(slot, (None,))[0] in src:
                    self._loc[slot] = (merged, None)
                else:        # rewritten or evicted while the build ran
                    merged.tombstone(slot)
            self._segments = [s for s in self._segments
                              if s not in src] + [merged]
            self._merges += 1

    # -- lookup ------------------------------------------------------------

    def _tail_device(self):
        if self._tail_dev is None:
            self._tail_dev = (jnp.asarray(self._tail_np),
                              jnp.asarray(self._tail_slots))
        return self._tail_dev

    def candidates(self, queries: jax.Array) -> Optional[jax.Array]:
        """(B, C_total) candidate slot ids across tail + segments
        (-1 = absent); None when the index holds no live entries."""
        with self._lock:
            segs = list(self._segments)
            for seg in segs:
                seg.flush()
            tail_emb, tail_slots = self._tail_device()
            tail_live = self._tail_live
        cands = []
        if tail_live:
            cands.append(_tail_topc(tail_emb, tail_slots, queries,
                                    self.tail_candidates))
        for seg in segs:
            if seg.live == 0:
                continue
            k = seg.n_clusters
            nprobe = k if self.nprobe is None else min(self.nprobe, k)
            nc = min(self.n_candidates, nprobe * seg.cap)
            _, cand = ivf_scan(queries, seg.centroids, seg.codes,
                               seg.scales, seg.row_ids, nprobe=nprobe,
                               n_candidates=nc, force=self.force)
            cands.append(cand)
        if not cands:
            return None
        return jnp.concatenate(cands, axis=1)

    def topk(self, queries: jax.Array, emb: jax.Array, k: int = 1):
        """Exact-reranked top-``k`` live slots. queries (B, d)
        L2-normalized; ``emb`` the live tier embedding matrix (C, d).
        Returns ((B, k) fp32 scores, (B, k) int32 slot ids); queries with
        no live candidate return (-inf, 0) like the flat masked scan."""
        cand = self.candidates(queries)
        B = queries.shape[0]
        if cand is None:
            return (jnp.full((B, k), -jnp.inf, jnp.float32),
                    jnp.zeros((B, k), jnp.int32))
        vals, idx = rerank_exact(queries, emb, cand,
                                 k=min(k, cand.shape[1]))
        idx = jnp.where(idx < 0, 0, idx)
        if vals.shape[1] < k:    # fewer candidates than asked: pad absent
            pad = k - vals.shape[1]
            vals = jnp.pad(vals, ((0, 0), (0, pad)),
                           constant_values=-jnp.inf)
            idx = jnp.pad(idx, ((0, 0), (0, pad)))
        return vals, idx

    # -- telemetry ---------------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            seg_live = sum(s.live for s in self._segments)
            seg_slots = sum(s.n_clusters * s.cap for s in self._segments)
            return {
                "live": self._tail_live + seg_live,
                "tail_live": self._tail_live,
                "tail_used": self._tail_count,
                "tail_rows": self.tail_rows,
                "segments": len(self._segments),
                "segment_live": seg_live,
                "segment_slots": seg_slots,
                "writes": self._writes,
                "tombstones": self._tombstones,
                "seals": self._seals,
                "merges": self._merges,
            }

    def describe(self) -> str:
        s = self.stats()
        probe = "full" if self.nprobe is None else self.nprobe
        return (f"segmented(live={s['live']}, tail={s['tail_live']}/"
                f"{self.tail_rows}, segs={s['segments']}, "
                f"seg_live={s['segment_live']}, nprobe={probe}, "
                f"C={self.n_candidates}, seals={s['seals']}, "
                f"merges={s['merges']})")
