"""Exact flat vector index: normalize + matmul + top-k.

This is the single-device form of the cache lookup (the paper's serving
hot path) and of recsys `retrieval_cand`. On TPU the fused Pallas
``simsearch`` kernel takes over via :mod:`repro.kernels.simsearch.ops`;
this jnp path is its oracle twin and the CPU/dry-run implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_normalize(x: jax.Array, eps: float = 1e-9) -> jax.Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine_topk(queries: jax.Array, corpus: jax.Array, k: int = 1,
                corpus_normalized: bool = False):
    """Cosine similarity top-k.

    queries (B, d), corpus (N, d) -> (scores (B, k), idx (B, k)).
    """
    q = l2_normalize(queries.astype(jnp.float32))
    c = corpus.astype(jnp.float32)
    if not corpus_normalized:
        c = l2_normalize(c)
    sims = q @ c.T
    return jax.lax.top_k(sims, k)


def topk_scores(queries: jax.Array, cand_vecs: jax.Array,
                cand_ids: jax.Array, k: int):
    """Raw-dot retrieval scoring: (B, d) x (N, d) -> top-k (scores, ids)."""
    scores = jnp.einsum("bd,nd->bn", queries, cand_vecs)
    vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
    return vals, jnp.take(cand_ids, idx)


def masked_cosine_topk(queries: jax.Array, corpus: jax.Array,
                       valid: jax.Array, k: int = 1,
                       corpus_normalized: bool = False):
    """Cosine top-k over a partially-valid corpus (the dynamic tier).

    valid (N,) bool — invalid rows score -inf. ``corpus_normalized``
    mirrors :func:`cosine_topk`: the dynamic tier's rows are already
    L2-normalized on insert (`core/tiers.py`), so the serving hot path
    passes True and skips a full-corpus renormalization per lookup.
    """
    q = l2_normalize(queries.astype(jnp.float32))
    c = corpus.astype(jnp.float32)
    if not corpus_normalized:
        c = l2_normalize(c)
    sims = q @ c.T
    sims = jnp.where(valid[None, :], sims, -jnp.inf)
    return jax.lax.top_k(sims, k)


class FlatIndex:
    """Exact flat search behind the injectable index protocol
    (``topk(queries, k)`` + ``describe()`` — see ``index/ivf.py``).
    Wraps the fused ``kernels/simsearch`` path over a fixed corpus.

    ``corpus_normalized`` only skips the one-time normalization at
    construction; the fused path re-normalizes internally on every
    call either way (in-kernel on TPU, in the jnp oracle elsewhere),
    which keeps it safe for arbitrary corpora.
    """

    def __init__(self, corpus: jax.Array, corpus_normalized: bool = False,
                 force: str | None = None):
        c = jnp.asarray(corpus, jnp.float32)
        self.corpus = c if corpus_normalized else l2_normalize(c)
        self.force = force

    def topk(self, queries: jax.Array, k: int = 1):
        """queries (B, d) L2-normalized -> (scores (B, k), idx (B, k))."""
        from repro.kernels.simsearch.ops import cosine_topk as fused
        return fused(queries, self.corpus, k=k, force=self.force)

    def describe(self) -> str:
        n, d = self.corpus.shape
        return f"flat(N={n}, d={d})"
