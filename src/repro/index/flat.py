"""Exact flat vector index: normalize + matmul + top-k.

This is the single-device form of the cache lookup (the paper's serving
hot path) and of recsys `retrieval_cand`. On TPU the fused Pallas
``simsearch`` kernel takes over via :mod:`repro.kernels.simsearch.ops`;
this jnp path is its oracle twin and the CPU/dry-run implementation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_normalize(x: jax.Array, eps: float = 1e-9) -> jax.Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine_topk(queries: jax.Array, corpus: jax.Array, k: int = 1,
                corpus_normalized: bool = False):
    """Cosine similarity top-k.

    queries (B, d), corpus (N, d) -> (scores (B, k), idx (B, k)).
    """
    q = l2_normalize(queries.astype(jnp.float32))
    c = corpus.astype(jnp.float32)
    if not corpus_normalized:
        c = l2_normalize(c)
    sims = q @ c.T
    return jax.lax.top_k(sims, k)


def topk_scores(queries: jax.Array, cand_vecs: jax.Array,
                cand_ids: jax.Array, k: int):
    """Raw-dot retrieval scoring: (B, d) x (N, d) -> top-k (scores, ids)."""
    scores = jnp.einsum("bd,nd->bn", queries, cand_vecs)
    vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
    return vals, jnp.take(cand_ids, idx)


def masked_cosine_topk(queries: jax.Array, corpus: jax.Array,
                       valid: jax.Array, k: int = 1):
    """Cosine top-k over a partially-valid corpus (the dynamic tier).

    valid (N,) bool — invalid rows score -inf.
    """
    q = l2_normalize(queries.astype(jnp.float32))
    c = l2_normalize(corpus.astype(jnp.float32))
    sims = q @ c.T
    sims = jnp.where(valid[None, :], sims, -jnp.inf)
    return jax.lax.top_k(sims, k)
