"""Distributed exact top-k over a corpus sharded across the 'model' axis.

This is the production layout of the Krites static tier (and of recsys
``retrieval_cand``): corpus rows live row-sharded across chips; each shard
computes a local top-k with the fused simsearch kernel, and only the tiny
(k scores, k indices) pairs cross the interconnect for the global merge —
instead of gathering the corpus or the full score matrix.

Implemented with ``shard_map`` + ``jax.lax`` collectives (all_gather of
per-shard top-k). The auto-GSPMD path (see index/flat.py under jit) is the
baseline; this manual-merge version is the optimized variant measured in
§Perf. At million-entry tier sizes the exact per-shard scan itself is
the bottleneck; ``build_sharded_ivf``/``sharded_ivf_topk`` swap it for
the IVF quantized scan + exact rerank (DESIGN.md §11) under the same
tiny k-candidate merge.

The *dynamic* tier has its own twins here (DESIGN.md §13): the
row-sharded masked top-k (``sharded_masked_topk``) mirrors
``index.flat.masked_cosine_topk`` bit for bit — per-shard masked scan,
tiny candidate merge, global slot ids — and the write side
(``sharded_dyn_write`` / ``sharded_bulk_insert`` / ``sharded_touch_many``)
routes every mutation to the owning shard as a shard-local scatter:
non-owners compute an out-of-range local slot and XLA's ``mode="drop"``
scatter discards it, so no collective and no tier gather is ever needed
to write. The merge contract every lookup twin obeys: per-shard
candidates are gathered in shard order and selected with the *stable*
``lax.top_k``, so score ties resolve to the lowest global row/slot id —
exactly the single-device ``argmax``/``top_k`` tie rule. That is what
lets the serving policies (``core/policy.py``) stay decision-for-decision
identical to the single-device path under any shard count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.5: public API, `check_vma`
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                    # jax 0.4.x: experimental, `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, **kw):
    """Version-portable shard_map: translates the replication-check kwarg
    (`check_vma` on new jax, `check_rep` on 0.4.x)."""
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    return _shard_map(f, **kw)

from repro.index.flat import l2_normalize
from repro.kernels.simsearch.ops import cosine_topk


def pad_rows(corpus, n_shards: int):
    """Pad a row-sharded corpus to a multiple of ``n_shards`` rows with
    copies of row 0. Safe for top-k serving: a pad row scores exactly
    like the real row 0, and the stable shard merge always prefers the
    earlier (real) occurrence, so a pad index is never returned.
    Works on numpy and jax arrays alike."""
    n = corpus.shape[0]
    pad = (-n) % n_shards
    if pad == 0:
        return corpus
    xp = np if isinstance(corpus, np.ndarray) else jnp
    return xp.concatenate([corpus, xp.repeat(corpus[:1], pad, axis=0)])


def shard_dynamic_tier(tier, mesh, axis: str = "model"):
    """Place every field of a ``tiers.DynamicTier`` row-sharded over
    ``axis`` (emb ``P(axis, None)``, the per-slot metadata ``P(axis)``),
    so the lookup/write twins below run shard-local from the start
    instead of resharding on first use. Capacity must divide the shard
    count."""
    n_shards = mesh.shape[axis]
    assert tier.emb.shape[0] % n_shards == 0, \
        (tier.emb.shape[0], n_shards)

    def put(a):
        spec = P(axis, *([None] * (a.ndim - 1)))
        return jax.device_put(a, jax.sharding.NamedSharding(mesh, spec))

    return jax.tree.map(put, tier)


def sharded_masked_topk(queries: jax.Array, emb: jax.Array,
                        valid: jax.Array, mesh, k: int = 1,
                        axis: str = "model"):
    """Dynamic-tier twin of :func:`sharded_cosine_topk`: masked top-k
    over a row-sharded mutable tier with a global-slot merge.

    queries (B, d) replicated; emb (C, d) and valid (C,) sharded over
    ``axis``. Returns (scores (B, k), global slot ids (B, k)). Scores
    are bit-identical to ``masked_cosine_topk(corpus_normalized=True)``
    (the per-row dot product is over the unpartitioned d axis) and the
    stable merge keeps the lowest-slot tie rule, so serving decisions
    match the single-device masked scan exactly. Invalid rows score
    -inf; a fully-invalid tier returns (-inf, 0) on both paths.
    """
    n_shards = mesh.shape[axis]
    rows_per = emb.shape[0] // n_shards
    q = l2_normalize(queries.astype(jnp.float32))

    def local(q, e, m):
        sims = q @ e.T                                   # (B, rows_per)
        sims = jnp.where(m[None, :], sims, -jnp.inf)
        vals, idx = jax.lax.top_k(sims, k)
        gidx = idx + jax.lax.axis_index(axis) * rows_per
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_idx, pos, axis=1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(None, None), P(axis, None), P(axis)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(q, emb, valid)


def _owned_slots(slots, axis: str, rows_per: int):
    """Map global slot ids to shard-local rows; slots owned elsewhere
    become ``rows_per`` (out of range), which a ``mode='drop'`` scatter
    silently discards — the shard-routing trick behind every write twin
    below. Guards against negative-index wraparound explicitly."""
    lo = jax.lax.axis_index(axis) * rows_per
    s = jnp.asarray(slots, jnp.int32)
    owned = jnp.logical_and(s >= lo, s < lo + rows_per)
    return jnp.where(owned, s - lo, rows_per)


def sharded_dyn_write(tier, slot, q, cls, answer_ref, static_origin, now,
                      mesh, axis: str = "model", last_used=None,
                      expires=0):
    """Shard-routed twin of ``tiers._write``: one slot write (scalar
    serve-path insert / async promotion) landing only on the owning
    shard. All operands are replicated scalars except the tier itself;
    no collective runs. Like the single-device twin, ``now`` stamps
    ``written_at`` (the LWW clock — enqueue time for promotions) and
    ``last_used`` defaults to it unless the caller passes the live
    clock so a delayed promotion lands LRU-warm."""
    rows_per = tier.emb.shape[0] // mesh.shape[axis]

    def local(emb, c, ar, so, va, lu, wa, xp, slot, q, cls, answer_ref,
              static_origin, now, lu_now, exp):
        ls = _owned_slots(slot, axis, rows_per)
        return (emb.at[ls].set(q, mode="drop"),
                c.at[ls].set(cls.astype(jnp.int32), mode="drop"),
                ar.at[ls].set(answer_ref.astype(jnp.int32), mode="drop"),
                so.at[ls].set(static_origin, mode="drop"),
                va.at[ls].set(True, mode="drop"),
                lu.at[ls].set(lu_now, mode="drop"),
                wa.at[ls].set(now, mode="drop"),
                xp.at[ls].set(exp, mode="drop"))

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(axis), P(), P(None), P(), P(),
                  P(), P(), P(), P()),
        out_specs=(P(axis, None), P(axis), P(axis), P(axis), P(axis),
                   P(axis), P(axis), P(axis)),
        check_vma=False)
    emb, c, ar, so, va, lu, wa, xp = fn(
        tier.emb, tier.cls, tier.answer_ref, tier.static_origin,
        tier.valid, tier.last_used, tier.written_at, tier.expires_at,
        jnp.asarray(slot, jnp.int32), q, jnp.asarray(cls),
        jnp.asarray(answer_ref), jnp.asarray(static_origin),
        jnp.asarray(now, jnp.int32),
        jnp.asarray(now if last_used is None else last_used, jnp.int32),
        jnp.asarray(expires, jnp.int32))
    return tier._replace(emb=emb, cls=c, answer_ref=ar, static_origin=so,
                         valid=va, last_used=lu, written_at=wa,
                         expires_at=xp)


def sharded_bulk_insert(tier, V, slots, rows, ts, cls, mesh,
                        axis: str = "model", exps=None):
    """Shard-routed twin of the policy's batched ``_bulk_insert``: a
    whole micro-batch of backend inserts scattered in one fused update
    per field, each landing only on the owning shard (``last_used`` is
    left to the batched touch, exactly like the single-device twin).
    ``slots``/``rows``/``ts``/``cls`` are replicated, padded the same
    way as single-device (duplicate scatters of identical values are
    benign)."""
    rows_per = tier.emb.shape[0] // mesh.shape[axis]

    def local(emb, c, ar, so, va, wa, xp, V, slots, rows, ts, cls, exps):
        ls = _owned_slots(slots, axis, rows_per)
        return (emb.at[ls].set(V[rows], mode="drop"),
                c.at[ls].set(cls, mode="drop"),
                ar.at[ls].set(jnp.int32(-1), mode="drop"),
                so.at[ls].set(False, mode="drop"),
                va.at[ls].set(True, mode="drop"),
                wa.at[ls].set(ts, mode="drop"),
                xp.at[ls].set(exps, mode="drop"))

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(None, None), P(None), P(None),
                  P(None), P(None), P(None)),
        out_specs=(P(axis, None), P(axis), P(axis), P(axis), P(axis),
                   P(axis), P(axis)),
        check_vma=False)
    if exps is None:
        exps = np.zeros(np.asarray(slots).shape[0], np.int32)
    emb, c, ar, so, va, wa, xp = fn(
        tier.emb, tier.cls, tier.answer_ref, tier.static_origin,
        tier.valid, tier.written_at, tier.expires_at, V,
        jnp.asarray(slots, jnp.int32), jnp.asarray(rows, jnp.int32),
        jnp.asarray(ts, jnp.int32), jnp.asarray(cls, jnp.int32),
        jnp.asarray(exps, jnp.int32))
    return tier._replace(emb=emb, cls=c, answer_ref=ar, static_origin=so,
                         valid=va, written_at=wa, expires_at=xp)


def sharded_touch_many(tier, slots, nows, mesh, axis: str = "model"):
    """Shard-routed twin of ``tiers.touch_many``: LRU clock scatter for
    a batch of hits, owner-local. Callers deduplicate ``slots`` (latest
    ``now`` wins) exactly as on the single-device path."""
    rows_per = tier.emb.shape[0] // mesh.shape[axis]

    def local(lu, slots, nows):
        ls = _owned_slots(slots, axis, rows_per)
        return lu.at[ls].set(nows, mode="drop")

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(None), P(None)),
                   out_specs=P(axis), check_vma=False)
    return tier._replace(last_used=fn(
        tier.last_used, jnp.asarray(slots, jnp.int32),
        jnp.asarray(nows, jnp.int32)))


def sharded_cosine_topk(queries: jax.Array, corpus: jax.Array, mesh,
                        k: int = 4, axis: str = "model",
                        force: str | None = None):
    """queries (B, d) replicated; corpus (N, d) sharded over ``axis``.

    Returns (scores (B, k), global indices (B, k)).
    """
    n_shards = mesh.shape[axis]
    N = corpus.shape[0]
    shard_rows = N // n_shards

    def local(q, c):
        vals, idx = cosine_topk(q, c, k=k, force=force)
        shard_id = jax.lax.axis_index(axis)
        gidx = idx + shard_id * shard_rows
        # gather the candidate sets from every shard: (n_shards*k,) each
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        top_i = jnp.take_along_axis(all_idx, pos, axis=1)
        return top_v, top_i

    other = [a for a in mesh.axis_names if a != axis]
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(*([None] * queries.ndim)), P(axis, None)),
        out_specs=(P(), P()),
        check_vma=False)
    return fn(queries, corpus)


def sharded_topk_scores(u: jax.Array, cand_vecs: jax.Array,
                        cand_ids: jax.Array, mesh, k: int = 100,
                        axis: str = "model"):
    """Distributed retrieval scoring: raw-dot top-k with per-shard
    selection + tiny merge (recsys `retrieval_cand` / cache lookup).

    u: (B, d) or (B, I, d) (multi-interest: max over I) — replicated.
    cand_vecs (N, d), cand_ids (N,) — sharded over ``axis``.
    """
    def local(uq, c, ids):
        if uq.ndim == 3:
            scores = jnp.einsum("bid,nd->bin", uq, c).max(axis=1)
        else:
            scores = jnp.einsum("bd,nd->bn", uq, c)
        vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
        gids = jnp.take(ids, idx)
        # merge: gather the k candidates from every shard (k*n_shards
        # scalars — instead of gathering the N-row corpus or scores)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    uspec = P(*([None] * u.ndim))
    fn = shard_map(local, mesh=mesh,
                   in_specs=(uspec, P(axis, None), P(axis)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(u, cand_vecs, cand_ids)


def sharded_topk_local_candidates(u: jax.Array, table: jax.Array,
                                  cand_ids: jax.Array, mesh, k: int = 100,
                                  axis: str = "model"):
    """Retrieval with *range-partitioned* candidates (production layout:
    each shard's candidate list references rows it owns, as in sharded
    ANN/DLRM serving). The embedding gather is then shard-LOCAL; the only
    collective is the k-candidate merge (KBs).

    table (V, d) row-sharded over ``axis``; cand_ids (N,) sharded over
    ``axis`` with values in the owning shard's row range.
    """
    V = table.shape[0]
    n_shards = mesh.shape[axis]
    rows_per = V // n_shards

    def local(uq, tab, ids):
        local_rows = ids - jax.lax.axis_index(axis) * rows_per
        c = jnp.take(tab, jnp.clip(local_rows, 0, rows_per - 1), axis=0)
        if uq.ndim == 3:
            scores = jnp.einsum("bid,nd->bin", uq, c).max(axis=1)
        else:
            scores = jnp.einsum("bd,nd->bn", uq, c)
        vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
        gids = jnp.take(ids, idx)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    uspec = P(*([None] * u.ndim))
    fn = shard_map(local, mesh=mesh,
                   in_specs=(uspec, P(axis, None), P(axis)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(u, table, cand_ids)


def build_sharded_ivf(corpus, n_shards: int, n_clusters: int | None = None,
                      **build_kw):
    """Per-shard IVF over a row-partitioned corpus (DESIGN.md §11).

    Shard ``s`` owns the contiguous row range ``[s*N/S, (s+1)*N/S)`` and
    gets its own sub-index (centroids trained on its rows only, local
    row ids). The per-shard layouts are padded to a common band
    capacity and stacked on a leading shard axis, so the whole index
    shards over ``P(axis, ...)`` like the corpus itself.
    """
    import numpy as np

    from repro.index.ivf import IVF, build_ivf

    corpus = np.asarray(corpus, np.float32)
    N = corpus.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    rows_per = N // n_shards
    parts = [build_ivf(corpus[s * rows_per:(s + 1) * rows_per],
                       n_clusters=n_clusters, **build_kw)
             for s in range(n_shards)]
    cap = max(p.codes.shape[1] for p in parts)

    def pad_band(a, fill):
        a = np.asarray(a)
        short = cap - a.shape[1]
        if not short:
            return a
        width = [(0, 0), (0, short)] + [(0, 0)] * (a.ndim - 2)
        return np.pad(a, width, constant_values=fill)

    return IVF(
        centroids=jnp.stack([jnp.asarray(p.centroids) for p in parts]),
        codes=jnp.asarray(np.stack([pad_band(p.codes, 0)
                                    for p in parts])),
        scales=jnp.asarray(np.stack([pad_band(p.scales, 0)
                                     for p in parts])),
        row_ids=jnp.asarray(np.stack([pad_band(p.row_ids, -1)
                                      for p in parts])),
        corpus=jnp.stack([jnp.asarray(p.corpus) for p in parts]))


def sharded_ivf_topk(queries: jax.Array, sivf, mesh, k: int = 1,
                     axis: str = "model", nprobe: int = 8,
                     n_candidates: int = 32, force: str | None = None):
    """ANN twin of :func:`sharded_cosine_topk`: per-shard IVF scan +
    exact rerank over the shard's own rows, then the same tiny
    k-candidate all-gather merge — only (k scores, k global ids) pairs
    cross the interconnect.

    queries (B, d) replicated; ``sivf`` a stacked :func:`build_sharded_ivf`
    index whose leading axis is sharded over ``axis``.
    Returns (scores (B, k), global row indices (B, k)).
    """
    from repro.kernels.ivf_scan.ops import ivf_search

    rows_per = sivf.corpus.shape[1]

    def local(q, cent, codes, scales, ids, corp):
        vals, lids = ivf_search(q, corp[0], cent[0], codes[0], scales[0],
                                ids[0], k=k, nprobe=nprobe,
                                n_candidates=n_candidates, force=force)
        shard_id = jax.lax.axis_index(axis)
        gids = jnp.where(lids >= 0, lids + shard_id * rows_per, -1)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), P(axis, None, None),
                  P(axis, None, None, None), P(axis, None, None),
                  P(axis, None, None), P(axis, None, None)),
        out_specs=(P(), P()), check_vma=False)
    return fn(queries, sivf.centroids, sivf.codes, sivf.scales,
              sivf.row_ids, sivf.corpus)


def sharded_ivf_lookup(mesh, sivf, axis: str = "model", nprobe: int = 8,
                       n_candidates: int = 32):
    """ANN twin of :func:`sharded_static_lookup`: a jitted
    (queries) -> (best_sim, best_idx) closure over a sharded IVF index
    kept on device — the serving-path static lookup at million-entry
    scale."""
    def spec(a):
        return jax.sharding.NamedSharding(
            mesh, P(axis, *([None] * (a.ndim - 1))))

    sivf = jax.tree.map(lambda a: jax.device_put(a, spec(a)), sivf)

    @jax.jit
    def lookup(queries):
        v, i = sharded_ivf_topk(queries, sivf, mesh, k=1, axis=axis,
                                nprobe=nprobe, n_candidates=n_candidates)
        return v[:, 0], i[:, 0]
    return lookup


class ShardedIVFIndex:
    """Injectable static-tier index (the ``topk(queries, k)`` +
    ``describe()`` protocol of ``index.ivf.IVFIndex``) serving lookups
    through the per-shard IVF scan + exact rerank + tiny k-candidate
    merge on a device mesh (DESIGN.md §13).

    Drop it into ``BaselinePolicy``/``KritesPolicy`` via ``index=`` and
    both serving entry points route their static top-1 through
    :func:`sharded_ivf_topk` with no further policy changes. The corpus
    is padded to a shard multiple with copies of row 0
    (:func:`pad_rows`) whose layout entries are then tombstoned
    (row id -1, the scan's padding convention) — so no ``k`` can return
    a global id >= the real row count. ``nprobe`` is clamped to the
    per-shard cluster count, so "full probe" configs stay
    exact-rerank-equal to flat search on every shard layout.
    """

    def __init__(self, corpus, mesh, axis: str = "model", nprobe: int = 8,
                 n_candidates: int = 32, n_clusters: int | None = None,
                 **build_kw):
        self.mesh, self.axis = mesh, axis
        self.n_shards = mesh.shape[axis]
        c = np.asarray(corpus, np.float32)
        self.n_rows = c.shape[0]
        padded = pad_rows(c, self.n_shards)
        sivf = build_sharded_ivf(padded, self.n_shards,
                                 n_clusters=n_clusters, **build_kw)
        if padded.shape[0] != self.n_rows:
            # tombstone the pad duplicates (they may span several
            # trailing shards when pad > rows_per): -1 row ids are the
            # scan's padding convention, so no k can ever surface a
            # phantom global id >= n_rows
            rows_per = padded.shape[0] // self.n_shards
            ids = np.asarray(sivf.row_ids).copy()     # (S, K, cap) local
            for s in range(self.n_shards):
                gids = np.where(ids[s] >= 0, ids[s] + s * rows_per, -1)
                ids[s] = np.where(gids >= self.n_rows, -1, ids[s])
            sivf = sivf._replace(row_ids=jnp.asarray(ids))
        self.nprobe = min(nprobe, sivf.centroids.shape[1])
        self.n_candidates = n_candidates

        def spec(a):
            return jax.sharding.NamedSharding(
                mesh, P(axis, *([None] * (a.ndim - 1))))

        self.sivf = jax.tree.map(lambda a: jax.device_put(a, spec(a)),
                                 sivf)
        self._fns: dict = {}          # k -> jitted lookup

    def topk(self, queries: jax.Array, k: int = 1):
        """queries (B, d) L2-normalized -> (scores (B, k), global row
        indices (B, k))."""
        fn = self._fns.get(k)
        if fn is None:
            fn = jax.jit(lambda q: sharded_ivf_topk(
                q, self.sivf, self.mesh, k=k, axis=self.axis,
                nprobe=self.nprobe, n_candidates=self.n_candidates))
            self._fns[k] = fn
        return fn(queries)

    def describe(self) -> str:
        K = int(self.sivf.centroids.shape[1])
        return (f"sharded-ivf(N={self.n_rows}, shards={self.n_shards}, "
                f"K/shard={K}, nprobe={self.nprobe}, "
                f"C={self.n_candidates})")


def sharded_static_lookup(mesh, static_emb: jax.Array, axis: str = "model"):
    """Returns a jitted (queries) -> (best_sim, best_idx) closure over a
    corpus kept sharded on device — the serving-path static lookup."""
    sharding = jax.sharding.NamedSharding(mesh, P(axis, None))
    corpus = jax.device_put(static_emb, sharding)

    @jax.jit
    def lookup(queries):
        v, i = sharded_cosine_topk(queries, corpus, mesh, k=1, axis=axis)
        return v[:, 0], i[:, 0]
    return lookup
