"""Distributed exact top-k over a corpus sharded across the 'model' axis.

This is the production layout of the Krites static tier (and of recsys
``retrieval_cand``): corpus rows live row-sharded across chips; each shard
computes a local top-k with the fused simsearch kernel, and only the tiny
(k scores, k indices) pairs cross the interconnect for the global merge —
instead of gathering the corpus or the full score matrix.

Implemented with ``shard_map`` + ``jax.lax`` collectives (all_gather of
per-shard top-k). The auto-GSPMD path (see index/flat.py under jit) is the
baseline; this manual-merge version is the optimized variant measured in
§Perf. At million-entry tier sizes the exact per-shard scan itself is
the bottleneck; ``build_sharded_ivf``/``sharded_ivf_topk`` swap it for
the IVF quantized scan + exact rerank (DESIGN.md §11) under the same
tiny k-candidate merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                   # jax >= 0.5: public API, `check_vma`
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                    # jax 0.4.x: experimental, `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, **kw):
    """Version-portable shard_map: translates the replication-check kwarg
    (`check_vma` on new jax, `check_rep` on 0.4.x)."""
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    return _shard_map(f, **kw)

from repro.kernels.simsearch.ops import cosine_topk


def sharded_cosine_topk(queries: jax.Array, corpus: jax.Array, mesh,
                        k: int = 4, axis: str = "model",
                        force: str | None = None):
    """queries (B, d) replicated; corpus (N, d) sharded over ``axis``.

    Returns (scores (B, k), global indices (B, k)).
    """
    n_shards = mesh.shape[axis]
    N = corpus.shape[0]
    shard_rows = N // n_shards

    def local(q, c):
        vals, idx = cosine_topk(q, c, k=k, force=force)
        shard_id = jax.lax.axis_index(axis)
        gidx = idx + shard_id * shard_rows
        # gather the candidate sets from every shard: (n_shards*k,) each
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_idx = jax.lax.all_gather(gidx, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        top_i = jnp.take_along_axis(all_idx, pos, axis=1)
        return top_v, top_i

    other = [a for a in mesh.axis_names if a != axis]
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(*([None] * queries.ndim)), P(axis, None)),
        out_specs=(P(), P()),
        check_vma=False)
    return fn(queries, corpus)


def sharded_topk_scores(u: jax.Array, cand_vecs: jax.Array,
                        cand_ids: jax.Array, mesh, k: int = 100,
                        axis: str = "model"):
    """Distributed retrieval scoring: raw-dot top-k with per-shard
    selection + tiny merge (recsys `retrieval_cand` / cache lookup).

    u: (B, d) or (B, I, d) (multi-interest: max over I) — replicated.
    cand_vecs (N, d), cand_ids (N,) — sharded over ``axis``.
    """
    def local(uq, c, ids):
        if uq.ndim == 3:
            scores = jnp.einsum("bid,nd->bin", uq, c).max(axis=1)
        else:
            scores = jnp.einsum("bd,nd->bn", uq, c)
        vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
        gids = jnp.take(ids, idx)
        # merge: gather the k candidates from every shard (k*n_shards
        # scalars — instead of gathering the N-row corpus or scores)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    uspec = P(*([None] * u.ndim))
    fn = shard_map(local, mesh=mesh,
                   in_specs=(uspec, P(axis, None), P(axis)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(u, cand_vecs, cand_ids)


def sharded_topk_local_candidates(u: jax.Array, table: jax.Array,
                                  cand_ids: jax.Array, mesh, k: int = 100,
                                  axis: str = "model"):
    """Retrieval with *range-partitioned* candidates (production layout:
    each shard's candidate list references rows it owns, as in sharded
    ANN/DLRM serving). The embedding gather is then shard-LOCAL; the only
    collective is the k-candidate merge (KBs).

    table (V, d) row-sharded over ``axis``; cand_ids (N,) sharded over
    ``axis`` with values in the owning shard's row range.
    """
    V = table.shape[0]
    n_shards = mesh.shape[axis]
    rows_per = V // n_shards

    def local(uq, tab, ids):
        local_rows = ids - jax.lax.axis_index(axis) * rows_per
        c = jnp.take(tab, jnp.clip(local_rows, 0, rows_per - 1), axis=0)
        if uq.ndim == 3:
            scores = jnp.einsum("bid,nd->bin", uq, c).max(axis=1)
        else:
            scores = jnp.einsum("bd,nd->bn", uq, c)
        vals, idx = jax.lax.top_k(scores.astype(jnp.float32), k)
        gids = jnp.take(ids, idx)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    uspec = P(*([None] * u.ndim))
    fn = shard_map(local, mesh=mesh,
                   in_specs=(uspec, P(axis, None), P(axis)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(u, table, cand_ids)


def build_sharded_ivf(corpus, n_shards: int, n_clusters: int | None = None,
                      **build_kw):
    """Per-shard IVF over a row-partitioned corpus (DESIGN.md §11).

    Shard ``s`` owns the contiguous row range ``[s*N/S, (s+1)*N/S)`` and
    gets its own sub-index (centroids trained on its rows only, local
    row ids). The per-shard layouts are padded to a common band
    capacity and stacked on a leading shard axis, so the whole index
    shards over ``P(axis, ...)`` like the corpus itself.
    """
    import numpy as np

    from repro.index.ivf import IVF, build_ivf

    corpus = np.asarray(corpus, np.float32)
    N = corpus.shape[0]
    assert N % n_shards == 0, (N, n_shards)
    rows_per = N // n_shards
    parts = [build_ivf(corpus[s * rows_per:(s + 1) * rows_per],
                       n_clusters=n_clusters, **build_kw)
             for s in range(n_shards)]
    cap = max(p.codes.shape[1] for p in parts)

    def pad_band(a, fill):
        a = np.asarray(a)
        short = cap - a.shape[1]
        if not short:
            return a
        width = [(0, 0), (0, short)] + [(0, 0)] * (a.ndim - 2)
        return np.pad(a, width, constant_values=fill)

    return IVF(
        centroids=jnp.stack([jnp.asarray(p.centroids) for p in parts]),
        codes=jnp.asarray(np.stack([pad_band(p.codes, 0)
                                    for p in parts])),
        scales=jnp.asarray(np.stack([pad_band(p.scales, 0)
                                     for p in parts])),
        row_ids=jnp.asarray(np.stack([pad_band(p.row_ids, -1)
                                      for p in parts])),
        corpus=jnp.stack([jnp.asarray(p.corpus) for p in parts]))


def sharded_ivf_topk(queries: jax.Array, sivf, mesh, k: int = 1,
                     axis: str = "model", nprobe: int = 8,
                     n_candidates: int = 32, force: str | None = None):
    """ANN twin of :func:`sharded_cosine_topk`: per-shard IVF scan +
    exact rerank over the shard's own rows, then the same tiny
    k-candidate all-gather merge — only (k scores, k global ids) pairs
    cross the interconnect.

    queries (B, d) replicated; ``sivf`` a stacked :func:`build_sharded_ivf`
    index whose leading axis is sharded over ``axis``.
    Returns (scores (B, k), global row indices (B, k)).
    """
    from repro.kernels.ivf_scan.ops import ivf_search

    rows_per = sivf.corpus.shape[1]

    def local(q, cent, codes, scales, ids, corp):
        vals, lids = ivf_search(q, corp[0], cent[0], codes[0], scales[0],
                                ids[0], k=k, nprobe=nprobe,
                                n_candidates=n_candidates, force=force)
        shard_id = jax.lax.axis_index(axis)
        gids = jnp.where(lids >= 0, lids + shard_id * rows_per, -1)
        all_vals = jax.lax.all_gather(vals, axis, axis=1, tiled=True)
        all_gids = jax.lax.all_gather(gids, axis, axis=1, tiled=True)
        top_v, pos = jax.lax.top_k(all_vals, k)
        return top_v, jnp.take_along_axis(all_gids, pos, axis=1)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None), P(axis, None, None),
                  P(axis, None, None, None), P(axis, None, None),
                  P(axis, None, None), P(axis, None, None)),
        out_specs=(P(), P()), check_vma=False)
    return fn(queries, sivf.centroids, sivf.codes, sivf.scales,
              sivf.row_ids, sivf.corpus)


def sharded_ivf_lookup(mesh, sivf, axis: str = "model", nprobe: int = 8,
                       n_candidates: int = 32):
    """ANN twin of :func:`sharded_static_lookup`: a jitted
    (queries) -> (best_sim, best_idx) closure over a sharded IVF index
    kept on device — the serving-path static lookup at million-entry
    scale."""
    def spec(a):
        return jax.sharding.NamedSharding(
            mesh, P(axis, *([None] * (a.ndim - 1))))

    sivf = jax.tree.map(lambda a: jax.device_put(a, spec(a)), sivf)

    @jax.jit
    def lookup(queries):
        v, i = sharded_ivf_topk(queries, sivf, mesh, k=1, axis=axis,
                                nprobe=nprobe, n_candidates=n_candidates)
        return v[:, 0], i[:, 0]
    return lookup


def sharded_static_lookup(mesh, static_emb: jax.Array, axis: str = "model"):
    """Returns a jitted (queries) -> (best_sim, best_idx) closure over a
    corpus kept sharded on device — the serving-path static lookup."""
    sharding = jax.sharding.NamedSharding(mesh, P(axis, None))
    corpus = jax.device_put(static_emb, sharding)

    @jax.jit
    def lookup(queries):
        v, i = sharded_cosine_topk(queries, corpus, mesh, k=1, axis=axis)
        return v[:, 0], i[:, 0]
    return lookup
