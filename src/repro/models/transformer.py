"""Decoder-only LM (dense or MoE) with scan-over-layers.

Entry points (all pure functions of (params, inputs)):

- ``init_params(cfg, key)``            -> pytree (layer weights stacked on L)
- ``forward(cfg, params, tokens)``     -> logits (training forward)
- ``train_loss(cfg, params, batch)``   -> scalar loss (chunked-vocab xent)
- ``prefill(cfg, params, tokens)``     -> (last-token logits, KVCache)
- ``decode_step(cfg, params, cache, token, pos)`` -> (logits, KVCache)

KV cache layout: dict(k=(L, B, S, Kv, D), v=(L, B, S, Kv, D), length=(B,)).
The sequence axis of the cache is the sharding target for long-context
decode (flash-decoding split-K under GSPMD).
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.act_sharding import (constrain_act, constrain_seq,
                                            constrain_tp_last)
from repro.models import attention as attn_lib
from repro.models.layers import (apply_rope, dense_init, embed_init, rms_norm,
                                 rope_cos_sin, swiglu)
from repro.models.moe import moe_ffn, moe_ffn_einsum

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: LMConfig):
    d, h = cfg.d_model, cfg.head_dim
    shapes = {
        "wq": (d, cfg.n_heads * h),
        "wk": (d, cfg.n_kv_heads * h),
        "wv": (d, cfg.n_kv_heads * h),
        "wo": (cfg.n_heads * h, d),
        "ln1": (d,),
        "ln2": (d,),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = (h,)
        shapes["k_norm"] = (h,)
    if cfg.is_moe:
        m = cfg.moe
        shapes.update({
            "router": (d, m.n_experts),
            "wg": (m.n_experts, d, m.d_ff_expert),
            "wu": (m.n_experts, d, m.d_ff_expert),
            "wd": (m.n_experts, m.d_ff_expert, d),
        })
        if m.n_shared_experts:
            f = m.n_shared_experts * m.d_ff_expert
            shapes.update({"shared_wg": (d, f), "shared_wu": (d, f),
                           "shared_wd": (f, d)})
    else:
        shapes.update({"wg": (d, cfg.d_ff), "wu": (d, cfg.d_ff),
                       "wd": (cfg.d_ff, d)})
    return shapes


def init_params(cfg: LMConfig, key: jax.Array) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    shapes = _layer_shapes(cfg)
    layer = {}
    lkeys = jax.random.split(keys[0], len(shapes))
    for lk, (name, shp) in zip(lkeys, sorted(shapes.items())):
        stacked = (cfg.n_layers, *shp)
        if name.startswith("ln") or name.endswith("_norm"):
            layer[name] = jnp.ones(stacked, dtype)
        else:
            # init each stacked layer with a different fold of the key
            layer[name] = dense_init(lk, stacked, dtype)
    params: Params = {
        "layers": layer,
        "embed": embed_init(keys[1], (cfg.vocab_size, cfg.d_model), dtype),
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            keys[2], (cfg.d_model, cfg.vocab_size), dtype)
    return params


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _attention_block(cfg: LMConfig, p: Params, x: jax.Array,
                     cos, sin, mode: str, cache_kv=None, length=None):
    """Shared attention sub-block. x: (B, S, d)."""
    B, S, d = x.shape
    h = cfg.head_dim
    q = constrain_tp_last(jnp.einsum("bsd,dq->bsq", x, p["wq"])).reshape(
        B, S, cfg.n_heads, h)
    k = constrain_tp_last(jnp.einsum("bsd,dq->bsq", x, p["wk"])).reshape(
        B, S, cfg.n_kv_heads, h)
    v = constrain_tp_last(jnp.einsum("bsd,dq->bsq", x, p["wv"])).reshape(
        B, S, cfg.n_kv_heads, h)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if mode == "decode":
        k_cache, v_cache = cache_kv
        pos = jnp.reshape(length, (-1,))[0]  # uniform position (batched step)
        # one-hot masked update instead of dynamic_update_slice: a DUS at a
        # traced offset cannot be partitioned along the (sequence-sharded)
        # cache axis — GSPMD all-gathers the whole KV cache per layer
        # (24 GiB/step measured on llama4 decode, §Perf). The where-update
        # is elementwise and stays fully sharded.
        sel = (jnp.arange(k_cache.shape[1]) == pos)[None, :, None, None]
        k_cache = jnp.where(sel, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(sel, v.astype(v_cache.dtype), v_cache)
        o = attn_lib.decode_attention(q, k_cache, v_cache, length + 1)
        new_kv = (k_cache, v_cache)
    else:
        o = attn_lib.causal_attention(q, k, v, cfg.attn_chunk,
                                      unroll=not cfg.scan_layers)
        new_kv = (k, v)
    o = constrain_tp_last(o.reshape(B, S, cfg.n_heads * h))
    return jnp.einsum("bsq,qd->bsd", o, p["wo"]), new_kv


def _ffn_block(cfg: LMConfig, p: Params, x: jax.Array, mode: str):
    B, S, d = x.shape
    if cfg.is_moe:
        flat = x.reshape(B * S, d)
        if mode == "decode":
            # decode steps have few tokens; the one-hot dispatch is cheap
            # and avoids sort latency on the serving path.
            y, aux = moe_ffn_einsum(flat, p, cfg.moe)
        elif cfg.moe.dispatch == "ep":
            from repro.distributed.act_sharding import current_mesh
            from repro.models.moe import moe_ffn_ep
            mesh = current_mesh()
            if mesh is not None and flat.shape[0] % mesh.devices.size == 0:
                y, aux = moe_ffn_ep(flat, p, cfg.moe, mesh)
            else:
                y, aux = moe_ffn(flat, p, cfg.moe)
        else:
            y, aux = moe_ffn(flat, p, cfg.moe)
        return y.reshape(B, S, d), aux
    return swiglu(x, p["wg"], p["wu"], p["wd"]), jnp.float32(0.0)


def _layer(cfg: LMConfig, p: Params, x, cos, sin, mode, cache_kv=None,
           length=None):
    sp = cfg.seq_parallel and mode != "decode" \
        and x.shape[1] % 16 == 0
    x = constrain_act(x)            # gather the seq-sharded carry
    a, new_kv = _attention_block(
        cfg, p, rms_norm(x, p["ln1"], cfg.norm_eps), cos, sin, mode,
        cache_kv, length)
    x = constrain_act(x + a)
    f, aux = _ffn_block(cfg, p, rms_norm(x, p["ln2"], cfg.norm_eps), mode)
    out = x + f
    # exit in sequence-parallel layout: the scan carry (= remat residual)
    # is sharded over 'model' on the seq axis
    out = constrain_seq(out) if sp else constrain_act(out)
    return out, aux, new_kv


# ---------------------------------------------------------------------------
# stacked forward passes
# ---------------------------------------------------------------------------

def _scan_layers(cfg: LMConfig, params: Params, x, cos, sin, mode,
                 cache=None, length=None):
    """Run all layers; layer weights are stacked on axis 0 and scanned."""
    layers = params["layers"]

    if mode == "decode":
        def body(carry, xs):
            xc, aux = carry
            p, kc, vc = xs
            y, a, (nk, nv) = _layer(cfg, p, xc, cos, sin, "decode",
                                    (kc, vc), length)
            return (y, aux + a), (nk, nv)

        if not cfg.scan_layers:
            aux = jnp.float32(0.0)
            ks, vs = [], []
            for l in range(cfg.n_layers):
                p_l = jax.tree.map(lambda a: a[l], layers)
                x, a, (nk, nv) = _layer(cfg, p_l, x, cos, sin, "decode",
                                        (cache["k"][l], cache["v"][l]),
                                        length)
                aux = aux + a
                ks.append(nk)
                vs.append(nv)
            return x, aux, {"k": jnp.stack(ks), "v": jnp.stack(vs),
                            "length": cache["length"] + 1}

        (x, aux), (new_k, new_v) = jax.lax.scan(
            body, (x, jnp.float32(0.0)),
            (layers, cache["k"], cache["v"]))
        return x, aux, {"k": new_k, "v": new_v,
                        "length": cache["length"] + 1}

    layer_fn = functools.partial(_layer, cfg)
    if cfg.remat:
        # args after partial: (p, x, cos, sin, mode) -> mode is static
        layer_fn = jax.checkpoint(
            layer_fn, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(4,))

    def body(carry, p):
        xc, aux = carry
        y, a, kv = layer_fn(p, xc, cos, sin, mode)
        out = kv if mode == "prefill" else None
        return (y, aux + a), out

    if not cfg.scan_layers:
        # unrolled layer stack (dry-run analysis variants: XLA cost
        # analysis undercounts while-loop bodies, so analysis lowers
        # loop-free HLO and extrapolates; see analysis/roofline.py)
        aux = jnp.float32(0.0)
        kvs = []
        for l in range(cfg.n_layers):
            p_l = jax.tree.map(lambda a: a[l], layers)
            x, a, kv = layer_fn(p_l, x, cos, sin, mode)
            aux = aux + a
            kvs.append(kv)
        if mode == "prefill":
            kv = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
        else:
            kv = None
        return x, aux, kv

    (x, aux), kv = jax.lax.scan(body, (x, jnp.float32(0.0)), layers)
    return x, aux, kv


def forward(cfg: LMConfig, params: Params, tokens: jax.Array):
    """Training/scoring forward. tokens: (B, S) int32 -> hidden (B, S, d)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = constrain_seq(x) if (cfg.seq_parallel and S % 16 == 0) \
        else constrain_act(x)
    cos, sin = rope_cos_sin(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    x, aux, _ = _scan_layers(cfg, params, x, cos, sin, "train")
    return rms_norm(x, params["final_ln"], cfg.norm_eps), aux


def _unembed_weight(cfg: LMConfig, params: Params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def train_loss(cfg: LMConfig, params: Params, batch: Dict[str, jax.Array],
               vocab_chunk_seq: int = 512, aux_weight: float = 0.01):
    """Next-token xent with sequence-chunked unembedding.

    The (B, S, V) logits tensor is never materialized: the loss is computed
    in a scan over sequence chunks, keeping peak memory at
    (B, vocab_chunk_seq, V) fp32 per device shard.
    """
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    hidden, aux = forward(cfg, params, tokens)
    hidden = constrain_act(hidden)
    w = _unembed_weight(cfg, params)
    n_chunks = max(1, S // vocab_chunk_seq)
    hs = hidden.reshape(B, n_chunks, S // n_chunks, cfg.d_model)
    ls = labels.reshape(B, n_chunks, S // n_chunks)
    hs = jnp.moveaxis(hs, 1, 0)
    ls = jnp.moveaxis(ls, 1, 0)

    def body(acc, xs):
        h, y = xs
        logits = jnp.einsum("bsd,dv->bsv", h, w).astype(jnp.float32)
        logits = constrain_tp_last(logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # gold logit via masked reduce over the (vocab-sharded) last axis —
        # take_along_axis would force GSPMD to materialize gathered logits
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vpos == y[..., None], logits, 0.0), axis=-1)
        mask = (y >= 0).astype(jnp.float32)
        return acc + jnp.sum((logz - gold) * mask), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ls),
                            unroll=n_chunks if not cfg.scan_layers else 1)
    n_tok = jnp.maximum(jnp.sum((labels >= 0).astype(jnp.float32)), 1.0)
    return total / n_tok + aux_weight * aux / cfg.n_layers


def prefill(cfg: LMConfig, params: Params, tokens: jax.Array,
            max_len: int | None = None):
    """Serving prefill: returns (last-position logits, KVCache)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = constrain_seq(x) if (cfg.seq_parallel and S % 16 == 0) \
        else constrain_act(x)
    cos, sin = rope_cos_sin(jnp.arange(S), cfg.head_dim, cfg.rope_theta)
    x, _, kv = _scan_layers(cfg, params, x, cos, sin, "prefill")
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    last = x[:, -1]
    logits = jnp.einsum("bd,dv->bv", last, _unembed_weight(cfg, params))
    k, v = kv
    if max_len is not None and max_len > S:
        pad = [(0, 0), (0, 0), (0, max_len - S), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    cache = {"k": k, "v": v,
             "length": jnp.full((B,), S, jnp.int32)}
    return logits.astype(jnp.float32), cache


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "length": jnp.zeros((batch,), jnp.int32)}


def decode_step(cfg: LMConfig, params: Params, cache: Dict[str, Any],
                token: jax.Array):
    """One decode step. token: (B,) int32. Returns (logits (B, V), cache)."""
    B = token.shape[0]
    x = constrain_act(params["embed"][token])[:, None, :]   # (B, 1, d)
    pos = cache["length"]                                # (B,)
    cos, sin = rope_cos_sin(pos[:, None], cfg.head_dim, cfg.rope_theta)
    x, _, new_cache = _scan_layers(cfg, params, x, cos, sin, "decode",
                                   cache=cache, length=pos)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], _unembed_weight(cfg, params))
    return logits.astype(jnp.float32), new_cache
