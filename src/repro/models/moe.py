"""Mixture-of-experts FFN with sort-based capacity dispatch.

Baseline dispatch is **sort-based** (MegaBlocks/GShard hybrid): token→expert
assignments are argsorted by expert id, scattered into a bounded (E, C, d)
buffer (capacity-factor drops on overflow), run through batched per-expert
SwiGLU matmuls, and gathered back with router-weight combine. This never
materializes the (tokens, E, C) one-hot dispatch tensor of the original
GShard einsum formulation (which is ~TB-scale at our token counts).

An einsum-dispatch variant is kept for small problems / cross-checking, and
an EP (expert-parallel, all_to_all) layout is exercised as a §Perf variant
for architectures whose expert count divides the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import swiglu


def router_topk(x: jax.Array, w_router: jax.Array, top_k: int):
    """Softmax router. x: (T, d), w_router: (d, E).

    Returns (expert_idx (T, k) int32, weights (T, k) fp32, probs (T, E)).
    Router math in fp32 (standard practice for stability).
    """
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    return idx.astype(jnp.int32), weights, probs


def load_balance_loss(probs: jax.Array, idx: jax.Array, n_experts: int):
    """Switch-style auxiliary load-balancing loss."""
    me = probs.mean(axis=0)                                   # (E,)
    assign = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    ce = assign.mean(axis=0)                                  # (E,)
    return n_experts * jnp.sum(me * ce)


def capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _pad_experts(w: jax.Array, e_pad: int) -> jax.Array:
    """Pad the expert axis with zero experts (for EP divisibility)."""
    if e_pad == 0:
        return w
    pad = [(0, e_pad)] + [(0, 0)] * (w.ndim - 1)
    return jnp.pad(w, pad)


def _moe_ffn_sort_group(x: jax.Array, params: dict, cfg: MoEConfig,
                        C: int):
    """Sort-based dispatch MoE for ONE capacity group. x: (T, d) -> (T, d).

    params: router (d, E); wg/wu (E, d, F); wd (E, F, d);
            optional shared_{wg,wu,wd} dense SwiGLU weights.
    """
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    idx, weights, probs = router_topk(x, params["router"], k)
    aux = load_balance_loss(probs, idx, E)

    flat_e = idx.reshape(-1)                       # (T*k,) expert of each slot
    order = jnp.argsort(flat_e)                    # stable sort by expert
    sorted_e = flat_e[order]
    # Position of each sorted slot within its expert's contiguous run.
    first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * k) - first[sorted_e]
    keep = pos_in_e < C                            # capacity drop
    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # E*C = trash row

    token_of_slot = order // k                     # which token fed this slot
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[dest].set(x[token_of_slot], mode="drop",
                           unique_indices=False)
    buf = buf[:E * C].reshape(E, C, d)

    # Batched per-expert SwiGLU.
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    h = jax.nn.silu(g) * u      # bf16 silu: avoids fp32 TP partials
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wd"]).reshape(E * C, d)

    # Gather back: slot s (unsorted order) lives at dest[inv_order[s]].
    inv = jnp.argsort(order)
    slot_dest = jnp.where(keep[inv], dest[inv], E * C)       # (T*k,)
    gathered = jnp.concatenate([out_buf, jnp.zeros((1, d), x.dtype)], 0)
    y = gathered[slot_dest].reshape(T, k, d)
    # combine in the compute dtype: an fp32 combine here propagates fp32
    # cotangents through the whole dispatch, doubling every MoE
    # collective payload (measured, §Perf)
    y = jnp.einsum("tkd,tk->td", y, weights.astype(x.dtype))
    return y, aux


def moe_ffn_sort(x: jax.Array, params: dict, cfg: MoEConfig):
    """Group-local sort dispatch. x: (T, d) -> ((T, d), aux).

    Tokens are reshaped into ``n_groups`` capacity groups and the per-group
    dispatch is vmapped, so the argsort/scatter stay *local to the data
    shard* under GSPMD (the group axis is sharded on 'data'; the sort axis
    is unsharded). This is the GShard "group" semantics realized without
    the dense one-hot dispatch tensor.

    NOTE on partitioning (§Perf log): two attempts to reshard the dispatch
    buffers expert-parallel inside the GSPMD partitioner (constraint pairs
    around the scatter/gather) REGRESSED 8.5s -> 58s / 19s because GSPMD
    cannot partition data-dependent scatters along the scattered dim and
    replicates instead; the production EP path needs an explicit shard_map
    block (future work, documented in EXPERIMENTS.md).
    """
    from repro.distributed.act_sharding import constrain_spec
    T, d = x.shape
    g = min(cfg.n_groups, T)
    while T % g:
        g //= 2
    Tg = T // g
    C = capacity(Tg, cfg.top_k, cfg.n_experts, cfg.capacity_factor)
    xg = constrain_spec(x.reshape(g, Tg, d), ("dp", None, None))
    y, aux = jax.vmap(
        lambda xi: _moe_ffn_sort_group(xi, params, cfg, C))(xg)
    y = constrain_spec(y, ("dp", None, None)).reshape(T, d)
    if "shared_wg" in params:
        y = y + _shared_expert_dp(x, params)
    return y, aux.mean()


def _shared_expert_dp(x: jax.Array, params: dict) -> jax.Array:
    """Shared-expert SwiGLU with DP-pinned intermediates (forces the
    partitioner to gather the small shared weights instead of
    all-reducing activation-sized partials)."""
    from repro.distributed.act_sharding import constrain_spec
    g = constrain_spec(
        jnp.einsum("td,df->tf", x, params["shared_wg"]), ("dp", None))
    u = constrain_spec(
        jnp.einsum("td,df->tf", x, params["shared_wu"]), ("dp", None))
    h = jax.nn.silu(g) * u
    return constrain_spec(
        jnp.einsum("tf,fd->td", h, params["shared_wd"]), ("dp", None))


def moe_ffn_einsum(x: jax.Array, params: dict, cfg: MoEConfig):
    """GShard one-hot einsum dispatch (small-T cross-check / decode path)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(T, k, E, cfg.capacity_factor)
    idx, weights, probs = router_topk(x, params["router"], k)
    aux = load_balance_loss(probs, idx, E)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)         # (T, k, E)
    flat_oh = onehot.reshape(T * k, E)
    flat_pos = jnp.cumsum(flat_oh, axis=0) - flat_oh           # pos within e
    pos = jnp.einsum("se,se->s", flat_pos, flat_oh).reshape(T, k)
    in_cap = pos < C
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * in_cap[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)      # (T, E, C)
    combine = jnp.einsum("tec,tk,tke->tec", dispatch, weights, onehot)

    buf = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x)
    g = jnp.einsum("ecd,edf->ecf", buf, params["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    h = jax.nn.silu(g) * u      # bf16 silu: avoids fp32 TP partials
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wd"])
    y = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), out_buf)

    if "shared_wg" in params:
        y = y + swiglu(x, params["shared_wg"], params["shared_wu"],
                       params["shared_wd"])
    return y, aux


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig):
    if cfg.dispatch == "einsum":
        return moe_ffn_einsum(x, params, cfg)
    return moe_ffn_sort(x, params, cfg)


# ---------------------------------------------------------------------------
# expert parallelism via shard_map (opt-in: MoEConfig.dispatch="ep")
# ---------------------------------------------------------------------------

def moe_ffn_ep(x: jax.Array, params: dict, cfg: MoEConfig, mesh):
    """True expert parallelism: tokens all-to-all to expert owners.

    Per-device flow (device = (data_i, model_j); tokens sharded over BOTH
    axes, experts padded to a multiple of the 'model' axis and owned
    model_j -> experts [j*Eloc, (j+1)*Eloc)):
      1. local router + sort + capacity -> (Ep, C_loc, d) send buffer
      2. all_to_all over 'model': expert slabs to their owners
      3. local expert GEMMs (E_loc experts per device)
      4. all_to_all back + local combine
    Interconnect carries the TOKEN flow (~C_loc*d per hop) instead of
    activation-partial all-reduces — the fix GSPMD could not express
    (EXPERIMENTS.md §Perf Cell C).
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.act_sharding import dp_axes_active

    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    n_model = mesh.shape["model"]
    Ep = -(-E // n_model) * n_model
    E_loc = Ep // n_model
    dp = tuple(a for a in mesh.axis_names if a != "model")
    n_tok_shards = 1
    for a in dp:
        n_tok_shards *= mesh.shape[a]
    n_tok_shards *= n_model
    T_loc = T // n_tok_shards
    C = capacity(T_loc, k, E, cfg.capacity_factor)

    wg = _pad_experts(params["wg"], Ep - E)
    wu = _pad_experts(params["wu"], Ep - E)
    wd = _pad_experts(params["wd"], Ep - E)

    def local(x_loc, router, wg_l, wu_l, wd_l):
        x_loc = x_loc.reshape(T_loc, d)
        idx, weights, probs = router_topk(x_loc, router, k)
        aux = load_balance_loss(probs, idx, E)

        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        pos = jnp.arange(T_loc * k) - first[sorted_e]
        keep = pos < C
        dest = jnp.where(keep, sorted_e * C + pos, Ep * C)
        tok = order // k
        send = jnp.zeros((Ep * C + 1, d), x_loc.dtype)
        send = send.at[dest].set(x_loc[tok])[:Ep * C]
        send = send.reshape(n_model, E_loc * C, d)

        # exchange expert slabs with their owners
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (n_model, E_loc*C, d) — slabs from every sender
        recv = recv.reshape(n_model, E_loc, C, d).transpose(1, 0, 2, 3)
        buf = recv.reshape(E_loc, n_model * C, d)

        g = jnp.einsum("ecd,edf->ecf", buf, wg_l)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_l)
        h = jax.nn.silu(g) * u
        out = jnp.einsum("ecf,efd->ecd", h, wd_l)

        out = out.reshape(E_loc, n_model, C, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(
            out.reshape(n_model, E_loc * C, d), "model",
            split_axis=0, concat_axis=0, tiled=False)
        back = back.reshape(Ep * C, d)
        back = jnp.concatenate([back, jnp.zeros((1, d), x_loc.dtype)], 0)

        inv = jnp.argsort(order)
        slot = jnp.where(keep[inv], dest[inv], Ep * C)
        y = back[slot].reshape(T_loc, k, d)
        y = jnp.einsum("tkd,tk->td", y, weights.astype(x_loc.dtype))
        aux = jax.lax.pmean(aux, "model")
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    tok_axes = dp + ("model",)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(tok_axes, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(tok_axes, None), P()),
        check_vma=False)
    y, aux = fn(x, params["router"], wg, wu, wd)
    if "shared_wg" in params:
        y = y + _shared_expert_dp(x, params)
    return y, aux
